"""Tour of the probabilistic query engine — one SPN, four query types,
four execution substrates.

Learns an SPN on nltcs, then answers:

1. joint likelihood p(x)               (the seed repo's only query),
2. marginals p(e) with half the variables summed out,
3. conditionals p(q | e),
4. MPE: the most probable completion of partial evidence (max-product
   sweep + argmax decode),
5. ancestral samples, cross-checked against exact marginals.

Every query is answered on all applicable substrates and the answers are
compared — the engine's core contract.

    PYTHONPATH=src python examples/query_engine.py
"""
import numpy as np

from repro.core.learn import learn_spn
from repro.data import spn_datasets
from repro.queries import QueryEngine, evidence_array, random_mask

BACKENDS = ("numpy", "leveled", "kernel", "sim")


def main() -> None:
    X = spn_datasets.load("nltcs", "train", 400)
    eng = QueryEngine(learn_spn(X, min_instances=64))
    V = eng.num_vars
    print(f"engine over {V} vars, {eng.prog.n_ops} ops\n")

    Xq = spn_datasets.load("nltcs", "test", 8)

    print("— joint: log p(x) on all four substrates —")
    for b in BACKENDS:
        print(f"  {b:8s} {np.round(eng.joint(Xq[:3], b), 4)}")

    print("\n— marginal: half the variables summed out —")
    Xm = random_mask(Xq, 0.5, seed=1)
    for b in BACKENDS:
        print(f"  {b:8s} {np.round(eng.marginal(Xm[:3], b), 4)}")

    print("\n— conditional: P(x0=1 | x1, ..., x4) —")
    q = evidence_array(V, {0: 1}, batch=3)
    e = np.full((3, V), -1, np.int64)
    e[:, 1:5] = Xq[:3, 1:5]
    print(f"  {np.round(np.exp(eng.conditional(q, e, 'leveled')), 4)}")

    print("\n— MPE: most probable completion of masked evidence —")
    res = eng.mpe(Xm[:3], backend="leveled")     # batched grad decode
    for row, (ev_row, a, lv) in enumerate(
            zip(Xm[:3], res.assignment, res.log_value)):
        print(f"  row {row}: {ev_row.tolist()}")
        print(f"       -> {a.tolist()}  (log p* = {lv:.4f})")

    print("\n— sampling: empirical vs exact marginals —")
    s = eng.sample(4000, seed=0, backend="kernel")
    emp = s.samples.mean(0)
    exact = np.array([float(np.exp(eng.marginal(
        evidence_array(V, {v: 1}), "numpy"))[0]) for v in range(V)])
    print(f"  empirical P(x_v=1): {np.round(emp[:8], 3)}")
    print(f"  exact     P(x_v=1): {np.round(exact[:8], 3)}")
    print(f"  max |err| over {V} vars: {np.abs(emp - exact).max():.4f}")
    print(f"  mean log p of draws (kernel-scored): {s.log_prob.mean():.4f}")


if __name__ == "__main__":
    main()
