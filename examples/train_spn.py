"""Train SPN parameters with EM and SGD, then deploy to the processor.

Shows the full lifecycle: structure learning → parameter learning (both
the exact-EM path and the Adam-on-logits path, differentiating through
the log-domain leveled executor) → deployment compile for Ptree.

    PYTHONPATH=src python examples/train_spn.py
"""
import numpy as np

from repro.core import executors, learn, program
from repro.core.compiler.pipeline import compile_program
from repro.core.processor import sim
from repro.core.processor.config import PTREE
from repro.data import spn_datasets


def main() -> None:
    Xtr = spn_datasets.load("msnbc", "train", 800)
    Xte = spn_datasets.load("msnbc", "test", 200)
    spn = learn.learn_spn(Xtr, min_instances=60)
    prog = program.lower(spn)
    leaves_te = prog.leaves_from_evidence(Xte).astype(np.float32)

    def test_ll(params):
        return float(np.mean(np.asarray(
            executors.eval_leveled(prog, leaves_te, params, True))))

    print(f"structure: {prog.n_ops} ops; initial test LL {test_ll(None):.4f}")

    state_em, hist_em = learn.fit_em(prog, Xtr, iters=12)
    print(f"EM:  train LL {hist_em[0]:.4f} → {hist_em[-1]:.4f}; "
          f"test LL {test_ll(state_em.params):.4f}")

    state_sgd, hist_sgd = learn.fit_sgd(prog, Xtr, steps=150, lr=3e-2)
    print(f"SGD: train LL {hist_sgd[0]:.4f} → {hist_sgd[-1]:.4f}; "
          f"test LL {test_ll(state_sgd.params):.4f}")

    # deploy the EM-trained model on the custom processor
    trained = program.lower(spn)
    trained.param_values = np.asarray(state_em.params, np.float64)
    vprog = compile_program(trained, PTREE)
    res = sim.simulate(vprog, trained, Xte[:16], PTREE)
    ref = executors.eval_ops_numpy(trained,
                                   trained.leaves_from_evidence(Xte[:16]))
    assert np.allclose(res.root_values, ref, rtol=1e-4)
    print(f"deployed on Ptree: {res.ops_per_cycle:.2f} ops/cycle, "
          f"outputs match oracle")


if __name__ == "__main__":
    main()
