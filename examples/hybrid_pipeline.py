"""The paper's fig. 1 end-to-end: DL perception + SPN reasoning.

A transformer backbone (qwen2-family smoke config) encodes token
sequences; its pooled features feed an SPN reasoning head as soft
evidence; the SPN — executed by the same leveled program the custom
processor runs — scores each sequence under a probabilistic model.
Backbone projection AND SPN weights train jointly end-to-end, then the
reasoning head is deployed through the Pallas kernel.

    PYTHONPATH=src python examples/hybrid_pipeline.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import learn, program
from repro.data import spn_datasets
from repro.data.lm_pipeline import PipelineConfig, TokenPipeline
from repro.models import api, spn_head
from repro.models.transformer import forward


def main() -> None:
    # --- perception backbone ------------------------------------------
    cfg = get_smoke_config("qwen2-0.5b")
    params = api.init_params(cfg, jax.random.PRNGKey(0))

    # --- reasoning head: SPN learned on a benchmark -------------------
    X = spn_datasets.load("nltcs", "train", 400)
    spn = learn.learn_spn(X, min_instances=80)
    prog = program.lower(spn)
    head = spn_head.init_spn_head(jax.random.PRNGKey(1), cfg.d_model, prog)
    print(f"backbone d_model={cfg.d_model}; SPN head: {prog.n_ops} ops, "
          f"{prog.num_vars} query variables")

    pipe = TokenPipeline(PipelineConfig(vocab=cfg.vocab, seq_len=32,
                                        global_batch=16, seed=0))

    def features(backbone_params, tokens):
        hidden, _ = forward(cfg, backbone_params, tokens, remat=False)
        return hidden.mean(axis=1)                    # pooled perception

    def loss_fn(head_params, tokens):
        f = features(params, tokens)
        return spn_head.nll_loss(prog, head_params, f)

    # --- joint training of the reasoning head --------------------------
    opt_lr = 3e-2
    grad = jax.jit(jax.value_and_grad(loss_fn))
    losses = []
    for step in range(30):
        batch = jnp.asarray(pipe.batch_for_step(step)["tokens"])
        loss, g = grad(head, batch)
        head = jax.tree.map(lambda p, gg: p - opt_lr * gg, head, g)
        losses.append(float(loss))
    print(f"joint NLL: {losses[0]:.4f} → {losses[-1]:.4f} "
          f"({'improved' if losses[-1] < losses[0] else 'no gain'})")

    # --- deployment: reasoning through the Pallas kernel ---------------
    batch = jnp.asarray(pipe.batch_for_step(999)["tokens"])
    f = features(params, batch)
    ll_exec = spn_head.apply_spn_head(prog, head, f, use_kernel=False)
    ll_kern = spn_head.apply_spn_head(prog, head, f, use_kernel=True)
    err = float(jnp.abs(ll_exec - ll_kern).max())
    print(f"deployed via Pallas kernel: max |Δ| vs executor {err:.2e}")
    assert err < 1e-3


if __name__ == "__main__":
    main()
