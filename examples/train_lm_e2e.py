"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

Exercises the full production path at CPU scale: real config (qwen2-0.5b
geometry scaled to ~100M params), sharded planner on the local mesh,
AdamW + warmup-cosine, deterministic data pipeline, async checkpointing,
heartbeat + watchdog, and an injected mid-run crash recovered through the
restart harness — proof the fault-tolerance contract holds end-to-end.

    PYTHONPATH=src python examples/train_lm_e2e.py --steps 300
(defaults to a 60-step run so CI stays fast; pass --steps 300 for the
full demonstration)
"""
import argparse
import dataclasses
import tempfile

import numpy as np

from repro.configs.base import ArchConfig
from repro.launch.train import TrainConfig, Trainer
from repro.models import api
from repro.models.common import count_params
from repro.optim import AdamWConfig
from repro.runtime import FailureInjector, run_with_restarts

# ~110M params: 12L × 768d GQA transformer over a 32k vocab
ARCH_100M = ArchConfig(
    name="lm-100m", family="dense", n_layers=12, d_model=768, n_heads=12,
    n_kv_heads=6, head_dim=64, d_ff=3072, vocab=32_000, qkv_bias=False,
    rope_theta=10_000.0, tie_embeddings=True,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--crash-at", type=int, default=None,
                    help="inject a crash at this step (default: mid-run)")
    args = ap.parse_args()
    crash_at = args.crash_at or args.steps // 2

    import jax
    n = count_params(jax.eval_shape(
        lambda: api.init_params(ARCH_100M, jax.random.PRNGKey(0))))
    print(f"arch {ARCH_100M.name}: {n/1e6:.1f}M params; "
          f"{args.steps} steps of {args.batch}x{args.seq} tokens")

    with tempfile.TemporaryDirectory() as ckpt:
        tc = TrainConfig(arch_config=ARCH_100M, steps=args.steps,
                         global_batch=args.batch, seq_len=args.seq,
                         ckpt_dir=ckpt, ckpt_every=max(args.steps // 6, 5),
                         opt=AdamWConfig(lr=6e-4, warmup_steps=20,
                                         total_steps=args.steps * 2))
        inj = FailureInjector({crash_at})

        def run(state):
            t = Trainer(tc, injector=inj)
            st = t.resume_state()
            if st is None:
                st = t.init_state()
            return t.run(st)

        out = run_with_restarts(lambda: None, lambda: None, run)
        losses = out["losses"]
        print(f"crash injected at step {crash_at}; run completed "
              f"{out['step']} steps after restart")
        k = max(len(losses) // 5, 1)
        print(f"loss: first-{k} mean {np.mean(losses[:k]):.4f} → "
              f"last-{k} mean {np.mean(losses[-k:]):.4f}")
        assert np.mean(losses[-k:]) < np.mean(losses[:k]), "loss must drop"
        print("OK: end-to-end training with crash recovery")


if __name__ == "__main__":
    main()
