"""Batched SPN serving — the paper's throughput workload (100k evals).

Serves batched marginal-inference requests against a learned SPN on
three backends and reports throughput; also answers conditional queries
P(Q | E) via two circuit passes (the standard SPN inference recipe).

    PYTHONPATH=src python examples/serve_spn.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import executors, learn, program
from repro.data import spn_datasets
from repro.kernels.spn_eval import spn_eval


def main() -> None:
    X = spn_datasets.load("plants", "train", 600)
    spn = learn.learn_spn(X, min_instances=60)
    prog = program.lower(spn)
    print(f"serving SPN: {prog.n_ops} ops, {prog.num_vars} vars")

    # ---- batched likelihood serving -----------------------------------
    rng = np.random.default_rng(0)
    batch = 512
    n_batches = 20
    queries = rng.integers(0, 2, size=(batch, prog.num_vars))
    leaves = jnp.asarray(prog.leaves_from_evidence(queries), jnp.float32)

    for name, fn in [
        ("leveled-jax", lambda: executors.eval_leveled(prog, leaves, None, True)),
        ("pallas-kernel", lambda: spn_eval(prog, leaves, log_domain=True)),
    ]:
        fn()                                    # compile
        t0 = time.perf_counter()
        for _ in range(n_batches):
            out = fn()
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        print(f"  {name:14s} {batch * n_batches / dt:12.0f} evals/s")

    # ---- conditional queries P(q | e) = P(q, e) / P(e) ------------------
    evidence = -np.ones((4, prog.num_vars), np.int64)     # all marginalized
    evidence[:, :5] = queries[:4, :5]                     # observe 5 vars
    joint = evidence.copy()
    joint[:, 5] = 1                                       # query var 5 = 1
    le = jnp.asarray(prog.leaves_from_evidence(evidence), jnp.float32)
    lj = jnp.asarray(prog.leaves_from_evidence(joint), jnp.float32)
    log_pe = spn_eval(prog, le, log_domain=True)
    log_pj = spn_eval(prog, lj, log_domain=True)
    cond = np.exp(np.asarray(log_pj) - np.asarray(log_pe))
    print("P(x5=1 | x0..x4):", np.round(cond, 4))
    assert ((cond >= 0) & (cond <= 1.0 + 1e-6)).all()


if __name__ == "__main__":
    main()
