"""Quickstart: the paper's pipeline in 40 lines.

Learn an SPN from data, lower it to the tensor program, evaluate it with
all three backends (JAX leveled executor, Pallas TPU kernel, and the
custom processor via compiler + cycle-accurate simulator), and compare
against the CPU/GPU baselines — the whole paper on one screen.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import executors, learn, program
from repro.core.compiler.pipeline import compile_program
from repro.core.processor import cpu_model, gpu_model, sim
from repro.core.processor.config import PTREE, PVECT
from repro.data import spn_datasets
from repro.kernels.spn_eval import spn_eval


def main() -> None:
    # 1. learn an SPN on a benchmark dataset (paper §V)
    X = spn_datasets.load("nltcs", "train", 500)
    spn = learn.learn_spn(X, min_instances=60)
    prog = program.lower(spn)
    print(f"SPN: {prog.n_ops} binary ops over {prog.num_levels} levels")

    # 2. evaluate a batch of queries on every backend
    Xq = spn_datasets.load("nltcs", "test", 64)
    leaves = prog.leaves_from_evidence(Xq).astype(np.float32)
    ref = executors.eval_ops_numpy(prog, leaves)              # float64 oracle
    jax_out = np.asarray(executors.eval_leveled(prog, leaves))
    kernel_out = np.asarray(spn_eval(prog, leaves))           # Pallas kernel
    print(f"max |Δ| JAX leveled vs oracle:  {abs(jax_out - ref).max():.2e}")
    print(f"max |Δ| Pallas kernel vs oracle: {abs(kernel_out - ref).max():.2e}")

    # 3. compile for the custom processor and simulate cycle-accurately
    for cfg in (PVECT, PTREE):
        vprog = compile_program(prog, cfg)
        res = sim.simulate(vprog, prog, Xq, cfg)
        assert np.allclose(res.root_values, ref, rtol=1e-4)
        print(f"{cfg.name}: {res.ops_per_cycle:5.2f} ops/cycle "
              f"({res.cycles} cycles)")

    # 4. the paper's baselines (structural performance models)
    cpu = cpu_model.analyze(prog)
    gpu = gpu_model.analyze(prog, 256)
    print(f"CPU model: {cpu.ops_per_cycle:.2f} ops/cycle (paper: 0.55); "
          f"GPU model @256thr: {gpu.ops_per_cycle:.2f} (paper: 0.95)")


if __name__ == "__main__":
    main()
