"""Assemble EXPERIMENTS.md §Dry-run / §Roofline tables from the dry-run JSONs.

    PYTHONPATH=src python -m benchmarks.roofline_report \
        --dir experiments/dryrun --md

Reads every ``<arch>_<shape>_<mesh>.json`` produced by
``repro.launch.dryrun`` and emits the per-cell roofline table: the three
terms (compute / memory / collective, seconds per step), the dominant
bottleneck, MODEL_FLOPS, and the useful-compute ratio.
"""
from __future__ import annotations

import argparse
import json
import os

from repro.configs.base import ARCH_IDS, SHAPES

MESHES = {"single": "pod16x16", "multi": "pod2x16x16"}


def load_records(dirpath: str, mesh: str = "single") -> list[dict]:
    out = []
    for arch in ARCH_IDS:
        for shape in SHAPES:
            p = os.path.join(dirpath, f"{arch}_{shape}_{mesh}.json")
            if os.path.exists(p):
                with open(p) as f:
                    out.append(json.load(f))
    return out


def fmt_row(r: dict) -> str:
    if r["status"] == "skipped":
        return (f"| {r['arch']} | {r['shape']} | — | — | — | — | skipped: "
                f"{r.get('reason','')} | — |")
    if r["status"] == "error":
        return f"| {r['arch']} | {r['shape']} | — | — | — | — | ERROR | — |"
    rf = r["roofline"]
    ratio = r.get("useful_flops_ratio")
    return ("| {a} | {s} | {tc:.4f} | {tm:.4f} | {tl:.4f} | **{b}** | "
            "{mf:.2e} | {ur} |".format(
                a=r["arch"], s=r["shape"], tc=rf["t_compute_s"],
                tm=rf["t_memory_s"], tl=rf["t_collective_s"],
                b=rf["bottleneck"], mf=r["model_flops"],
                ur=f"{ratio:.3f}" if ratio else "—"))


HEADER = ("| arch | shape | t_compute (s) | t_memory (s) | t_collective (s)"
          " | bottleneck | MODEL_FLOPS | useful ratio |\n"
          "|---|---|---|---|---|---|---|---|")


def emit_table(records: list[dict]) -> str:
    return "\n".join([HEADER] + [fmt_row(r) for r in records])


def emit_dryrun_summary(records: list[dict]) -> str:
    lines = ["| arch | shape | mesh | status | compile (s) | args/dev (GiB) |"
             " temp/dev (GiB) | collective bytes/dev |",
             "|---|---|---|---|---|---|---|---|"]
    for r in records:
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"{r['status']} | — | — | — | — |")
            continue
        m = r["memory"]
        cb = sum(r["collectives"]["bytes"].values())
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
            f"{r['compile_s']:.1f} | {m.get('argument_size_gib', 0):.2f} | "
            f"{m.get('temp_size_gib', 0):.2f} | {cb:.3e} |")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="single", choices=list(MESHES))
    ap.add_argument("--summary", action="store_true")
    args = ap.parse_args()
    recs = load_records(args.dir, args.mesh)
    if args.summary:
        print(emit_dryrun_summary(recs))
    else:
        print(emit_table(recs))


if __name__ == "__main__":
    main()
