"""Paper Table I: compute and memory resources of each platform."""
from __future__ import annotations

from repro.core.processor.config import CPU_MODEL, GPU_MODEL, PTREE, PVECT
from .common import csv_row

ROWS = [
    ("CPU", "2 arith units (superscalar)", "168 80b regs + 32KB L1", 16),
    ("GPU", f"{GPU_MODEL.cuda_cores} CUDA cores",
     "64K 32b regs + 64KB shared", GPU_MODEL.shared_banks),
]


def run(verbose: bool = True) -> dict:
    rows = list(ROWS)
    for cfg in (PVECT, PTREE):
        rows.append((f"Ours ({cfg.name})", f"{cfg.num_pes} PEs",
                     f"{cfg.total_regs} 32b regs + 64KB data mem",
                     cfg.banks))
    if verbose:
        print(f"{'Platform':14s} {'Compute':28s} {'Memory':28s} Banks")
        for r in rows:
            print(f"{r[0]:14s} {r[1]:28s} {r[2]:28s} {r[3]}")
    # Table I invariants
    assert PTREE.num_pes == 30 and PVECT.num_pes == 16
    assert PTREE.total_regs == 2048            # 2K 32b registers
    assert PTREE.banks == GPU_MODEL.shared_banks == 32
    assert PTREE.data_mem_rows * PTREE.banks * 4 == 64 * 1024  # 64 KB
    return {"rows": rows}


def main() -> list[str]:
    run()
    return [csv_row("table1_resources", 0.0,
                    "ptree_pes=30;pvect_pes=16;banks=32;datamem=64KB")]


if __name__ == "__main__":
    main()
