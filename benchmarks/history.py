"""Bench-history regression sentinel over ``BENCH_history.jsonl``.

``BENCH_serve.json`` is a snapshot — it holds exactly one run, and the
``--compare`` gate can only see the single committed baseline. This
module gives the bench a *memory*: every ``serve_bench`` run appends one
JSONL record (git SHA, timestamp, workload fingerprint, the run's
deterministic metrics) to ``BENCH_history.jsonl``, and the sentinel
compares each new run against the **best prior run with the same
fingerprint** — so a slow creep across many commits is caught even when
every individual step stays inside the snapshot gate's tolerance.

Only *deterministic* metrics participate: modeled lockstep cycle counts
(NoC topology sweep, multicore scaling curve, single-core VLIW,
autotuned cycles/eval). They are value- and machine-independent, so the
sentinel holds them **exactly**: any increase over the historical best
for the same workload fingerprint is a failure. Wall-clock throughput
is deliberately excluded — machines differ; the snapshot gate already
covers it with machine-speed normalization.

The fingerprint hashes every knob that changes what the deterministic
metrics mean (dataset, batch, query, topology, sweep shapes, autotune
budget/cores): runs with different fingerprints are incommensurable and
never compared, so changing the bench config can't fake a win or a
regression.

    PYTHONPATH=src python -m benchmarks.history \\
        --record BENCH_serve.json --history BENCH_history.jsonl [--check]
"""
from __future__ import annotations

import argparse
import hashlib
import json
import subprocess
import sys
import time

DEFAULT_HISTORY = "BENCH_history.jsonl"


def git_sha(cwd: str | None = None) -> str:
    """Short git SHA of HEAD, or ``"unknown"`` outside a checkout."""
    try:
        out = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             capture_output=True, text=True, cwd=cwd,
                             timeout=10)
    except (OSError, subprocess.TimeoutExpired):
        return "unknown"
    sha = out.stdout.strip()
    return sha if out.returncode == 0 and sha else "unknown"


def run_fingerprint(record: dict) -> str:
    """Stable hash of every knob the deterministic metrics depend on.

    Two runs compare iff their fingerprints match; anything that changes
    the *meaning* of a cycle count (workload, topology, sweep shape,
    autotune search context) must land here.
    """
    at = record.get("autotune") or {}
    key = {
        "dataset": record.get("dataset"),
        "batch": record.get("batch"),
        "query": record.get("query"),
        "mc_topology": record.get("mc_topology", "xbar"),
        "noc": {ds: {"cores": sweep.get("cores"),
                     "topologies": sorted(sweep.get("topologies", {}))}
                for ds, sweep in sorted((record.get("noc") or {}).items())},
        "scaling": {ds: {"topology": s.get("topology"),
                         "cores": sorted(s.get("cores", {}))}
                    for ds, s in sorted(
                        (record.get("multicore_scaling") or {}).items())},
        "autotune": {"budget": at.get("budget"),
                     "max_cores": at.get("max_cores"),
                     "datasets": sorted(at.get("datasets", {}))},
    }
    co = record.get("coresidency") or {}
    if co:
        # only present since the multi-tenant fabric landed; included
        # conditionally so older records keep their fingerprints
        key["coresidency"] = {"cores": co.get("cores"),
                              "topology": co.get("topology"),
                              "tenants": sorted(co.get("tenants", {}))}
    blob = json.dumps(key, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


def deterministic_metrics(record: dict) -> dict:
    """Flatten the record's deterministic cycle counts; all lower-is-
    better, all machine-independent, all held exactly by the sentinel."""
    out: dict[str, float] = {}
    for ds, sweep in sorted((record.get("noc") or {}).items()):
        for topo, entry in sorted(sweep.get("topologies", {}).items()):
            out[f"noc.{ds}.{topo}.cycles"] = int(entry["cycles"])
    for ds, s in sorted((record.get("multicore_scaling") or {}).items()):
        out[f"scaling.{ds}.single_core.cycles"] = \
            int(s["single_core_cycles"])
        for k, entry in sorted(s.get("cores", {}).items()):
            out[f"scaling.{ds}.c{k}.cycles"] = int(entry["cycles"])
    at = record.get("autotune") or {}
    for ds, entry in sorted(at.get("datasets", {}).items()):
        out[f"autotune.{ds}.tuned_cycles_per_eval"] = \
            float(entry["tuned_cycles_per_eval"])
    co = record.get("coresidency") or {}
    for t, entry in sorted(co.get("tenants", {}).items()):
        out[f"coresidency.{t}.cycles"] = int(entry["cycles"])
        out[f"coresidency.{t}.full_fabric_cycles"] = \
            int(entry["full_fabric_cycles"])
    fast = record.get("vliw_fastsim") or {}
    if "cycles" in fast:
        out["vliw_sim.cycles"] = int(fast["cycles"])
    return out


def make_entry(record: dict, *, sha: str | None = None,
               now: float | None = None) -> dict:
    """One history line for ``record`` (sha/now injectable for tests)."""
    return {"sha": sha if sha is not None else git_sha(),
            "time": round(float(time.time() if now is None else now), 3),
            "fingerprint": run_fingerprint(record),
            "metrics": deterministic_metrics(record)}


def load_history(path: str) -> list[dict]:
    """All prior entries; a missing file is an empty history."""
    try:
        with open(path) as fh:
            return [json.loads(line) for line in fh if line.strip()]
    except FileNotFoundError:
        return []


def append_run(path: str, record: dict, *, sha: str | None = None,
               now: float | None = None) -> dict:
    """Append one entry for ``record`` to the history; returns it."""
    entry = make_entry(record, sha=sha, now=now)
    with open(path, "a") as fh:
        fh.write(json.dumps(entry, sort_keys=True) + "\n")
    return entry


def best_prior(history: list[dict], fingerprint: str) -> dict:
    """Per-metric historical best among entries with this fingerprint.

    Returns ``{metric: (value, sha)}`` — the lowest value ever recorded
    for each metric, and the commit that recorded it.
    """
    best: dict[str, tuple] = {}
    for entry in history:
        if entry.get("fingerprint") != fingerprint:
            continue
        for name, value in (entry.get("metrics") or {}).items():
            if name not in best or value < best[name][0]:
                best[name] = (value, entry.get("sha", "unknown"))
    return best


def sentinel_compare(record: dict, history: list[dict]) -> list[str]:
    """New run vs the historical best for the same fingerprint.

    Returns human-readable failure lines (empty = sentinel passes).
    Deterministic metrics are held exactly: any increase over the best
    prior value fails. Metrics never seen before pass (they become the
    new best on append), and an empty matching history passes trivially.
    """
    fp = run_fingerprint(record)
    best = best_prior(history, fp)
    failures: list[str] = []
    for name, value in deterministic_metrics(record).items():
        prior = best.get(name)
        if prior is None:
            continue
        prior_value, prior_sha = prior
        if value > prior_value:
            failures.append(
                f"history sentinel: {name} = {value:g} vs best prior "
                f"{prior_value:g} (commit {prior_sha}) — deterministic "
                "counts are held exactly against the historical best")
    return failures


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--record", default="BENCH_serve.json",
                    help="bench record to append/compare")
    ap.add_argument("--history", default=DEFAULT_HISTORY,
                    help="history JSONL path")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero when the record regresses "
                         "against the historical best (compare runs "
                         "BEFORE the record is appended)")
    ap.add_argument("--no-append", action="store_true",
                    help="compare only; do not append the record")
    args = ap.parse_args(argv)
    with open(args.record) as fh:
        record = json.load(fh)
    history = load_history(args.history)
    failures = sentinel_compare(record, history)
    n_same = sum(1 for e in history
                 if e.get("fingerprint") == run_fingerprint(record))
    if not args.no_append:
        entry = append_run(args.history, record)
        print(f"  appended {entry['sha']}@{entry['fingerprint']} to "
              f"{args.history} ({len(entry['metrics'])} metrics, "
              f"{n_same} prior comparable runs)")
    for line in failures:
        print(f"  {line}")
    if failures and args.check:
        return 2
    if not failures:
        print(f"  history sentinel: ok vs {n_same} comparable prior "
              f"run(s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
