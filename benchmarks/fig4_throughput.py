"""Paper fig. 4: throughput (ops/cycle) of CPU / GPU / Pvect / Ptree on the
benchmark suite; plus Table-adjacent claims: Ptree ≥ 12× CPU/GPU at peak,
Ptree ≈ 2× Pvect.

CPU/GPU numbers come from the structural performance models (§III);
Ptree/Pvect from the real compiler + cycle-accurate simulator (§IV–V).
"""
from __future__ import annotations

import numpy as np

from repro.core import executors
from repro.core.compiler.pipeline import compile_program
from repro.core.processor import cpu_model, gpu_model, sim
from repro.core.processor.config import PTREE, PVECT
from repro.data import spn_datasets
from .common import BENCH_SUITE, bench_spn, csv_row, timeit


def run(verbose: bool = True, suite=None) -> dict:
    from repro.core.program import interleave
    suite = suite or BENCH_SUITE
    table = {}
    for name in suite:
        spn, prog = bench_spn(name)
        X = spn_datasets.load(name, "test", 8)
        cpu = cpu_model.analyze(prog).ops_per_cycle
        gpu = gpu_model.analyze(prog, 256).ops_per_cycle
        row = {"ops": prog.n_ops, "cpu": cpu, "gpu": gpu}
        for cfg in (PVECT, PTREE):
            vprog = compile_program(prog, cfg)
            res = sim.simulate(vprog, prog, X, cfg)
            ref = executors.eval_ops_numpy(
                prog, prog.leaves_from_evidence(X))
            assert np.allclose(res.root_values, ref, rtol=1e-4), name
            row[cfg.name.lower()] = res.ops_per_cycle
        # §Perf-C beyond-paper mode: 2 evaluations software-pipelined
        # through the trees (the paper's 100k-execution throughput regime)
        vp2 = compile_program(interleave(prog, 2), PTREE)
        row["ptree_x2"] = vp2.ops_per_cycle
        table[name] = row
        if verbose:
            print(f"  {name:10s} ops={row['ops']:6d}  "
                  f"CPU {cpu:4.2f}  GPU {gpu:4.2f}  "
                  f"Pvect {row['pvect']:5.2f}  Ptree {row['ptree']:5.2f}  "
                  f"Ptree-pipe2 {row['ptree_x2']:5.2f}  "
                  f"(Ptree/GPU {row['ptree']/max(gpu,1e-9):4.1f}x)")

    peak_tree = max(r["ptree"] for r in table.values())
    peak_pipe = max(r["ptree_x2"] for r in table.values())
    peak_cpu = max(r["cpu"] for r in table.values())
    peak_gpu = max(r["gpu"] for r in table.values())
    mean_ratio_vect = float(np.mean([r["ptree"] / r["pvect"]
                                     for r in table.values()]))
    speedup_cpu = min(r["ptree"] / r["cpu"] for r in table.values())
    speedup_gpu = min(r["ptree"] / r["gpu"] for r in table.values())
    out = {"table": table, "peak_ptree": peak_tree, "peak_cpu": peak_cpu,
           "peak_gpu": peak_gpu, "ptree_vs_pvect": mean_ratio_vect,
           "min_speedup_cpu": speedup_cpu, "min_speedup_gpu": speedup_gpu,
           "peak_ptree_pipelined": peak_pipe}
    if verbose:
        print(f"fig4: peak Ptree {peak_tree:.2f} ops/cycle "
              f"(paper: 11.6); pipelined-x2 {peak_pipe:.2f}; "
              f"CPU {peak_cpu:.2f} (0.55); GPU {peak_gpu:.2f} (0.95)")
        print(f"  min Ptree speedup vs CPU {speedup_cpu:.1f}x, vs GPU "
              f"{speedup_gpu:.1f}x (paper: ≥12x); Ptree/Pvect "
              f"{mean_ratio_vect:.2f}x (paper: ~2x)")
    return out


def main() -> list[str]:
    out = run()
    _, prog = bench_spn("nltcs")
    us = timeit(lambda: compile_program(prog, PTREE), n_iter=3, warmup=1)
    return [csv_row("fig4_throughput", us,
                    f"peak_ptree={out['peak_ptree']:.2f};"
                    f"min_speedup_cpu={out['min_speedup_cpu']:.1f}x;"
                    f"min_speedup_gpu={out['min_speedup_gpu']:.1f}x;"
                    f"ptree_vs_pvect={out['ptree_vs_pvect']:.2f}x")]


if __name__ == "__main__":
    main()
