"""Benchmark harness — one entry per paper table/figure.

``python -m benchmarks.run`` prints a ``name,us_per_call,derived`` CSV row
per benchmark (plus the human-readable tables above them).
"""
from __future__ import annotations

import sys


def main() -> None:
    from . import fig2c_gpu_scaling, fig4_throughput, kernel_microbench, table1_resources
    rows: list[str] = []
    for mod in (table1_resources, fig2c_gpu_scaling, fig4_throughput,
                kernel_microbench):
        print(f"\n=== {mod.__name__.split('.')[-1]} ===")
        rows.extend(mod.main())
    print("\nname,us_per_call,derived")
    for r in rows:
        print(r)


if __name__ == "__main__":
    main()
