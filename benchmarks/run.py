"""Benchmark harness — one entry per paper table/figure.

``python -m benchmarks.run`` prints a ``name,us_per_call,derived`` CSV row
per benchmark (plus the human-readable tables above them). ``--only``
restricts to a substring-matched subset, e.g. ``--only serve`` runs just
the substrate-serving benchmark (the CI bench-smoke step).
"""
from __future__ import annotations

import argparse


def main(only: str | None = None) -> None:
    from . import (fig2c_gpu_scaling, fig4_throughput, kernel_microbench,
                   serve_bench, table1_resources)
    rows: list[str] = []
    for mod in (table1_resources, fig2c_gpu_scaling, fig4_throughput,
                kernel_microbench, serve_bench):
        name = mod.__name__.split(".")[-1]
        if only and only not in name:
            continue
        print(f"\n=== {name} ===")
        rows.extend(mod.main())
    print("\nname,us_per_call,derived")
    for r in rows:
        print(r)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run only benchmarks whose name contains this")
    args = ap.parse_args()
    main(args.only)
