"""Pallas spn_eval kernel microbenchmark (interpret-mode on CPU).

Wall-times are CPU-interpret numbers (the TPU target can't be timed here);
the derived metric that transfers is the *instruction/VMEM geometry*:
value-buffer residency, instruction bytes, and padding overhead.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import executors
from repro.data import spn_datasets
from repro.kernels.spn_eval import pad_program, spn_eval
from .common import bench_spn, csv_row, timeit


def run(verbose: bool = True, dataset: str = "nltcs", batch: int = 256):
    _, prog = bench_spn(dataset)
    pp = pad_program(prog)
    X = spn_datasets.load(dataset, "test", batch)
    leaves = jnp.asarray(prog.leaves_from_evidence(X), jnp.float32)

    r_kernel = spn_eval(prog, leaves, log_domain=True)
    r_leveled = executors.eval_leveled(prog, leaves, None, True)
    err = float(jnp.abs(r_kernel - r_leveled).max())
    assert err < 1e-4

    us_kernel = timeit(lambda: jax.block_until_ready(
        spn_eval(prog, leaves, log_domain=True)))
    us_leveled = timeit(lambda: jax.block_until_ready(
        executors.eval_leveled(prog, leaves, None, True)))
    us_scan = timeit(lambda: jax.block_until_ready(
        executors.eval_scan(prog, leaves, None, True)), n_iter=5)

    pad_ops = pp.n_pad_nodes
    vmem_kib = pp.num_slots * 128 * 4 / 1024
    stats = {
        "ops": prog.n_ops, "levels": pp.num_levels,
        "segments": pp.num_segments,
        "fused_nodes": pp.n_nodes,
        "pad_overhead": pad_ops / pp.n_nodes,
        "vmem_kib_per_tile": vmem_kib,
        "instr_bytes": len(pp.gather) * 4,
        "us_kernel": us_kernel, "us_leveled": us_leveled, "us_scan": us_scan,
    }
    if verbose:
        print(f"kernel_microbench[{dataset}] ops={prog.n_ops} -> "
              f"{pp.n_nodes} fused nodes, {pp.num_segments} segments / "
              f"{pp.num_levels} levels, pad={pad_ops/pp.n_nodes:.1%} "
              f"VMEM/tile={vmem_kib:.0f}KiB")
        print(f"  pallas(interp) {us_kernel:9.1f} us | leveled "
              f"{us_leveled:9.1f} us | scan {us_scan:9.1f} us  (batch {batch})")
    return stats


def main() -> list[str]:
    s = run()
    return [csv_row("kernel_microbench", s["us_kernel"],
                    f"ops={s['ops']};levels={s['levels']};"
                    f"pad={s['pad_overhead']:.2f};"
                    f"vmem_kib={s['vmem_kib_per_tile']:.0f}")]


if __name__ == "__main__":
    main()
