"""Serving throughput across substrates + VLIW fast-sim speedup.

Runs batched queries through :class:`repro.runtime.Server` on one suite
SPN and records per-substrate evals/s, plus the vectorized fast-sim vs
cycle-accurate checked-sim comparison (bit-identity asserted, speedup
measured). Results are printed as CSV rows and persisted to
``BENCH_serve.json`` so the throughput trajectory accumulates across
commits (the CI bench-smoke step runs this on the smallest dataset).

    PYTHONPATH=src python -m benchmarks.serve_bench [--dataset nltcs]
        [--batch 256] [--out BENCH_serve.json]
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core.processor import fastsim, sim
from repro.queries import random_mask
from repro.runtime import DEFAULT_SUBSTRATES, Server, verify_parity

from .common import bench_spn, csv_row, timeit


def _median_ms(fn, n_iter: int, warmup: int = 2) -> float:
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(n_iter):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e3


def main(dataset: str = "nltcs", batch: int = 256,
         out_path: str = "BENCH_serve.json") -> list[str]:
    spn, prog = bench_spn(dataset)
    server = Server(spn)
    Xq = random_mask(
        np.random.default_rng(0).integers(0, 2, (batch, prog.num_vars)),
        0.3, seed=0)
    record: dict = {"dataset": dataset, "batch": batch, "query": "marginal",
                    "n_ops": prog.n_ops, "substrates": {}}
    rows: list[str] = []

    for name in DEFAULT_SUBSTRATES:
        us = timeit(lambda n=name: server.query(Xq, "marginal", n), n_iter=9)
        evals_s = batch / (us / 1e6)
        record["substrates"][name] = {"us_per_batch": us,
                                      "evals_per_s": evals_s}
        rows.append(csv_row(f"serve_{name}_b{batch}", us,
                            f"evals/s={evals_s:.0f}"))
        print(f"  {name:12s} {us:10.1f} us/batch ({evals_s:12.0f} evals/s)")

    devs = verify_parity(server, Xq[:32], query="marginal")
    record["parity_max_abs_dev"] = max(devs.values())

    # fast-sim vs checked-sim: same artifact, same leaves, bit-identical
    art = server.artifact("marginal", "vliw-sim")
    vprog, dense, workspace = art.payload
    cfg = server.substrate("vliw-sim").processor
    leaves = art.prog.leaves_from_evidence(Xq).astype(np.float32)
    assert np.array_equal(sim.simulate_leaves(vprog, leaves, cfg).root_values,
                          fastsim.run(dense, leaves, workspace))
    t_checked = _median_ms(
        lambda: sim.simulate_leaves(vprog, leaves, cfg), n_iter=5)
    t_fast = _median_ms(
        lambda: fastsim.run(dense, leaves, workspace), n_iter=30)
    speedup = t_checked / t_fast
    record["vliw_fastsim"] = {
        "checked_ms_per_batch": t_checked, "fast_ms_per_batch": t_fast,
        "speedup": speedup, "bit_identical": True,
        "cycles": vprog.num_cycles, "ops_per_cycle": vprog.ops_per_cycle}
    rows.append(csv_row(f"fastsim_vs_checked_b{batch}", t_fast * 1e3,
                        f"speedup={speedup:.1f}x"))
    print(f"  fast-sim {t_fast:.3f} ms vs checked {t_checked:.2f} ms "
          f"-> {speedup:.1f}x (bit-identical)")

    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)
    print(f"  wrote {out_path}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="nltcs")
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()
    main(args.dataset, args.batch, args.out)
