"""Serving throughput across substrates + VLIW fast-sim speedup.

Runs batched queries through :class:`repro.runtime.Server` on one suite
SPN and records per-substrate evals/s **with request-latency p50/p95/p99
percentiles** (computed by the :mod:`repro.obs.metrics` histogram over
every measured iteration), plus the vectorized fast-sim vs
cycle-accurate checked-sim comparison (bit-identity asserted, speedup
measured). Under ``--compare`` the run also enforces the observability
overhead budget: the permanently-instrumented request path with tracing
*disabled* must cost < 2% of a request (see :func:`obs_overhead_check`). Results are printed as CSV rows and persisted to
``BENCH_serve.json`` so the throughput trajectory accumulates across
commits. The record also carries the Pallas kernel mode (``interpret``
vs compiled) and the segment-scheduler descriptor stats, so numbers are
never compared across incommensurable configurations.

``--compare BASELINE.json`` turns the run into a **regression gate**: it
exits non-zero when any substrate's throughput regressed by more than
25% against the baseline record (the CI bench-smoke step runs this
against the committed ``BENCH_serve.json`` before overwriting it).

``--cores 1,2,4,8`` adds a multi-core scaling sweep: for each core
count the ``vliw-mc`` substrate is compiled and its calibrated lockstep
cycle count compared against single-core ``vliw-sim`` — the
speedup-vs-cores curve plus the communication/compute cycle ratio, per
dataset. The default run records the 1/2/4-core points so the scaling
trajectory accumulates in ``BENCH_serve.json`` alongside throughput.

The run also measures a **``vliw-mc-tuned``** row — the same requests
served through a second server whose ``vliw-mc`` substrate compiled the
per-SPN autotuner's winning config (:mod:`repro.core.autotune`) — and
records a suite-wide tuned-vs-default modeled cycles/eval sweep
(``record["autotune"]``). Those cycle counts are deterministic, so the
``--compare`` gate holds them exactly, and additionally fails if the
tuner ever returns a config that loses to its own default trial.

The run also measures a **``vliw-mc-degraded``** row — the same
requests served through a third server whose fabric loses core 1 to a
seeded fault plan (``core=1@t0``) on first touch: the resilient request
path recompiles the SPN onto the three surviving cores (same
content-addressed cache, ``/alive=`` fingerprint) and the row measures
the repartitioned fabric's throughput next to the healthy baseline.
The degraded artifact is oracle-parity checked and the server's
``stats()["resilience"]`` snapshot (fault plan, applied events,
degraded-artifact records) lands in ``record["resilience"]``.

The run also records a **co-residency row** (``record["coresidency"]``):
two suite SPNs served as tenants of ONE server, co-scheduled onto
disjoint core sets of the same ``vliw-mc`` mesh fabric
(:mod:`repro.runtime.tenancy`). The row compares the modeled aggregate
throughput of the co-resident fabric against a time-sliced baseline
where a full-fabric server alternates between the two SPNs — the
co-resident side must win or tie (asserted), per-tenant oracle parity
and core-set disjointness are asserted, and the per-tenant cycle
counts are deterministic so the ``--compare`` gate and the history
sentinel hold them exactly.

``--topology {xbar,ring,mesh,torus}`` selects the NoC the served
``vliw-mc`` substrate models. Independently of it, every run records a
**NoC topology sweep** (``record["noc"]``): per topology the calibrated
cycle count, per-link contention (link-stall cycles, busiest-link
occupancy) and — for physical topologies — the topology-aware vs naive
placement delta, at the sweep's largest core count. Those cycle counts
are value- and machine-independent, so the ``--compare`` gate holds
them exactly — any increase fails (wall-clock throughput keeps its
noise-tolerant >25% gate).

    PYTHONPATH=src python -m benchmarks.serve_bench [--dataset nltcs]
        [--batch 256] [--out BENCH_serve.json] [--compare BENCH_serve.json]
        [--cores 1,2,4,8] [--topology mesh]
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

from repro.core import multicore
from repro.core.autotune import tune_program
from repro.core.processor import fastsim, sim
from repro.core.processor.config import PTREE
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.queries import random_mask
from repro.runtime import DEFAULT_SUBSTRATES, Server, verify_parity

from .common import BENCH_SUITE, bench_spn, csv_row
from .history import (DEFAULT_HISTORY, append_run, load_history,
                      sentinel_compare)

#: per-substrate throughput regression tolerance for ``--compare``
REGRESSION_TOLERANCE = 0.25
#: disabled-observability overhead budget: the estimated cost of the
#: permanently-instrumented hot path with tracing OFF must stay under
#: this fraction of a request (asserted under ``--compare``)
OBS_OVERHEAD_BUDGET = 0.02
#: numpy-canary bound: beyond this machine-speed scale the gate fails
#: outright instead of normalizing (see :func:`compare_records`)
MACHINE_SCALE_BOUND = 3.0
#: autotune trials for the served ``vliw-mc-tuned`` row
TUNED_BUDGET = 16
#: autotune trials per dataset in the suite-wide tuned-vs-default sweep
AUTOTUNE_SWEEP_BUDGET = 8
AUTOTUNE_SWEEP_CORES = 4
#: the degraded row's fabric: kill core 1 of 4 on the first touch, so
#: the measured substrate is the 3-core repartition the resilient
#: request path compiled onto the survivors
DEGRADED_CORES = 4
DEGRADED_FAULTS = "core=1@t0"
#: the co-residency row: these suite SPNs share one mesh fabric as
#: tenants of a single server, on disjoint core sets
CORESIDENCY_TENANTS = ("nltcs", "kdd")
CORESIDENCY_CORES = 8
CORESIDENCY_TOPOLOGY = "mesh"


def _best_round_us(fn, rounds: int = 4, n_iter: int = 5,
                   warmup: int = 2, samples: list | None = None) -> float:
    """Best per-round median wall-time in microseconds.

    Shared-machine CPU throttling comes in multi-second phases that can
    slow *everything* 2-3x; a single median-of-N taken inside one phase
    is meaningless. Timing several short rounds spread over the run and
    keeping the best round's median measures the code, not the phase.
    Callers interleave the benchmarked configurations across rounds so
    every configuration gets a shot at the fast phases.

    ``samples`` (optional list) collects every individual iteration
    time in microseconds — the raw distribution behind the p50/p95/p99
    latency percentiles recorded in ``BENCH_serve.json``.
    """
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(rounds):
        times = []
        for _ in range(n_iter):
            t0 = time.perf_counter()
            fn()
            times.append(time.perf_counter() - t0)
        if samples is not None:
            samples.extend(t * 1e6 for t in times)
        times.sort()
        best = min(best, times[len(times) // 2])
    return best * 1e6


def _median_ms(fn, n_iter: int, warmup: int = 2) -> float:
    return _best_round_us(fn, rounds=3, n_iter=n_iter, warmup=warmup) / 1e3


def compare_records(new: dict, baseline: dict,
                    tolerance: float = REGRESSION_TOLERANCE) -> list[str]:
    """Per-substrate throughput regressions of ``new`` vs ``baseline``.

    Returns human-readable failure lines (empty = gate passes). Only
    substrates present in both records are compared, and only when the
    workloads match (dataset/batch/query; the Pallas substrate is
    additionally skipped when the two records ran different kernel
    modes — interpreter vs compiled numbers are incommensurable).

    Comparisons are **machine-speed normalized**: the numpy oracle is
    byte-identical reference code in every run, so the ratio of the two
    records' numpy times measures the machines (or the CI runner's
    noisy-neighbor phase), not the code; each substrate's time is scaled
    by it before applying the tolerance. Absolute cross-machine
    wall-clock comparisons would fail every PR run on a runner merely
    slower than the box that recorded the baseline.
    """
    failures: list[str] = []
    for key in ("dataset", "batch", "query"):
        if baseline.get(key) != new.get(key):
            return [f"baseline is a different workload "
                    f"({key}: {baseline.get(key)!r} vs {new.get(key)!r})"]
    subs_new = new.get("substrates", {})
    subs_old = baseline.get("substrates", {})
    scale = 1.0
    if "numpy" in subs_new and "numpy" in subs_old:
        scale = subs_new["numpy"]["us_per_batch"] / \
            subs_old["numpy"]["us_per_batch"]
    if scale > MACHINE_SCALE_BOUND:
        # the canary must stay roughly canary-shaped: a huge numpy
        # slowdown is either a regression in code shared by every
        # substrate's request path (which normalization would absorb)
        # or a machine unsuitable for benchmarking — fail either way
        failures.append(
            f"numpy oracle itself slowed {scale:.1f}x vs baseline "
            f"(> {MACHINE_SCALE_BOUND:.0f}x bound): shared-path "
            f"regression or unsuitable benchmark machine")
    for name, old in subs_old.items():
        cur = subs_new.get(name)
        if cur is None or name == "numpy":   # numpy IS the speed canary
            continue
        if (name == "pallas"
                and baseline.get("pallas_interpret") is not None
                and baseline.get("pallas_interpret")
                != new.get("pallas_interpret")):
            continue
        if (name in ("vliw-mc", "vliw-mc-tuned", "vliw-mc-degraded")
                and baseline.get("mc_topology", "xbar")
                != new.get("mc_topology", "xbar")):
            continue    # different NoC configs are incommensurable
        slowdown = cur["us_per_batch"] / (old["us_per_batch"] * scale) - 1.0
        if slowdown > tolerance:
            failures.append(
                f"{name}: {cur['us_per_batch']:.0f} us/batch vs baseline "
                f"{old['us_per_batch']:.0f} x{scale:.2f} machine-speed "
                f"scale (+{slowdown:.0%} > {tolerance:.0%} tolerance)")

    # NoC topology-sweep cycle counts are deterministic and machine-
    # independent, so they are held EXACTLY: any increase fails (a
    # decrease is an improvement and passes). A sweep-shape mismatch is
    # announced loudly instead of silently shrinking the gate.
    for ds, old_sweep in (baseline.get("noc") or {}).items():
        new_sweep = (new.get("noc") or {}).get(ds)
        if not new_sweep or new_sweep.get("cores") != old_sweep.get("cores"):
            print(f"  WARNING: noc gate skipped for {ds!r} — sweep shape "
                  f"changed vs baseline (cores "
                  f"{old_sweep.get('cores')} -> "
                  f"{(new_sweep or {}).get('cores')}); regenerate the "
                  f"baseline to restore coverage")
            continue
        for topo, old_t in old_sweep.get("topologies", {}).items():
            cur_t = new_sweep.get("topologies", {}).get(topo)
            if cur_t is None:
                print(f"  WARNING: noc gate skipped for {ds}/{topo} — "
                      f"topology missing from the new sweep")
                continue
            if cur_t["cycles"] > old_t["cycles"]:
                failures.append(
                    f"noc {ds}/{topo}@{old_sweep['cores']}c: "
                    f"{cur_t['cycles']} modeled cycles vs baseline "
                    f"{old_t['cycles']} (deterministic counts are held "
                    f"exactly; update the baseline deliberately)")

    # autotuned modeled cycles/eval are deterministic in (digest, budget,
    # seed) — held exactly, like the NoC counts, when the search context
    # matches; tuned must also never lose to its own default
    old_at = baseline.get("autotune") or {}
    new_at = new.get("autotune") or {}
    if old_at and (old_at.get("budget") != new_at.get("budget")
                   or old_at.get("max_cores") != new_at.get("max_cores")):
        print("  WARNING: autotune gate skipped — search context changed "
              f"vs baseline (budget {old_at.get('budget')} -> "
              f"{new_at.get('budget')}, cores {old_at.get('max_cores')} "
              f"-> {new_at.get('max_cores')}); regenerate the baseline")
    else:
        for ds, old_e in old_at.get("datasets", {}).items():
            cur_e = new_at.get("datasets", {}).get(ds)
            if cur_e is None:
                print(f"  WARNING: autotune gate skipped for {ds!r} — "
                      f"dataset missing from the new sweep")
                continue
            if (cur_e["tuned_cycles_per_eval"]
                    > old_e["tuned_cycles_per_eval"]):
                failures.append(
                    f"autotune {ds}: {cur_e['tuned_cycles_per_eval']:g} "
                    f"tuned cycles/eval vs baseline "
                    f"{old_e['tuned_cycles_per_eval']:g} (deterministic "
                    f"counts are held exactly)")
    # co-residency cycle counts are deterministic too — held exactly
    # when the fabric/tenant shape matches, and the co-resident fabric
    # must keep beating (or tying) its own time-sliced baseline
    old_co = baseline.get("coresidency") or {}
    new_co = new.get("coresidency") or {}
    if old_co and (old_co.get("cores") != new_co.get("cores")
                   or old_co.get("topology") != new_co.get("topology")
                   or sorted(old_co.get("tenants", {}))
                   != sorted(new_co.get("tenants", {}))):
        print("  WARNING: coresidency gate skipped — fabric/tenant shape "
              f"changed vs baseline (cores {old_co.get('cores')} -> "
              f"{new_co.get('cores')}, topology {old_co.get('topology')} "
              f"-> {new_co.get('topology')}); regenerate the baseline")
    elif old_co:
        for t, old_e in sorted(old_co.get("tenants", {}).items()):
            cur_e = new_co["tenants"][t]
            for fld in ("cycles", "full_fabric_cycles"):
                if cur_e[fld] > old_e[fld]:
                    failures.append(
                        f"coresidency {t}: {cur_e[fld]} {fld} vs baseline "
                        f"{old_e[fld]} (deterministic counts are held "
                        f"exactly; update the baseline deliberately)")
    if new_co and new_co.get("coresidency_gain", 1.0) < 1.0:
        failures.append(
            f"coresidency aggregate lost to the time-sliced baseline "
            f"(gain {new_co['coresidency_gain']}x < 1.0)")

    for ds, cur_e in new_at.get("datasets", {}).items():
        if (cur_e["tuned_cycles_per_eval"]
                > cur_e["default_cycles_per_eval"]):
            failures.append(
                f"autotune {ds}: tuned {cur_e['tuned_cycles_per_eval']:g} "
                f"cycles/eval LOST to the default "
                f"{cur_e['default_cycles_per_eval']:g} — the tuner must "
                f"never pick a config worse than its own baseline trial")
    return failures


def obs_overhead_check(server: Server, Xq: np.ndarray,
                       substrate: str = "vliw-sim") -> dict:
    """Measured cost of the *disabled* observability layer per request.

    The serving stack is permanently instrumented; the contract is that
    with no tracer installed every span is an allocation-free no-op.
    This check makes the contract a number: time the disabled span
    primitive in a tight loop, count the spans one real request emits
    (by installing a throwaway tracer for a single query), and compare
    the product against the measured request latency. The estimated
    overhead fraction must stay under :data:`OBS_OVERHEAD_BUDGET` (2%)
    — it fails if the disabled path grows allocations or the request
    path starts emitting hundreds of spans.
    """
    assert not obs_trace.active(), \
        "overhead check must run with tracing disabled"
    n = 200_000
    nul = obs_trace.span  # the module-level fast path under test
    t0 = time.perf_counter()
    for _ in range(n):
        with nul("bench.overhead"):
            pass
    ns_per_span = (time.perf_counter() - t0) / n * 1e9

    tracer = obs_trace.install()
    try:
        server.query(Xq, "marginal", substrate)
    finally:
        obs_trace.uninstall()
    spans_per_request = len(tracer.events)

    request_us = _best_round_us(
        lambda: server.query(Xq, "marginal", substrate),
        rounds=2, n_iter=5, warmup=1)
    frac = ns_per_span * spans_per_request / (request_us * 1e3)
    out = {"ns_per_span_disabled": round(ns_per_span, 1),
           "spans_per_request": spans_per_request,
           "request_us": round(request_us, 1),
           "overhead_frac": round(frac, 6),
           "budget": OBS_OVERHEAD_BUDGET}
    print(f"  obs overhead (disabled): {ns_per_span:.0f} ns/span x "
          f"{spans_per_request} spans/request = "
          f"{frac:.4%} of a {request_us:.0f} us request "
          f"(budget {OBS_OVERHEAD_BUDGET:.0%})")
    return out


def noc_sweep(dataset: str, prog, cores: int,
              topologies: tuple = ("xbar", "ring", "mesh", "torus"),
              rows: list[str] | None = None) -> dict:
    """Modeled NoC comparison at one core count, per topology.

    Records the calibrated lockstep cycle count, the flat and
    hop-weighted cut, per-link contention (link-stall cycles and
    busiest-link occupancy from the probe simulation) and — for
    physical topologies — the cycle delta of topology-aware core
    placement vs the naive flat partition. All numbers are
    value-independent modeled cycles: deterministic and machine-free,
    so :func:`compare_records` holds them exactly (any increase over
    the baseline fails the gate).
    """
    out: dict = {"cores": cores, "topologies": {}}
    for topo in topologies:
        icfg = multicore.named_interconnect(topo)
        meta = multicore.compile_multicore(prog, PTREE, cores, icfg).meta
        comm = meta["comm"]
        entry = {
            "cycles": int(meta["cycles"]),
            "cut_values": meta["cut_values"],
            "hop_cut": meta["hop_cut"],
            "link_stall_cycles": comm.get("link_stall_cycles", 0),
            "inject_stall_cycles": comm.get("inject_stall_cycles", 0),
            "busiest_link_occupancy": comm.get("busiest_link_occupancy",
                                               0.0),
        }
        extra = ""
        if topo != "xbar":
            naive = multicore.compile_multicore(
                prog, PTREE, cores, icfg, placement="naive").meta
            entry["naive_cycles"] = int(naive["cycles"])
            entry["placement_gain"] = round(
                1.0 - entry["cycles"] / max(entry["naive_cycles"], 1), 4)
            extra = (f", naive-place {entry['naive_cycles']} "
                     f"({entry['placement_gain']:+.0%} from placement)")
        out["topologies"][topo] = entry
        if rows is not None:
            rows.append(csv_row(f"noc_{dataset}_{topo}_c{cores}",
                                entry["cycles"],
                                f"hop_cut={entry['hop_cut']}"))
        print(f"  [{dataset}] noc {topo}@{cores}c: {entry['cycles']} "
              f"cycles, hop_cut={entry['hop_cut']}, "
              f"link_stalls={entry['link_stall_cycles']}, busiest_link="
              f"{entry['busiest_link_occupancy']}{extra}")
    return out


def multicore_scaling(dataset: str, cores_list: list[int],
                      rows: list[str] | None = None,
                      prog=None, icfg=None) -> dict:
    """Speedup-vs-cores curve of ``vliw-mc`` against single-core VLIW.

    Cycle counts come from the calibrated lockstep checked simulation
    (value-independent), so the curve is machine-speed independent and
    comparable across runs. ``comm_compute_ratio`` splits each
    configuration's total core-cycles into communication-attributable
    (flow-control stalls, end-of-program barrier idling, SEND/RECV slot
    occupancy) versus compute.
    """
    from repro.core.compiler.pipeline import compile_program

    if prog is None:
        _spn, prog = bench_spn(dataset)
    icfg = icfg or multicore.XBAR
    base = compile_program(prog, PTREE)
    out: dict = {"single_core_cycles": base.num_cycles,
                 "topology": icfg.topology, "cores": {}}
    print(f"  [{dataset}] single-core vliw-sim: {base.num_cycles} cycles")
    for k in cores_list:
        mcp = multicore.compile_multicore(prog, PTREE, k, icfg)
        meta = mcp.meta
        cycles = int(meta["cycles"])
        n_eff = meta["effective_cores"]
        comm_slots = sum(cp.vprog.stats.get("sends", 0)
                         + cp.vprog.stats.get("recvs", 0)
                         for cp in mcp.cores)
        comm_cycles = (sum(meta["stall_cycles"])
                       + sum(meta["barrier_idle"]) + comm_slots)
        total = n_eff * cycles
        speedup = base.num_cycles / cycles
        entry = {
            "cycles": cycles, "speedup": round(speedup, 3),
            "effective_cores": n_eff,
            "cut_values": meta["cut_values"],
            "comm_values_per_batch": meta["comm"]["values"],
            "comm_rows": meta["comm"]["rows"],
            "stall_cycles": sum(meta["stall_cycles"]),
            "barrier_idle_cycles": sum(meta["barrier_idle"]),
            "comm_compute_ratio": round(
                comm_cycles / max(total - comm_cycles, 1), 4),
        }
        out["cores"][str(k)] = entry
        if rows is not None:
            rows.append(csv_row(f"mc_scaling_{dataset}_c{k}", cycles,
                                f"speedup={speedup:.2f}x"))
        print(f"  [{dataset}] vliw-mc cores={k}: {cycles} cycles "
              f"({speedup:.2f}x), {entry['comm_values_per_batch']} values "
              f"crossed, comm/compute={entry['comm_compute_ratio']}")
    return out


def coresidency_bench(batch: int = 256,
                      tenants: tuple = CORESIDENCY_TENANTS,
                      cores: int = CORESIDENCY_CORES,
                      topology: str = CORESIDENCY_TOPOLOGY,
                      rows: list[str] | None = None) -> dict:
    """Multi-SPN co-residency vs a time-sliced two-server baseline.

    One :class:`~repro.runtime.Server` hosts every tenant SPN on the
    same ``vliw-mc`` fabric, co-scheduled onto **disjoint core sets**
    (QoS-weighted apportionment, :mod:`repro.runtime.tenancy`). The
    modeled aggregate throughput — each tenant completing a batch every
    ``cycles(tenant @ its cores)``, concurrently — is compared against
    the time-sliced baseline where one full-fabric server alternates
    between the tenants (one batch of each per
    ``sum over tenants of cycles(tenant @ all cores)``). Both sides are
    calibrated lockstep cycle counts: deterministic and machine-free,
    so :func:`compare_records` and the history sentinel hold them
    exactly. The co-resident fabric must win or tie (asserted), every
    tenant is oracle-parity checked through the shared server, the core
    sets must be pairwise disjoint, and wall-clock per-tenant serving
    throughput on the shared server is recorded alongside.
    """
    server = Server(tenants={name: bench_spn(name)[1] for name in tenants},
                    substrates=("numpy", "vliw-sim", "vliw-mc"),
                    cores=cores, topology=topology)
    out: dict = {"cores": cores, "topology": topology, "query": "marginal",
                 "tenants": {}}
    label_sets: dict[str, set] = {}
    co_agg = 0.0           # batches/cycle, tenants running concurrently
    ts_cycle_sum = 0       # full-fabric cycles to serve one batch of each
    for name in tenants:
        prog = server.registry.get(name).prog
        art = server.artifact("marginal", "vliw-mc", tenant=name)
        mc = art.meta["multicore"]
        labels = list(mc["core_labels"])
        label_sets[name] = set(labels)
        Xq = random_mask(
            np.random.default_rng(1).integers(0, 2, (batch, prog.num_vars)),
            0.3, seed=1)
        verify_parity(server, Xq[:32], query="marginal",
                      substrates=("numpy", "vliw-sim", "vliw-mc"),
                      tenant=name)
        us = _best_round_us(
            lambda X=Xq, n=name: server.query(X, "marginal", "vliw-mc",
                                              tenant=n),
            rounds=3, n_iter=5)
        # the time-sliced baseline: the same SPN owning the WHOLE fabric
        solo = Server(bench_spn(name)[0], substrates=("vliw-mc",),
                      cores=cores, topology=topology)
        full = int(solo.artifact("marginal", "vliw-mc")
                   .meta["multicore"]["cycles"])
        cyc = int(mc["cycles"])
        co_agg += 1.0 / cyc
        ts_cycle_sum += full
        out["tenants"][name] = {
            "cores": labels, "cycles": cyc, "full_fabric_cycles": full,
            "us_per_batch": us, "evals_per_s": batch / (us / 1e6)}
        if rows is not None:
            rows.append(csv_row(f"coresidency_{name}_c{len(labels)}", cyc,
                                f"full_fabric={full}"))
        print(f"  coresidency [{name}] cores={labels}: {cyc} cycles "
              f"(full fabric {full}), {batch / (us / 1e6):.0f} evals/s "
              f"served co-resident")
    seen: set = set()
    for name, labels in label_sets.items():
        assert not (labels & seen), \
            f"tenant {name} shares cores with another tenant: " \
            f"{sorted(labels & seen)}"
        seen |= labels
    assert len(seen) <= cores
    st = server.stats()
    out["mode"] = st["tenancy"]["mode"]
    assert out["mode"] == "co-resident", \
        f"co-residency row fell back to {out['mode']} scheduling"
    ts_agg = len(tenants) / ts_cycle_sum
    out["aggregate_batches_per_kilocycle"] = round(co_agg * 1e3, 4)
    out["timesliced_batches_per_kilocycle"] = round(ts_agg * 1e3, 4)
    out["coresidency_gain"] = round(co_agg / ts_agg, 4)
    assert co_agg >= ts_agg, \
        f"co-resident aggregate {co_agg:.6f} batches/cycle LOST to the " \
        f"time-sliced baseline {ts_agg:.6f} — sharing the fabric must " \
        f"not cost aggregate throughput"
    if rows is not None:
        rows.append(csv_row(f"coresidency_agg_c{cores}_{topology}",
                            out["aggregate_batches_per_kilocycle"],
                            f"gain={out['coresidency_gain']}x_vs_timesliced"))
    print(f"  coresidency aggregate: {out['aggregate_batches_per_kilocycle']}"
          f" batches/kcycle co-resident vs "
          f"{out['timesliced_batches_per_kilocycle']} time-sliced "
          f"({out['coresidency_gain']}x)")
    return out


def main(dataset: str = "nltcs", batch: int = 256,
         out_path: str = "BENCH_serve.json",
         compare_path: str | None = None,
         cores_list: list[int] | None = None,
         topology: str = "xbar",
         noc_datasets: list[str] | None = None,
         history_path: str | None = DEFAULT_HISTORY) -> list[str]:
    baseline = None
    if compare_path:
        try:
            with open(compare_path) as f:
                baseline = json.load(f)
        except FileNotFoundError:
            print(f"  (no baseline at {compare_path}; gate skipped)")

    spn, prog = bench_spn(dataset)
    server = Server(spn, topology=topology)
    # the tuned row: same SPN, same request path, but the vliw-mc
    # substrate compiles the autotuner's winning config instead of the
    # defaults (see repro.core.autotune); its modeled cycles/eval land
    # in record["autotune"] below next to the defaults'
    tuned_server = Server(spn, topology=topology, substrates=("vliw-mc",),
                          cores=AUTOTUNE_SWEEP_CORES,
                          autotune=f"budget={TUNED_BUDGET}")
    # the degraded row: a seeded fault plan kills core 1 of 4 on first
    # touch; the resilient request path recompiles onto the 3 surviving
    # cores (same cache, /alive= fingerprint) and the row measures the
    # repartitioned fabric next to the healthy baseline above
    degraded_server = Server(spn, topology=topology,
                             substrates=("vliw-mc",),
                             cores=DEGRADED_CORES, faults=DEGRADED_FAULTS)
    Xq = random_mask(
        np.random.default_rng(0).integers(0, 2, (batch, prog.num_vars)),
        0.3, seed=0)
    record: dict = {"dataset": dataset, "batch": batch, "query": "marginal",
                    "n_ops": prog.n_ops, "mc_topology": topology,
                    "substrates": {}}
    rows: list[str] = []

    # round-robin over substrates so CPU-throttle phases hit all of them
    # equally; per substrate keep the best round's median. Rounds are
    # spread over a few seconds of wall time because throttle phases on
    # shared machines last whole seconds — back-to-back rounds would all
    # land in one phase and defeat the best-of aggregation.
    targets: dict[str, tuple] = {n: (server, n) for n in DEFAULT_SUBSTRATES}
    targets["vliw-mc-tuned"] = (tuned_server, "vliw-mc")
    targets["vliw-mc-degraded"] = (degraded_server, "vliw-mc")
    best: dict[str, float] = {n: float("inf") for n in targets}
    samples: dict[str, list] = {n: [] for n in targets}
    for srv, sub in targets.values():          # warmup / compile / tune
        srv.query(Xq, "marginal", sub)
    for r in range(6):
        if r:
            time.sleep(0.4)
        for name, (srv, sub) in targets.items():
            # one unmeasured call re-warms caches after the round-robin
            # switch, matching the back-to-back conditions the historical
            # baselines were recorded under
            us = _best_round_us(
                lambda s=srv, n=sub: s.query(Xq, "marginal", n),
                rounds=1, n_iter=5, warmup=1, samples=samples[name])
            best[name] = min(best[name], us)
    for name in targets:
        us = best[name]
        evals_s = batch / (us / 1e6)
        # request-latency percentiles over every measured iteration,
        # computed by the obs histogram (the same implementation behind
        # Server.stats()["metrics"]["serve.latency_us.*"])
        hist = obs_metrics.Histogram(f"bench.latency_us.{name}",
                                     obs_metrics.REGISTRY)
        for t in samples[name]:
            hist.observe(t)
        record["substrates"][name] = {
            "us_per_batch": us, "evals_per_s": evals_s,
            "latency_us": {"p50": round(hist.percentile(50), 1),
                           "p95": round(hist.percentile(95), 1),
                           "p99": round(hist.percentile(99), 1)}}
        lat = record["substrates"][name]["latency_us"]
        rows.append(csv_row(f"serve_{name}_b{batch}", us,
                            f"evals/s={evals_s:.0f}"))
        print(f"  {name:12s} {us:10.1f} us/batch ({evals_s:12.0f} evals/s) "
              f"p50={lat['p50']:.0f} p95={lat['p95']:.0f} "
              f"p99={lat['p99']:.0f} us")

    devs = verify_parity(server, Xq[:32], query="marginal")
    record["parity_max_abs_dev"] = max(devs.values())
    # the tuned artifact must agree with the oracle and its own checked
    # sim (which clocks the tuned interleaved multicore machine) too
    verify_parity(tuned_server, Xq[:32], query="marginal",
                  substrates=("vliw-mc",))
    # the degraded artifact must too — and the row must actually have
    # measured a degraded fabric, not a healthy one (fault plan engaged,
    # no fallback off the vliw-mc substrate)
    verify_parity(degraded_server, Xq[:32], query="marginal",
                  substrates=("vliw-mc",))
    res = degraded_server.stats()["resilience"]
    assert res["fabric"]["dead_cores"], \
        "degraded row measured a healthy fabric (fault plan never fired)"
    assert not res["redirects"], \
        f"degraded row fell back off vliw-mc: {res['redirects']}"
    record["resilience"] = res
    n_total = res["fabric"]["total_cores"]
    n_alive = n_total - len(res["fabric"]["dead_cores"])
    print(f"  degraded fabric: dead_cores={res['fabric']['dead_cores']}, "
          f"{n_alive}/{n_total} cores healthy, "
          f"{len(res.get('degraded_artifacts', []))} degraded artifact(s)")
    record["obs_overhead"] = obs_overhead_check(server, Xq)
    record["pallas_interpret"] = \
        server.artifact("marginal", "pallas").meta["interpret"]
    record["segments"] = \
        server.artifact("marginal", "leveled-jax").meta["segments"]

    # multi-core scaling points (calibrated lockstep cycle counts), on
    # the same program the throughput numbers above were measured on
    cores_list = cores_list or [1, 2, 4]
    record["multicore_scaling"] = {
        dataset: multicore_scaling(
            dataset, cores_list, rows, prog=server.prog,
            icfg=multicore.named_interconnect(topology))}

    # NoC topology sweep at the largest swept core count: modeled
    # mesh/torus/ring vs ideal-crossbar cycles, per-link contention and
    # the topology-aware placement delta, per dataset (the main bench
    # dataset plus larger suite SPNs whose traffic makes placement bite)
    noc_cores = max(cores_list)
    record["noc"] = {}
    for ds in dict.fromkeys(noc_datasets or [dataset, "kdd"]):
        ds_prog = server.prog if ds == dataset else bench_spn(ds)[1]
        record["noc"][ds] = noc_sweep(ds, ds_prog, noc_cores, rows=rows)

    # multi-SPN co-residency: two suite SPNs as tenants of one server,
    # disjoint core sets on the mesh fabric, vs the time-sliced
    # full-fabric baseline (deterministic cycle counts, held exactly)
    record["coresidency"] = coresidency_bench(batch, rows=rows)

    # per-SPN autotuning, tuned vs default modeled cycles/eval on every
    # suite dataset at the sweep core count — exact calibrated lockstep
    # counts, deterministic and machine-free, so the --compare gate
    # holds them exactly like the NoC sweep
    tuned_meta = tuned_server.artifact("marginal", "vliw-mc").meta
    record["autotune"] = {
        "budget": AUTOTUNE_SWEEP_BUDGET,
        "max_cores": AUTOTUNE_SWEEP_CORES,
        "served": dict(tuned_meta["autotune"],
                       interleave=tuned_meta["interleave"],
                       budget=TUNED_BUDGET),
        "datasets": {}}
    for ds in dict.fromkeys([dataset] + list(BENCH_SUITE)):
        ds_prog = prog if ds == dataset else bench_spn(ds)[1]
        res = tune_program(ds_prog, PTREE,
                           max_cores=AUTOTUNE_SWEEP_CORES,
                           budget=AUTOTUNE_SWEEP_BUDGET)
        entry = {
            "config": res.config.fingerprint(),
            "tuned_cycles": res.cycles,
            "tuned_cycles_per_eval": res.cycles_per_eval,
            "default_cycles_per_eval": res.default_cycles_per_eval,
            "speedup": round(res.default_cycles_per_eval
                             / res.cycles_per_eval, 3),
        }
        record["autotune"]["datasets"][ds] = entry
        rows.append(csv_row(
            f"autotune_{ds}_c{AUTOTUNE_SWEEP_CORES}",
            entry["tuned_cycles_per_eval"],
            f"default={entry['default_cycles_per_eval']:g}"))
        print(f"  [{ds}] autotune@{AUTOTUNE_SWEEP_CORES}c: "
              f"{entry['tuned_cycles_per_eval']:g} cycles/eval "
              f"(default {entry['default_cycles_per_eval']:g}, "
              f"{entry['speedup']:.2f}x, {entry['config']})")

    # fast-sim vs checked-sim: same artifact, same leaves, bit-identical
    art = server.artifact("marginal", "vliw-sim")
    vprog, dense, workspace = art.payload
    cfg = server.substrate("vliw-sim").processor
    leaves = art.prog.leaves_from_evidence(Xq).astype(np.float32)
    assert np.array_equal(sim.simulate_leaves(vprog, leaves, cfg).root_values,
                          fastsim.run(dense, leaves, workspace))
    t_checked = _median_ms(
        lambda: sim.simulate_leaves(vprog, leaves, cfg), n_iter=5)
    t_fast = _median_ms(
        lambda: fastsim.run(dense, leaves, workspace), n_iter=30)
    speedup = t_checked / t_fast
    record["vliw_fastsim"] = {
        "checked_ms_per_batch": t_checked, "fast_ms_per_batch": t_fast,
        "speedup": speedup, "bit_identical": True,
        "cycles": vprog.num_cycles, "ops_per_cycle": vprog.ops_per_cycle}
    rows.append(csv_row(f"fastsim_vs_checked_b{batch}", t_fast * 1e3,
                        f"speedup={speedup:.1f}x"))
    print(f"  fast-sim {t_fast:.3f} ms vs checked {t_checked:.2f} ms "
          f"-> {speedup:.1f}x (bit-identical)")

    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)
    print(f"  wrote {out_path}")

    # bench-history sentinel: compare against the best prior run with
    # the same workload fingerprint BEFORE appending this one, so a
    # slow creep across commits is caught even when each step clears
    # the single-baseline gate; failures only fail the process under
    # --compare (standalone runs append + report)
    sentinel_failures: list[str] = []
    if history_path and history_path != "none":
        history = load_history(history_path)
        sentinel_failures = sentinel_compare(record, history)
        entry = append_run(history_path, record)
        print(f"  history: appended {entry['sha']}@{entry['fingerprint']} "
              f"to {history_path} ({len(history)} prior entries)")
        if sentinel_failures and baseline is None:
            for line in sentinel_failures:
                print(f"  WARNING: {line}")
        elif not sentinel_failures:
            print("  history sentinel: ok vs historical best")

    if baseline is not None:
        failures = compare_records(record, baseline) + sentinel_failures
        ov = record["obs_overhead"]
        if ov["overhead_frac"] > OBS_OVERHEAD_BUDGET:
            failures.append(
                f"disabled-observability overhead {ov['overhead_frac']:.2%} "
                f"of a request exceeds the {OBS_OVERHEAD_BUDGET:.0%} budget "
                f"({ov['ns_per_span_disabled']:.0f} ns/span x "
                f"{ov['spans_per_request']} spans/request)")
        if failures:
            print(f"  REGRESSION GATE FAILED vs {compare_path}:")
            for line in failures:
                print(f"    {line}")
            sys.exit(2)
        print(f"  regression gate vs {compare_path}: ok "
              f"(tolerance {REGRESSION_TOLERANCE:.0%})")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="nltcs")
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--compare", default=None, metavar="BASELINE",
                    help="baseline BENCH_serve.json; exit non-zero on >25%% "
                         "per-substrate throughput regression")
    ap.add_argument("--cores", default=None, metavar="1,2,4,8",
                    help="multi-core scaling sweep: comma-separated core "
                         "counts for the vliw-mc cycle-count curve "
                         "(default 1,2,4); the NoC topology sweep runs "
                         "at the largest count")
    ap.add_argument("--topology", default="xbar",
                    choices=["xbar", "ring", "mesh", "torus"],
                    help="NoC topology for the served vliw-mc substrate "
                         "and the scaling sweep")
    ap.add_argument("--noc-datasets", default=None, metavar="nltcs,kdd",
                    help="datasets for the NoC topology sweep "
                         "(default: the bench dataset + kdd)")
    ap.add_argument("--history", default=DEFAULT_HISTORY,
                    metavar="HISTORY.jsonl",
                    help="bench-history JSONL the run appends to and the "
                         "regression sentinel compares against "
                         "(see benchmarks.history; 'none' disables)")
    args = ap.parse_args()
    cores = ([int(c) for c in args.cores.split(",")]
             if args.cores else None)
    main(args.dataset, args.batch, args.out, args.compare, cores,
         topology=args.topology,
         noc_datasets=(args.noc_datasets.split(",")
                       if args.noc_datasets else None),
         history_path=args.history)
