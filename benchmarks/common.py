"""Shared benchmark utilities: the paper's benchmark suite of SPNs."""
from __future__ import annotations

import functools
import time

from repro.core import learn, program
from repro.data import spn_datasets

BENCH_SUITE = ["nltcs", "msnbc", "kdd", "plants", "baudio", "jester",
               "bnetflix"]


@functools.lru_cache(maxsize=None)
def bench_spn(name: str):
    """Learned SPN + lowered program for one suite dataset (cached)."""
    X = spn_datasets.load(name, "train", 600)
    spn = learn.learn_spn(X, min_instances=60, seed=0)
    prog = program.lower(spn)
    return spn, prog


def timeit(fn, n_iter: int = 20, warmup: int = 2) -> float:
    """Median wall-time per call in microseconds."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(n_iter):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def csv_row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.2f},{derived}"
