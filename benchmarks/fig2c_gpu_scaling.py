"""Paper fig. 2(c): CPU vs GPU ops/cycle as GPU thread count scales.

Reproduces the paper's measurement with the structural CPU/GPU performance
models: the GPU with 1 thread is *worse* than the CPU; 256 threads only
reach ~0.95 ops/cycle (sublinear — sync + bank conflicts + divergence).
"""
from __future__ import annotations

import numpy as np

from repro.core import program
from repro.core.learn import hmm_spn
from repro.core.processor import cpu_model, gpu_model
from .common import BENCH_SUITE, bench_spn, csv_row, timeit

THREADS = [1, 2, 4, 8, 16, 32, 64, 128, 256]


def run(verbose: bool = True) -> dict:
    # circuit mix matching the paper's benchmark shape: learned mixtures
    # (wide) + a deep chain circuit (the [7]-style deep regime). The mix
    # is what pins BOTH endpoints near the paper's 0.55 / 0.95 — see
    # EXPERIMENTS.md §fig2c for the shape-dependence analysis.
    progs = [bench_spn(n)[1] for n in BENCH_SUITE[:3]]
    progs += [program.lower(hmm_spn(24, n_states=8, seed=0))]
    cpu_opc = float(np.mean([cpu_model.analyze(p).ops_per_cycle
                             for p in progs]))
    rows = []
    for t in THREADS:
        opc = float(np.mean([gpu_model.analyze(p, t).ops_per_cycle
                             for p in progs]))
        rows.append((t, opc))
    out = {"cpu_ops_per_cycle": cpu_opc,
           "gpu_scaling": rows,
           "gpu_peak": max(o for _, o in rows)}
    if verbose:
        print(f"fig2c: CPU {cpu_opc:.2f} ops/cycle (paper: 0.55)")
        for t, o in rows:
            bar = "#" * int(o * 40)
            print(f"  T={t:4d}  {o:5.2f} ops/cycle {bar}")
        scale = rows[-1][1] / rows[0][1]
        print(f"  1→256 threads speedup: {scale:.1f}x "
              f"(paper: 4.1x — sublinear)")
    # paper claims to validate
    assert rows[0][1] < cpu_opc, "GPU@1thread must be worse than CPU"
    assert 0.6 < out["gpu_peak"] < 1.4, "GPU must stay near ~1 op/cycle"
    assert 0.45 < cpu_opc < 0.7, "CPU endpoint must match paper's 0.55"
    return out


def main() -> list[str]:
    out = run()
    us = timeit(lambda: gpu_model.analyze(bench_spn("nltcs")[1], 256),
                n_iter=5)
    return [csv_row("fig2c_gpu_scaling", us,
                    f"cpu={out['cpu_ops_per_cycle']:.2f};"
                    f"gpu_peak={out['gpu_peak']:.2f};"
                    f"scaling_1_to_256="
                    f"{out['gpu_scaling'][-1][1]/out['gpu_scaling'][0][1]:.1f}x")]


if __name__ == "__main__":
    main()
