"""Ancestral sampling from an SPN — the AIA discrete-sampling workload.

Sampling runs **top-down on the SPN graph** (not the lowered program):
starting from the root, a sum node draws one child from its (locally
normalized) weights, a product node activates all children, and the
indicator leaves reached by the walk spell out the sample. Smoothness +
decomposability guarantee the activated nodes form an *induced tree* in
which every variable's distribution appears exactly once, so each node
needs at most one categorical draw per sample — which is what makes the
whole batch vectorizable.

Two implementations that consume the **same uniform-draw tensor** ``U``
of shape ``(num_nodes, n)`` and therefore produce bit-identical samples
(the cross-substrate agreement contract for the ``sample`` query):

- :func:`sample_ancestral_numpy` — reverse-topological python loop over
  nodes, batch-vectorized per node (the oracle),
- :func:`sample_ancestral_jax` — one ``lax.scan`` over nodes carrying the
  ``(num_nodes+1, n)`` active-flag matrix; sum choices are computed as
  ``count(cdf <= u)`` against per-node padded CDF tables and scattered
  with ``.at[children].max``. Jit-compiled; recompiles only when the
  node-table shapes change.

Both use the identical float32 CDF tables and float32 comparisons so the
categorical boundaries agree exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core.spn import LEAF_IND, SUM, SPN


def _tables(spn: SPN) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Padded per-node ``(children, cdf, is_sum)`` tables.

    ``children``: (N, Cmax) int32, padded with the sentinel ``N`` (a dummy
    row in the active matrix); ``cdf``: (N, Cmax) float32 cumulative
    locally-normalized sum weights, padded with 2.0 (never selected).
    """
    N = spn.num_nodes
    cmax = max((len(ch) for ch in spn.children), default=0) or 1
    children = np.full((N, cmax), N, dtype=np.int32)
    cdf = np.full((N, cmax), 2.0, dtype=np.float32)
    is_sum = spn.node_type == SUM
    for i in range(N):
        ch = spn.children[i]
        if not ch:
            continue
        children[i, : len(ch)] = ch
        if is_sum[i]:
            w = spn.weights[i]
            w = (np.ones(len(ch)) if w is None
                 else np.asarray(w, dtype=np.float64))
            tot = w.sum()
            w = w / tot if tot > 0 else np.ones(len(ch)) / len(ch)
            cdf[i, : len(ch)] = np.cumsum(w).astype(np.float32)
    return children, cdf, is_sum


def _assignments(spn: SPN, active: np.ndarray) -> np.ndarray:
    """Decode the active indicator leaves into ``(n, num_vars)`` samples."""
    n = active.shape[1]
    x = np.full((n, spn.num_vars), -1, dtype=np.int64)
    for i in np.flatnonzero(spn.node_type == LEAF_IND):
        x[active[i], int(spn.leaf_var[i])] = int(spn.leaf_value[i])
    return x


def draw_uniforms(spn: SPN, n: int, seed: int = 0) -> np.ndarray:
    """The ``(num_nodes, n)`` uniform tensor both samplers consume."""
    return np.random.default_rng(seed).random((spn.num_nodes, n))


def sample_ancestral_numpy(spn: SPN, n: int, seed: int = 0,
                           uniforms: np.ndarray | None = None) -> np.ndarray:
    """Ancestral sampling, numpy oracle. Returns ``(n, num_vars)`` int64."""
    children, cdf, is_sum = _tables(spn)
    N, cmax = children.shape
    U = (draw_uniforms(spn, n, seed) if uniforms is None
         else np.asarray(uniforms)).astype(np.float32)
    active = np.zeros((N + 1, n), dtype=bool)
    active[spn.root] = True
    for i in range(N - 1, -1, -1):
        row = active[i]
        if not row.any():
            continue
        ch = children[i]
        valid = ch < N
        if not valid.any():
            continue                                   # leaf
        if is_sum[i]:
            choice = np.minimum((cdf[i][:, None] <= U[i][None, :]).sum(0),
                                cmax - 1)
            sel = (np.arange(cmax)[:, None] == choice[None, :])
        else:                                          # product: all children
            sel = np.ones((cmax, n), dtype=bool)
        sel = sel & valid[:, None] & row[None, :]
        for j in np.flatnonzero(valid):
            active[ch[j]] |= sel[j]
    return _assignments(spn, active[:N])


@jax.jit
def _scan_sample(children: jnp.ndarray, cdf: jnp.ndarray,
                 is_sum: jnp.ndarray, U: jnp.ndarray,
                 root: jnp.ndarray) -> jnp.ndarray:
    """Top-down activation pass as one lax.scan over nodes (descending)."""
    N, cmax = children.shape
    n = U.shape[1]
    active0 = jnp.zeros((N + 1, n), dtype=bool).at[root].set(True)

    def step(active, i):
        row = active[i]                                # (n,)
        ch = children[i]                               # (cmax,)
        valid = (ch < N)[:, None]
        choice = jnp.minimum(jnp.sum(cdf[i][:, None] <= U[i][None, :],
                                     axis=0), cmax - 1)
        sel_sum = jnp.arange(cmax)[:, None] == choice[None, :]
        sel = jnp.where(is_sum[i], sel_sum, True) & valid & row[None, :]
        return active.at[ch].max(sel), None

    active, _ = jax.lax.scan(step, active0, jnp.arange(N - 1, -1, -1))
    return active[:N]


def sample_ancestral_jax(spn: SPN, n: int, seed: int = 0,
                         uniforms: np.ndarray | None = None) -> np.ndarray:
    """Ancestral sampling via the batched lax.scan pass.

    Bit-identical to :func:`sample_ancestral_numpy` for the same
    ``uniforms`` (or the same ``seed``).
    """
    children, cdf, is_sum = _tables(spn)
    U = (draw_uniforms(spn, n, seed) if uniforms is None
         else np.asarray(uniforms)).astype(np.float32)
    active = np.asarray(_scan_sample(
        jnp.asarray(children), jnp.asarray(cdf), jnp.asarray(is_sum),
        jnp.asarray(U), jnp.asarray(spn.root, jnp.int32)))
    return _assignments(spn, active)
