"""Multi-query inference engine over the pluggable substrate runtime.

:class:`QueryEngine` turns one SPN into a query server. It lowers the
circuit once into its sum-product :class:`~repro.core.program.TensorProgram`
(holding the max-product twin alive for decoders) and dispatches each
query through the substrate registry of
:mod:`repro.runtime.substrates` — compiled artifacts (kernel builds,
VLIW compiles + fast-sim decodes, leveled closures) live in a
content-addressed :class:`~repro.runtime.cache.ArtifactCache`, so
repeated queries never recompile:

====================  ========  =========  ========  ========
query \\ backend       numpy     leveled    kernel    sim
====================  ========  =========  ========  ========
``joint``             ✓         ✓          ✓         ✓
``marginal``          ✓         ✓          ✓         ✓
``conditional``       ✓         ✓          ✓         ✓
``mpe`` (value)       ✓         ✓          ✓         ✓
``mpe`` (decode)      backtrace grad-AD    backtrace backtrace
``sample`` (draw)     numpy     lax.scan   lax.scan  lax.scan
``sample`` (score)    ✓         ✓          ✓         ✓
====================  ========  =========  ========  ========

Backend names are the engine's historical spellings; they resolve to
registry substrates via :data:`repro.runtime.substrates.ALIASES`
(``numpy`` → float64 alg.-1 oracle, ``leveled`` → ``leveled-jax``,
``kernel`` → ``pallas`` (interpret-mode off-TPU), ``sim`` →
``vliw-sim``, the VLIW compile + vectorized fast-sim of the paper's
processor). Sampling draws never run *on* the kernel/sim substrates (a
fixed op stream cannot flip coins), so those backends draw with the JAX
sampler and score the draws on-substrate.

All log values are base e.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core import program
from ..core.processor.config import PTREE, ProcessorConfig
from ..core.spn import SPN
from ..runtime.cache import ArtifactCache
from ..runtime.substrates import canonical, make_substrate
from . import evidence as ev
from . import mpe as mpe_mod
from . import sampling

BACKENDS = ("numpy", "leveled", "kernel", "sim")


@dataclasses.dataclass
class MPEResult:
    assignment: np.ndarray   # (batch, num_vars) evidence completed w/ argmax
    log_value: np.ndarray    # (batch,) max-product log value on the backend


@dataclasses.dataclass
class SampleResult:
    samples: np.ndarray      # (n, num_vars)
    log_prob: np.ndarray     # (n,) joint log-likelihood scored on the backend


class QueryEngine:
    """Marginal / conditional / MPE / sampling over one SPN.

    Evidence arrays follow the mask convention of
    :mod:`repro.queries.evidence`: ``-1`` marginalizes (or maximizes over)
    a variable, ``>= 0`` observes it.
    """

    def __init__(self, spn: SPN, *, processor: ProcessorConfig = PTREE,
                 interpret: bool | None = None, cache_capacity: int = 16):
        self.spn = spn
        self.prog = program.lower(spn)
        self.max_prog = program.to_max_product(self.prog)
        self.processor = processor
        self.interpret = interpret
        self.cache = ArtifactCache(cache_capacity)
        self._substrates: dict[str, object] = {}

    @property
    def num_vars(self) -> int:
        return self.prog.num_vars

    # ---------------- substrate dispatch ---------------------------------- #
    def substrate(self, backend: str):
        """Registry substrate instance for an engine backend name."""
        name = canonical(backend)
        if name not in self._substrates:
            try:
                self._substrates[name] = make_substrate(
                    name, processor=self.processor, interpret=self.interpret)
            except ValueError:
                raise ValueError(f"unknown backend {backend!r}; pick from "
                                 f"{BACKENDS}") from None
        return self._substrates[name]

    def artifact(self, query: str, backend: str):
        """Compiled artifact for (this SPN, query, backend) — cached."""
        return self.cache.get_or_compile(self.substrate(backend), self.prog,
                                         query=query, log_domain=True)

    def vliw_program(self, prog: program.TensorProgram):
        """Compiled VLIW program for ``prog``.

        The engine's own programs route through the artifact cache; any
        other program is compiled directly (one-off, uncached).
        """
        if prog.digest() == self.prog.digest():
            return self.artifact("joint", "sim").payload[0]
        if prog.digest() == self.max_prog.digest():
            return self.artifact("mpe", "sim").payload[0]
        from ..core.compiler.pipeline import compile_program
        return compile_program(prog, self.processor)

    def _eval_log(self, x: np.ndarray, backend: str,
                  query: str) -> np.ndarray:
        """Root log value of the query's program under evidence ``x``."""
        x = np.atleast_2d(x)
        art = self.artifact(query, backend)
        sub = self.substrate(backend)
        return sub.execute(art, art.prog.leaves_from_evidence(x))

    # ---------------- queries --------------------------------------------- #
    def joint(self, x: np.ndarray, backend: str = "leveled") -> np.ndarray:
        """log p(x) for fully observed rows ``x`` (batch, num_vars)."""
        x = np.atleast_2d(x)
        if (x < 0).any():
            raise ValueError("joint() needs full evidence; use marginal() "
                             "for rows containing -1")
        return self._eval_log(x, backend, "joint")

    def marginal(self, x: np.ndarray, backend: str = "leveled") -> np.ndarray:
        """log p(evidence): -1 entries are summed out by the indicator mask."""
        return self._eval_log(x, backend, "marginal")

    def conditional(self, query: np.ndarray, evidence: np.ndarray,
                    backend: str = "leveled") -> np.ndarray:
        """log p(query | evidence) = log p(q, e) - log p(e)."""
        merged = ev.merge_evidence(np.atleast_2d(query),
                                  np.atleast_2d(evidence))
        return (self.marginal(merged, backend)
                - self.marginal(evidence, backend))

    def mpe(self, x: np.ndarray, backend: str = "leveled") -> MPEResult:
        """Most probable explanation of the -1 entries given the rest.

        The max-product *value* is computed on ``backend``; the argmax
        *decode* uses reverse-mode AD on the leveled substrate
        (``backend="leveled"``) and the float64 backtrace elsewhere.
        """
        x = np.atleast_2d(x)
        if backend == "leveled":
            log_value = self._eval_log(x, backend, "mpe")
            assignment = mpe_mod.mpe_decode_grad(self.max_prog, x)
        elif backend == "numpy":
            # one sweep: the backtrace's buffer root IS the numpy value
            assignment, log_value = mpe_mod.mpe_backtrace(self.max_prog, x)
        else:
            log_value = self._eval_log(x, backend, "mpe")
            assignment, _ = mpe_mod.mpe_backtrace(self.max_prog, x)
        return MPEResult(assignment=assignment, log_value=log_value)

    def sample(self, n: int, seed: int = 0,
               backend: str = "leveled") -> SampleResult:
        """Draw ``n`` ancestral samples and score them on ``backend``."""
        if backend == "numpy":
            samples = sampling.sample_ancestral_numpy(self.spn, n, seed)
        else:
            samples = sampling.sample_ancestral_jax(self.spn, n, seed)
        return SampleResult(samples=samples,
                            log_prob=self._eval_log(samples, backend,
                                                    "sample"))
