"""Multi-query inference engine over all four execution substrates.

:class:`QueryEngine` turns one SPN into a query server. It lowers the
circuit once into its sum-product :class:`~repro.core.program.TensorProgram`
and the max-product twin, holds both alive (substrate caches — Pallas
kernel builds, VLIW compiles — key on program identity), and dispatches
each query to the requested backend:

====================  ========  =========  ========  ========
query \\ backend       numpy     leveled    kernel    sim
====================  ========  =========  ========  ========
``joint``             ✓         ✓          ✓         ✓
``marginal``          ✓         ✓          ✓         ✓
``conditional``       ✓         ✓          ✓         ✓
``mpe`` (value)       ✓         ✓          ✓         ✓
``mpe`` (decode)      backtrace grad-AD    backtrace backtrace
``sample`` (draw)     numpy     lax.scan   lax.scan  lax.scan
``sample`` (score)    ✓         ✓          ✓         ✓
====================  ========  =========  ========  ========

Backends: ``numpy`` — float64 alg.-1 oracle; ``leveled`` — group-decomposed
jit'd JAX; ``kernel`` — the Pallas TPU kernel (interpret-mode off-TPU);
``sim`` — VLIW compile + cycle-accurate processor simulation (linear f32;
the engine logs the root afterwards). Sampling draws never run *on* the
kernel/sim substrates (a fixed op stream cannot flip coins), so those
backends draw with the JAX sampler and score the draws on-substrate.

All log values are base e.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from ..core import executors, program
from ..core.processor import sim as processor_sim
from ..core.processor.config import PTREE, ProcessorConfig
from ..core.spn import SPN
from ..kernels.spn_eval import spn_eval
from . import evidence as ev
from . import mpe as mpe_mod
from . import sampling

BACKENDS = ("numpy", "leveled", "kernel", "sim")


@dataclasses.dataclass
class MPEResult:
    assignment: np.ndarray   # (batch, num_vars) evidence completed w/ argmax
    log_value: np.ndarray    # (batch,) max-product log value on the backend


@dataclasses.dataclass
class SampleResult:
    samples: np.ndarray      # (n, num_vars)
    log_prob: np.ndarray     # (n,) joint log-likelihood scored on the backend


class QueryEngine:
    """Marginal / conditional / MPE / sampling over one SPN.

    Evidence arrays follow the mask convention of
    :mod:`repro.queries.evidence`: ``-1`` marginalizes (or maximizes over)
    a variable, ``>= 0`` observes it.
    """

    def __init__(self, spn: SPN, *, processor: ProcessorConfig = PTREE,
                 interpret: bool | None = None):
        self.spn = spn
        self.prog = program.lower(spn)
        self.max_prog = program.to_max_product(self.prog)
        self.processor = processor
        self.interpret = interpret
        self._vliw: dict[int, object] = {}    # id(prog) -> VLIWProgram

    @property
    def num_vars(self) -> int:
        return self.prog.num_vars

    # ---------------- substrate dispatch ---------------------------------- #
    def vliw_program(self, prog: program.TensorProgram):
        """Compiled VLIW program for ``prog`` (cached on the engine)."""
        key = id(prog)
        if key not in self._vliw:
            from ..core.compiler.pipeline import compile_program
            self._vliw[key] = compile_program(prog, self.processor)
        return self._vliw[key]

    def _eval_log(self, prog: program.TensorProgram, x: np.ndarray,
                  backend: str) -> np.ndarray:
        """Root log value of ``prog`` under evidence ``x`` on ``backend``."""
        x = np.atleast_2d(x)
        if backend == "sim":       # the simulator expands evidence itself
            res = processor_sim.simulate(self.vliw_program(prog), prog, x,
                                         self.processor)
            with np.errstate(divide="ignore"):
                return np.log(res.root_values.astype(np.float64))
        leaf = prog.leaves_from_evidence(x)
        if backend == "numpy":
            return executors.eval_ops_numpy(prog, leaf, log_domain=True)
        if backend == "leveled":
            out = executors.eval_leveled(prog, jnp.asarray(leaf, jnp.float32),
                                         None, True)
            return np.asarray(out, np.float64)
        if backend == "kernel":
            out = spn_eval(prog, leaf.astype(np.float32), log_domain=True,
                           interpret=self.interpret)
            return np.asarray(out, np.float64)
        raise ValueError(f"unknown backend {backend!r}; pick from {BACKENDS}")

    # ---------------- queries --------------------------------------------- #
    def joint(self, x: np.ndarray, backend: str = "leveled") -> np.ndarray:
        """log p(x) for fully observed rows ``x`` (batch, num_vars)."""
        x = np.atleast_2d(x)
        if (x < 0).any():
            raise ValueError("joint() needs full evidence; use marginal() "
                             "for rows containing -1")
        return self._eval_log(self.prog, x, backend)

    def marginal(self, x: np.ndarray, backend: str = "leveled") -> np.ndarray:
        """log p(evidence): -1 entries are summed out by the indicator mask."""
        return self._eval_log(self.prog, x, backend)

    def conditional(self, query: np.ndarray, evidence: np.ndarray,
                    backend: str = "leveled") -> np.ndarray:
        """log p(query | evidence) = log p(q, e) - log p(e)."""
        merged = ev.merge_evidence(np.atleast_2d(query),
                                  np.atleast_2d(evidence))
        return (self.marginal(merged, backend)
                - self.marginal(evidence, backend))

    def mpe(self, x: np.ndarray, backend: str = "leveled") -> MPEResult:
        """Most probable explanation of the -1 entries given the rest.

        The max-product *value* is computed on ``backend``; the argmax
        *decode* uses reverse-mode AD on the leveled substrate
        (``backend="leveled"``) and the float64 backtrace elsewhere.
        """
        x = np.atleast_2d(x)
        if backend == "leveled":
            log_value = self._eval_log(self.max_prog, x, backend)
            assignment = mpe_mod.mpe_decode_grad(self.max_prog, x)
        elif backend == "numpy":
            # one sweep: the backtrace's buffer root IS the numpy value
            assignment, log_value = mpe_mod.mpe_backtrace(self.max_prog, x)
        else:
            log_value = self._eval_log(self.max_prog, x, backend)
            assignment, _ = mpe_mod.mpe_backtrace(self.max_prog, x)
        return MPEResult(assignment=assignment, log_value=log_value)

    def sample(self, n: int, seed: int = 0,
               backend: str = "leveled") -> SampleResult:
        """Draw ``n`` ancestral samples and score them on ``backend``."""
        if backend == "numpy":
            samples = sampling.sample_ancestral_numpy(self.spn, n, seed)
        else:
            samples = sampling.sample_ancestral_jax(self.spn, n, seed)
        return SampleResult(samples=samples,
                            log_prob=self.joint(samples, backend))
