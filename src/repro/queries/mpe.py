"""MPE / MAP queries: max-product sweeps + argmax decoding.

The max-product semiring
------------------------
Swapping the circuit's semiring from sum-product ``(+, ×)`` to
**max-product** ``(max, ×)`` — in log domain ``(max, +)``, the tropical
semiring — turns the marginalization sweep into a Viterbi sweep: the root
no longer holds ``Σ_T ∏ w·leaf`` over induced trees ``T`` but
``max_T ∏ w·leaf``, the probability of the single best explanation
consistent with the evidence. :func:`repro.core.program.to_max_product`
performs the swap at the IR level (``OP_SUM → OP_MAX``), so the identical
program skeleton runs on every substrate; only the PE/ALU op changes
(``PE_MAX`` on the VLIW processor, ``jnp.maximum`` in the Pallas kernel).

For *selective* circuits (at most one sum child non-zero per complete
state — e.g. fully factorized models) the sweep computes the exact MPE
probability; for general SPNs it is the standard Poon–Domingos
max-product approximation: the returned assignment maximizes the best
single-tree explanation, and its true probability upper-bounds the
reported max-product value (``p(x*) ≥ max_T``, verified in the tests).

Decoding the argmax
-------------------
Two independent decoders, used to cross-check each other:

- :func:`mpe_backtrace` — the oracle: fill the float64 value buffer
  bottom-up, then walk top-down from the root taking the argmax operand of
  every MAX op and both operands of every PROD op; indicator leaves
  reached by the walk spell out the assignment.
- :func:`mpe_decode_grad` — batched JAX decode: the gradient of the
  max-product root w.r.t. the *log* leaf inputs is 1 exactly on the leaves
  the backtrace would visit (``max`` routes the cotangent to its argmax,
  log-products pass it through), so one reverse-mode sweep decodes the
  whole batch with no host loop.

Zero leaves are represented by the finite ``NEG_INF`` stand-in for
``log 0`` so reverse-mode AD never materializes ``0 · ∞ = NaN``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..core import executors
from ..core.program import OP_MAX, OP_PROD, TensorProgram

NEG_INF = -1e30    # finite log(0): keeps max/plus arithmetic & grads NaN-free


def log_leaves(leaf_ind: np.ndarray) -> np.ndarray:
    """Log-domain leaf vector with the finite ``NEG_INF`` zero."""
    leaf_ind = np.atleast_2d(np.asarray(leaf_ind, dtype=np.float64))
    return np.where(leaf_ind > 0.0,
                    np.log(np.maximum(leaf_ind, 1e-300)), NEG_INF)


def _log_params(prog: TensorProgram) -> np.ndarray:
    pv = np.asarray(prog.param_values, np.float64)
    return np.where(pv > 0.0, np.log(np.maximum(pv, 1e-300)),
                    NEG_INF).astype(np.float32)


@functools.partial(jax.jit, static_argnums=(0,))
def max_root_from_log_leaves(prog: TensorProgram,
                             log_leaf: jnp.ndarray) -> jnp.ndarray:
    """Leveled max-product sweep over *already-logged* leaves (batched).

    ``prog`` must be a max-product program. Differentiable w.r.t.
    ``log_leaf`` — the gradient is the argmax-path indicator used by
    :func:`mpe_decode_grad`.
    """
    log_leaf = jnp.atleast_2d(log_leaf).astype(jnp.float32)
    batch = log_leaf.shape[0]
    lp = jnp.broadcast_to(jnp.asarray(_log_params(prog)),
                          (batch, prog.m_param))
    full = jnp.concatenate([log_leaf, lp], axis=1)
    return executors._leveled_impl(prog, full.T, log_domain=True)


def _decode_from_scores(prog: TensorProgram, scores: np.ndarray,
                        evidence: np.ndarray) -> np.ndarray:
    """Per-variable argmax over indicator-slot scores → assignment.

    Evidence entries pass through untouched; free variables take the value
    of their highest-scoring indicator.
    """
    batch = scores.shape[0]
    x = np.atleast_2d(evidence).astype(np.int64, copy=True)
    free = x < 0                                     # frozen before updates
    best = np.full((batch, prog.num_vars), -np.inf)
    for s in range(prog.m_ind):                      # m_ind ~ 2·num_vars
        v = int(prog.ind_var[s])
        upd = free[:, v] & (scores[:, s] > best[:, v])
        best[upd, v] = scores[upd, s]
        x[upd, v] = int(prog.ind_value[s])
    return x


def mpe_decode_grad(prog: TensorProgram, evidence: np.ndarray) -> np.ndarray:
    """Batched MPE decode via reverse-mode AD through the max sweep.

    Caveat: on an *exact* max tie JAX splits the cotangent 0.5/0.5
    between the tied operands, so the per-variable argmax can mix two
    equally-good explanations (whereas :func:`mpe_backtrace` commits to
    one deterministically). With learned float weights exact ties are
    measure-zero; callers that must be tie-robust should compare decoded
    assignments by their max-product *value*, not identity.
    """
    evidence = np.atleast_2d(evidence)
    ll = jnp.asarray(log_leaves(prog.leaves_from_evidence(evidence)),
                     jnp.float32)
    grad_fn = jax.grad(lambda L: max_root_from_log_leaves(prog, L).sum())
    g = np.asarray(grad_fn(ll), np.float64)          # (batch, m_ind)
    return _decode_from_scores(prog, g, evidence)


def mpe_backtrace(prog: TensorProgram,
                  evidence: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Oracle MPE: float64 sweep + top-down argmax walk.

    Returns ``(assignment, root_log)`` where ``assignment`` is the
    evidence completed with the maximizing values and ``root_log`` the
    max-product log value (base e).
    """
    evidence = np.atleast_2d(evidence)
    leaf = prog.leaves_from_evidence(evidence)
    # float64 log buffer from the oracle (true -inf is fine outside AD)
    A = executors.eval_ops_numpy(prog, leaf, log_domain=True,
                                 return_buffer=True)
    m = prog.m
    batch = leaf.shape[0]
    x = evidence.astype(np.int64, copy=True)
    for r in range(batch):
        stack = [int(prog.root_slot)]
        while stack:
            s = stack.pop()
            if s < prog.m_ind:
                v = int(prog.ind_var[s])
                if x[r, v] < 0:
                    x[r, v] = int(prog.ind_value[s])
            elif s < m:
                continue                              # parameter leaf
            else:
                i = s - m
                o = int(prog.opcode[i])
                bs, cs = int(prog.b[i]), int(prog.c[i])
                if o == OP_PROD:
                    stack.append(bs)
                    stack.append(cs)
                elif o == OP_MAX:
                    stack.append(bs if A[bs, r] >= A[cs, r] else cs)
                else:
                    raise ValueError(
                        "mpe_backtrace needs a max-product program "
                        "(run program.to_max_product first)")
    return x, A[prog.root_slot].copy()
