"""Evidence / query-mask conventions for the query engine.

Every query in :mod:`repro.queries` takes evidence as an integer array of
shape ``(batch, num_vars)`` in the **evidence-mask convention**:

- ``x[b, v] >= 0`` — variable ``v`` is *observed* with that value,
- ``x[b, v] == -1`` — variable ``v`` is *marginalized* (sum queries) or
  *free/maximized-over* (MPE queries).

The convention maps onto the circuit exactly as the SPN literature
prescribes: a marginalized variable sets **all** of its indicator leaves
to 1 (log 0), which makes the sum-product sweep integrate it out and the
max-product sweep maximize over it — no program rewrite, just a different
leaf vector. ``TensorProgram.leaves_from_evidence`` implements the
indicator fill, so all four substrates inherit the convention for free.
"""
from __future__ import annotations

from typing import Mapping

import numpy as np


def evidence_array(num_vars: int, observed: Mapping[int, int] | None = None,
                   batch: int = 1) -> np.ndarray:
    """Build a ``(batch, num_vars)`` evidence array, -1 everywhere except
    the ``observed`` ``{var: value}`` entries (broadcast across the batch).
    """
    x = np.full((batch, num_vars), -1, dtype=np.int64)
    for v, val in (observed or {}).items():
        if not 0 <= v < num_vars:
            raise ValueError(f"variable {v} out of range [0, {num_vars})")
        x[:, v] = int(val)
    return x


def merge_evidence(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Union of two evidence arrays; raises on conflicting observations."""
    a, b = np.atleast_2d(a), np.atleast_2d(b)
    if a.shape != b.shape:
        raise ValueError(f"evidence shapes differ: {a.shape} vs {b.shape}")
    clash = (a >= 0) & (b >= 0) & (a != b)
    if clash.any():
        rows, cols = np.nonzero(clash)
        raise ValueError(f"conflicting evidence at (row, var) "
                         f"{list(zip(rows.tolist(), cols.tolist()))[:5]}")
    return np.where(a >= 0, a, b)


def mask_vars(x: np.ndarray, vars_to_mask, *, copy: bool = True) -> np.ndarray:
    """Return ``x`` with the given variables set to -1 (marginalized)."""
    out = np.atleast_2d(x).astype(np.int64, copy=copy)
    out[:, np.asarray(list(vars_to_mask), dtype=np.int64)] = -1
    return out


def random_mask(x: np.ndarray, frac: float, seed: int = 0) -> np.ndarray:
    """Marginalize a random ``frac`` of each row's variables (the standard
    marginal/MPE benchmark workload: partial observations)."""
    x = np.atleast_2d(x).astype(np.int64, copy=True)
    rng = np.random.default_rng(seed)
    mask = rng.random(x.shape) < frac
    x[mask] = -1
    return x
