"""Probabilistic query engine: marginal / conditional / MPE / sampling.

The seed stack answered exactly one query — the joint likelihood p(x) via
the sum-product sweep. This package turns it into a multi-query inference
engine, the reason SPNs are worth accelerating in the first place: the
same circuit answers *many* tractable queries, each a different sweep
over the same :class:`~repro.core.program.TensorProgram` skeleton:

- **marginal / conditional** — evidence masks (-1 entries) set the
  marginalized indicators to 1; ``p(q|e) = p(q,e) / p(e)`` on top,
- **MPE / MAP** — the max-product (tropical) semiring: ``OP_SUM →
  OP_MAX`` at the IR level, ``PE_MAX`` on the VLIW processor, plus an
  argmax backtrace / gradient decode for the maximizing assignment,
- **ancestral sampling** — top-down induced-tree draws, numpy oracle and
  a batched ``lax.scan`` implementation.

:class:`QueryEngine` dispatches every query across the four execution
substrates (numpy oracle, leveled JAX, Pallas kernel, VLIW processor
sim); see its docstring for the query × backend matrix.
"""
from .engine import BACKENDS, MPEResult, QueryEngine, SampleResult
from .evidence import (evidence_array, mask_vars, merge_evidence,
                       random_mask)
from .mpe import mpe_backtrace, mpe_decode_grad
from .sampling import (draw_uniforms, sample_ancestral_jax,
                       sample_ancestral_numpy)

__all__ = [
    "BACKENDS", "MPEResult", "QueryEngine", "SampleResult",
    "evidence_array", "mask_vars", "merge_evidence", "random_mask",
    "mpe_backtrace", "mpe_decode_grad",
    "draw_uniforms", "sample_ancestral_jax", "sample_ancestral_numpy",
]
