"""Roofline terms from a compiled dry-run artifact.

``compiled.cost_analysis()`` on the CPU backend does NOT weight while-loop
bodies by trip count (a scanned 24-layer model under-reports ~800×), so we
parse the optimized HLO ourselves:

- module → computations → instructions (result type, opcode, operands);
- ``while`` bodies are weighted by ``known_trip_count`` from
  backend_config (the scan-over-layers / flash-attention loops all carry
  it); nested loops multiply;
- FLOPs: dots count 2·|result|·|contraction|; elementwise arithmetic
  counts |result|; transcendentals tracked separately;
- HBM bytes: per top-level instruction, operands + result — with fusions
  treated as single units (their internals are register/VMEM traffic) and
  dynamic-(update-)slice counted at slice size (in-place semantics), which
  approximates XLA's own post-fusion bytes-accessed model;
- collectives: result bytes of all-gather / all-reduce / reduce-scatter /
  all-to-all / collective-permute, trip-weighted.

Hardware model (TPU v5e-class, per assignment): 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "u4": 1, "s4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "negate", "abs", "and", "or", "xor", "remainder", "clamp",
}
_TRANSCENDENTAL = {
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "rsqrt", "sqrt", "power", "cosine", "sine", "logistic", "erf", "atan2",
    "cbrt", "tan",
}
_NO_TRAFFIC = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string (tuples sum their elements)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def shape_elems(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n
    return total


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


@dataclasses.dataclass
class Instr:
    name: str
    result_type: str
    opcode: str
    operands: list[str]
    raw: str


# result type: either a tuple "( ... )" (may contain /*index=N*/ comments,
# no nested parens) or a plain array type; then "opcode(operands)".
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*"
    r"(\([^()]*\)|[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?)\s*"
    r"([\w\-]+)\((.*)\)(.*)$")

_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->.*\{")


class HLOModule:
    def __init__(self, text: str):
        self.computations: dict[str, list[Instr]] = {}
        self.entry: str | None = None
        self.types: dict[str, str] = {}        # instr name -> result type
        cur: list[Instr] | None = None
        for line in text.splitlines():
            mc = _COMP_RE.match(line.strip())
            if mc and not line.startswith("  "):
                name = mc.group(2)
                cur = self.computations.setdefault(name, [])
                if mc.group(1):
                    self.entry = name
                continue
            mi = _INSTR_RE.match(line)
            if mi and cur is not None:
                name, rtype, opcode, ops, rest = mi.groups()
                operands = re.findall(r"%([\w.\-]+)", ops)
                ins = Instr(name, rtype, opcode, operands, line)
                cur.append(ins)
                self.types[name] = rtype

    # ------------------------------------------------------------------
    def _called(self, ins: Instr, attr: str) -> str | None:
        m = re.search(attr + r"=%?([\w.\-]+)", ins.raw)
        return m.group(1) if m else None

    def _trip_count(self, ins: Instr) -> int:
        m = re.search(r'known_trip_count[^0-9]*(\d+)', ins.raw)
        if m:
            return int(m.group(1))
        m = re.search(r"trip_count=(\d+)", ins.raw)
        return int(m.group(1)) if m else 1

    def _dot_flops(self, ins: Instr) -> float:
        out = 1
        for d in _shape_dims(ins.result_type):
            out *= d
        lhs = ins.operands[0] if ins.operands else None
        lhs_type = self.types.get(lhs, "")
        dims = _shape_dims(lhs_type)
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.raw)
        k = 1
        if m and dims:
            for idx in m.group(1).split(","):
                if idx:
                    k *= dims[int(idx)]
        return 2.0 * out * k

    # ------------------------------------------------------------------
    def analyze(self) -> dict:
        """Walk from entry; returns flops / bytes / transcendentals /
        per-collective bytes+counts, trip-weighted."""
        acc = {
            "flops": 0.0, "hbm_bytes": 0.0, "transcendentals": 0.0,
            "collective_bytes": defaultdict(float),
            "collective_count": defaultdict(float),
            "dot_flops": 0.0,
            "bytes_by_op": defaultdict(float),      # per-opcode HBM profile
        }
        if self.entry:
            self._walk(self.entry, 1.0, acc, bytes_mode=True)
        acc["collective_bytes"] = dict(acc["collective_bytes"])
        acc["collective_count"] = dict(acc["collective_count"])
        acc["bytes_by_op"] = dict(acc["bytes_by_op"])
        return acc

    def _walk(self, comp: str, mult: float, acc: dict, bytes_mode: bool):
        for ins in self.computations.get(comp, ()):
            op = ins.opcode
            if op == "while":
                trip = self._trip_count(ins)
                body = self._called(ins, "body")
                cond = self._called(ins, "condition")
                if body:
                    self._walk(body, mult * trip, acc, bytes_mode)
                if cond:
                    self._walk(cond, mult * trip, acc, bytes_mode)
                continue
            if op == "fusion":
                callee = self._called(ins, "calls")
                if callee:          # FLOPs inside; bytes at the boundary
                    self._walk(callee, mult, acc, bytes_mode=False)
                if bytes_mode:
                    b = mult * self._io_bytes(ins)
                    acc["hbm_bytes"] += b
                    acc["bytes_by_op"][op] += b
                continue
            if op in ("call", "conditional", "custom-call"):
                for attr in ("to_apply", "calls", "branch_computations"):
                    callee = self._called(ins, attr)
                    if callee:
                        self._walk(callee, mult, acc, bytes_mode)
                        break
                if bytes_mode and op != "call":
                    b = mult * self._io_bytes(ins)
                    acc["hbm_bytes"] += b
                    acc["bytes_by_op"][op] += b
                continue

            # ---- collectives ----
            base = op[:-6] if op.endswith("-start") else op
            if base in COLLECTIVES:
                b = shape_bytes(ins.result_type)
                acc["collective_bytes"][base] += mult * b
                acc["collective_count"][base] += mult
                if bytes_mode:
                    b = mult * self._io_bytes(ins)
                    acc["hbm_bytes"] += b
                    acc["bytes_by_op"][base] += b
                continue
            if op.endswith("-done"):
                continue

            # ---- flops ----
            if op == "dot":
                f = self._dot_flops(ins)
                acc["flops"] += mult * f
                acc["dot_flops"] += mult * f
            elif op in _ELEMENTWISE or op == "select" or op == "compare":
                acc["flops"] += mult * shape_elems(ins.result_type)
            elif op in _TRANSCENDENTAL:
                acc["transcendentals"] += mult * shape_elems(ins.result_type)
            elif op in ("reduce", "reduce-window"):
                if ins.operands:
                    acc["flops"] += mult * shape_elems(
                        self.types.get(ins.operands[0], ""))

            # ---- bytes ----
            if bytes_mode and op not in _NO_TRAFFIC:
                b = mult * self._io_bytes(ins)
                acc["hbm_bytes"] += b
                acc["bytes_by_op"][op] += b

    def _io_bytes(self, ins: Instr) -> float:
        op = ins.opcode
        if op == "dynamic-update-slice":
            upd = shape_bytes(self.types.get(ins.operands[1], "")
                              if len(ins.operands) > 1 else "")
            return 2.0 * upd
        if op == "dynamic-slice":
            return 2.0 * shape_bytes(ins.result_type)
        result = shape_bytes(ins.result_type)
        op_bytes = []
        aliased = False
        for o in ins.operands:
            t = self.types.get(o)
            if not t:
                continue
            b = shape_bytes(t)
            if op == "fusion" and b == result and result > 0:
                # in-place update pattern (scan residual stacking): the
                # result aliases this operand; actual write is slice-sized
                aliased = True
                continue
            op_bytes.append(b)
        payload = sum(op_bytes)
        if aliased:
            # measurement model v2.1: charge the slice write (≈ payload)
            # instead of the whole aliased buffer per iteration
            return float(2.0 * max(payload, 1) )
        total = result
        for b in op_bytes:
            # v2: an operand vastly larger than the result is sliced, not
            # streamed (e.g. one layer of (L, …) stacked weights)
            if op == "fusion" and result > 0 and b > 64 * result:
                b = result
            total += b
        return float(total)


def analyze_hlo(hlo_text: str) -> dict:
    return HLOModule(hlo_text).analyze()


# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Roofline:
    """Per-chip roofline terms.

    The optimized HLO from ``compiled.as_text()`` is the post-SPMD
    PER-DEVICE program, so the analyzer's flops/bytes are already
    per-chip; no division by chip count.
    """
    flops: float                 # trip-weighted HLO flops (per chip)
    hbm_bytes: float             # trip-weighted HLO bytes (per chip)
    collective_bytes: float      # collective bytes (per chip)
    chips: int
    links_per_chip: int = 4      # 2D torus: 4 ICI links per chip

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / (self.links_per_chip * ICI_BW)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def as_dict(self) -> dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes, "chips": self.chips,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
        }


def model_flops_train(n_params_active: float, tokens: float) -> float:
    """6·N·D estimator (fwd 2ND + bwd 4ND)."""
    return 6.0 * n_params_active * tokens


def model_flops_infer(n_params_active: float, tokens: float) -> float:
    return 2.0 * n_params_active * tokens
