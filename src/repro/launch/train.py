"""Training driver — the full production loop at laptop scale.

Wires every substrate together: config → sharding plan → jit'd train step
(AdamW + optional grad accumulation + optional int8 error-feedback
compression) → deterministic data pipeline → async checkpointing →
heartbeat/watchdog → restart-from-last-good on failure.

CPU-host note: runs the SMOKE config of the chosen arch by default (the
full configs are exercised via the dry-run); pass ``--full`` only on real
hardware.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --steps 50 --ckpt /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import AsyncCheckpointer, latest_step, restore
from ..configs.base import get_config, get_smoke_config
from ..data.lm_pipeline import PipelineConfig, TokenPipeline
from ..models import api
from ..models.common import reset_act_rules, set_act_rules
from ..optim import AdamWConfig, adamw
from ..optim import compress as C
from ..parallel.plan import Planner
from ..runtime import FailureInjector, Heartbeat, Watchdog
from . import step_fns
from .mesh import make_local_mesh


@dataclasses.dataclass
class TrainConfig:
    arch: str = "qwen2-0.5b"
    arch_config: Any = None        # explicit ArchConfig overrides ``arch``
    full: bool = False
    steps: int = 50
    global_batch: int = 8
    seq_len: int = 128
    ckpt_dir: str | None = None
    ckpt_every: int = 10
    seed: int = 0
    accum: int = 1
    compress_grads: bool = False
    opt: AdamWConfig = dataclasses.field(default_factory=lambda: AdamWConfig(
        lr=1e-3, warmup_steps=10, total_steps=1000))


class Trainer:
    def __init__(self, tc: TrainConfig, *, mesh=None,
                 injector: FailureInjector | None = None):
        self.tc = tc
        self.cfg = (tc.arch_config if tc.arch_config is not None else
                    get_config(tc.arch) if tc.full
                    else get_smoke_config(tc.arch))
        self.mesh = mesh if mesh is not None else make_local_mesh()
        self.planner = Planner(self.cfg, self.mesh)
        self.pipe = TokenPipeline(PipelineConfig(
            vocab=self.cfg.vocab, seq_len=tc.seq_len,
            global_batch=tc.global_batch, seed=tc.seed))
        self.injector = injector
        if tc.accum > 1:
            fn = step_fns.make_grad_accum_step(self.cfg, tc.opt, tc.accum,
                                               remat=False)
        else:
            fn = step_fns.make_train_step(self.cfg, tc.opt, remat=False)
        self.step_fn = jax.jit(fn, donate_argnums=(0, 1))
        self.ckpt = (AsyncCheckpointer(tc.ckpt_dir)
                     if tc.ckpt_dir else None)
        self.hb = (Heartbeat(tc.ckpt_dir + "/hb", 0) if tc.ckpt_dir else None)
        self.watchdog = Watchdog(tc.ckpt_dir + "/hb") if tc.ckpt_dir else None
        self.residuals = None

    # ------------------------------------------------------------------
    def init_state(self) -> dict:
        params = api.init_params(self.cfg, jax.random.PRNGKey(self.tc.seed))
        return {"params": params, "opt": adamw.init_state(params), "step": 0}

    def resume_state(self) -> dict | None:
        if not self.tc.ckpt_dir or latest_step(self.tc.ckpt_dir) is None:
            return None
        target = jax.eval_shape(self.init_state)
        state, extras = restore(self.tc.ckpt_dir, target)
        state["step"] = int(extras["step"])
        return state

    # ------------------------------------------------------------------
    def run(self, state: dict) -> dict:
        tc = self.tc
        token = set_act_rules(self.planner.act_rules())
        losses = []
        try:
            params, opt = state["params"], state["opt"]
            if tc.compress_grads and self.residuals is None:
                self.residuals = C.init_residuals(params)
            for step in range(state["step"], tc.steps):
                t0 = time.time()
                if self.injector is not None:
                    self.injector.maybe_fail(step)
                batch = jax.tree.map(jnp.asarray,
                                     self.pipe.batch_for_step(step))
                params, opt, metrics = self.step_fn(params, opt, batch)
                loss = float(metrics["loss"])
                losses.append(loss)
                dt = time.time() - t0
                if self.hb:
                    self.hb.beat(step)
                    self.watchdog.record_step_time(0, dt)
                if self.ckpt and (step + 1) % tc.ckpt_every == 0:
                    self.ckpt.save(step + 1,
                                   {"params": params, "opt": opt,
                                    "step": jnp.asarray(step + 1)},
                                   extras={"step": step + 1,
                                           "data": self.pipe.state_dict(step + 1)})
            if self.ckpt:
                self.ckpt.save(tc.steps, {"params": params, "opt": opt,
                                          "step": jnp.asarray(tc.steps)},
                               extras={"step": tc.steps,
                                       "data": self.pipe.state_dict(tc.steps)})
                self.ckpt.close()
            return {"params": params, "opt": opt, "step": tc.steps,
                    "losses": losses}
        finally:
            reset_act_rules(token)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()
    tc = TrainConfig(arch=args.arch, full=args.full, steps=args.steps,
                     global_batch=args.batch, seq_len=args.seq,
                     ckpt_dir=args.ckpt, accum=args.accum,
                     compress_grads=args.compress_grads)
    tr = Trainer(tc)
    state = tr.resume_state() or tr.init_state()
    out = tr.run(state)
    l = out["losses"]
    print(f"steps {len(l)}  first loss {l[0]:.4f}  last loss {l[-1]:.4f}")


if __name__ == "__main__":
    main()
