"""Serving driver.

Two workloads:

- ``spn``: the paper's workload — batched SPN inference, now with a
  **query axis**. Learns an SPN, wraps it in the
  :class:`repro.queries.QueryEngine` and serves batched requests of the
  selected query type on every substrate (leveled JAX executor, Pallas
  kernel, VLIW processor sim), reporting throughput per backend plus the
  processor's ops/cycle (the paper's metric):

  - ``--query joint``     — full-evidence likelihood (the seed workload),
  - ``--query marginal``  — partial evidence, ``--mask-frac`` of the
    variables marginalized per row,
  - ``--query mpe``       — max-product sweep on the same masked evidence
    (the ``PE_MAX`` instruction stream on the processor) + argmax decode,
  - ``--query sample``    — ancestral sampling (numpy vs lax.scan
    samplers) + on-substrate scoring of the draws.

- ``lm``: batched LM serving — prefill a prompt batch then decode N
  tokens with the KV cache, on the smoke config (CPU-sized).

    PYTHONPATH=src python -m repro.launch.serve --mode spn --dataset nltcs
    PYTHONPATH=src python -m repro.launch.serve --mode spn --query mpe
    PYTHONPATH=src python -m repro.launch.serve --mode lm --arch qwen2-0.5b
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def serve_spn(dataset: str, batch: int, n_batches: int,
              use_kernel: bool = True, query: str = "joint",
              mask_frac: float = 0.3) -> dict:
    from ..core import executors, learn
    from ..core.processor import sim
    from ..data import spn_datasets
    from ..kernels.spn_eval import spn_eval
    from ..queries import QueryEngine, random_mask, sample_ancestral_jax, \
        sample_ancestral_numpy

    X = spn_datasets.load(dataset, "train", 400)
    eng = QueryEngine(learn.learn_spn(X, min_instances=64))
    # MPE rides the max-product twin; every other query the sum-product one
    prog = eng.max_prog if query == "mpe" else eng.prog
    print(f"SPN[{dataset}] query={query}: {prog.n_ops} ops, "
          f"{prog.num_levels} levels")

    # warmup + timed loops
    out = {}
    def bench(name, fn):
        fn()  # compile
        t0 = time.time()
        for _ in range(n_batches):
            r = fn()
        jax.block_until_ready(r)
        dt = time.time() - t0
        out[name] = {"us_per_batch": dt / n_batches * 1e6,
                     "evals_per_s": batch * n_batches / dt}
        print(f"  {name:18s} {out[name]['us_per_batch']:10.1f} us/batch "
              f"({out[name]['evals_per_s']:12.0f} evals/s)")
        return r

    if query == "sample":
        bench("sampler-numpy",
              lambda: sample_ancestral_numpy(eng.spn, batch, seed=0))
        samples = bench("sampler-lax-scan",
                        lambda: sample_ancestral_jax(eng.spn, batch, seed=0))
        assert np.array_equal(
            samples, sample_ancestral_numpy(eng.spn, batch, seed=0)), \
            "sampler substrate mismatch"
        leaves = jnp.asarray(prog.leaves_from_evidence(samples), jnp.float32)
    else:
        Xq = spn_datasets.load(dataset, "test", batch)
        if query in ("marginal", "mpe"):
            Xq = random_mask(Xq, mask_frac, seed=0)
        leaves = jnp.asarray(prog.leaves_from_evidence(Xq), jnp.float32)

    score = "score-" if query == "sample" else ""
    r_lvl = bench(f"{score}leveled-jax",
                  lambda: executors.eval_leveled(prog, leaves, None, True))
    if use_kernel:
        r_ker = bench(f"{score}pallas-kernel",
                      lambda: spn_eval(prog, leaves, log_domain=True))
        err = float(jnp.abs(r_ker - r_lvl).max())
        print(f"  kernel vs leveled max |Δ|: {err:.2e}")

    # VLIW processor: compile once (cached on the engine), simulate a slice
    Xs = (np.asarray(samples[:8]) if query == "sample" else Xq[:8])
    vprog = eng.vliw_program(prog)
    res = sim.simulate(vprog, prog, Xs, eng.processor)
    ref = executors.eval_ops_numpy(prog, np.asarray(
        prog.leaves_from_evidence(Xs)))
    assert np.allclose(res.root_values, ref, rtol=1e-4), "processor mismatch"
    out["processor_sim"] = {"ops_per_cycle": res.ops_per_cycle,
                            "cycles": res.cycles}
    print(f"  processor-sim      {res.ops_per_cycle:.2f} ops/cycle "
          f"({res.cycles} cycles/eval-batch)")

    if query == "mpe":
        r = eng.mpe(Xq[:4], backend="numpy")
        # tie-robust self-check: the decoded assignment must reproduce the
        # sweep's root value under the max program (argmax identity may
        # legitimately differ between decoders on exact ties)
        dec = executors.eval_ops_numpy(
            prog, prog.leaves_from_evidence(r.assignment), log_domain=True)
        assert np.allclose(dec, r.log_value, atol=1e-6), "decode mismatch"
        out["mpe_example"] = {"evidence": Xq[:4].tolist(),
                              "assignment": r.assignment.tolist(),
                              "log_value": r.log_value.tolist()}
        print(f"  MPE decode self-check ok, e.g. row 0: "
              f"{Xq[0].tolist()} -> {r.assignment[0].tolist()} "
              f"(log p* = {r.log_value[0]:.4f})")
    return out


def serve_lm(arch: str, batch: int, prompt_len: int, gen_len: int) -> dict:
    from ..configs.base import get_smoke_config
    from ..models import api

    cfg = get_smoke_config(arch)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (batch, prompt_len)),
                          jnp.int32)
    cache = api.init_cache(cfg, batch, prompt_len + gen_len)

    prefill = jax.jit(lambda p, t, c: api.prefill(cfg, p, t, c))
    decode = jax.jit(lambda p, c, t: api.decode_step(cfg, p, c, t))

    t0 = time.time()
    logits, cache = prefill(params, prompts, cache)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    toks = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    t0 = time.time()
    outs = [toks]
    for _ in range(gen_len - 1):
        logits, cache = decode(params, cache, toks)
        toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        outs.append(toks)
    jax.block_until_ready(toks)
    t_decode = time.time() - t0
    text = jnp.concatenate(outs, axis=1)
    tok_s = batch * (gen_len - 1) / max(t_decode, 1e-9)
    print(f"LM[{arch}] prefill {batch}x{prompt_len} in {t_prefill*1e3:.1f} ms; "
          f"decode {gen_len-1} steps @ {tok_s:.0f} tok/s")
    return {"prefill_s": t_prefill, "decode_s": t_decode,
            "tokens": np.asarray(text)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["spn", "lm"], default="spn")
    ap.add_argument("--query", choices=["joint", "marginal", "mpe", "sample"],
                    default="joint",
                    help="SPN query type served (see repro.queries)")
    ap.add_argument("--mask-frac", type=float, default=0.3,
                    help="fraction of variables marginalized for "
                         "marginal/mpe queries")
    ap.add_argument("--dataset", default="nltcs")
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--batches", type=int, default=20)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    args = ap.parse_args()
    if args.mode == "spn":
        serve_spn(args.dataset, args.batch, args.batches,
                  query=args.query, mask_frac=args.mask_frac)
    else:
        serve_lm(args.arch, min(args.batch, 8), args.prompt_len,
                 args.gen_len)


if __name__ == "__main__":
    main()
