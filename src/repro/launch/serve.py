"""Serving driver — a thin CLI over :class:`repro.runtime.Server`.

Two workloads:

- ``spn``: the paper's workload — batched SPN inference with a **query
  axis** and a **substrate axis**. Learns an SPN, wraps it in the
  unified substrate runtime (``repro.runtime``: substrate registry,
  content-addressed compiled-artifact cache, dynamic micro-batcher) and
  serves batched requests of the selected query type on the selected
  substrate(s), reporting throughput per substrate plus the processor's
  ops/cycle (the paper's metric):

  - ``--query {joint,marginal,mpe,sample}`` — which query is served
    (``--mask-frac`` controls the evidence mask for marginal/mpe);
  - ``--substrate {numpy,leveled-jax,pallas,vliw-sim,all}`` — which
    backend serves it; every request flows through the same
    ``runtime.Server`` path regardless of the backend.

  Cross-substrate agreement is checked with
  :func:`repro.runtime.verify_parity` (including bit-exact VLIW
  fast-sim vs checked-sim conformance).

- ``lm``: batched LM serving — prefill a prompt batch then decode N
  tokens with the KV cache, on the smoke config (CPU-sized).

    PYTHONPATH=src python -m repro.launch.serve --mode spn --dataset nltcs
    PYTHONPATH=src python -m repro.launch.serve --mode spn --query mpe \\
        --substrate vliw-sim
    PYTHONPATH=src python -m repro.launch.serve --mode lm --arch qwen2-0.5b
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

SPN_SUBSTRATES = ("numpy", "leveled-jax", "pallas", "vliw-sim",
                  "vliw-mc")


def bench(fn, n_batches: int, batch: int) -> dict:
    """Time ``fn`` honestly: block on every iteration's result.

    Earlier revisions only blocked after the loop, so asynchronously
    dispatched iterations were untimed; per-iteration ``block_until_ready``
    makes ``us_per_batch`` the real request latency.
    """
    fn()  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(n_batches):
        jax.block_until_ready(fn())
    dt = time.perf_counter() - t0
    return {"us_per_batch": dt / n_batches * 1e6,
            "evals_per_s": batch * n_batches / dt}


def serve_spn(dataset: str, batch: int, n_batches: int,
              substrate: str = "all", query: str = "joint",
              mask_frac: float = 0.3,
              interpret: bool | None = None,
              cores: int = 2, topology: str = "xbar",
              link_width: int = 32,
              autotune: str | None = None,
              faults=None,
              trace_path: str | None = None,
              observe_path: str | None = None,
              metrics_dump: bool = False) -> dict:
    from .. import obs

    # ``--trace out.json``: record every request/compile/execute span and
    # write a Chrome trace_event file (open in https://ui.perfetto.dev);
    # if vliw-mc is served, the per-core simulated-cycle timelines land
    # in the same file on a second process track (virtual cycles clock).
    # The finally clause flushes a valid *partial* trace when the run
    # dies mid-flight (exception, Ctrl-C): write_chrome_trace always
    # emits complete JSON, so a crashed serve still leaves evidence.
    tracer = obs.trace.install() if trace_path else None
    trace_written = False
    try:
        out = _serve_spn_run(
            obs, dataset, batch, n_batches, substrate, query, mask_frac,
            interpret, cores, topology, link_width, autotune,
            faults, observe_path, metrics_dump, tracer)
        if tracer is not None:
            extra = out.pop("_trace_extra", [])
            n_events = obs.trace.write_chrome_trace(trace_path, tracer,
                                                    extra_events=extra)
            trace_written = True
            print(f"  wrote {trace_path}: {n_events} trace events "
                  f"({len(tracer.events)} wall-clock spans"
                  + (f", {len(extra)} cycle-timeline events" if extra
                     else "")
                  + ") — open in https://ui.perfetto.dev")
        return out
    finally:
        if tracer is not None:
            if not trace_written:
                n_events = obs.trace.write_chrome_trace(trace_path, tracer)
                print(f"  wrote PARTIAL trace {trace_path}: "
                      f"{n_events} events (run did not finish)")
            obs.trace.uninstall()


def _serve_spn_run(obs, dataset, batch, n_batches, substrate, query,
                   mask_frac, interpret, cores, topology, link_width,
                   autotune, faults, observe_path,
                   metrics_dump, tracer) -> dict:
    from ..core import learn
    from ..data import spn_datasets
    from ..queries import (mpe_backtrace, random_mask, sample_ancestral_jax,
                           sample_ancestral_numpy)
    from ..runtime import Server, verify_parity

    from ..core.multicore import named_interconnect

    X = spn_datasets.load(dataset, "train", 400)
    spn = learn.learn_spn(X, min_instances=64)
    server = Server(spn, interpret=interpret, cores=cores,
                    interconnect=named_interconnect(topology,
                                                    link_width=link_width),
                    autotune=autotune, faults=faults)
    names = SPN_SUBSTRATES if substrate in ("all", None) else (substrate,)
    print(f"SPN[{dataset}] query={query}: {server.prog.n_ops} ops, "
          f"{server.prog.num_levels} levels; substrates: {', '.join(names)}")
    if faults is not None:
        print(f"  fault injection: "
              f"{', '.join(server.resilience.injector.plan.specs())}")

    out: dict = {}
    if query == "sample":
        out["sampler-numpy"] = bench(
            lambda: sample_ancestral_numpy(spn, batch, seed=0),
            n_batches, batch)
        Xq = sample_ancestral_jax(spn, batch, seed=0)
        out["sampler-lax-scan"] = bench(
            lambda: sample_ancestral_jax(spn, batch, seed=0),
            n_batches, batch)
        assert np.array_equal(
            Xq, sample_ancestral_numpy(spn, batch, seed=0)), \
            "sampler substrate mismatch"
        Xq = np.asarray(Xq)
    else:
        Xq = spn_datasets.load(dataset, "test", batch)
        if query in ("marginal", "mpe"):
            Xq = random_mask(Xq, mask_frac, seed=0)
    for name, r in out.items():
        print(f"  {name:18s} {r['us_per_batch']:10.1f} us/batch "
              f"({r['evals_per_s']:12.0f} evals/s)")

    # every substrate serves the same batched requests through the Server
    score = "score-" if query == "sample" else ""
    for name in names:
        out[score + name] = bench(
            lambda n=name: server.query(Xq, query, n), n_batches, batch)
        r = out[score + name]
        extra = ""
        if name == "vliw-sim":
            meta = server.artifact(query, name).meta
            out["processor_sim"] = {"ops_per_cycle": meta["ops_per_cycle"],
                                    "cycles": meta["cycles"]}
            extra = (f"  [{meta['ops_per_cycle']:.2f} ops/cycle, "
                     f"{meta['cycles']} cycles/eval-batch]")
        elif name == "vliw-mc":
            meta = server.artifact(query, name).meta
            mc = meta["multicore"]
            out["processor_mc"] = {"cycles": meta["cycles"],
                                   "cores": mc["effective_cores"],
                                   "cut_values": mc["cut_values"],
                                   "topology": mc["topology"],
                                   "hop_cut": mc["hop_cut"]}
            extra = (f"  [{mc['effective_cores']} cores/"
                     f"{mc['topology']}, "
                     f"{meta['cycles']} cycles/eval-batch, "
                     f"{mc['comm']['values']} values crossed]")
            if "autotune" in meta:
                tune = meta["autotune"]
                out["processor_mc"]["autotune"] = tune
                out["processor_mc"]["cycles_per_eval"] = \
                    meta["cycles_per_eval"]
                extra += (f"\n  {'':18s} autotuned {tune['config']}: "
                          f"{meta['cycles_per_eval']:g} cycles/eval "
                          f"(default {tune['default_cycles_per_eval']:g}, "
                          f"{tune['evaluated']} trials)")
        elif name == "pallas":
            meta = server.artifact(query, name).meta
            out["pallas_interpret"] = meta["interpret"]
            extra = ("  [interpret-mode]" if meta["interpret"]
                     else f"  [compiled, {meta['backend']}]")
        print(f"  {score + name:18s} {r['us_per_batch']:10.1f} us/batch "
              f"({r['evals_per_s']:12.0f} evals/s){extra}")

    # cross-substrate agreement (includes bit-exact fast-vs-checked sim)
    devs = verify_parity(server, Xq[: min(len(Xq), 32)], query=query,
                         substrates=names)
    out["parity"] = devs
    print("  parity vs numpy oracle: " +
          ", ".join(f"{k}={v:.1e}" for k, v in devs.items()))

    if query == "mpe":
        art = server.artifact("mpe", names[0])
        with obs.trace.span("serve.decode", {"rows": 4}):
            assignment, log_value = mpe_backtrace(art.prog, Xq[:4])
        dec = server.query(assignment, "joint", names[0])
        # tie-robust self-check: the decoded assignment's max-product
        # value must reproduce the sweep's root value
        chk = server.query(assignment, "mpe", names[0])
        assert np.allclose(chk, log_value, atol=1e-4), "decode mismatch"
        out["mpe_example"] = {"evidence": Xq[:4].tolist(),
                              "assignment": assignment.tolist(),
                              "log_value": log_value.tolist()}
        print(f"  MPE decode self-check ok, e.g. row 0: "
              f"{Xq[0].tolist()} -> {assignment[0].tolist()} "
              f"(log p* = {log_value[0]:.4f}, log p = {dec[0]:.4f})")

    out["runtime_stats"] = server.stats()
    cs = out["runtime_stats"]["cache"]
    print(f"  artifact cache: {cs['hits']} hits / {cs['misses']} misses "
          f"({cs['size']} artifacts resident)")
    res = out["runtime_stats"]["resilience"]
    if res["enabled"]:
        fab = res["fabric"]
        print(f"  resilience: tick={res['tick']}, "
              f"healthy={fab['healthy_cores']}, "
              f"dead_cores={fab['dead_cores']}, "
              f"dead_links={fab['dead_links']}, "
              f"redirects={res['redirects']}")
        for h in res["history"]:
            print(f"    [{h['kind']}@t{h['tick']}] "
                  + ", ".join(f"{k}={v}" for k, v in h.items()
                              if k not in ("kind", "tick")))
    for key, mc in out["runtime_stats"]["multicore"].items():
        print(f"  multicore[{key}]: {mc['cores']} cores/{mc['topology']}, "
              f"{mc['cycles']} cycles, util={mc['core_utilization']}, "
              f"{mc['comm_values_per_batch']} values/batch crossed, "
              f"stalls={mc['stall_cycles']}, "
              f"barrier_idle={mc['barrier_idle_cycles']}, "
              f"link_stalls={mc['link_stall_cycles']}, "
              f"busiest_link={mc['busiest_link_occupancy']}")
    for key, tu in out["runtime_stats"].get("autotune", {}).items():
        if "config" in tu:
            print(f"  autotune[{key}]: {tu['config']} "
                  f"({tu['cycles_per_eval']:g} cycles/eval, default "
                  f"{tu['default_cycles_per_eval']:g}, "
                  f"{tu['evaluated']}/{tu['budget']} trials)")
        elif tu.get("core_decision", {}).get("reason") \
                == "single-core-fallback":
            d = tu["core_decision"]
            print(f"  autotune[{key}]: single-core fallback "
                  f"({d['single_core_cycles']} < "
                  f"{d['multicore_cycles']} cycles at "
                  f"{d['requested']} cores)")

    # cycle attribution: where every vliw artifact's cycles go, from the
    # meta attached at compile time (see repro.obs.attr)
    for art in server.cache.artifacts():
        attr = art.meta.get("attribution")
        if not attr or art.substrate != "vliw-mc":
            continue
        frac = attr["fractions"][attr["bottleneck"]]
        print(f"  attribution[{art.semiring}/{art.substrate}]: "
              f"bottleneck={attr['bottleneck']} "
              f"({attr['bottleneck_group']}-bound, {frac:.1%}), "
              f"roofline={attr['roofline']['bound']} "
              f"util={attr['roofline']['utilization']:.1%}")

    if observe_path:
        # ``--observe report.json``: one self-contained observatory
        # report — attribution tables, rooflines, SLO status, the
        # resilience snapshot, autotune decisions and the OpenMetrics
        # rendering (see repro.obs.export)
        report = obs.export.write_observatory_report(observe_path, server)
        out["observatory"] = {"path": observe_path,
                              "artifacts": len(report["attribution"])}
        print(f"  wrote {observe_path}: observatory report "
              f"({len(report['attribution'])} attributed artifacts)")

    if tracer is not None:
        extra: list = []
        if "vliw-mc" in names:
            # exact per-core cycle timeline from a 1-row lockstep probe
            # of the artifact actually served (cycle counts are value-
            # independent, so the probe IS the serving timeline)
            mcp = server.artifact(query, "vliw-mc").payload[0]
            recorder, res = obs.timeline.record_multicore(mcp)
            extra = recorder.to_chrome_events()
            totals = recorder.core_totals()
            assert all(sum(t.values()) == res.cycles
                       for t in totals.values()), \
                "per-core timeline does not cover the full run"
            out["cycle_timeline"] = {
                "cycles": res.cycles,
                "core_totals": {str(c): t for c, t in totals.items()}}
        out["_trace_extra"] = extra   # written by serve_spn's wrapper
    if metrics_dump:
        print("  metrics registry:")
        for line in obs.metrics.dump().splitlines():
            print(f"    {line}")
    out["metrics"] = obs.metrics.snapshot()
    return out


def serve_tenants(specs, batch: int, n_batches: int, *,
                  query: str = "marginal", mask_frac: float = 0.3,
                  cores: int = 8, topology: str = "mesh",
                  link_width: int = 32,
                  flush_max_age_s: float | None = None) -> dict:
    """Multi-tenant serving: N SPNs co-resident on one Server.

    ``specs``: ``DATASET[:QOS_WEIGHT]`` strings (e.g. ``nltcs kdd:2``).
    Each dataset's learned SPN becomes a tenant; with ``vliw-mc``
    enabled the machine's cores are apportioned into disjoint
    QoS-weighted blocks and every tenant serves from its own core set
    (one NoC, priced by the occupancy model). Reports per-tenant
    throughput, core allocation, parity vs the numpy oracle, and the
    tenancy section of ``Server.stats()``.

        PYTHONPATH=src python -m repro.launch.serve --mode spn \\
            --tenants nltcs kdd:2 --cores 8 --topology mesh
    """
    from ..core import learn
    from ..data import spn_datasets
    from ..queries import random_mask
    from ..runtime import Server, Tenant, verify_parity

    tenants: dict = {}
    eval_x: dict[str, np.ndarray] = {}
    for spec in specs:
        name, _, w = spec.partition(":")
        if name in tenants:
            raise ValueError(f"duplicate tenant dataset {name!r}")
        X = spn_datasets.load(name, "train", 400)
        spn = learn.learn_spn(X, min_instances=64)
        tenants[name] = Tenant(name, prog=None, spn=spn,
                               qos_weight=float(w) if w else 1.0)
        Xq = spn_datasets.load(name, "test", batch)
        if query in ("marginal", "mpe"):
            Xq = random_mask(Xq, mask_frac, seed=0)
        eval_x[name] = Xq

    from ..core.multicore import named_interconnect
    server = Server(tenants=tenants,
                    substrates=("numpy", "vliw-sim", "vliw-mc"),
                    cores=cores,
                    interconnect=named_interconnect(
                        topology, link_width=link_width),
                    flush_max_age_s=flush_max_age_s)
    print(f"tenants[{', '.join(tenants)}] query={query}: "
          f"{cores} cores/{topology}, mode="
          f"{server.stats()['tenancy']['mode']}")
    out: dict = {"tenants": {}}
    for name, t in ((n, server.registry.get(n)) for n in tenants):
        art = server.artifact(query, "vliw-mc", tenant=name)
        mc = art.meta["multicore"]
        r = bench(lambda n=name: server.query(
            eval_x[n], query, "vliw-mc", tenant=n), n_batches, batch)
        devs = verify_parity(server, eval_x[name][:32], query=query,
                             substrates=("vliw-mc", "vliw-sim"),
                             tenant=name)
        out["tenants"][name] = {
            "qos_weight": t.qos_weight,
            "cores": list(t.cores) if t.cores is not None else None,
            "core_labels": list(mc["core_labels"]),
            "cycles": art.meta["cycles"],
            "parity": devs, **r}
        print(f"  {name:10s} w={t.qos_weight:g} "
              f"cores={list(mc['core_labels'])} "
              f"{art.meta['cycles']:6d} cycles/eval-batch "
              f"{r['us_per_batch']:10.1f} us/batch "
              f"({r['evals_per_s']:12.0f} evals/s)  parity ok")
    # disjoint-core invariant: co-resident tenants never share a core
    seen: set = set()
    for name, entry in out["tenants"].items():
        labels = set(entry["core_labels"])
        overlap = seen & labels
        assert not overlap or len(tenants) > cores, \
            f"tenant {name} shares cores {sorted(overlap)}"
        seen |= labels
    rb = server.rebalance(query=query)
    if rb is not None:
        print(f"  rebalance: applied={rb['applied']} "
              f"makespan {rb['makespan']:g} -> "
              f"{rb.get('candidate_makespan', rb['makespan']):g}")
        out["rebalance"] = {k: v for k, v in rb.items()
                            if k != "pressure"}
    stats = server.stats()
    out["tenancy"] = stats["tenancy"]
    out["multicore_keys"] = sorted(stats["multicore"])
    print(f"  stats multicore keys: {out['multicore_keys']}")
    return out


def serve_lm(arch: str, batch: int, prompt_len: int, gen_len: int) -> dict:
    from ..configs.base import get_smoke_config
    from ..models import api

    cfg = get_smoke_config(arch)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (batch, prompt_len)),
                          jnp.int32)
    cache = api.init_cache(cfg, batch, prompt_len + gen_len)

    prefill = jax.jit(lambda p, t, c: api.prefill(cfg, p, t, c))
    decode = jax.jit(lambda p, c, t: api.decode_step(cfg, p, c, t))

    t0 = time.time()
    logits, cache = prefill(params, prompts, cache)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    toks = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    t0 = time.time()
    outs = [toks]
    for _ in range(gen_len - 1):
        logits, cache = decode(params, cache, toks)
        toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        outs.append(toks)
    jax.block_until_ready(toks)
    t_decode = time.time() - t0
    text = jnp.concatenate(outs, axis=1)
    tok_s = batch * (gen_len - 1) / max(t_decode, 1e-9)
    print(f"LM[{arch}] prefill {batch}x{prompt_len} in {t_prefill*1e3:.1f} ms; "
          f"decode {gen_len-1} steps @ {tok_s:.0f} tok/s")
    return {"prefill_s": t_prefill, "decode_s": t_decode,
            "tokens": np.asarray(text)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["spn", "lm"], default="spn")
    ap.add_argument("--query", choices=["joint", "marginal", "mpe", "sample"],
                    default="joint",
                    help="SPN query type served (see repro.queries)")
    ap.add_argument("--substrate",
                    choices=list(SPN_SUBSTRATES) + ["all"], default="all",
                    help="execution substrate serving the SPN queries "
                         "(see repro.runtime.substrates)")
    ap.add_argument("--mask-frac", type=float, default=0.3,
                    help="fraction of variables marginalized for "
                         "marginal/mpe queries")
    ap.add_argument("--interpret", choices=["auto", "on", "off"],
                    default="auto",
                    help="Pallas kernel mode: 'auto' compiles on TPU and "
                         "interprets elsewhere; 'on'/'off' force it")
    ap.add_argument("--cores", type=int, default=2,
                    help="core count for the vliw-mc substrate "
                         "(N replicated VLIW cores + interconnect)")
    ap.add_argument("--topology",
                    choices=["xbar", "ring", "mesh", "torus"],
                    default="xbar",
                    help="NoC topology of the vliw-mc interconnect: ideal "
                         "crossbar, or a physical ring/mesh/torus with "
                         "per-link contention + topology-aware placement")
    ap.add_argument("--link-width", type=int, default=32,
                    help="values serialized per cycle per NoC link")
    ap.add_argument("--autotune", default="off", metavar="MODE",
                    help="per-SPN compiler autotuning for vliw-mc: 'off' "
                         "(default), 'cached' (reuse any in-process tune "
                         "for this SPN, else tune once at the default "
                         "budget), or 'budget=N' (fast-sim-guided search "
                         "over partition/schedule/interleave knobs, N "
                         "compile+probe trials)")
    ap.add_argument("--inject-faults", default=None, metavar="SPEC",
                    nargs="+",
                    help="deterministic fabric fault plan for chaos "
                         "drills: core=N[@tT] (kill a core), "
                         "link=A-B[@tT] (kill a NoC link both ways), "
                         "slow=A-BxF[@tT] (serialize a link F x slower), "
                         "flip[@tT] (one transient execute corruption, "
                         "detected + retried); ticks count batched "
                         "executes. The server degrades and falls back "
                         "instead of failing (see "
                         "repro.runtime.resilience)")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="record a Chrome trace_event file of the run: "
                         "wall-clock request/compile/execute spans plus "
                         "(for vliw-mc) per-core simulated-cycle "
                         "timelines; open in https://ui.perfetto.dev")
    ap.add_argument("--observe", default=None, metavar="OUT.json",
                    help="write a self-contained observatory report: "
                         "per-artifact cycle attribution (issue/stall/"
                         "barrier/link/inject per core + roofline + "
                         "named bottleneck), SLO burn-rate status, the "
                         "resilience snapshot, autotune decisions, and "
                         "an OpenMetrics rendering of the metrics "
                         "registry (see repro.obs.export)")
    ap.add_argument("--metrics-dump", action="store_true",
                    help="print the metrics registry (counters, gauges, "
                         "latency percentiles) after serving")
    ap.add_argument("--tenants", default=None, metavar="DS[:W]",
                    nargs="+",
                    help="multi-tenant serving: one dataset per tenant "
                         "with an optional QoS weight (e.g. "
                         "'--tenants nltcs kdd:2'). All tenants share "
                         "one Server; on vliw-mc they are co-scheduled "
                         "onto disjoint QoS-weighted core blocks of the "
                         "--cores/--topology fabric")
    ap.add_argument("--dataset", default="nltcs")
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--batches", type=int, default=20)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    args = ap.parse_args()
    if args.mode == "spn" and args.tenants:
        serve_tenants(args.tenants, args.batch, args.batches,
                      query=("marginal" if args.query == "joint"
                             else args.query),
                      mask_frac=args.mask_frac, cores=args.cores,
                      topology=(args.topology if args.topology != "xbar"
                                else "mesh"),
                      link_width=args.link_width)
    elif args.mode == "spn":
        serve_spn(args.dataset, args.batch, args.batches,
                  substrate=args.substrate, query=args.query,
                  mask_frac=args.mask_frac,
                  interpret={"auto": None, "on": True,
                             "off": False}[args.interpret],
                  cores=args.cores, topology=args.topology,
                  link_width=args.link_width,
                  autotune=(None if args.autotune == "off"
                            else args.autotune),
                  faults=args.inject_faults,
                  trace_path=args.trace, observe_path=args.observe,
                  metrics_dump=args.metrics_dump)
    else:
        serve_lm(args.arch, min(args.batch, 8), args.prompt_len,
                 args.gen_len)


if __name__ == "__main__":
    main()
