"""Serving driver.

Two workloads:

- ``spn``: the paper's workload — batched SPN inference. Learns (or
  loads) an SPN, compiles it three ways (leveled JAX executor, Pallas
  kernel, VLIW processor program) and serves batched requests, reporting
  throughput per backend plus the processor's ops/cycle (the paper's
  metric).
- ``lm``: batched LM serving — prefill a prompt batch then decode N
  tokens with the KV cache, on the smoke config (CPU-sized).

    PYTHONPATH=src python -m repro.launch.serve --mode spn --dataset nltcs
    PYTHONPATH=src python -m repro.launch.serve --mode lm --arch qwen2-0.5b
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def serve_spn(dataset: str, batch: int, n_batches: int,
              use_kernel: bool = True) -> dict:
    from ..core import executors, learn, program
    from ..core.compiler.pipeline import compile_program
    from ..core.processor import sim
    from ..core.processor.config import PTREE
    from ..data import spn_datasets
    from ..kernels.spn_eval import spn_eval

    X = spn_datasets.load(dataset, "train", 400)
    net = learn.learn_spn(X, min_instances=64)
    prog = program.lower(net)
    vprog = compile_program(prog, PTREE)
    print(f"SPN[{dataset}]: {prog.n_ops} ops, {prog.num_levels} levels; "
          f"Ptree {vprog.ops_per_cycle:.2f} ops/cycle")

    Xq = spn_datasets.load(dataset, "test", batch)
    leaves = jnp.asarray(prog.leaves_from_evidence(Xq), jnp.float32)

    # warmup + timed loops
    out = {}
    def bench(name, fn):
        fn()  # compile
        t0 = time.time()
        for _ in range(n_batches):
            r = fn()
        jax.block_until_ready(r)
        dt = time.time() - t0
        out[name] = {"us_per_batch": dt / n_batches * 1e6,
                     "evals_per_s": batch * n_batches / dt}
        print(f"  {name:18s} {out[name]['us_per_batch']:10.1f} us/batch "
              f"({out[name]['evals_per_s']:12.0f} evals/s)")
        return r

    r_lvl = bench("leveled-jax", lambda: executors.eval_leveled(prog, leaves, None, True))
    if use_kernel:
        r_ker = bench("pallas-kernel", lambda: spn_eval(prog, leaves, log_domain=True))
        err = float(jnp.abs(r_ker - r_lvl).max())
        print(f"  kernel vs leveled max |Δ|: {err:.2e}")
    res = sim.simulate(vprog, prog, Xq[:8], PTREE)
    ref = executors.eval_ops_numpy(prog, np.asarray(prog.leaves_from_evidence(Xq[:8])))
    assert np.allclose(res.root_values, ref, rtol=1e-4), "processor mismatch"
    out["processor_sim"] = {"ops_per_cycle": res.ops_per_cycle,
                            "cycles": res.cycles}
    print(f"  processor-sim      {res.ops_per_cycle:.2f} ops/cycle "
          f"({res.cycles} cycles/eval-batch)")
    return out


def serve_lm(arch: str, batch: int, prompt_len: int, gen_len: int) -> dict:
    from ..configs.base import get_smoke_config
    from ..models import api

    cfg = get_smoke_config(arch)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (batch, prompt_len)),
                          jnp.int32)
    cache = api.init_cache(cfg, batch, prompt_len + gen_len)

    prefill = jax.jit(lambda p, t, c: api.prefill(cfg, p, t, c))
    decode = jax.jit(lambda p, c, t: api.decode_step(cfg, p, c, t))

    t0 = time.time()
    logits, cache = prefill(params, prompts, cache)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    toks = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    t0 = time.time()
    outs = [toks]
    for _ in range(gen_len - 1):
        logits, cache = decode(params, cache, toks)
        toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        outs.append(toks)
    jax.block_until_ready(toks)
    t_decode = time.time() - t0
    text = jnp.concatenate(outs, axis=1)
    tok_s = batch * (gen_len - 1) / max(t_decode, 1e-9)
    print(f"LM[{arch}] prefill {batch}x{prompt_len} in {t_prefill*1e3:.1f} ms; "
          f"decode {gen_len-1} steps @ {tok_s:.0f} tok/s")
    return {"prefill_s": t_prefill, "decode_s": t_decode,
            "tokens": np.asarray(text)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["spn", "lm"], default="spn")
    ap.add_argument("--dataset", default="nltcs")
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--batches", type=int, default=20)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    args = ap.parse_args()
    if args.mode == "spn":
        serve_spn(args.dataset, args.batch, args.batches)
    else:
        serve_lm(args.arch, min(args.batch, 8), args.prompt_len,
                 args.gen_len)


if __name__ == "__main__":
    main()
