"""Step functions: train / prefill / decode, shared by dry-run + drivers."""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..models import api
from ..optim import adamw


def make_train_step(cfg: ArchConfig, opt_cfg: adamw.AdamWConfig,
                    *, remat: bool = True):
    def train_step(params, opt_state, batch):
        def lossf(p):
            return api.loss_fn(cfg, p, batch, remat=remat)
        (loss, metrics), grads = jax.value_and_grad(
            lossf, has_aux=True)(params)
        params, opt_state, opt_metrics = adamw.apply_gradients(
            opt_cfg, params, grads, opt_state)
        return params, opt_state, dict(metrics, loss=loss, **opt_metrics)
    return train_step


def make_grad_accum_step(cfg: ArchConfig, opt_cfg: adamw.AdamWConfig,
                         n_micro: int, *, remat: bool = True):
    """Gradient accumulation: batch's leading dim is split into n_micro."""
    def train_step(params, opt_state, batch):
        def lossf(p, mb):
            return api.loss_fn(cfg, p, mb, remat=remat)

        def micro(carry, mb):
            gsum, lsum = carry
            (loss, _), g = jax.value_and_grad(lossf, has_aux=True)(params, mb)
            return (jax.tree.map(jnp.add, gsum, g), lsum + loss), None

        micro_batches = jax.tree.map(
            lambda x: x.reshape((n_micro, x.shape[0] // n_micro)
                                + x.shape[1:]), batch)
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gsum, lsum), _ = jax.lax.scan(micro, (zeros, 0.0), micro_batches)
        grads = jax.tree.map(lambda g: g / n_micro, gsum)
        params, opt_state, om = adamw.apply_gradients(
            opt_cfg, params, grads, opt_state)
        return params, opt_state, dict(loss=lsum / n_micro, **om)
    return train_step


def make_prefill_step(cfg: ArchConfig):
    def prefill_step(params, tokens, cache, **extras):
        return api.prefill(cfg, params, tokens, cache, **extras)
    return prefill_step


def make_decode_step(cfg: ArchConfig):
    def decode_step(params, cache, tokens):
        return api.decode_step(cfg, params, cache, tokens)
    return decode_step
