"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this builds the production mesh (16×16 single-pod or
2×16×16 multi-pod), the sharding plan, ShapeDtypeStruct stand-ins for
params / optimizer / inputs (no allocation), jits the step function with
explicit in/out shardings, and runs ``.lower().compile()``. Success
proves the distribution config is coherent; the compiled artifact yields
``memory_analysis`` / ``cost_analysis`` / collective bytes for §Roofline.

CLI:
    python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k
    python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
"""
# The VERY FIRST executable lines (before any jax import, which locks the
# device count): 512 placeholder host devices for the production meshes.
import os  # noqa: E402
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse
import json
import time
import traceback
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import (ARCH_IDS, SHAPES, ArchConfig, ShapeConfig,
                            applicable_shapes, get_config)
from ..models import api
from ..models.common import reset_act_rules, set_act_rules
from ..optim import adamw
from ..parallel.plan import Planner
from . import hlo_analysis, step_fns
from .mesh import make_production_mesh


# ---------------------------------------------------------------------------
# model-FLOPs estimators (6·N·D / 2·N·D with MoE active-param correction)
# ---------------------------------------------------------------------------
def param_counts(cfg: ArchConfig) -> tuple[float, float]:
    """(total, active) parameter counts from the shape-only param tree."""
    tree = api.param_specs(cfg)
    paths, _ = jax.tree_util.tree_flatten_with_path(tree)
    total = active = 0.0
    for kp, leaf in paths:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        n = float(np.prod(leaf.shape))
        total += n
        if "moe/" in path and any(s in path for s in ("gate", "up", "down")):
            active += n * cfg.top_k / max(cfg.n_experts, 1)
        else:
            active += n
    return total, active


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    _, active = param_counts(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return hlo_analysis.model_flops_train(active, tokens)
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return hlo_analysis.model_flops_infer(active, tokens)
    tokens = shape.global_batch * 1          # decode: one token per seq
    return hlo_analysis.model_flops_infer(active, tokens)


# ---------------------------------------------------------------------------
# cell construction
# ---------------------------------------------------------------------------
def _replicated_like(mesh, tree: Any) -> Any:
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


def build_cell(cfg: ArchConfig, shape: ShapeConfig, mesh,
               opt_cfg: adamw.AdamWConfig | None = None):
    """Returns (jitted_fn, arg_specs tuple) ready to lower."""
    planner = Planner(cfg, mesh)
    param_sds = api.param_specs(cfg)
    p_sh = planner.params_sharding(param_sds)

    if shape.kind == "train":
        opt_cfg = opt_cfg or adamw.AdamWConfig()
        opt_sds = jax.eval_shape(adamw.init_state, param_sds)
        o_sh = {"m": p_sh, "v": p_sh,
                "step": NamedSharding(mesh, P())}
        batch_sds = api.train_batch_specs(cfg, shape)
        b_sh = planner.batch_sharding(batch_sds)
        fn = step_fns.make_train_step(cfg, opt_cfg)
        out_sds = jax.eval_shape(fn, param_sds, opt_sds, batch_sds)
        out_sh = (p_sh, o_sh, _replicated_like(mesh, out_sds[2]))
        jf = jax.jit(fn, in_shardings=(p_sh, o_sh, b_sh),
                     out_shardings=out_sh, donate_argnums=(0, 1))
        return jf, (param_sds, opt_sds, batch_sds), planner

    if shape.kind == "prefill":
        specs = api.prefill_specs(cfg, shape)
        cache_sds = specs.pop("cache")
        tokens_sds = specs.pop("tokens")
        extras_sds = specs                      # frames / patch_embeds
        c_sh = planner.cache_sharding(cache_sds)
        t_sh = planner.batch_sharding(tokens_sds)
        e_sh = planner.batch_sharding(extras_sds)

        def fn(params, tokens, cache, extras):
            return api.prefill(cfg, params, tokens, cache, **extras)

        out_sds = jax.eval_shape(fn, param_sds, tokens_sds, cache_sds,
                                 extras_sds)
        out_sh = (planner.batch_sharding(out_sds[0]), c_sh)
        jf = jax.jit(fn, in_shardings=(p_sh, t_sh, c_sh, e_sh),
                     out_shardings=out_sh, donate_argnums=(2,))
        return jf, (param_sds, tokens_sds, cache_sds, extras_sds), planner

    # decode
    specs = api.decode_specs(cfg, shape)
    cache_sds, tokens_sds = specs["cache"], specs["tokens"]
    c_sh = planner.cache_sharding(cache_sds)
    t_sh = planner.batch_sharding(tokens_sds)
    fn = step_fns.make_decode_step(cfg)
    out_sds = jax.eval_shape(fn, param_sds, cache_sds, tokens_sds)
    out_sh = (planner.batch_sharding(out_sds[0]), c_sh)
    jf = jax.jit(fn, in_shardings=(p_sh, c_sh, t_sh),
                 out_shardings=out_sh, donate_argnums=(1,))
    return jf, (param_sds, cache_sds, tokens_sds), planner


def run_cell(arch_id: str, shape_name: str, *, multi_pod: bool = False,
             verbose: bool = True) -> dict:
    """Lower + compile one cell; return the roofline record."""
    cfg = get_config(arch_id)
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec: dict[str, Any] = {"arch": arch_id, "shape": shape_name,
                           "mesh": mesh_name, "status": "ok"}
    if shape_name not in applicable_shapes(cfg):
        rec["status"] = "skipped"
        rec["reason"] = ("full-attention arch skips long_500k"
                         if shape_name == "long_500k" else "not applicable")
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    jf, arg_sds, planner = build_cell(cfg, shape, mesh)
    token = set_act_rules(planner.act_rules())
    try:
        with mesh:
            lowered = jf.lower(*arg_sds)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
    finally:
        reset_act_rules(token)

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    acc = hlo_analysis.analyze_hlo(hlo)
    chips = int(np.prod(list(mesh.shape.values())))
    flops_total = float(acc["flops"])
    bytes_total = float(acc["hbm_bytes"])
    coll_total = float(sum(acc["collective_bytes"].values()))
    roof = hlo_analysis.Roofline(
        flops=flops_total, hbm_bytes=bytes_total,
        collective_bytes=coll_total, chips=chips)
    mf = model_flops(cfg, shape)
    rec.update(
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        memory=_mem_dict(mem),
        cost_analysis_flops=float(cost.get("flops", 0.0)),
        hlo_dot_flops=float(acc["dot_flops"]),
        transcendentals=float(acc["transcendentals"]),
        collectives={"bytes": acc["collective_bytes"],
                     "count": acc["collective_count"]},
        roofline=roof.as_dict(),
        model_flops=mf,
        useful_flops_ratio=(mf / (flops_total * chips)
                            if flops_total else None),
    )
    if verbose:
        m = rec["memory"]
        print(f"[{arch_id} × {shape_name} × {mesh_name}] "
              f"lower {t_lower:.1f}s compile {t_compile:.1f}s | "
              f"args {m.get('argument_size_gib', 0):.2f} GiB "
              f"temp {m.get('temp_size_gib', 0):.2f} GiB | "
              f"t_comp {roof.t_compute:.4f}s t_mem {roof.t_memory:.4f}s "
              f"t_coll {roof.t_collective:.4f}s → {roof.bottleneck}")
    return rec


def _mem_dict(mem) -> dict:
    out = {}
    for name in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        v = getattr(mem, name, None)
        if v is not None:
            out[name] = int(v)
            out[name.replace("_in_bytes", "_gib")] = round(v / 2 ** 30, 3)
    return out


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------
def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    cells: list[tuple[str, str]] = []
    if args.all:
        cells = [(a, s) for a in ARCH_IDS for s in SHAPES]
    else:
        if not args.arch or not args.shape:
            ap.error("--arch/--shape or --all required")
        cells = [(args.arch, args.shape)]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch, shp in cells:
        for mp in meshes:
            name = f"{arch}_{shp}_{'multi' if mp else 'single'}"
            try:
                rec = run_cell(arch, shp, multi_pod=mp)
            except Exception as e:
                traceback.print_exc()
                rec = {"arch": arch, "shape": shp,
                       "mesh": "pod2x16x16" if mp else "pod16x16",
                       "status": "error", "error": f"{type(e).__name__}: {e}"}
                failures += 1
            with open(os.path.join(args.out, name + ".json"), "w") as f:
                json.dump(rec, f, indent=1, default=str)
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
