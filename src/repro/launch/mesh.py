"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init to make the 256/512-chip shapes constructible on a CPU host.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int | None = None, model: int = 1):
    """Small mesh over the actually-present devices (tests/examples)."""
    n = jax.device_count()
    data = n // model if data is None else data
    return jax.make_mesh((data, model), ("data", "model"))
