"""Synthetic stand-ins for the Lowd & Davis / UCI binary benchmark suite.

The paper benchmarks SPNs "trained on a suite of standard benchmarks
[3], [7]" — the 20-datasets density-estimation suite (NLTCS, MSNBC, ...).
This container has no network access, so we synthesize datasets with the
*same variable counts* from deterministic teacher distributions (mixtures
of tree-structured Bernoulli networks), seeded per dataset name. LearnSPN
on these produces irregular DAGs of realistic shape/size, which is what
the processor benchmarks need.
"""
from __future__ import annotations

import hashlib

import numpy as np

# name -> number of binary variables (faithful to the public suite)
DATASETS: dict[str, int] = {
    "nltcs": 16, "msnbc": 17, "kdd": 64, "plants": 69, "baudio": 100,
    "jester": 100, "bnetflix": 100, "accidents": 111, "tretail": 135,
    "pumsb_star": 163, "dna": 180, "kosarek": 190, "msweb": 294,
    "book": 500, "tmovie": 500, "cwebkb": 839, "cr52": 889,
    "c20ng": 910, "bbc": 1058, "ad": 1556,
}

# the subset used by the throughput benchmarks (small/medium, fast to learn)
BENCH_SUITE = ["nltcs", "msnbc", "kdd", "plants", "baudio", "jester", "bnetflix"]

_SPLIT_SALT = {"train": 0, "valid": 1, "test": 2}


def _seed(name: str, split: str) -> int:
    h = hashlib.sha256(f"{name}/{split}".encode()).digest()
    return int.from_bytes(h[:8], "little") ^ _SPLIT_SALT[split]


def _teacher(name: str, num_vars: int):
    """Deterministic teacher: mixture of tree-structured Bernoulli nets."""
    rng = np.random.default_rng(_seed(name, "train") & 0x7FFFFFFF)
    k = int(rng.integers(3, 8))
    mix = rng.dirichlet(np.ones(k) * 2.0)
    parents, roots_p, cpts = [], [], []
    for _ in range(k):
        par = np.array([-1] + [int(rng.integers(0, i)) for i in range(1, num_vars)])
        order = rng.permutation(num_vars)              # random var relabeling
        parents.append((par, order))
        roots_p.append(float(rng.beta(0.6, 0.6)))
        cpts.append(rng.beta(0.5, 0.5, size=(num_vars, 2)))
    return mix, parents, roots_p, cpts


def sample(name: str, n: int, split: str = "train") -> np.ndarray:
    """Sample ``n`` binary rows from the teacher for ``name``/``split``."""
    num_vars = DATASETS[name]
    mix, parents, roots_p, cpts = _teacher(name, num_vars)
    rng = np.random.default_rng(_seed(name, split))
    comp = rng.choice(len(mix), size=n, p=mix)
    X = np.zeros((n, num_vars), dtype=np.int8)
    for c in range(len(mix)):
        rows = np.flatnonzero(comp == c)
        if not len(rows):
            continue
        par, order = parents[c]
        vals = np.zeros((len(rows), num_vars), dtype=np.int8)
        vals[:, 0] = rng.random(len(rows)) < roots_p[c]
        for i in range(1, num_vars):
            pv = vals[:, par[i]]
            pr = cpts[c][i, pv.astype(np.int64)]
            vals[:, i] = rng.random(len(rows)) < pr
        X[rows] = vals[:, np.argsort(order)]
    return X


_DEFAULT_N = {"train": 2000, "valid": 500, "test": 500}


def load(name: str, split: str = "train", n: int | None = None) -> np.ndarray:
    if name not in DATASETS:
        raise KeyError(f"unknown dataset {name!r}; options: {sorted(DATASETS)}")
    return sample(name, n or _DEFAULT_N[split], split)
