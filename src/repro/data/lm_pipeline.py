"""Deterministic, checkpointable LM token pipeline.

Synthetic corpus (no network): a seeded Zipf-ish unigram mixture with
Markov bigram structure so losses actually *decrease* during the example
training runs. The pipeline state is just ``(seed, step)`` — saved in the
checkpoint extras, so restart resumes mid-epoch exactly (fault-tolerance
requirement: data order is part of the training state).

Multi-host contract: ``batch_for_step`` produces the *global* batch
deterministically, and each process slices ``[proc*per_proc, ...)`` — the
same code path a 1000-node run uses, degenerate on one host.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0


class TokenPipeline:
    def __init__(self, cfg: PipelineConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab
        # unigram: zipf-ish; bigram: each token prefers a few successors
        self._uni = 1.0 / np.arange(1, v + 1) ** 1.1
        self._uni /= self._uni.sum()
        self._succ = rng.integers(0, v, size=(v, 4))

    def batch_for_step(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        B, S, v = cfg.global_batch, cfg.seq_len, cfg.vocab
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = rng.choice(v, size=B, p=self._uni)
        # vectorized Markov walk: 70% pick a preferred successor, 30% unigram
        for t in range(1, S + 1):
            prefer = self._succ[toks[:, t - 1],
                                rng.integers(0, 4, size=B)]
            fresh = rng.choice(v, size=B, p=self._uni)
            toks[:, t] = np.where(rng.random(B) < 0.7, prefer, fresh)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def local_slice(self, batch: dict[str, np.ndarray], process_index: int,
                    process_count: int) -> dict[str, np.ndarray]:
        B = self.cfg.global_batch
        assert B % process_count == 0
        per = B // process_count
        lo = process_index * per
        return {k: v[lo: lo + per] for k, v in batch.items()}

    # checkpointable state is (seed, step): nothing else to save
    def state_dict(self, step: int) -> dict:
        return {"seed": self.cfg.seed, "step": step}
