from . import spn_datasets  # noqa: F401
