"""int8 error-feedback gradient compression for the DP all-reduce.

The classic distributed-optimization trick: before the data-parallel
gradient reduction, each shard quantizes its gradient to int8 with a
per-tensor scale; the quantization residual is kept locally and added
back the next step (error feedback keeps the scheme unbiased over time).
The reduction then moves 1/4 of the bytes.

Two entry points:

- :func:`compress_tree` — pure per-leaf quantize→dequantize with residual
  carry. The trainer applies it to local gradients before the (implicit)
  DP mean; GSPMD still reduces f32, but the *information content* matches
  the compressed scheme, so convergence behaviour is faithful and testable.
- :func:`compressed_mean_shardmap` — the explicit-collective variant: a
  ``shard_map`` over the DP axes whose psum operands are the dequantized
  int8 values; use when the mesh is real and the collective bytes matter.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def quantize(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_roundtrip(x: jnp.ndarray, residual: jnp.ndarray
                       ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Quantize (x + residual); return (dequantized, new residual)."""
    t = x.astype(jnp.float32) + residual
    q, s = quantize(t)
    d = dequantize(q, s)
    return d, t - d


def init_residuals(grads: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_tree(grads: Any, residuals: Any) -> tuple[Any, Any]:
    """Error-feedback int8 roundtrip over a whole gradient pytree."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residuals)
    out = [compress_roundtrip(g, r) for g, r in zip(flat_g, flat_r)]
    return (jax.tree.unflatten(treedef, [o[0] for o in out]),
            jax.tree.unflatten(treedef, [o[1] for o in out]))


def compressed_mean_shardmap(mesh: Mesh, axes, grad_leaf: jnp.ndarray,
                             residual_leaf: jnp.ndarray):
    """Explicit compressed DP-mean of one leaf.

    ``grad_leaf`` has a leading DP-shard axis of size = prod(axes sizes)
    (per-replica partial gradients); returns (mean (unsharded), residual').
    """
    axes_t = axes if isinstance(axes, tuple) else (axes,)
    n = 1
    for a in axes_t:
        n *= mesh.shape[a]

    @functools.partial(
        jax.shard_map, mesh=mesh,
        in_specs=(P(axes_t if len(axes_t) > 1 else axes_t[0]),
                  P(axes_t if len(axes_t) > 1 else axes_t[0])),
        out_specs=(P(), P(axes_t if len(axes_t) > 1 else axes_t[0])))
    def body(g, r):
        d, r_new = compress_roundtrip(g[0], r[0])
        total = jax.lax.psum(d, axes_t) / n
        return total, r_new[None]

    return body(grad_leaf, residual_leaf)
