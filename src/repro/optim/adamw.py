"""AdamW with global-norm clipping, warmup-cosine schedule, ZeRO-1 sharding.

Functional, pytree-native (no optax dependency). Moments are f32 and
*inherit the parameter sharding* — with FSDP-sharded params this IS
ZeRO-1/3: each device holds only its shard of m/v/master weights.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = cfg.lr * (step + 1) / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def init_state(params: Any) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_gradients(cfg: AdamWConfig, params: Any, grads: Any,
                    state: dict) -> tuple[Any, dict, dict]:
    """One AdamW step; returns (params', state', metrics)."""
    step = state["step"]
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    lr = schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** (step.astype(jnp.float32) + 1)
    bc2 = 1 - b2 ** (step.astype(jnp.float32) + 1)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        if p.ndim >= 2:                      # decoupled decay, matrices only
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_state = {"m": jax.tree.unflatten(treedef, [o[1] for o in out]),
                 "v": jax.tree.unflatten(treedef, [o[2] for o in out]),
                 "step": step + 1}
    return params, new_state, {"grad_norm": gnorm, "lr": lr}


def state_specs(params_specs: Any) -> dict:
    """Optimizer-state ShapeDtypeStructs/shardings mirroring the params."""
    return {"m": params_specs, "v": params_specs, "step": None}
