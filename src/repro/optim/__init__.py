from .adamw import AdamWConfig, apply_gradients, global_norm, init_state, schedule
from . import compress

__all__ = ["AdamWConfig", "apply_gradients", "global_norm", "init_state",
           "schedule", "compress"]
