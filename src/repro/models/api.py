"""Unified model API — family dispatch + per-shape input specs.

Everything downstream (launcher, dry-run, trainer, server, tests) talks
to models through this module:

- ``init_params(cfg, key)`` / ``param_specs(cfg)``
- ``loss_fn(cfg, params, batch)``           (train shapes)
- ``prefill(cfg, params, tokens, cache, **extras)``
- ``decode_step(cfg, params, cache, tokens)``
- ``cache_specs(cfg, batch, max_len)``
- ``input_specs(cfg, shape)``               ShapeDtypeStruct stand-ins
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeConfig
from . import encdec, hybrid, ssm, transformer, vlm
from .common import Params

_FAMILIES = {
    "dense": transformer,
    "moe": transformer,
    "vlm": vlm,
    "encdec": encdec,
    "ssm": ssm,
    "hybrid": hybrid,
}


def family_module(cfg: ArchConfig):
    return _FAMILIES[cfg.family]


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------
def init_params(cfg: ArchConfig, key) -> Params:
    return family_module(cfg).init_params(cfg, key)


def param_specs(cfg: ArchConfig) -> Params:
    """Parameter ShapeDtypeStructs without allocating (for the dry-run)."""
    return jax.eval_shape(
        functools.partial(init_params, cfg), jax.random.key(0))


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------
def loss_fn(cfg: ArchConfig, params: Params, batch: dict, **kw):
    return family_module(cfg).loss_fn(cfg, params, batch, **kw)


def prefill(cfg: ArchConfig, params: Params, tokens, cache, **extras):
    return family_module(cfg).prefill(cfg, params, tokens, cache, **extras)


def decode_step(cfg: ArchConfig, params: Params, cache, tokens):
    return family_module(cfg).decode_step(cfg, params, cache, tokens)


def cache_specs(cfg: ArchConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16):
    if cfg.family == "ssm":
        return ssm.mamba_cache_specs(cfg, batch)
    return family_module(cfg).cache_specs(cfg, batch, max_len, dtype)


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    if cfg.family == "ssm":
        return ssm.init_mamba_cache(cfg, batch)
    return family_module(cfg).init_cache(cfg, batch, max_len, dtype)


# ---------------------------------------------------------------------------
# input specs per assigned shape (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------
def _i32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _bf16(shape):
    return jax.ShapeDtypeStruct(shape, jnp.bfloat16)


def train_batch_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        return {"frames": _bf16((B, cfg.enc_ctx, cfg.d_model)),
                "tokens": _i32((B, S)), "labels": _i32((B, S))}
    if cfg.family == "vlm":
        St = S - cfg.n_img_tokens
        return {"patch_embeds": _bf16((B, cfg.n_img_tokens, cfg.d_model)),
                "tokens": _i32((B, St)), "labels": _i32((B, St))}
    return {"tokens": _i32((B, S)), "labels": _i32((B, S))}


def prefill_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """kwargs specs for ``prefill`` (tokens + cache + modality extras)."""
    B, S = shape.global_batch, shape.seq_len
    out: dict[str, Any] = {"cache": cache_specs(cfg, B, S)}
    if cfg.family == "encdec":
        out["tokens"] = _i32((B, S))
        out["frames"] = _bf16((B, cfg.enc_ctx, cfg.d_model))
    elif cfg.family == "vlm":
        out["tokens"] = _i32((B, S - cfg.n_img_tokens))
        out["patch_embeds"] = _bf16((B, cfg.n_img_tokens, cfg.d_model))
    else:
        out["tokens"] = _i32((B, S))
    return out


def decode_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """kwargs specs for ``decode_step``: one new token, cache of seq_len."""
    B, S = shape.global_batch, shape.seq_len
    return {"cache": cache_specs(cfg, B, S), "tokens": _i32((B, 1))}


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    if shape.kind == "train":
        return {"batch": train_batch_specs(cfg, shape)}
    if shape.kind == "prefill":
        return prefill_specs(cfg, shape)
    return decode_specs(cfg, shape)
