"""Mamba2 (SSD — state-space duality) blocks and LM.

Training/prefill uses the chunked SSD algorithm (Mamba2 paper §6): the
sequence is cut into chunks of length Q; within a chunk the recurrence is
computed as a masked attention-like matmul (quadratic in Q only), and a
per-chunk state (H, P, N) is carried across chunks with ``lax.scan`` —
linear in sequence length and entirely matmul-based (MXU-friendly).

Decode is the O(1) recurrence: ``h ← exp(dt·A)·h + dt·(B ⊗ x)``,
``y = C·h + D·x`` plus a rolling depthwise-conv window.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .common import (Params, init_rmsnorm, mm, rmsnorm, shard)


def d_inner(cfg: ArchConfig) -> int:
    return cfg.ssm_expand * cfg.d_model


def n_ssm_heads(cfg: ArchConfig) -> int:
    return d_inner(cfg) // cfg.ssm_headdim


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def init_mamba_block(cfg: ArchConfig, key) -> Params:
    D, DI, N, H = cfg.d_model, d_inner(cfg), cfg.ssm_state, n_ssm_heads(cfg)
    conv_dim = DI + 2 * N                                 # x, B, C share the conv
    k1, k2, k3, k4 = jax.random.split(key, 4)
    proj_out = 2 * DI + 2 * N + H                         # z, x, B, C, dt
    return {
        "norm": init_rmsnorm(D),
        "in_proj": (jax.random.normal(k1, (D, proj_out), jnp.float32)
                    / jnp.sqrt(D)).astype(jnp.bfloat16),
        "conv_w": (jax.random.normal(k2, (cfg.conv_width, conv_dim),
                                     jnp.float32) * 0.1).astype(jnp.bfloat16),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.linspace(1e-3, 1e-1, H).astype(jnp.float32))),
        "ssm_norm": init_rmsnorm(DI),
        "out_proj": (jax.random.normal(k3, (DI, D), jnp.float32)
                     / jnp.sqrt(DI)).astype(jnp.bfloat16),
    }


def init_params(cfg: ArchConfig, key) -> Params:
    from .common import embed_init, init_linear
    k_e, k_l, k_h = jax.random.split(key, 3)
    keys = jnp.stack(jax.random.split(k_l, cfg.n_layers))
    layers = jax.vmap(lambda k: init_mamba_block(cfg, k))(keys)
    return {
        "embed": embed_init(k_e, cfg.vocab, cfg.d_model),
        "layers": layers,
        "final_norm": init_rmsnorm(cfg.d_model),
        "lm_head": init_linear(k_h, cfg.d_model, cfg.vocab),
    }


# ---------------------------------------------------------------------------
# projections shared by chunked + step paths
# ---------------------------------------------------------------------------
def _split_proj(cfg: ArchConfig, zxbcdt: jnp.ndarray):
    DI, N, H = d_inner(cfg), cfg.ssm_state, n_ssm_heads(cfg)
    z = zxbcdt[..., :DI]
    xBC = zxbcdt[..., DI: 2 * DI + 2 * N]
    dt = zxbcdt[..., 2 * DI + 2 * N:]
    return z, xBC, dt


def _causal_conv(p: Params, xBC: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv over (B,L,C) with window ``conv_width``."""
    W = p["conv_w"].shape[0]
    pad = jnp.pad(xBC, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i: i + xBC.shape[1]] * p["conv_w"][i].astype(xBC.dtype)
              for i in range(W))
    return jax.nn.silu((out + p["conv_b"].astype(out.dtype))
                       .astype(jnp.float32)).astype(xBC.dtype)


# ---------------------------------------------------------------------------
# chunked SSD (training / prefill)
# ---------------------------------------------------------------------------
def ssd_chunked(cfg: ArchConfig, x: jnp.ndarray, B_mat: jnp.ndarray,
                C_mat: jnp.ndarray, dt: jnp.ndarray, A_log: jnp.ndarray,
                init_state: jnp.ndarray | None = None):
    """SSD scan. x (B,L,H,P), B/C (B,L,N), dt (B,L,H) post-softplus.

    Returns (y (B,L,H,P), final_state (B,H,P,N)).
    """
    Bb, L, H, P = x.shape
    N = B_mat.shape[-1]
    Q = min(cfg.ssm_chunk, L)
    assert L % Q == 0, (L, Q)
    nc = L // Q
    A = -jnp.exp(A_log)                                    # (H,) negative

    xc = x.reshape(Bb, nc, Q, H, P)
    Bc = B_mat.reshape(Bb, nc, Q, N)
    Cc = C_mat.reshape(Bb, nc, Q, N)
    dtc = dt.reshape(Bb, nc, Q, H)
    dA = dtc * A                                           # (B,nc,Q,H)
    cum = jnp.cumsum(dA, axis=2)                           # within-chunk
    seg_end = cum[:, :, -1:]                               # (B,nc,1,H)

    # ---- intra-chunk (quadratic in Q) --------------------------------
    # M[t,s] = exp(cum_t - cum_s) for s<=t; weight dt_s on the input side
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,Q,Q,H)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    cb = jnp.einsum("bctn,bcsn->bcts", Cc, Bc,
                    preferred_element_type=jnp.float32)[..., None]  # (B,nc,Q,Q,1)
    w = cb * decay * dtc[:, :, None, :, :]                  # (B,nc,Q,Q,H)
    y_diag = jnp.einsum("bctsh,bcshp->bcthp", w.astype(x.dtype), xc,
                        preferred_element_type=jnp.float32)

    # ---- inter-chunk state recurrence ---------------------------------
    # state contribution of chunk: sum_s exp(segend - cum_s)·dt_s·(B_s ⊗ x_s)
    in_decay = jnp.exp(seg_end - cum) * dtc                 # (B,nc,Q,H)
    states = jnp.einsum("bcsh,bcsn,bcshp->bchpn", in_decay.astype(x.dtype),
                        Bc, xc, preferred_element_type=jnp.float32)
    seg_full = jnp.exp(seg_end[:, :, 0])                    # (B,nc,H)

    def step(s, xs):
        st_c, dec = xs                                      # (B,H,P,N),(B,H)
        s_new = s * dec[..., None, None] + st_c
        return s_new, s                                     # emit state *before* chunk

    s0 = (jnp.zeros((Bb, H, P, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    final_state, prev_states = jax.lax.scan(
        step, s0,
        (states.transpose(1, 0, 2, 3, 4), seg_full.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)      # (B,nc,H,P,N)

    out_decay = jnp.exp(cum)                                # (B,nc,Q,H)
    y_off = jnp.einsum("bctn,bchpn,bcth->bcthp",
                       Cc, prev_states.astype(x.dtype),
                       out_decay.astype(x.dtype),
                       preferred_element_type=jnp.float32)
    y = (y_diag + y_off).reshape(Bb, L, H, P).astype(x.dtype)
    return y, final_state


def apply_mamba_block(cfg: ArchConfig, p: Params, x: jnp.ndarray,
                      init_state: jnp.ndarray | None = None,
                      return_state: bool = False):
    """x (B,L,D) → (B,L,D) residual-added."""
    DI, N, H, P = (d_inner(cfg), cfg.ssm_state, n_ssm_heads(cfg),
                   cfg.ssm_headdim)
    Bb, L, D = x.shape
    h = rmsnorm(p["norm"], x)
    zxbcdt = mm(h, p["in_proj"])
    z, xBC, dt_raw = _split_proj(cfg, zxbcdt)
    xBC = _causal_conv(p, xBC)
    xs = xBC[..., :DI].reshape(Bb, L, H, P)
    B_mat = xBC[..., DI: DI + N]
    C_mat = xBC[..., DI + N:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"])                    # (B,L,H)
    # pad to a chunk multiple; dt=0 on padded steps leaves the state fixed
    pad = (-L) % max(min(cfg.ssm_chunk, L), 1)
    if pad:
        zpad = lambda a: jnp.pad(a, [(0, 0), (0, pad)]
                                 + [(0, 0)] * (a.ndim - 2))
        xs, B_mat, C_mat, dt = map(zpad, (xs, B_mat, C_mat, dt))
    y, state = ssd_chunked(cfg, xs, B_mat, C_mat, dt, p["A_log"], init_state)
    if pad:
        y, xs = y[:, :L], xs[:, :L]
    y = y + xs * p["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(Bb, L, DI)
    y = rmsnorm(p["ssm_norm"], y) * jax.nn.silu(
        z.astype(jnp.float32)).astype(y.dtype)
    out = x + mm(y, p["out_proj"])
    out = shard(out, "act_resid")
    return (out, state) if return_state else (out, None)


# ---------------------------------------------------------------------------
# decode: O(1) per-token recurrence
# ---------------------------------------------------------------------------
def mamba_cache_specs(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    DI, N, H, P = (d_inner(cfg), cfg.ssm_state, n_ssm_heads(cfg),
                   cfg.ssm_headdim)
    conv_dim = DI + 2 * N
    return {
        "ssm": jax.ShapeDtypeStruct((cfg.n_layers, batch, H, P, N), dtype),
        "conv": jax.ShapeDtypeStruct(
            (cfg.n_layers, batch, cfg.conv_width - 1, conv_dim), jnp.bfloat16),
    }


def init_mamba_cache(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        mamba_cache_specs(cfg, batch, dtype))


def mamba_block_step(cfg: ArchConfig, p: Params, x: jnp.ndarray,
                     ssm_state: jnp.ndarray, conv_state: jnp.ndarray):
    """One-token step. x (B,1,D); states (B,H,P,N), (B,W-1,conv_dim)."""
    DI, N, H, P = (d_inner(cfg), cfg.ssm_state, n_ssm_heads(cfg),
                   cfg.ssm_headdim)
    Bb = x.shape[0]
    h = rmsnorm(p["norm"], x)
    zxbcdt = mm(h, p["in_proj"])
    z, xBC, dt_raw = _split_proj(cfg, zxbcdt)
    xBC = xBC[:, 0]                                         # (B, conv_dim)
    window = jnp.concatenate([conv_state, xBC[:, None, :]], axis=1)
    conv_state = window[:, 1:]
    conv = jnp.einsum("bwc,wc->bc", window, p["conv_w"].astype(window.dtype))
    xBC = jax.nn.silu((conv + p["conv_b"].astype(conv.dtype))
                      .astype(jnp.float32)).astype(x.dtype)
    xs = xBC[:, :DI].reshape(Bb, H, P)
    B_mat = xBC[:, DI: DI + N]
    C_mat = xBC[:, DI + N:]
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)                                    # (B,H)
    dBx = jnp.einsum("bh,bn,bhp->bhpn", dt.astype(x.dtype), B_mat, xs,
                     preferred_element_type=jnp.float32)
    ssm_state = ssm_state * dA[..., None, None] + dBx
    y = jnp.einsum("bhpn,bn->bhp", ssm_state.astype(x.dtype), C_mat,
                   preferred_element_type=jnp.float32).astype(x.dtype)
    y = y + xs * p["D"].astype(x.dtype)[None, :, None]
    y = y.reshape(Bb, 1, DI)
    y = rmsnorm(p["ssm_norm"], y) * jax.nn.silu(
        z.astype(jnp.float32)).astype(y.dtype)
    return x + mm(y, p["out_proj"]), ssm_state, conv_state


# ---------------------------------------------------------------------------
# LM-level entry points
# ---------------------------------------------------------------------------
def forward(cfg: ArchConfig, params: Params, tokens: jnp.ndarray,
            *, remat: bool = True) -> tuple[jnp.ndarray, jnp.ndarray]:
    x = jnp.take(params["embed"], tokens, axis=0)
    x = shard(x, "act_resid")

    def body(h, layer_p):
        fn = apply_mamba_block
        if remat:
            import functools
            fn = jax.checkpoint(functools.partial(apply_mamba_block, cfg),
                                policy=jax.checkpoint_policies.nothing_saveable)
            h2, _ = fn(layer_p, h)
        else:
            h2, _ = fn(cfg, layer_p, h)
        return h2, None

    x, _ = jax.lax.scan(body, x, params["layers"])
    return x, jnp.zeros((), jnp.float32)


def loss_fn(cfg: ArchConfig, params: Params, batch: dict,
            *, remat: bool = True):
    from .transformer import logits_from_hidden
    from .common import softmax_xent
    hidden, aux = forward(cfg, params, batch["tokens"], remat=remat)
    logits = logits_from_hidden(cfg, params, hidden)
    xent = softmax_xent(logits, batch["labels"], batch.get("loss_mask"))
    return xent, {"xent": xent, "aux": aux}


def prefill(cfg: ArchConfig, params: Params, tokens: jnp.ndarray,
            cache: Params):
    """Chunked prefill; caches final ssm/conv state per layer."""
    x = jnp.take(params["embed"], tokens, axis=0)

    def body(h, layer_p):
        h2, state = apply_mamba_block(cfg, layer_p, h, return_state=True)
        return h2, state

    x, states = jax.lax.scan(body, x, params["layers"])
    W = cfg.conv_width
    # conv tail: recompute per layer is awkward under scan; store zeros and
    # accept a W-1-token warmup approximation on the first decoded tokens.
    cache = dict(cache)
    cache["ssm"] = states.astype(cache["ssm"].dtype)
    cache["conv"] = jnp.zeros_like(cache["conv"])
    from .transformer import logits_from_hidden
    return logits_from_hidden(cfg, params, x[:, -1:]), cache


def decode_step(cfg: ArchConfig, params: Params, cache: Params,
                tokens: jnp.ndarray):
    x = jnp.take(params["embed"], tokens, axis=0)

    def body(h, xs):
        layer_p, s_ssm, s_conv = xs
        h2, s_ssm, s_conv = mamba_block_step(cfg, layer_p, h, s_ssm, s_conv)
        return h2, (s_ssm, s_conv)

    x, (ssm_new, conv_new) = jax.lax.scan(
        body, x, (params["layers"], cache["ssm"], cache["conv"]))
    cache = dict(cache, ssm=ssm_new.astype(cache["ssm"].dtype),
                 conv=conv_new.astype(cache["conv"].dtype))
    from .transformer import logits_from_hidden
    return logits_from_hidden(cfg, params, x), cache
