"""Feed-forward blocks: SwiGLU (llama-family) and GELU (whisper/GPT-family)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import Params, init_linear, linear, shard


def init_swiglu(key, d_model: int, d_ff: int, dtype=jnp.bfloat16) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": init_linear(k1, d_model, d_ff, dtype=dtype),
        "up": init_linear(k2, d_model, d_ff, dtype=dtype),
        "down": init_linear(k3, d_ff, d_model, dtype=dtype),
    }


def swiglu(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.silu(linear(p["gate"], x).astype(jnp.float32)).astype(x.dtype)
    h = h * linear(p["up"], x)
    h = shard(h, "act_ff")
    return linear(p["down"], h)


def init_gelu_mlp(key, d_model: int, d_ff: int, *, bias: bool = True,
                  dtype=jnp.bfloat16) -> Params:
    k1, k2 = jax.random.split(key, 2)
    return {
        "up": init_linear(k1, d_model, d_ff, bias=bias, dtype=dtype),
        "down": init_linear(k2, d_ff, d_model, bias=bias, dtype=dtype),
    }


def gelu_mlp(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.gelu(linear(p["up"], x).astype(jnp.float32)).astype(x.dtype)
    h = shard(h, "act_ff")
    return linear(p["down"], h)
