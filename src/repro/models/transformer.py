"""Decoder-only transformer LM (dense + MoE families).

Layers are *stacked* (leading layer axis on every weight) and executed
with ``lax.scan`` + per-layer remat — the MaxText pattern — so a 94-layer
model lowers to a compact HLO and activation memory is O(1) in layers.

Supports: GQA, RoPE, QKV bias, parallel attn+FFN blocks (command-r),
tied embeddings, MoE FFN with top-k routing, blockwise flash attention
for long sequences, KV-cache prefill/decode.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import attention as A
from . import moe as M
from .common import (Params, embed_init, init_layernorm, init_linear,
                     init_rmsnorm, layernorm, linear, mm, rmsnorm, shard,
                     softmax_xent, split_keys)


def _norm_fns(cfg: ArchConfig):
    if cfg.norm == "rmsnorm":
        return init_rmsnorm, rmsnorm
    return init_layernorm, layernorm


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def init_layer(cfg: ArchConfig, key) -> Params:
    init_norm, _ = _norm_fns(cfg)
    k_attn, k_ffn = jax.random.split(key)
    p: Params = {
        "ln1": init_norm(cfg.d_model),
        "attn": A.init_attention(k_attn, cfg.d_model, cfg.n_heads,
                                 cfg.n_kv_heads, cfg.head_dim,
                                 qkv_bias=cfg.qkv_bias,
                                 out_bias=cfg.attn_out_bias),
    }
    if not cfg.parallel_block:
        p["ln2"] = init_norm(cfg.d_model)
    if cfg.n_experts:
        p["moe"] = M.init_moe(k_ffn, cfg.d_model, cfg.d_ff, cfg.n_experts)
    elif cfg.act == "swiglu":
        from .mlp import init_swiglu
        p["mlp"] = init_swiglu(k_ffn, cfg.d_model, cfg.d_ff)
    else:
        from .mlp import init_gelu_mlp
        p["mlp"] = init_gelu_mlp(k_ffn, cfg.d_model, cfg.d_ff,
                                 bias=cfg.mlp_bias)
    return p


def init_params(cfg: ArchConfig, key) -> Params:
    init_norm, _ = _norm_fns(cfg)
    k_embed, k_layers, k_head = jax.random.split(key, 3)
    layer_keys = jnp.stack(split_keys(k_layers, cfg.n_layers))
    layers = jax.vmap(lambda k: init_layer(cfg, k))(layer_keys)
    p: Params = {
        "embed": embed_init(k_embed, cfg.vocab, cfg.d_model),
        "layers": layers,
        "final_norm": init_norm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = init_linear(k_head, cfg.d_model, cfg.vocab)
    return p


# ---------------------------------------------------------------------------
# layer application
# ---------------------------------------------------------------------------
def apply_layer(cfg: ArchConfig, p: Params, x: jnp.ndarray,
                positions: jnp.ndarray | None = None,
                flash: bool | None = None) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One block over (B,S,D); returns (x, moe aux loss)."""
    _, norm = _norm_fns(cfg)
    aux = jnp.zeros((), jnp.float32)
    # §Perf-D: constrain the norm OUTPUT (bf16, D replicated) so GSPMD
    # all-gathers 2-byte activations instead of the f32 upcast inside it
    h = shard(norm(p["ln1"], x), "act_norm_out")
    attn_out = A.attention_block(
        p["attn"], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
        positions=positions, flash=flash)
    if cfg.parallel_block:                     # command-r: shared pre-norm
        if cfg.n_experts:
            ffn_out, aux = M.moe_ffn(p["moe"], h, top_k=cfg.top_k,
                                     impl=cfg.moe_impl,
                                     capacity_factor=cfg.capacity_factor,
                                     group_size=cfg.moe_group_size)
        else:
            ffn_out = _mlp(cfg, p, h)
        x = x + attn_out + ffn_out
    else:
        x = x + attn_out
        h2 = shard(norm(p["ln2"], x), "act_norm_out")
        if cfg.n_experts:
            ffn_out, aux = M.moe_ffn(p["moe"], h2, top_k=cfg.top_k,
                                     impl=cfg.moe_impl,
                                     capacity_factor=cfg.capacity_factor,
                                     group_size=cfg.moe_group_size)
        else:
            ffn_out = _mlp(cfg, p, h2)
        x = x + ffn_out
    return shard(x, "act_resid"), aux


def _mlp(cfg: ArchConfig, p: Params, h: jnp.ndarray) -> jnp.ndarray:
    from .mlp import gelu_mlp, swiglu
    return swiglu(p["mlp"], h) if cfg.act == "swiglu" else gelu_mlp(p["mlp"], h)


def _scan_layers(cfg: ArchConfig, layers: Params, x: jnp.ndarray,
                 positions: jnp.ndarray | None,
                 flash: bool | None, remat: bool = True) -> tuple[jnp.ndarray, jnp.ndarray]:
    def body(carry, layer_p):
        h, aux = carry
        fn = apply_layer
        if remat:
            fn = jax.checkpoint(
                functools.partial(apply_layer, cfg),
                policy=jax.checkpoint_policies.nothing_saveable)
            h2, a = fn(layer_p, h, positions, flash)
        else:
            h2, a = fn(cfg, layer_p, h, positions, flash)
        return (h2, aux + a), None

    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), layers)
    return x, aux


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------
def embed_tokens(cfg: ArchConfig, params: Params, tokens: jnp.ndarray) -> jnp.ndarray:
    x = jnp.take(params["embed"], tokens, axis=0)
    return shard(x, "act_resid")


def logits_from_hidden(cfg: ArchConfig, params: Params, x: jnp.ndarray) -> jnp.ndarray:
    _, norm = _norm_fns(cfg)
    x = norm(params["final_norm"], x)
    if cfg.tie_embeddings:
        out = jax.lax.dot_general(
            x, params["embed"], (((x.ndim - 1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
    else:
        out = mm(x, params["lm_head"]["w"]).astype(jnp.float32)
    if cfg.logit_softcap:
        out = cfg.logit_softcap * jnp.tanh(out / cfg.logit_softcap)
    return shard(out, "act_logits")


def forward(cfg: ArchConfig, params: Params, tokens: jnp.ndarray,
            *, prefix_embeds: jnp.ndarray | None = None,
            flash: bool | None = None, remat: bool = True) -> tuple[jnp.ndarray, jnp.ndarray]:
    """tokens (B,S) → (hidden (B,S',D), moe aux). ``prefix_embeds`` (B,P,D)
    are prepended (the VLM patch-embedding stub)."""
    x = embed_tokens(cfg, params, tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    x, aux = _scan_layers(cfg, params["layers"], x, None, flash, remat)
    return x, aux


def loss_fn(cfg: ArchConfig, params: Params, batch: dict,
            *, remat: bool = True) -> tuple[jnp.ndarray, dict]:
    """batch: tokens (B,S), labels (B,S), optional loss_mask, prefix_embeds."""
    hidden, aux = forward(cfg, params, batch["tokens"],
                          prefix_embeds=batch.get("prefix_embeds"),
                          remat=remat)
    P = 0 if batch.get("prefix_embeds") is None else batch["prefix_embeds"].shape[1]
    hidden = hidden[:, P:]
    logits = logits_from_hidden(cfg, params, hidden)
    xent = softmax_xent(logits, batch["labels"], batch.get("loss_mask"))
    loss = xent + cfg.aux_loss_weight * aux
    return loss, {"xent": xent, "aux": aux}


# ---------------------------------------------------------------------------
# serving: prefill + decode with KV cache
# ---------------------------------------------------------------------------
def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Params:
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "length": jnp.zeros((), jnp.int32)}


def cache_specs(cfg: ArchConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16) -> Params:
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jax.ShapeDtypeStruct(shape, dtype),
            "v": jax.ShapeDtypeStruct(shape, dtype),
            "length": jax.ShapeDtypeStruct((), jnp.int32)}


def prefill(cfg: ArchConfig, params: Params, tokens: jnp.ndarray,
            cache: Params, *, prefix_embeds: jnp.ndarray | None = None
            ) -> tuple[jnp.ndarray, Params]:
    """Run the prompt, fill the cache, return last-position logits."""
    x = embed_tokens(cfg, params, tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape

    def body(h, xs):
        layer_p, _ = xs
        q, k, v = A.qkv(layer_p["attn"], _prenorm(cfg, layer_p, h),
                        cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                        None, cfg.rope_theta)
        h = _finish_layer(cfg, layer_p, h, q, k, v, flash=S > 2048)
        return h, (k, v)

    idx = jnp.arange(cfg.n_layers)
    x, kv = jax.lax.scan(body, x, (params["layers"], idx))
    k_all, v_all = kv                                   # (L,B,S,KV,hd)
    T = cache["k"].shape[2]
    cache = dict(cache)
    cache["k"] = jax.lax.dynamic_update_slice(
        cache["k"], k_all.astype(cache["k"].dtype), (0, 0, 0, 0, 0))
    cache["v"] = jax.lax.dynamic_update_slice(
        cache["v"], v_all.astype(cache["v"].dtype), (0, 0, 0, 0, 0))
    cache["length"] = jnp.asarray(S, jnp.int32)
    logits = logits_from_hidden(cfg, params, x[:, -1:])
    return logits, cache


def _prenorm(cfg, layer_p, h):
    _, norm = _norm_fns(cfg)
    return shard(norm(layer_p["ln1"], h), "act_norm_out")


def _finish_layer(cfg, layer_p, x, q, k, v, *, flash: bool):
    """Residual + attention-output + FFN given projected q/k/v."""
    B, S = q.shape[0], q.shape[1]
    if flash:
        o = A.flash_attention(q, k, v, causal=True,
                              q_block=min(2048, S), kv_block=min(1024, S))
    else:
        o = A.full_attention(q, k, v, causal=True)
    attn_out = linear(layer_p["attn"]["o"], o.reshape(B, S, -1))
    _, norm = _norm_fns(cfg)
    if cfg.parallel_block:
        h = norm(layer_p["ln1"], x)
        ffn = (_mlp(cfg, layer_p, h) if not cfg.n_experts else
               M.moe_ffn(layer_p["moe"], h, top_k=cfg.top_k,
                         impl=cfg.moe_impl, group_size=cfg.moe_group_size)[0])
        return x + attn_out + ffn
    x = x + attn_out
    h2 = norm(layer_p["ln2"], x)
    ffn = (_mlp(cfg, layer_p, h2) if not cfg.n_experts else
           M.moe_ffn(layer_p["moe"], h2, top_k=cfg.top_k,
                     impl=cfg.moe_impl, group_size=cfg.moe_group_size)[0])
    return x + ffn


def decode_step(cfg: ArchConfig, params: Params, cache: Params,
                tokens: jnp.ndarray) -> tuple[jnp.ndarray, Params]:
    """One token step. tokens (B,1) → (logits (B,1,V), cache')."""
    x = embed_tokens(cfg, params, tokens)
    B = x.shape[0]
    length = cache["length"]
    positions = jnp.full((B, 1), length, jnp.int32)

    def body(carry, xs):
        h = carry
        layer_p, k_c, v_c = xs
        q, k, v = A.qkv(layer_p["attn"], _prenorm(cfg, layer_p, h),
                        cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                        positions, cfg.rope_theta)
        k_c = jax.lax.dynamic_update_slice(
            k_c, k.astype(k_c.dtype), (0, length, 0, 0))
        v_c = jax.lax.dynamic_update_slice(
            v_c, v.astype(v_c.dtype), (0, length, 0, 0))
        o = A.decode_attention(q, k_c, v_c, length + 1)
        attn_out = linear(layer_p["attn"]["o"], o.reshape(B, 1, -1))
        _, norm = _norm_fns(cfg)
        if cfg.parallel_block:
            hh = norm(layer_p["ln1"], h)
            ffn = (_mlp(cfg, layer_p, hh) if not cfg.n_experts else
                   M.moe_ffn(layer_p["moe"], hh, top_k=cfg.top_k,
                             impl=cfg.moe_impl, group_size=B)[0])
            h = h + attn_out + ffn
        else:
            h = h + attn_out
            h2 = norm(layer_p["ln2"], h)
            ffn = (_mlp(cfg, layer_p, h2) if not cfg.n_experts else
                   M.moe_ffn(layer_p["moe"], h2, top_k=cfg.top_k,
                             impl=cfg.moe_impl, group_size=B)[0])
            h = h + ffn
        return h, (k_c, v_c)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"]))
    cache = dict(cache, k=k_new, v=v_new, length=length + 1)
    logits = logits_from_hidden(cfg, params, x)
    return logits, cache
