from . import api, attention, common, encdec, hybrid, mlp, moe, spn_head, ssm, transformer, vlm  # noqa: F401
