"""Shared building blocks for the architecture zoo.

Pure-functional JAX: parameters are plain dict pytrees, every module is a
pair of ``init_*`` (shape-only, usable under ``jax.eval_shape``) and apply
functions. Compute dtype is bf16 with f32 accumulation (``preferred_element_type``
on matmuls); parameters are bf16 with f32 norms.
"""
from __future__ import annotations

import contextvars
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Params = dict  # nested dict pytree of jnp arrays

# ---------------------------------------------------------------------------
# activation-sharding hook: parallel/plan.py installs a rule table; models
# call shard(x, "logical_name") at block boundaries. No mesh → no-op.
# ---------------------------------------------------------------------------
_ACT_RULES: contextvars.ContextVar[dict | None] = contextvars.ContextVar(
    "act_rules", default=None)


def set_act_rules(rules: dict | None):
    return _ACT_RULES.set(rules)


def reset_act_rules(token) -> None:
    _ACT_RULES.reset(token)


def shard(x: jnp.ndarray, name: str) -> jnp.ndarray:
    rules = _ACT_RULES.get()
    if rules is None or rules.get(name) is None:
        return x            # no rule → leave it to GSPMD propagation
    return jax.lax.with_sharding_constraint(x, rules[name])


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def dense_init(key, d_in: int, d_out: int, dtype=jnp.bfloat16,
               scale: float | None = None) -> jnp.ndarray:
    scale = (1.0 / np.sqrt(d_in)) if scale is None else scale
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.bfloat16) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


def split_keys(key, n: int):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def init_rmsnorm(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return out.astype(x.dtype)


def init_layernorm(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10000.0) -> jnp.ndarray:
    """Rotate ``x`` (..., seq, heads, head_dim) by per-position angles.

    ``positions``: (..., seq) int32 (broadcastable against x's batch dims).
    """
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                         # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                   # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# matmul with f32 accumulation
# ---------------------------------------------------------------------------
def mm(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(x.dtype)


def linear(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    y = mm(x, p["w"])
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def init_linear(key, d_in: int, d_out: int, *, bias: bool = False,
                dtype=jnp.bfloat16) -> Params:
    p = {"w": dense_init(key, d_in, d_out, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------
def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray,
                 mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Mean next-token cross-entropy. logits (..., V) f32, labels (...)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def count_params(params: Any) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
