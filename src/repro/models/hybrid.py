"""Zamba2-style hybrid: Mamba2 backbone + a *shared* attention block.

Structure (simplification of Zamba2 noted in DESIGN.md §4): ``n_layers``
mamba2 blocks; ONE transformer block (attention + SwiGLU MLP, single set
of weights) is applied after every ``attn_every`` mamba blocks. With 81
layers and attn_every=6 that is 13 shared-block applications; the 3
trailing mamba layers close the stack.

Layout: mamba params are stacked ``(n_groups, attn_every, ...)`` for a
nested scan, plus a ``(n_tail, ...)`` stack. Each shared-block
*application* has its own KV cache at decode time (weights shared, state
not).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import attention as A
from . import ssm as S
from .common import (Params, embed_init, init_linear, init_rmsnorm, linear,
                     rmsnorm, shard, softmax_xent, split_keys)
from .mlp import init_swiglu, swiglu


def group_shape(cfg: ArchConfig) -> tuple[int, int, int]:
    """(n_groups, group_size, n_tail)."""
    g = cfg.attn_every
    n_groups = cfg.n_layers // g
    return n_groups, g, cfg.n_layers - n_groups * g


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def init_shared_block(cfg: ArchConfig, key) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_rmsnorm(cfg.d_model),
        "attn": A.init_attention(k1, cfg.d_model, cfg.n_heads,
                                 cfg.n_kv_heads, cfg.head_dim),
        "ln2": init_rmsnorm(cfg.d_model),
        "mlp": init_swiglu(k2, cfg.d_model, cfg.d_ff),
    }


def init_params(cfg: ArchConfig, key) -> Params:
    n_groups, g, n_tail = group_shape(cfg)
    k_e, k_m, k_t, k_s, k_h = jax.random.split(key, 5)
    mkeys = jnp.stack(split_keys(k_m, n_groups * g))
    mkeys = mkeys.reshape((n_groups, g) + mkeys.shape[1:])   # typed-key safe
    groups = jax.vmap(jax.vmap(lambda k: S.init_mamba_block(cfg, k)))(mkeys)
    p: Params = {
        "embed": embed_init(k_e, cfg.vocab, cfg.d_model),
        "mamba_groups": groups,
        "shared": init_shared_block(cfg, k_s),
        "final_norm": init_rmsnorm(cfg.d_model),
        "lm_head": init_linear(k_h, cfg.d_model, cfg.vocab),
    }
    if n_tail:
        tkeys = jnp.stack(split_keys(k_t, n_tail))
        p["mamba_tail"] = jax.vmap(lambda k: S.init_mamba_block(cfg, k))(tkeys)
    return p


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------
def _apply_shared(cfg: ArchConfig, p: Params, x: jnp.ndarray,
                  flash: bool | None = None) -> jnp.ndarray:
    h = rmsnorm(p["ln1"], x)
    attn_out = A.attention_block(
        p["attn"], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.head_dim, rope_theta=cfg.rope_theta, flash=flash)
    x = x + attn_out
    x = x + swiglu(p["mlp"], rmsnorm(p["ln2"], x))
    return shard(x, "act_resid")


def forward(cfg: ArchConfig, params: Params, tokens: jnp.ndarray,
            *, remat: bool = True) -> tuple[jnp.ndarray, jnp.ndarray]:
    x = jnp.take(params["embed"], tokens, axis=0)
    x = shard(x, "act_resid")
    n_groups, g, n_tail = group_shape(cfg)

    mamba = functools.partial(S.apply_mamba_block, cfg)
    shared = functools.partial(_apply_shared, cfg, params["shared"])
    if remat:
        mamba = jax.checkpoint(mamba,
                               policy=jax.checkpoint_policies.nothing_saveable)
        shared = jax.checkpoint(shared,
                                policy=jax.checkpoint_policies.nothing_saveable)

    def inner(h, layer_p):
        h2, _ = mamba(layer_p, h)
        return h2, None

    def outer(h, group_p):
        h, _ = jax.lax.scan(inner, h, group_p)
        return shared(h), None

    x, _ = jax.lax.scan(outer, x, params["mamba_groups"])
    if n_tail:
        x, _ = jax.lax.scan(inner, x, params["mamba_tail"])
    return x, jnp.zeros((), jnp.float32)


def loss_fn(cfg: ArchConfig, params: Params, batch: dict, *,
            remat: bool = True):
    from .transformer import logits_from_hidden
    hidden, aux = forward(cfg, params, batch["tokens"], remat=remat)
    logits = logits_from_hidden(cfg, params, hidden)
    xent = softmax_xent(logits, batch["labels"], batch.get("loss_mask"))
    return xent, {"xent": xent, "aux": aux}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------
def cache_specs(cfg: ArchConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16) -> Params:
    n_groups, g, n_tail = group_shape(cfg)
    mamba = S.mamba_cache_specs(cfg, batch)
    def regroup(s, lead):
        return jax.ShapeDtypeStruct((lead,) + s.shape[1:], s.dtype)
    specs = {
        "ssm_groups": jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n_groups, g) + s.shape[1:], s.dtype),
            {"ssm": regroup(mamba["ssm"], 1), "conv": regroup(mamba["conv"], 1)}),
        "kv": {
            "k": jax.ShapeDtypeStruct(
                (n_groups, batch, max_len, cfg.n_kv_heads, cfg.head_dim), dtype),
            "v": jax.ShapeDtypeStruct(
                (n_groups, batch, max_len, cfg.n_kv_heads, cfg.head_dim), dtype),
        },
        "length": jax.ShapeDtypeStruct((), jnp.int32),
    }
    if n_tail:
        specs["ssm_tail"] = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n_tail,) + s.shape[1:], s.dtype),
            {"ssm": regroup(mamba["ssm"], 1), "conv": regroup(mamba["conv"], 1)})
    return specs


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Params:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_specs(cfg, batch, max_len, dtype))


def _shared_decode(cfg: ArchConfig, p: Params, h: jnp.ndarray,
                   k_c, v_c, length):
    B = h.shape[0]
    positions = jnp.full((B, 1), length, jnp.int32)
    q, k, v = A.qkv(p["attn"], rmsnorm(p["ln1"], h), cfg.n_heads,
                    cfg.n_kv_heads, cfg.head_dim, positions, cfg.rope_theta)
    k_c = jax.lax.dynamic_update_slice(k_c, k.astype(k_c.dtype),
                                       (0, length, 0, 0))
    v_c = jax.lax.dynamic_update_slice(v_c, v.astype(v_c.dtype),
                                       (0, length, 0, 0))
    o = A.decode_attention(q, k_c, v_c, length + 1)
    h = h + linear(p["attn"]["o"], o.reshape(B, 1, -1))
    h = h + swiglu(p["mlp"], rmsnorm(p["ln2"], h))
    return h, k_c, v_c


def decode_step(cfg: ArchConfig, params: Params, cache: Params,
                tokens: jnp.ndarray):
    x = jnp.take(params["embed"], tokens, axis=0)
    n_groups, g, n_tail = group_shape(cfg)
    length = cache["length"]

    def inner(h, xs):
        layer_p, s_ssm, s_conv = xs
        h2, s_ssm, s_conv = S.mamba_block_step(cfg, layer_p, h, s_ssm, s_conv)
        return h2, (s_ssm, s_conv)

    def outer(h, xs):
        group_p, states, k_c, v_c = xs
        h, new_states = jax.lax.scan(
            inner, h, (group_p, states["ssm"], states["conv"]))
        h, k_c, v_c = _shared_decode(cfg, params["shared"], h, k_c, v_c, length)
        return h, ({"ssm": new_states[0], "conv": new_states[1]}, k_c, v_c)

    x, (gstates, k_new, v_new) = jax.lax.scan(
        outer, x, (params["mamba_groups"], cache["ssm_groups"],
                   cache["kv"]["k"], cache["kv"]["v"]))
    new_cache = dict(cache, ssm_groups=gstates,
                     kv={"k": k_new, "v": v_new}, length=length + 1)
    if n_tail:
        x, tstates = jax.lax.scan(
            inner, x, (params["mamba_tail"], cache["ssm_tail"]["ssm"],
                       cache["ssm_tail"]["conv"]))
        new_cache["ssm_tail"] = {"ssm": tstates[0], "conv": tstates[1]}
    from .transformer import logits_from_hidden
    return logits_from_hidden(cfg, params, x), new_cache


def prefill(cfg: ArchConfig, params: Params, tokens: jnp.ndarray,
            cache: Params):
    """Prefill: chunked SSD for mamba, flash attention for shared blocks."""
    x = jnp.take(params["embed"], tokens, axis=0)
    B, Sq = tokens.shape
    n_groups, g, n_tail = group_shape(cfg)

    def inner(h, layer_p):
        h2, state = S.apply_mamba_block(cfg, layer_p, h, return_state=True)
        return h2, state

    def outer(h, xs):
        group_p, k_c, v_c = xs
        h, states = jax.lax.scan(inner, h, group_p)
        # shared attn over the full prefix, cache K/V
        hn = rmsnorm(params["shared"]["ln1"], h)
        q, k, v = A.qkv(params["shared"]["attn"], hn, cfg.n_heads,
                        cfg.n_kv_heads, cfg.head_dim, None, cfg.rope_theta)
        o = A.flash_attention(q, k, v, causal=True,
                              q_block=min(2048, Sq), kv_block=min(1024, Sq))
        h = h + linear(params["shared"]["attn"]["o"], o.reshape(B, Sq, -1))
        h = h + swiglu(params["shared"]["mlp"],
                       rmsnorm(params["shared"]["ln2"], h))
        k_c = jax.lax.dynamic_update_slice(
            k_c, k.astype(k_c.dtype), (0, 0, 0, 0))
        v_c = jax.lax.dynamic_update_slice(
            v_c, v.astype(v_c.dtype), (0, 0, 0, 0))
        return h, (states, k_c, v_c)

    x, (gstates, k_new, v_new) = jax.lax.scan(
        outer, x, (params["mamba_groups"], cache["kv"]["k"],
                   cache["kv"]["v"]))
    new_cache = dict(cache)
    new_cache["ssm_groups"] = {
        "ssm": gstates.astype(cache["ssm_groups"]["ssm"].dtype),
        "conv": jnp.zeros_like(cache["ssm_groups"]["conv"])}
    new_cache["kv"] = {"k": k_new, "v": v_new}
    new_cache["length"] = jnp.asarray(Sq, jnp.int32)
    if n_tail:
        x, tstates = jax.lax.scan(inner, x, params["mamba_tail"])
        new_cache["ssm_tail"] = {
            "ssm": tstates.astype(cache["ssm_tail"]["ssm"].dtype),
            "conv": jnp.zeros_like(cache["ssm_tail"]["conv"])}
    from .transformer import logits_from_hidden
    return logits_from_hidden(cfg, params, x[:, -1:]), new_cache
