"""Mixture-of-Experts FFN with top-k routing.

Two dispatch strategies, selectable per call site:

- ``dense`` — capacity-based one-hot einsum dispatch (Switch/MaxText
  style). Expert-parallel friendly: with experts sharded over the
  ``model`` mesh axis the two dispatch einsums lower to all-to-alls under
  GSPMD. Tokens beyond an expert's capacity are dropped (standard).
- ``ragged`` — sort-by-expert + ``lax.ragged_dot``. No token dropping, no
  O(N·E·C) dispatch tensor; the efficient single-replica / serving path.

Router: softmax over expert logits, top-k, probs renormalized over the
selected k. Load-balancing auxiliary loss (Switch eq. 4) is returned next
to the output so the trainer can weight it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import Params, dense_init, shard

SwiGLUExperts = Params  # {"gate": (E,D,F), "up": (E,D,F), "down": (E,F,D), "router": (D,E)}


def init_moe(key, d_model: int, d_ff: int, n_experts: int,
             dtype=jnp.bfloat16) -> Params:
    kr, kg, ku, kd = jax.random.split(key, 4)
    s_in = 1.0 / jnp.sqrt(d_model)
    s_out = 1.0 / jnp.sqrt(d_ff)
    def exp_init(k, shape, scale):
        return (jax.random.normal(k, shape, jnp.float32) * scale).astype(dtype)
    return {
        "router": dense_init(kr, d_model, n_experts, jnp.float32),
        "gate": exp_init(kg, (n_experts, d_model, d_ff), s_in),
        "up": exp_init(ku, (n_experts, d_model, d_ff), s_in),
        "down": exp_init(kd, (n_experts, d_ff, d_model), s_out),
    }


def _router(p: Params, x2d: jnp.ndarray, top_k: int):
    """x2d (N,D) → (probs (N,k) f32, idx (N,k) i32, aux_loss scalar)."""
    logits = (x2d.astype(jnp.float32) @ p["router"])          # (N, E)
    n_experts = logits.shape[-1]
    full_probs = jax.nn.softmax(logits, axis=-1)
    probs, idx = jax.lax.top_k(full_probs, top_k)
    probs = probs / jnp.maximum(probs.sum(-1, keepdims=True), 1e-9)
    # Switch aux loss: E * Σ_e f_e · P_e
    onehot = jax.nn.one_hot(idx, n_experts, dtype=jnp.float32)  # (N,k,E)
    frac_tokens = onehot.sum((0, 1)) / jnp.maximum(onehot.sum(), 1.0)
    frac_probs = full_probs.mean(0)
    aux = n_experts * jnp.sum(frac_tokens * frac_probs)
    return probs, idx, aux


def _expert_ffn(p: Params, h: jnp.ndarray) -> jnp.ndarray:
    """Per-expert SwiGLU. h (E, G, C, D) — expert axis LEADING (both for
    EP sharding on dim 0 and for the CPU executor's batched-dot layout)."""
    g = jnp.einsum("egcd,edf->egcf", h, p["gate"],
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("egcd,edf->egcf", h, p["up"],
                   preferred_element_type=jnp.float32)
    a = (jax.nn.silu(g) * u).astype(h.dtype)
    return jnp.einsum("egcf,efd->egcd", a, p["down"],
                      preferred_element_type=jnp.float32).astype(h.dtype)


# ---------------------------------------------------------------------------
# dense capacity dispatch (EP path)
# ---------------------------------------------------------------------------
def moe_dense(p: Params, x: jnp.ndarray, *, top_k: int,
              capacity_factor: float = 1.25,
              group_size: int = 1024) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x (B,S,D) → (out (B,S,D), aux loss). Tokens are processed in groups
    of ``group_size``; per-group expert capacity c = g·k/E·cf."""
    B, S, D = x.shape
    E = p["router"].shape[-1]
    N = B * S
    g = min(group_size, N)
    assert N % g == 0, (N, g)
    G = N // g
    c = max(int(g * top_k / E * capacity_factor), 1)

    x2d = x.reshape(N, D)
    probs, idx, aux = _router(p, x2d, top_k)                 # (N,k)

    xg = x2d.reshape(G, g, D)
    pg = probs.reshape(G, g, top_k)
    ig = idx.reshape(G, g, top_k)

    # position of each (token, choice) in its expert's queue, per group
    eh = jax.nn.one_hot(ig, E, dtype=jnp.int32)              # (G,g,k,E)
    flat = eh.reshape(G, g * top_k, E)
    pos = jnp.cumsum(flat, axis=1) - 1                       # (G,g*k,E)
    pos = (pos * flat).sum(-1).reshape(G, g, top_k)          # queue slot
    expert_pos = (pos * (ig >= 0)).astype(jnp.int32)
    keep = pos < c                                           # capacity drop

    # §Perf-A: the naive formulation materializes a (G,g,k,E,c) one-hot
    # (k·E·c per token — 21 GiB/device/layer for qwen3-moe). Instead the
    # k axis is contracted IMMEDIATELY: accumulate per-choice rank-1
    # one-hot products into the (G,g,E,c) dispatch/combine masks — an 8×
    # (= top_k) cut in dispatch bytes; combine weights ride the same
    # accumulation instead of a second (G,g,k,E,c) product.
    disp_mask = jnp.zeros((G, g, E, c), x.dtype)
    combine = jnp.zeros((G, g, E, c), x.dtype)
    for j in range(top_k):                                   # static, small
        ehj = jax.nn.one_hot(ig[..., j], E, dtype=x.dtype)   # (G,g,E)
        phj = jax.nn.one_hot(expert_pos[..., j], c, dtype=x.dtype)
        phj = phj * keep[..., j, None].astype(x.dtype)       # (G,g,c)
        hot = ehj[..., None] * phj[..., None, :]             # (G,g,E,c)
        disp_mask = disp_mask + hot
        combine = combine + hot * pg[..., j, None, None].astype(x.dtype)

    expert_in = jnp.einsum("ngec,ngd->encd", disp_mask, xg,
                           preferred_element_type=jnp.float32).astype(x.dtype)
    expert_in = shard(expert_in, "moe_expert_in")             # (E,G,c,D)
    expert_out = _expert_ffn(p, expert_in)                    # (E,G,c,D)
    expert_out = shard(expert_out, "moe_expert_out")
    out = jnp.einsum("ngec,encd->ngd", combine, expert_out,
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return out.reshape(B, S, D), aux


# ---------------------------------------------------------------------------
# ragged (sorted) dispatch — single-replica / serving path
# ---------------------------------------------------------------------------
def moe_ragged(p: Params, x: jnp.ndarray, *, top_k: int
               ) -> tuple[jnp.ndarray, jnp.ndarray]:
    B, S, D = x.shape
    E = p["router"].shape[-1]
    N = B * S
    x2d = x.reshape(N, D)
    probs, idx, aux = _router(p, x2d, top_k)

    flat_e = idx.reshape(-1)                                  # (N*k,)
    flat_t = jnp.repeat(jnp.arange(N), top_k)
    flat_w = probs.reshape(-1)
    order = jnp.argsort(flat_e)
    xs = x2d[flat_t[order]]                                   # (N*k, D)
    group_sizes = jnp.bincount(flat_e, length=E).astype(jnp.int32)

    g = jax.lax.ragged_dot(xs, p["gate"], group_sizes)
    u = jax.lax.ragged_dot(xs, p["up"], group_sizes)
    a = (jax.nn.silu(g.astype(jnp.float32)) * u).astype(x.dtype)
    eo = jax.lax.ragged_dot(a, p["down"], group_sizes)        # (N*k, D)

    out = jnp.zeros((N, D), eo.dtype)
    out = out.at[flat_t[order]].add(eo * flat_w[order, None].astype(eo.dtype))
    return out.reshape(B, S, D), aux


def moe_ffn(p: Params, x: jnp.ndarray, *, top_k: int,
            impl: str = "dense", capacity_factor: float = 1.25,
            group_size: int = 1024) -> tuple[jnp.ndarray, jnp.ndarray]:
    if impl == "dense":
        return moe_dense(p, x, top_k=top_k, capacity_factor=capacity_factor,
                         group_size=group_size)
    if impl == "ragged":
        return moe_ragged(p, x, top_k=top_k)
    raise ValueError(f"unknown moe impl {impl!r}")
