"""Whisper-style encoder-decoder transformer.

The conv audio frontend is a STUB per the assignment: ``input_specs()``
feeds precomputed frame embeddings (B, enc_ctx, D) directly (the two
stride-2 convs + GELU that produce them are outside scope). Everything
after — sinusoidal positions, pre-LN GELU encoder, decoder with causal
self-attention + cross-attention, tied output embedding — is implemented.

``cfg.n_layers`` is the *decoder* depth; ``cfg.n_enc_layers`` the encoder
depth (whisper-medium: 24/24).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from . import attention as A
from .common import (Params, embed_init, init_layernorm, layernorm, linear,
                     mm, shard, softmax_xent, split_keys)
from .mlp import gelu_mlp, init_gelu_mlp


def sinusoids(length: int, channels: int) -> np.ndarray:
    """Whisper's sinusoidal position embedding."""
    log_timescale = np.log(10000.0) / (channels // 2 - 1)
    inv = np.exp(-log_timescale * np.arange(channels // 2))
    scaled = np.arange(length)[:, None] * inv[None, :]
    return np.concatenate([np.sin(scaled), np.cos(scaled)], axis=1)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _init_enc_layer(cfg: ArchConfig, key) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_layernorm(cfg.d_model),
        "attn": A.init_attention(k1, cfg.d_model, cfg.n_heads,
                                 cfg.n_kv_heads, cfg.head_dim,
                                 qkv_bias=True, out_bias=True),
        "ln2": init_layernorm(cfg.d_model),
        "mlp": init_gelu_mlp(k2, cfg.d_model, cfg.d_ff, bias=True),
    }


def _init_dec_layer(cfg: ArchConfig, key) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": init_layernorm(cfg.d_model),
        "self_attn": A.init_attention(k1, cfg.d_model, cfg.n_heads,
                                      cfg.n_kv_heads, cfg.head_dim,
                                      qkv_bias=True, out_bias=True),
        "ln_x": init_layernorm(cfg.d_model),
        "cross_attn": A.init_attention(k2, cfg.d_model, cfg.n_heads,
                                       cfg.n_kv_heads, cfg.head_dim,
                                       qkv_bias=True, out_bias=True),
        "ln2": init_layernorm(cfg.d_model),
        "mlp": init_gelu_mlp(k3, cfg.d_model, cfg.d_ff, bias=True),
    }


def init_params(cfg: ArchConfig, key) -> Params:
    k_e, k_enc, k_dec, k_pos = jax.random.split(key, 4)
    enc_keys = jnp.stack(split_keys(k_enc, cfg.n_enc_layers))
    dec_keys = jnp.stack(split_keys(k_dec, cfg.n_layers))
    return {
        "embed": embed_init(k_e, cfg.vocab, cfg.d_model),   # tied head
        "dec_pos": (jax.random.normal(k_pos, (4096, cfg.d_model),
                                      jnp.float32) * 0.01).astype(jnp.bfloat16),
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(cfg, k))(enc_keys),
        "enc_ln_post": init_layernorm(cfg.d_model),
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(cfg, k))(dec_keys),
        "dec_ln": init_layernorm(cfg.d_model),
    }


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------
def encode(cfg: ArchConfig, params: Params, frames: jnp.ndarray,
           *, remat: bool = True) -> jnp.ndarray:
    """frames (B, enc_ctx, D) — precomputed embeddings (frontend stub)."""
    T = frames.shape[1]
    pos = jnp.asarray(sinusoids(T, cfg.d_model), frames.dtype)
    x = frames + pos[None]
    x = shard(x, "act_resid")

    def layer(p, h):
        # encoder attention is bidirectional (causal=False)
        h2 = layernorm(p["ln1"], h)
        q, k, v = A.qkv(p["attn"], h2, cfg.n_heads, cfg.n_kv_heads,
                        cfg.head_dim, None, None)
        o = A.full_attention(q, k, v, causal=False)
        h = h + linear(p["attn"]["o"], o.reshape(h.shape[0], T, -1))
        h = h + gelu_mlp(p["mlp"], layernorm(p["ln2"], h))
        return shard(h, "act_resid")

    def body(h, p):
        fn = layer
        if remat:
            fn = jax.checkpoint(layer,
                                policy=jax.checkpoint_policies.nothing_saveable)
        return fn(p, h), None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return layernorm(params["enc_ln_post"], x)


# ---------------------------------------------------------------------------
# decoder
# ---------------------------------------------------------------------------
def _dec_layer(cfg: ArchConfig, p: Params, h: jnp.ndarray,
               enc_out: jnp.ndarray, flash: bool) -> jnp.ndarray:
    B, S, _ = h.shape
    a = layernorm(p["ln1"], h)
    q, k, v = A.qkv(p["self_attn"], a, cfg.n_heads, cfg.n_kv_heads,
                    cfg.head_dim, None, None)
    if flash:
        o = A.flash_attention(q, k, v, causal=True,
                              q_block=min(2048, S), kv_block=min(1024, S))
    else:
        o = A.full_attention(q, k, v, causal=True)
    h = h + linear(p["self_attn"]["o"], o.reshape(B, S, -1))
    # cross attention
    cx = layernorm(p["ln_x"], h)
    qx = linear(p["cross_attn"]["q"], cx).reshape(B, S, cfg.n_heads,
                                                  cfg.head_dim)
    kx = linear(p["cross_attn"]["k"], enc_out).reshape(
        B, -1, cfg.n_kv_heads, cfg.head_dim)
    vx = linear(p["cross_attn"]["v"], enc_out).reshape(
        B, -1, cfg.n_kv_heads, cfg.head_dim)
    ox = A.full_attention(qx, kx, vx, causal=False)
    h = h + linear(p["cross_attn"]["o"], ox.reshape(B, S, -1))
    h = h + gelu_mlp(p["mlp"], layernorm(p["ln2"], h))
    return shard(h, "act_resid")


def decode_train(cfg: ArchConfig, params: Params, tokens: jnp.ndarray,
                 enc_out: jnp.ndarray, *, remat: bool = True) -> jnp.ndarray:
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x + params["dec_pos"][:S][None].astype(x.dtype) if S <= 4096 else x
    flash = S > 2048

    def layer(p, h, enc):
        return _dec_layer(cfg, p, h, enc, flash)   # flash baked in (static)

    def body(h, p):
        fn = layer
        if remat:
            fn = jax.checkpoint(layer,
                                policy=jax.checkpoint_policies.nothing_saveable)
        return fn(p, h, enc_out), None

    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    return layernorm(params["dec_ln"], x)


def logits_from_hidden(cfg: ArchConfig, params: Params,
                       x: jnp.ndarray) -> jnp.ndarray:
    out = jax.lax.dot_general(
        x, params["embed"], (((x.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    return shard(out, "act_logits")


def loss_fn(cfg: ArchConfig, params: Params, batch: dict, *,
            remat: bool = True):
    """batch: frames (B,enc_ctx,D), tokens (B,S), labels (B,S)."""
    enc_out = encode(cfg, params, batch["frames"], remat=remat)
    hidden = decode_train(cfg, params, batch["tokens"], enc_out, remat=remat)
    logits = logits_from_hidden(cfg, params, hidden)
    xent = softmax_xent(logits, batch["labels"], batch.get("loss_mask"))
    return xent, {"xent": xent, "aux": jnp.zeros((), jnp.float32)}


# ---------------------------------------------------------------------------
# serving: cross-KV computed once at prefill; self-KV cached per step
# ---------------------------------------------------------------------------
def cache_specs(cfg: ArchConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16) -> Params:
    L = cfg.n_layers
    kv = (L, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    xkv = (L, batch, cfg.enc_ctx, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jax.ShapeDtypeStruct(kv, dtype),
        "v": jax.ShapeDtypeStruct(kv, dtype),
        "xk": jax.ShapeDtypeStruct(xkv, dtype),
        "xv": jax.ShapeDtypeStruct(xkv, dtype),
        "length": jax.ShapeDtypeStruct((), jnp.int32),
    }


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Params:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_specs(cfg, batch, max_len, dtype))


def prefill(cfg: ArchConfig, params: Params, tokens: jnp.ndarray,
            cache: Params, *, frames: jnp.ndarray):
    enc_out = encode(cfg, params, frames, remat=False)
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    if S <= 4096:
        x = x + params["dec_pos"][:S][None].astype(x.dtype)
    flash = S > 2048
    T = cache["k"].shape[2]

    def body(h, p):
        a = layernorm(p["ln1"], h)
        q, k, v = A.qkv(p["self_attn"], a, cfg.n_heads, cfg.n_kv_heads,
                        cfg.head_dim, None, None)
        if flash:
            o = A.flash_attention(q, k, v, causal=True,
                                  q_block=min(2048, S), kv_block=min(1024, S))
        else:
            o = A.full_attention(q, k, v, causal=True)
        h = h + linear(p["self_attn"]["o"], o.reshape(B, S, -1))
        cx = layernorm(p["ln_x"], h)
        qx = linear(p["cross_attn"]["q"], cx).reshape(B, S, cfg.n_heads,
                                                      cfg.head_dim)
        kx = linear(p["cross_attn"]["k"], enc_out).reshape(
            B, -1, cfg.n_kv_heads, cfg.head_dim)
        vx = linear(p["cross_attn"]["v"], enc_out).reshape(
            B, -1, cfg.n_kv_heads, cfg.head_dim)
        ox = A.full_attention(qx, kx, vx, causal=False)
        h = h + linear(p["cross_attn"]["o"], ox.reshape(B, S, -1))
        h = h + gelu_mlp(p["mlp"], layernorm(p["ln2"], h))
        return h, (k, v, kx, vx)

    x, (k, v, xk, xv) = jax.lax.scan(body, x, params["dec_layers"])
    cache = dict(cache)
    cache["k"] = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0, 0))
    cache["v"] = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0, 0))
    cache["xk"] = xk.astype(cache["xk"].dtype)
    cache["xv"] = xv.astype(cache["xv"].dtype)
    cache["length"] = jnp.asarray(S, jnp.int32)
    x = layernorm(params["dec_ln"], x[:, -1:])
    return logits_from_hidden(cfg, params, x), cache


def decode_step(cfg: ArchConfig, params: Params, cache: Params,
                tokens: jnp.ndarray):
    B = tokens.shape[0]
    length = cache["length"]
    x = jnp.take(params["embed"], tokens, axis=0)
    pos_emb = jax.lax.dynamic_slice_in_dim(
        params["dec_pos"], jnp.minimum(length, 4095), 1, 0)
    x = x + pos_emb[None].astype(x.dtype)

    def body(h, xs):
        p, k_c, v_c, xk, xv = xs
        a = layernorm(p["ln1"], h)
        q, k, v = A.qkv(p["self_attn"], a, cfg.n_heads, cfg.n_kv_heads,
                        cfg.head_dim, None, None)
        k_c = jax.lax.dynamic_update_slice(k_c, k.astype(k_c.dtype),
                                           (0, length, 0, 0))
        v_c = jax.lax.dynamic_update_slice(v_c, v.astype(v_c.dtype),
                                           (0, length, 0, 0))
        o = A.decode_attention(q, k_c, v_c, length + 1)
        h = h + linear(p["self_attn"]["o"], o.reshape(B, 1, -1))
        cx = layernorm(p["ln_x"], h)
        qx = linear(p["cross_attn"]["q"], cx).reshape(B, 1, cfg.n_heads,
                                                      cfg.head_dim)
        ox = A.decode_attention(qx, xk, xv, None)
        h = h + linear(p["cross_attn"]["o"], ox.reshape(B, 1, -1))
        h = h + gelu_mlp(p["mlp"], layernorm(p["ln2"], h))
        return h, (k_c, v_c)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]))
    cache = dict(cache, k=k_new, v=v_new, length=length + 1)
    x = layernorm(params["dec_ln"], x)
    return logits_from_hidden(cfg, params, x), cache
