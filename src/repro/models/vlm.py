"""InternVL2-style VLM: InternLM2 text backbone + stubbed ViT frontend.

Per the assignment the modality frontend is a STUB: ``input_specs()``
provides precomputed patch embeddings (B, n_img_tokens, d_model) — the
InternViT-300M tower + pixel-shuffle + MLP projector that produce them
are outside scope. The backbone (24L/2048d GQA transformer) is the full
implementation from :mod:`transformer`; image tokens are prepended to the
text sequence and excluded from the LM loss.
"""
from __future__ import annotations

import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import transformer as T
from .common import Params


def init_params(cfg: ArchConfig, key) -> Params:
    return T.init_params(cfg, key)


def loss_fn(cfg: ArchConfig, params: Params, batch: dict, *,
            remat: bool = True):
    """batch: patch_embeds (B,P,D), tokens (B,S), labels (B,S)."""
    return T.loss_fn(cfg, params,
                     dict(batch, prefix_embeds=batch["patch_embeds"]),
                     remat=remat)


def cache_specs(cfg: ArchConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16) -> Params:
    return T.cache_specs(cfg, batch, max_len, dtype)


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Params:
    return T.init_cache(cfg, batch, max_len, dtype)


def prefill(cfg: ArchConfig, params: Params, tokens: jnp.ndarray,
            cache: Params, *, patch_embeds: jnp.ndarray):
    return T.prefill(cfg, params, tokens, cache, prefix_embeds=patch_embeds)


def decode_step(cfg: ArchConfig, params: Params, cache: Params,
                tokens: jnp.ndarray):
    return T.decode_step(cfg, params, cache, tokens)
