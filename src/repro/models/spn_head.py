"""SPN reasoning head — the paper's fig. 1 hybrid integration point.

"Deep Learning for perception and probabilistic models for reasoning":
any backbone in the zoo can attach this head. The backbone's pooled
features are mapped to *soft evidence* on the SPN's indicator leaves
(per-variable Bernoulli probabilities), and the SPN — executed by the
Pallas kernel (deploy) or the leveled executor (train, differentiable) —
returns the log-probability of the query under the probabilistic model.

The SPN parameters can be trained jointly (gradients flow through the
log-domain leveled executor into both SPN weights and the projection).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core import executors
from ..core.program import TensorProgram
from .common import Params, init_linear, linear


def init_spn_head(key, d_model: int, prog: TensorProgram) -> Params:
    """Trainable head. SPN sum-weights live as per-sum softmax logits so
    training keeps the circuit a NORMALIZED distribution (log P ≤ 0)."""
    return {
        "proj": init_linear(key, d_model, prog.num_vars, dtype=jnp.float32),
        "spn_logits": jnp.log(jnp.clip(
            jnp.asarray(prog.param_values, jnp.float32), 1e-6, None)),
    }


def _group_info(prog: TensorProgram):
    gidx = np.full(prog.m_param, -1, np.int32)
    for g, idx in enumerate(prog.sum_weight_groups):
        gidx[idx] = g
    return jnp.asarray(gidx), len(prog.sum_weight_groups)


def spn_params_from_logits(prog: TensorProgram, logits: jnp.ndarray
                           ) -> jnp.ndarray:
    """Per-sum softmax; frozen (non-weight) params pass through exp∘log."""
    gidx, ng = _group_info(prog)
    p = jnp.exp(logits)
    grp = jnp.where(gidx < 0, ng, gidx)
    totals = jnp.zeros(ng + 1, p.dtype).at[grp].add(p)
    denom = jnp.where(gidx < 0, 1.0, totals[grp])
    return p / jnp.maximum(denom, 1e-30)


def evidence_from_features(prog: TensorProgram, probs: jnp.ndarray
                           ) -> jnp.ndarray:
    """Per-variable Bernoulli probs (B, num_vars) → leaf inputs (B, m_ind).

    Soft evidence: indicator [var==1] gets p, [var==0] gets 1-p — the SPN
    then computes the expected likelihood under independent leaf beliefs.
    """
    var = jnp.asarray(prog.ind_var)
    val = jnp.asarray(prog.ind_value)
    pv = probs[:, var]                                 # (B, m_ind)
    return jnp.where(val[None, :] == 1, pv, 1.0 - pv)


def apply_spn_head(prog: TensorProgram, p: Params, features: jnp.ndarray,
                   *, use_kernel: bool = False) -> jnp.ndarray:
    """features (B, D) → (B,) log-probability of the soft evidence."""
    probs = jax.nn.sigmoid(linear(p["proj"], features.astype(jnp.float32)))
    leaves = evidence_from_features(prog, probs)
    params = spn_params_from_logits(prog, p["spn_logits"])
    if use_kernel:
        from ..kernels.spn_eval import spn_eval
        return spn_eval(prog, leaves, params, log_domain=True)
    return executors.eval_leveled(prog, leaves, params, True)


def nll_loss(prog: TensorProgram, p: Params, features: jnp.ndarray
             ) -> jnp.ndarray:
    return -jnp.mean(apply_spn_head(prog, p, features))
