"""Attention: GQA projections, full / blockwise-flash causal attention,
cross-attention, and single-step decode against a KV cache.

Blockwise attention (``flash_attention``) is the lax.scan online-softmax
formulation: O(S·block) live memory instead of O(S²), which is what lets
the 32k-prefill shapes lower without materializing the score matrix.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .common import Params, apply_rope, init_linear, linear, mm, shard

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# projections
# ---------------------------------------------------------------------------
def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, *, qkv_bias: bool = False,
                   out_bias: bool = False, dtype=jnp.bfloat16) -> Params:
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "q": init_linear(kq, d_model, n_heads * head_dim, bias=qkv_bias, dtype=dtype),
        "k": init_linear(kk, d_model, n_kv_heads * head_dim, bias=qkv_bias, dtype=dtype),
        "v": init_linear(kv, d_model, n_kv_heads * head_dim, bias=qkv_bias, dtype=dtype),
        "o": init_linear(ko, n_heads * head_dim, d_model, bias=out_bias, dtype=dtype),
    }


def qkv(p: Params, x: jnp.ndarray, n_heads: int, n_kv_heads: int,
        head_dim: int, positions: jnp.ndarray | None,
        rope_theta: float | None):
    """x (B,S,D) → q (B,S,H,hd), k/v (B,S,KV,hd), RoPE applied if theta."""
    B, S, _ = x.shape
    q = linear(p["q"], x).reshape(B, S, n_heads, head_dim)
    k = linear(p["k"], x).reshape(B, S, n_kv_heads, head_dim)
    v = linear(p["v"], x).reshape(B, S, n_kv_heads, head_dim)
    if rope_theta is not None:
        if positions is None:
            positions = jnp.arange(S, dtype=jnp.int32)[None, :]
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    # sharding constraints apply to every caller (train/prefill/decode)
    q = shard(q, "act_heads")
    k = shard(k, "act_kv_heads")
    v = shard(v, "act_kv_heads")
    return q, k, v


def _expand_kv(k: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """(B,S,KV,hd) → (B,S,H,hd) by group broadcast (GQA)."""
    B, S, KV, hd = k.shape
    if KV == n_heads:
        return k
    rep = n_heads // KV
    return jnp.broadcast_to(k[:, :, :, None, :], (B, S, KV, rep, hd)
                            ).reshape(B, S, n_heads, hd)


# ---------------------------------------------------------------------------
# full attention (short sequences; O(S^2) scores in bf16)
# ---------------------------------------------------------------------------
def full_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                   causal: bool = True) -> jnp.ndarray:
    """q (B,S,H,hd), k/v (B,T,KV,hd) → (B,S,H,hd)."""
    B, S, H, hd = q.shape
    T = k.shape[1]
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    scores = jnp.einsum("bshd,bthd->bhst", q, k,
                        preferred_element_type=jnp.float32) * scale
    if causal:
        mask = jnp.tril(jnp.ones((S, T), bool), T - S)
        scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhst,bthd->bshd", probs, v,
                      preferred_element_type=jnp.float32).astype(q.dtype)


# ---------------------------------------------------------------------------
# blockwise flash attention (online softmax over KV blocks via lax.scan)
# ---------------------------------------------------------------------------
def _expand_g(x: jnp.ndarray, group: int) -> jnp.ndarray:
    return jnp.repeat(x, group, axis=2) if group > 1 else x


def _flash_fwd_impl(q, k, v, causal, q_block, kv_block):
    """Returns (out (B,S,H,hd), lse (nq,B,KV,g,qb)).

    GQA is handled by GROUPED einsums — K/V are never repeat-expanded to
    H heads (§Perf-E: the per-tile `jnp.repeat` materialization was 81 %
    of the qwen3-moe prefill bytes). Score layout: (B, KV, g, qb, kb).
    """
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    nq, nk = S // q_block, T // kv_block
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    group = H // KV

    qb = q.reshape(B, nq, q_block, KV, group, hd).transpose(1, 0, 2, 3, 4, 5)
    kb = k.reshape(B, nk, kv_block, KV, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nk, kv_block, KV, hd).transpose(1, 0, 2, 3, 4)

    def q_step(iq, qi):                       # qi (B, qb, KV, g, hd)
        q_pos = iq * q_block + jnp.arange(q_block)

        def attend(acc, m, l, ki, vi, ik):
            k_pos = ik * kv_block + jnp.arange(kv_block)
            s = jnp.einsum("bqkgd,bckd->bkgqc", qi, ki,
                           preferred_element_type=jnp.float32) * scale
            if causal:
                # additive (qb,kb) mask: no big pred materialization
                madd = jnp.where(q_pos[:, None] >= k_pos[None, :],
                                 0.0, NEG_INF).astype(jnp.float32)
                s = s + madd[None, None, None]
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(-1)
            pv = jnp.einsum("bkgqc,bckd->bkgqd", p.astype(vi.dtype), vi,
                            preferred_element_type=jnp.float32)
            return acc * corr[..., None] + pv, m_new, l

        def kv_step(carry, kv):
            acc, m, l, ik = carry
            ki, vi = kv
            if causal:     # whole block in the future of every query → skip
                live = ik * kv_block <= (iq + 1) * q_block - 1
                acc, m, l = jax.lax.cond(
                    live,
                    lambda a, mm, ll: attend(a, mm, ll, ki, vi, ik),
                    lambda a, mm, ll: (a, mm, ll), acc, m, l)
            else:
                acc, m, l = attend(acc, m, l, ki, vi, ik)
            return (acc, m, l, ik + 1), None

        acc0 = jnp.zeros((B, KV, group, q_block, hd), jnp.float32)
        m0 = jnp.full((B, KV, group, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, group, q_block), jnp.float32)
        (acc, m, l, _), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0, jnp.zeros((), jnp.int32)), (kb, vb))
        out = acc / jnp.maximum(l[..., None], 1e-30)    # (B,KV,g,qb,hd)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))        # (B,KV,g,qb)
        return iq + 1, (out.transpose(0, 3, 1, 2, 4), lse)

    _, (ob, lse) = jax.lax.scan(q_step, jnp.zeros((), jnp.int32), qb)
    out = (ob.transpose(1, 0, 2, 3, 4, 5)
           .reshape(B, S, H, hd).astype(q.dtype))
    return out, lse                                # lse (nq,B,KV,g,qb)


def _flash_bwd_impl(causal, q_block, kv_block, res, do):
    """Block-recomputing backward (flash attention 2 style): no stacked
    score residuals — each (i,j) tile recomputes p from q,k and the saved
    log-sum-exp, entirely inside the scan body (§Perf-A). Grouped GQA
    einsums throughout — K/V never repeat-expanded (§Perf-E)."""
    q, k, v, out, lse = res                    # lse (nq,B,KV,g,qb)
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    nq, nk = S // q_block, T // kv_block
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    group = H // KV
    do = do.astype(jnp.float32)

    # D_i = rowsum(do ⊙ o) per position, in grouped layout (nq,B,KV,g,qb)
    Dfull = (do * out.astype(jnp.float32)).sum(-1)        # (B,S,H)
    qb = (q.reshape(B, nq, q_block, KV, group, hd)
          .transpose(1, 0, 2, 3, 4, 5))                   # (nq,B,qb,KV,g,hd)
    dob = (do.reshape(B, nq, q_block, KV, group, hd)
           .transpose(1, 0, 2, 3, 4, 5))
    Db = (Dfull.reshape(B, nq, q_block, KV, group)
          .transpose(1, 0, 3, 4, 2))                      # (nq,B,KV,g,qb)
    kb = k.reshape(B, nk, kv_block, KV, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nk, kv_block, KV, hd).transpose(1, 0, 2, 3, 4)

    dk = jnp.zeros((nk, B, kv_block, KV, hd), jnp.float32)
    dv = jnp.zeros((nk, B, kv_block, KV, hd), jnp.float32)

    def q_step(carry, xs):
        dk, dv, iq = carry
        qi, doi, lsei, Di = xs                 # per-q-block slices (grouped)

        def tile(ik, ki, vi):
            k_pos = ik * kv_block + jnp.arange(kv_block)
            q_pos = iq * q_block + jnp.arange(q_block)
            s = jnp.einsum("bqkgd,bckd->bkgqc", qi, ki,
                           preferred_element_type=jnp.float32) * scale
            if causal:
                madd = jnp.where(q_pos[:, None] >= k_pos[None, :],
                                 0.0, NEG_INF).astype(jnp.float32)
                s = s + madd[None, None, None]
            p = jnp.exp(s - lsei[..., None])              # (B,KV,g,qb,kb)
            dvj = jnp.einsum("bkgqc,bqkgd->bckd", p, doi,
                             preferred_element_type=jnp.float32)
            dp = jnp.einsum("bqkgd,bckd->bkgqc", doi,
                            vi.astype(jnp.float32),
                            preferred_element_type=jnp.float32)
            ds = p * (dp - Di[..., None]) * scale         # (B,KV,g,qb,kb)
            dqj = jnp.einsum("bkgqc,bckd->bqkgd", ds,
                             ki.astype(jnp.float32),
                             preferred_element_type=jnp.float32)
            dkj = jnp.einsum("bkgqc,bqkgd->bckd", ds, qi.astype(jnp.float32),
                             preferred_element_type=jnp.float32)
            return dqj, dkj, dvj

        def kv_step(carry2, kv):
            dqi, dk, dv, ik = carry2
            ki, vi = kv
            zeros = (jnp.zeros((B, q_block, KV, group, hd), jnp.float32),
                     jnp.zeros((B, kv_block, KV, hd), jnp.float32),
                     jnp.zeros((B, kv_block, KV, hd), jnp.float32))
            if causal:
                live = ik * kv_block <= (iq + 1) * q_block - 1
                dqj, dkj, dvj = jax.lax.cond(
                    live, lambda: tile(ik, ki, vi), lambda: zeros)
            else:
                dqj, dkj, dvj = tile(ik, ki, vi)
            dk = jax.lax.dynamic_update_index_in_dim(
                dk, dk[ik] + dkj, ik, 0)
            dv = jax.lax.dynamic_update_index_in_dim(
                dv, dv[ik] + dvj, ik, 0)
            return (dqi + dqj, dk, dv, ik + 1), None

        dq0 = jnp.zeros((B, q_block, KV, group, hd), jnp.float32)
        (dqi, dk, dv, _), _ = jax.lax.scan(
            kv_step, (dq0, dk, dv, jnp.zeros((), jnp.int32)), (kb, vb))
        return (dk, dv, iq + 1), dqi

    (dk, dv, _), dqb = jax.lax.scan(
        q_step, (dk, dv, jnp.zeros((), jnp.int32)), (qb, dob, lse, Db))
    dq = dqb.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, H, hd)
    dk = dk.transpose(1, 0, 2, 3, 4).reshape(B, T, KV, hd)
    dv = dv.transpose(1, 0, 2, 3, 4).reshape(B, T, KV, hd)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype))


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash(q, k, v, causal, q_block, kv_block):
    return _flash_fwd_impl(q, k, v, causal, q_block, kv_block)[0]


def _flash_vjp_fwd(q, k, v, causal, q_block, kv_block):
    out, lse = _flash_fwd_impl(q, k, v, causal, q_block, kv_block)
    return out, (q, k, v, out, lse)


def _flash_vjp_bwd(causal, q_block, kv_block, res, do):
    return _flash_bwd_impl(causal, q_block, kv_block, res, do)


_flash.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


@functools.partial(jax.jit, static_argnames=("causal", "q_block", "kv_block"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, q_block: int = 2048,
                    kv_block: int = 1024) -> jnp.ndarray:
    """Memory-O(block) attention with a block-recomputing custom VJP.

    q (B,S,H,hd), k/v (B,T,KV,hd). §Perf-A notes: block indices ride scan
    carries (so causal masks are per-iteration iota math, not hoisted
    stacked buffers); fully-masked kv blocks are skipped with scalar
    `lax.cond`; the backward never materializes stacked probabilities —
    residuals are just (q, k, v, out, lse).
    """
    B, S, H, hd = q.shape
    T = k.shape[1]
    assert S % q_block == 0 and T % kv_block == 0, (S, T, q_block, kv_block)
    return _flash(q, k, v, causal, q_block, kv_block)


# ---------------------------------------------------------------------------
# decode: one query position against a cache
# ---------------------------------------------------------------------------
def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray,
                     length: jnp.ndarray | int | None = None) -> jnp.ndarray:
    """q (B,1,H,hd), cache (B,T,KV,hd) → (B,1,H,hd).

    ``length``: valid cache prefix (positions ≥ length masked out).
    """
    B, _, H, hd = q.shape
    T, KV = k_cache.shape[1], k_cache.shape[2]
    group = H // KV
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    # (B,1,H,hd) x (B,T,KV,hd) — grouped einsum without materializing repeat
    qg = q.reshape(B, 1, KV, group, hd)
    s = jnp.einsum("bqkgd,btkd->bkgqt", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    if length is not None:
        pos = jnp.arange(T)
        s = jnp.where(pos[None, None, None, None, :] < length, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqt,btkd->bqkgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, H, hd).astype(q.dtype)


def attention_block(p: Params, x: jnp.ndarray, *, n_heads: int,
                    n_kv_heads: int, head_dim: int,
                    rope_theta: float | None = 10000.0,
                    positions: jnp.ndarray | None = None,
                    flash: bool | None = None,
                    q_block: int = 2048, kv_block: int = 1024) -> jnp.ndarray:
    """Self-attention over x (B,S,D) → (B,S,D); picks full vs flash by S."""
    B, S, D = x.shape
    q, k, v = qkv(p, x, n_heads, n_kv_heads, head_dim, positions, rope_theta)
    q = shard(q, "act_heads")
    k = shard(k, "act_kv_heads")
    v = shard(v, "act_kv_heads")
    use_flash = (S > 2048) if flash is None else flash
    if use_flash:
        o = flash_attention(q, k, v, causal=True,
                            q_block=min(q_block, S), kv_block=min(kv_block, S))
    else:
        o = full_attention(q, k, v, causal=True)
    o = o.reshape(B, S, n_heads * head_dim)
    return linear(p["o"], o)
