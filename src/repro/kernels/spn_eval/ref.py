"""Pure-jnp oracle for the spn_eval Pallas kernel.

Implements exactly the computation the kernel performs — a leveled pass
over the slot value buffer with static per-level operand gathers — in
plain ``jnp`` with no Pallas, no padding tricks, float32 throughout
(kernels compute in f32; float64 reference lives in
``repro.core.executors.eval_ops_numpy``).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ...core.program import TensorProgram


def spn_eval_ref(prog: TensorProgram, leaf_ind: jnp.ndarray,
                 params: jnp.ndarray | None = None,
                 log_domain: bool = False) -> jnp.ndarray:
    """Evaluate ``prog`` for a batch. ``leaf_ind``: (batch, m_ind) → (batch,).

    Value-buffer layout identical to the kernel: slots [0, m) leaves,
    [m, m+n) op outputs, level-contiguous.
    """
    leaf_ind = jnp.atleast_2d(leaf_ind).astype(jnp.float32)
    batch = leaf_ind.shape[0]
    p = jnp.asarray(prog.param_values, jnp.float32) if params is None else params
    p = jnp.broadcast_to(p.astype(jnp.float32), (batch, prog.m_param))
    A = jnp.concatenate([leaf_ind, p], axis=1).T          # (m, batch)
    if log_domain:
        A = jnp.log(A)
    for lo, hi in zip(prog.level_offsets[:-1], prog.level_offsets[1:]):
        lo, hi = int(lo), int(hi)
        b = np.asarray(prog.b[lo:hi])                      # static gather
        c = np.asarray(prog.c[lo:hi])
        op = np.asarray(prog.opcode[lo:hi])[:, None]
        vb, vc = A[b], A[c]
        prod = vb + vc if log_domain else vb * vc
        add = jnp.logaddexp(vb, vc) if log_domain else vb + vc
        new = jnp.where(op == 1, prod,
                        jnp.where(op == 2, jnp.maximum(vb, vc), add))
        A = jnp.concatenate([A, new], axis=0)
    return A[prog.root_slot]
