"""Pure-jnp oracle for the spn_eval Pallas kernel.

Implements exactly the computation the kernel performs — the segment
schedule of :mod:`repro.core.segments`: per level, one static gather and
one unpredicated halving reduction per opcode-homogeneous segment — in
plain ``jnp`` with no Pallas, float32 throughout, sharing the kernel's
:func:`~repro.kernels.spn_eval.kernel._logaddexp` so log-domain results
are bitwise comparable too (the float64 reference lives in
``repro.core.executors.eval_ops_numpy``).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ...core import segments
from ...core.program import TensorProgram
from .kernel import _segment_reduce


def spn_eval_ref(prog: TensorProgram, leaf_ind: jnp.ndarray,
                 params: jnp.ndarray | None = None,
                 log_domain: bool = False) -> jnp.ndarray:
    """Evaluate ``prog`` for a batch. ``leaf_ind``: (batch, m_ind) → (batch,).

    Value-buffer layout identical to the kernel: slots [0, m) leaves,
    [m, node_base) neutral pads + alignment, then level-contiguous
    fused-node outputs.
    """
    seg = segments.segment_program(prog)
    leaf_ind = jnp.atleast_2d(leaf_ind).astype(jnp.float32)
    batch = leaf_ind.shape[0]
    p = jnp.asarray(prog.param_values, jnp.float32) if params is None else params
    p = jnp.broadcast_to(p.astype(jnp.float32), (batch, prog.m_param))
    A = jnp.concatenate([leaf_ind, p], axis=1).T          # (m, batch)
    if log_domain:
        A = jnp.log(A)
    tail = jnp.asarray(seg.init_rows(log_domain)[seg.m:], jnp.float32)
    A = jnp.concatenate(
        [A, jnp.broadcast_to(tail[:, None], (seg.node_base - seg.m, batch))],
        axis=0)
    for s in range(seg.num_segments):
        g0 = int(seg.seg_off[s])
        ns = int(seg.seg_nodes[s])
        idx = np.asarray(seg.gather[g0: g0 + int(seg.seg_arity[s]) * ns])
        vals = _segment_reduce(A[idx], int(seg.seg_op[s]),
                               log_domain, ns)
        A = jnp.concatenate([A, vals], axis=0)
    return A[seg.root_slot]
