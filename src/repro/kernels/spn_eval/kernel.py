"""Pallas TPU kernel for segment-scheduled SPN evaluation.

TPU adaptation of the paper's processor (DESIGN.md §2): the *batch*
dimension rides the 128 VPU lanes (the paper's node-parallel scalar PEs
become lane-parallel evaluations), node slots ride sublanes, and the whole
slot value buffer lives in a **VMEM scratch** — the analogue of the
paper's banked register file. All levels execute inside one
``pallas_call``, so intermediates never round-trip through HBM (the
analogue of PE-tree datapath fusion: "avoiding frequent writebacks to the
register file").

Scheduling follows the **segment scheduler**
(:mod:`repro.core.segments`): every level is a run of opcode-homogeneous
n-ary segments, and each segment executes as one sublane gather followed
by unpredicated halving ufuncs — exactly the paper's "one homogeneous
operation per PE group per step". The old per-element ``is_prod`` /
``is_max`` masks and the three-way ``where`` select are gone from the
inner loop; the opcode is resolved *per segment at trace time*, not per
element at run time.

The per-segment operand indices are streamed to the kernel as an
**instruction tensor** — the Pallas analogue of the paper's VLIW
instruction stream: the flat bit-reversed gather stream resident
on-chip, consumed one segment per step; the ``(seg_off, arity, op)``
descriptor table is static and unrolled into the kernel body. Levels
are 8-aligned (enforced by :func:`repro.core.segments.segment_program`)
so every value-buffer slice is tile-friendly; gathers index the sublane
axis with i32 vectors (Mosaic `dynamic_gather`).

Because segments carry the full opcode alphabet (SUM_N / PROD_N /
MAX_N), the same kernel executes sum-product (likelihood/marginal) and
max-product (MPE) programs — the query engine just streams a different
descriptor table.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core import segments
from ...core.segments import SegmentedProgram

SUBLANE = 8     # f32 sublane tile
LANE = 128      # lane tile


def default_interpret() -> bool:
    """Auto-detected interpret mode: compiled on TPU, interpreter elsewhere.

    The kernel used to hardwire ``interpret=True``, silently running the
    (orders-of-magnitude slower) Pallas interpreter even on TPU hosts;
    now the backend decides and callers may force either mode explicitly.
    """
    return jax.default_backend() != "tpu"


def _logaddexp(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Mosaic-friendly stable logaddexp (handles -inf without NaN)."""
    mx = jnp.maximum(a, b)
    mn = jnp.minimum(a, b)
    safe = jnp.isfinite(mx)
    diff = jnp.where(safe, mn - mx, 0.0)
    return jnp.where(safe, mx + jnp.log1p(jnp.exp(diff)), mx)


def _segment_reduce(vals: jnp.ndarray, op: int, log_domain: bool,
                    n_nodes: int) -> jnp.ndarray:
    """Unpredicated halving reduction of one homogeneous segment —
    the shared pairing rule with the kernel's Mosaic-safe logaddexp."""
    return segments.halving_reduce(
        vals, segments.combine_fn(op, log_domain, jnp,
                                  logaddexp=_logaddexp), n_nodes)


def _kernel_body(seg: SegmentedProgram, log_domain: bool,
                 in_ref, instr_ref, out_ref, a_ref):
    """One batch tile: leaves → segment-scheduled sweep in VMEM → root."""
    a_ref[0: seg.node_base, :] = in_ref[...]
    for level in range(seg.num_levels):
        s0, s1 = int(seg.level_offsets[level]), int(seg.level_offsets[level + 1])
        lo, hi = seg.level_out_range(level)           # 8-aligned range
        # one whole-buffer read per level; gather indices only ever point
        # below ``lo`` (validated invariant), so reading the not-yet-
        # written tail is safe and cheaper than slicing a prefix per level
        A = a_ref[...]
        outs = []
        for s in range(s0, s1):
            g0 = int(seg.seg_off[s])
            ns = int(seg.seg_nodes[s])
            g1 = g0 + int(seg.seg_arity[s]) * ns
            idx = instr_ref[g0: g1, 0]
            vals = jnp.take(A, idx, axis=0)           # sublane gather
            outs.append(_segment_reduce(vals, int(seg.seg_op[s]),
                                        log_domain, ns))
        block = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=0)
        a_ref[lo: hi, :] = block
    root = a_ref[seg.root_slot, :]
    out_ref[...] = jnp.broadcast_to(root[None, :], out_ref.shape)


def build_spn_kernel(seg: SegmentedProgram, *, batch_tile: int = LANE,
                     log_domain: bool = False,
                     interpret: bool | None = None):
    """Compile a pallas_call evaluating ``seg`` over a batch.

    Returns ``fn(buf, instr)`` mapping a ``(node_base, B)`` value-buffer
    prefix (domain-transformed leaves + neutral pad rows, B a multiple
    of ``batch_tile``) plus the ``(n_gather, 1)`` instruction tensor to
    ``(B,)`` root values. ``interpret=None`` auto-detects the backend
    (:func:`default_interpret`).
    """
    if batch_tile % LANE:
        raise ValueError(f"batch_tile must be a multiple of {LANE}")
    interpret = default_interpret() if interpret is None else bool(interpret)
    n_instr = max(len(seg.gather), 1)
    vmem_bytes = ((seg.num_slots + seg.node_base + SUBLANE) * batch_tile * 4
                  + n_instr * 4)
    if vmem_bytes > 14 * 2 ** 20:
        raise ValueError(
            f"value buffer needs {vmem_bytes / 2**20:.1f} MiB VMEM "
            f"({seg.num_slots} slots x {batch_tile} lanes); reduce "
            f"batch_tile or split the SPN")

    body = functools.partial(_kernel_body, seg, log_domain)

    def fn(buf: jnp.ndarray, instr: jnp.ndarray) -> jnp.ndarray:
        node_base, B = buf.shape
        assert node_base == seg.node_base and B % batch_tile == 0
        grid = (B // batch_tile,)
        out = pl.pallas_call(
            body,
            grid=grid,
            in_specs=[
                pl.BlockSpec((node_base, batch_tile), lambda i: (0, i)),
                pl.BlockSpec((n_instr, 1), lambda i: (0, 0)),
            ],
            out_specs=pl.BlockSpec((SUBLANE, batch_tile), lambda i: (0, i)),
            out_shape=jax.ShapeDtypeStruct((SUBLANE, B), jnp.float32),
            scratch_shapes=[pltpu.VMEM((seg.num_slots, batch_tile),
                                       jnp.float32)],
            interpret=interpret,
        )(buf, instr)
        return out[0]

    return fn
