"""Pallas TPU kernel for leveled SPN evaluation.

TPU adaptation of the paper's processor (DESIGN.md §2): the *batch*
dimension rides the 128 VPU lanes (the paper's node-parallel scalar PEs
become lane-parallel evaluations), node slots ride sublanes, and the whole
slot value buffer lives in a **VMEM scratch** — the analogue of the
paper's banked register file. All levels execute inside one
``pallas_call``, so intermediates never round-trip through HBM (the
analogue of PE-tree datapath fusion: "avoiding frequent writebacks to the
register file").

The per-level operand indices (the paper's B/C vectors) are streamed to
the kernel as an **instruction tensor** — the Pallas analogue of the
paper's VLIW instruction stream: op-codes + operand addresses resident
on-chip, consumed one level ("group", fig. 2a) per step. Levels are
8-aligned so every slice is tile-friendly; gathers index the sublane axis
with i32 vectors (Mosaic `dynamic_gather`).

The O column of the instruction tensor carries the full opcode alphabet
(0=sum, 1=prod, 2=max), so the same kernel executes sum-product
(likelihood/marginal) and max-product (MPE) programs — the query engine
just streams a different instruction tensor.

Layout contract (produced by :func:`repro.kernels.spn_eval.ops.pad_program`):

- slots ``[0, m_pad)``: leaf inputs (indicators + parameters), 8-aligned,
- each level's outputs occupy an 8-aligned contiguous slot range,
- padded ops compute ``A[0] (op) A[0]`` (finite in both domains).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

SUBLANE = 8     # f32 sublane tile
LANE = 128      # lane tile


@dataclasses.dataclass(eq=False)   # identity-hash: used as a static jit arg
class PaddedProgram:
    """Level-padded, 8-aligned slot program consumed by the kernel."""
    m_pad: int                      # leaf slots incl. padding
    num_slots: int                  # total padded slots (multiple of 8)
    levels: list                    # [(offset, b, c, is_prod), ...] np arrays
    root_slot: int

    @property
    def num_levels(self) -> int:
        return len(self.levels)

    @property
    def n_ops_pad(self) -> int:
        return sum(len(b) for (_, b, _, _) in self.levels)

    def instruction_tensor(self) -> np.ndarray:
        """(n_ops_pad, 3) int32: columns = B, C, O (the paper's vectors)."""
        b = np.concatenate([lv[1] for lv in self.levels])
        c = np.concatenate([lv[2] for lv in self.levels])
        o = np.concatenate([lv[3] for lv in self.levels]).astype(np.int32)
        return np.stack([b, c, o], axis=1).astype(np.int32)


def _logaddexp(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Mosaic-friendly stable logaddexp (handles -inf without NaN)."""
    mx = jnp.maximum(a, b)
    mn = jnp.minimum(a, b)
    safe = jnp.isfinite(mx)
    diff = jnp.where(safe, mn - mx, 0.0)
    return jnp.where(safe, mx + jnp.log1p(jnp.exp(diff)), mx)


def _kernel_body(pprog: PaddedProgram, log_domain: bool,
                 in_ref, instr_ref, out_ref, a_ref):
    """One batch tile: leaves → leveled sweep in VMEM → root row."""
    a_ref[0: pprog.m_pad, :] = in_ref[...]
    ip = 0                                          # instruction pointer
    for (off, b, c, isp) in pprog.levels:
        width = len(b)
        bi = instr_ref[ip: ip + width, 0]
        ci = instr_ref[ip: ip + width, 1]
        oi = instr_ref[ip: ip + width, 2]
        ip += width
        prefix = a_ref[0: off, :]                   # aligned static slice
        vb = jnp.take(prefix, bi, axis=0)           # sublane gather
        vc = jnp.take(prefix, ci, axis=0)
        is_prod = (oi == 1)[:, None]
        is_max = (oi == 2)[:, None]
        mx = jnp.maximum(vb, vc)                    # max: same in both domains
        if log_domain:
            new = jnp.where(is_prod, vb + vc,
                            jnp.where(is_max, mx, _logaddexp(vb, vc)))
        else:
            new = jnp.where(is_prod, vb * vc,
                            jnp.where(is_max, mx, vb + vc))
        a_ref[off: off + width, :] = new
    root = a_ref[pprog.root_slot, :]
    out_ref[...] = jnp.broadcast_to(root[None, :], out_ref.shape)


def build_spn_kernel(pprog: PaddedProgram, *, batch_tile: int = LANE,
                     log_domain: bool = False, interpret: bool = True):
    """Compile a pallas_call evaluating ``pprog`` over a batch.

    Returns ``fn(full_leaves, instr)`` mapping an ``(m_pad, B)`` leaf
    buffer (domain-transformed, B a multiple of ``batch_tile``) plus the
    ``(n_ops_pad, 3)`` instruction tensor to ``(B,)`` root values.
    """
    if batch_tile % LANE:
        raise ValueError(f"batch_tile must be a multiple of {LANE}")
    n_instr = pprog.n_ops_pad
    vmem_bytes = ((pprog.num_slots + pprog.m_pad + SUBLANE) * batch_tile * 4
                  + n_instr * 3 * 4)
    if vmem_bytes > 14 * 2 ** 20:
        raise ValueError(
            f"value buffer needs {vmem_bytes / 2**20:.1f} MiB VMEM "
            f"({pprog.num_slots} slots x {batch_tile} lanes); reduce "
            f"batch_tile or split the SPN")

    body = functools.partial(_kernel_body, pprog, log_domain)

    def fn(full_leaves: jnp.ndarray, instr: jnp.ndarray) -> jnp.ndarray:
        m_pad, B = full_leaves.shape
        assert m_pad == pprog.m_pad and B % batch_tile == 0
        grid = (B // batch_tile,)
        out = pl.pallas_call(
            body,
            grid=grid,
            in_specs=[
                pl.BlockSpec((m_pad, batch_tile), lambda i: (0, i)),
                pl.BlockSpec((n_instr, 3), lambda i: (0, 0)),
            ],
            out_specs=pl.BlockSpec((SUBLANE, batch_tile), lambda i: (0, i)),
            out_shape=jax.ShapeDtypeStruct((SUBLANE, B), jnp.float32),
            scratch_shapes=[pltpu.VMEM((pprog.num_slots, batch_tile),
                                       jnp.float32)],
            interpret=interpret,
        )(full_leaves, instr)
        return out[0]

    return fn
