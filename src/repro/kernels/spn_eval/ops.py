"""jit'd public wrapper around the spn_eval Pallas kernel.

Handles everything the kernel contract demands: the segment schedule
(:func:`pad_program` — opcode-homogeneous, 8-aligned n-ary segments),
parameter splicing (for learned weights), domain transform, neutral pad
rows, batch padding to the lane tile, and interpret-mode selection
(auto-detected from the backend, overridable by callers).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ...core import segments
from ...core.program import TensorProgram
from . import kernel as K


def _round_up(x: int, k: int) -> int:
    return (x + k - 1) // k * k


def pad_program(prog: TensorProgram) -> segments.SegmentedProgram:
    """Segment schedule of ``prog`` — the kernel's instruction layout.

    Alias of :func:`repro.core.segments.segment_program` (cached there):
    the tile-aligned segmented representation *is* the padded program —
    every level's output block starts 8-aligned and spans a multiple of
    8 slots, each segment is one opcode at one padded arity.
    """
    return segments.segment_program(prog)


def _build(prog: TensorProgram, batch_tile: int, log_domain: bool,
           interpret: bool):
    # memoized on the program instance (like segment_program), so the
    # compiled kernel dies with its program instead of being pinned in a
    # module-level cache after the ArtifactCache evicts the artifact
    key = (batch_tile, log_domain, interpret)
    builds = prog.__dict__.setdefault("_pallas_builds", {})
    cached = builds.get(key)
    if cached is not None:
        return cached
    seg = pad_program(prog)
    fn = K.build_spn_kernel(seg, batch_tile=batch_tile,
                            log_domain=log_domain, interpret=interpret)
    m_ind, m, node_base = prog.m_ind, prog.m, seg.node_base
    instr = jnp.asarray(seg.gather[:, None])
    # everything but the indicator rows is static per artifact: parameter
    # rows (domain-transformed once), neutral pad + alignment rows, and
    # the batch-padding columns (indicator 1 → 0 in log domain). Per call
    # only the (B, m_ind) leaf block is transformed and spliced in.
    # param logs go through the same f32 jnp.log as the ref/leaf path so
    # kernel and pure-jnp oracle stay bitwise comparable in log domain
    pcol = jnp.asarray(prog.param_values, jnp.float32)
    lead = jnp.zeros(m_ind, jnp.float32) if log_domain \
        else jnp.ones(m_ind, jnp.float32)            # batch-pad columns
    base_col = jnp.concatenate([
        lead, jnp.log(pcol) if log_domain else pcol,
        jnp.asarray(seg.init_rows(log_domain)[m:], jnp.float32)])

    @jax.jit
    def run(leaf_ind: jnp.ndarray, params: jnp.ndarray | None) -> jnp.ndarray:
        leaf_ind = jnp.atleast_2d(leaf_ind).astype(jnp.float32)
        B = leaf_ind.shape[0]
        B_pad = _round_up(max(B, 1), batch_tile)
        buf = jnp.broadcast_to(base_col[:, None], (node_base, B_pad))
        if log_domain:
            leaf_ind = jnp.log(leaf_ind)
        buf = buf.at[:m_ind, :B].set(leaf_ind.T)
        if params is not None:
            p = params.astype(jnp.float32)
            buf = buf.at[m_ind: m, :].set(
                (jnp.log(p) if log_domain else p)[:, None])
        return fn(buf, instr)[:B]

    builds[key] = run
    return run


def build_eval(prog: TensorProgram, *, batch_tile: int = K.LANE,
               log_domain: bool = False, interpret: bool | None = None):
    """Compile ``prog`` into a reusable kernel closure (pad + build + jit).

    This is the "compile" step of the pallas substrate
    (:mod:`repro.runtime.substrates`): the returned ``run(leaf_ind,
    params=None)`` closure is the cacheable artifact payload. ``spn_eval``
    remains the one-shot convenience wrapper over the same builder.
    ``interpret=None`` resolves via :func:`K.default_interpret` at build
    time (compiled on TPU, interpreter elsewhere) — resolved *before*
    the build cache so explicit and auto-detected callers requesting the
    same mode share one compiled kernel.
    """
    interpret = K.default_interpret() if interpret is None else bool(interpret)
    return _build(prog, int(batch_tile), bool(log_domain), interpret)


def spn_eval(prog: TensorProgram, leaf_ind, params=None, *,
             log_domain: bool = False, batch_tile: int = K.LANE,
             interpret: bool | None = None) -> jnp.ndarray:
    """Evaluate ``prog`` for a batch of leaf inputs via the Pallas kernel.

    ``leaf_ind``: (batch, m_ind) indicator values → (batch,) root values
    (root log-probabilities when ``log_domain``).
    """
    run = build_eval(prog, batch_tile=batch_tile, log_domain=log_domain,
                     interpret=interpret)
    return run(jnp.asarray(leaf_ind), params)
