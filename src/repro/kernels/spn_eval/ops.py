"""jit'd public wrapper around the spn_eval Pallas kernel.

Handles everything the kernel contract demands: level padding/slot
remapping to 8-aligned ranges, parameter splicing (for learned weights),
domain transform, batch padding to the lane tile, and interpret-mode
selection (interpret on CPU hosts, compiled on TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ...core.program import TensorProgram
from . import kernel as K


def _round_up(x: int, k: int) -> int:
    return (x + k - 1) // k * k


@functools.cache
def pad_program(prog: TensorProgram) -> K.PaddedProgram:
    """Remap a level-contiguous program to 8-aligned padded slot ranges.

    The slot permutation is order-preserving within leaves and within each
    level, so ``new_slot = old_slot + shift(level)`` with a per-region
    shift — cheap to apply to the B/C index vectors.
    """
    m_pad = _round_up(prog.m, K.SUBLANE)
    # old-slot -> new-slot lookup (leaves first, then per level)
    new_of_old = np.zeros(prog.num_slots, np.int64)
    new_of_old[: prog.m] = np.arange(prog.m)
    levels = []
    off = m_pad
    for lo, hi in zip(prog.level_offsets[:-1], prog.level_offsets[1:]):
        lo, hi = int(lo), int(hi)
        width = hi - lo
        width_pad = _round_up(max(width, 1), K.SUBLANE)
        new_of_old[prog.m + lo: prog.m + hi] = off + np.arange(width)
        b = new_of_old[prog.b[lo:hi]].astype(np.int32)
        c = new_of_old[prog.c[lo:hi]].astype(np.int32)
        isp = prog.opcode[lo:hi].astype(np.uint8)
        pad = width_pad - width
        if pad:  # padded ops: A[0] (prod) A[0] — finite in both domains
            b = np.concatenate([b, np.zeros(pad, np.int32)])
            c = np.concatenate([c, np.zeros(pad, np.int32)])
            isp = np.concatenate([isp, np.ones(pad, np.uint8)])
        levels.append((off, b, c, isp))
        off += width_pad
    return K.PaddedProgram(
        m_pad=m_pad, num_slots=off, levels=levels,
        root_slot=int(new_of_old[prog.root_slot]))


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.cache
def _build(prog: TensorProgram, batch_tile: int, log_domain: bool,
           interpret: bool):
    pprog = pad_program(prog)
    fn = K.build_spn_kernel(pprog, batch_tile=batch_tile,
                            log_domain=log_domain, interpret=interpret)
    m_ind, m, m_pad = prog.m_ind, prog.m, pprog.m_pad
    stored = jnp.asarray(prog.param_values, jnp.float32)
    instr = jnp.asarray(pprog.instruction_tensor())

    @jax.jit
    def run(leaf_ind: jnp.ndarray, params: jnp.ndarray | None) -> jnp.ndarray:
        leaf_ind = jnp.atleast_2d(leaf_ind).astype(jnp.float32)
        B = leaf_ind.shape[0]
        B_pad = _round_up(max(B, 1), batch_tile)
        p = stored if params is None else params.astype(jnp.float32)
        full = jnp.ones((B_pad, m_pad), jnp.float32)       # pad rows = 1.0
        full = full.at[:B, :m_ind].set(leaf_ind)
        full = full.at[:, m_ind: m].set(p[None, :])
        if log_domain:
            full = jnp.log(full)
        return fn(full.T, instr)[:B]

    return run


def build_eval(prog: TensorProgram, *, batch_tile: int = K.LANE,
               log_domain: bool = False, interpret: bool | None = None):
    """Compile ``prog`` into a reusable kernel closure (pad + build + jit).

    This is the "compile" step of the pallas substrate
    (:mod:`repro.runtime.substrates`): the returned ``run(leaf_ind,
    params=None)`` closure is the cacheable artifact payload. ``spn_eval``
    remains the one-shot convenience wrapper over the same builder.
    """
    interpret = _default_interpret() if interpret is None else interpret
    return _build(prog, int(batch_tile), bool(log_domain), bool(interpret))


def spn_eval(prog: TensorProgram, leaf_ind, params=None, *,
             log_domain: bool = False, batch_tile: int = K.LANE,
             interpret: bool | None = None) -> jnp.ndarray:
    """Evaluate ``prog`` for a batch of leaf inputs via the Pallas kernel.

    ``leaf_ind``: (batch, m_ind) indicator values → (batch,) root values
    (root log-probabilities when ``log_domain``).
    """
    interpret = _default_interpret() if interpret is None else interpret
    run = _build(prog, int(batch_tile), bool(log_domain), bool(interpret))
    return run(jnp.asarray(leaf_ind), params)
