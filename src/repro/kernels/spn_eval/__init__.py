from .ops import pad_program, spn_eval
from .ref import spn_eval_ref

__all__ = ["spn_eval", "spn_eval_ref", "pad_program"]
