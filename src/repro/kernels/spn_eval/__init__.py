from .ops import build_eval, pad_program, spn_eval
from .ref import spn_eval_ref

__all__ = ["build_eval", "spn_eval", "spn_eval_ref", "pad_program"]
