"""Sharded checkpointing: atomic, async, elastic-remesh restore.

Format: one directory per step —

    <root>/step_000123/
        manifest.json        # tree structure, shapes, dtypes, step, extras
        arrays.npz           # flat path → host array

Commit protocol: write into ``step_000123.tmp`` then ``os.rename`` —
readers never observe a partial checkpoint (restart-safe). An async
writer thread makes ``save`` non-blocking (the training loop donates
nothing: arrays are fetched to host first, so the step can proceed).

Elastic restore: arrays are saved *unsharded* (host-gathered); restore
``device_put``s against whatever mesh/sharding the *new* topology built —
a checkpoint taken on 256 chips restores onto 512 or 8 (the resharding is
GSPMD's problem, not the format's). At real multi-pod scale the same
manifest schema holds per-shard chunk files instead; noted in DESIGN.md.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from typing import Any

import jax
import numpy as np

_SENTINEL = object()


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    paths, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for kp, leaf in paths:
        key = "/".join(_k(k) for k in kp)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or arr.dtype.name == "bfloat16":
            # npz has no native bf16: store f32, restore casts back via the
            # target dtype (recorded in the manifest)
            arr = arr.astype(np.float32)
        out[key] = arr
    return out


def _k(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def save(root: str, step: int, tree: Any, extras: dict | None = None) -> str:
    """Synchronous atomic save; returns the committed directory."""
    flat = _flatten(tree)
    treedef = jax.tree_util.tree_structure(tree)
    final = os.path.join(root, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "keys": sorted(flat),
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "extras": extras or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(root: str) -> int | None:
    if not os.path.isdir(root):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(root)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(root: str, target: Any, step: int | None = None,
            shardings: Any = None) -> tuple[Any, dict]:
    """Restore into the structure of ``target`` (pytree of arrays or
    ShapeDtypeStructs). ``shardings``: optional same-structure NamedShardings
    for elastic remesh placement. Returns (tree, extras)."""
    step = latest_step(root) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {root}")
    d = os.path.join(root, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "arrays.npz"))
    paths, treedef = jax.tree_util.tree_flatten_with_path(target)
    shard_leaves = (jax.tree.leaves(shardings)
                    if shardings is not None else [None] * len(paths))
    leaves = []
    for (kp, tgt), shd in zip(paths, shard_leaves):
        key = "/".join(_k(k) for k in kp)
        if key not in data:
            raise KeyError(f"checkpoint missing {key!r}")
        arr = data[key]
        if tuple(arr.shape) != tuple(tgt.shape):
            raise ValueError(f"{key}: saved {arr.shape} != target {tgt.shape}")
        arr = arr.astype(tgt.dtype)
        leaves.append(jax.device_put(arr, shd) if shd is not None else
                      jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["extras"]


def gc_old(root: str, keep: int = 3) -> list[str]:
    """Delete all but the newest ``keep`` checkpoints."""
    if not os.path.isdir(root):
        return []
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(root)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    removed = []
    for s in steps[:-keep] if keep else steps:
        p = os.path.join(root, f"step_{s:08d}")
        shutil.rmtree(p)
        removed.append(p)
    return removed


class AsyncCheckpointer:
    """Background-thread writer: ``save`` returns once arrays are on host."""

    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        self._q: queue.Queue = queue.Queue(maxsize=2)
        self._errors: list[BaseException] = []
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def _loop(self):
        while True:
            item = self._q.get()
            if item is _SENTINEL:
                return
            step, host_tree, extras = item
            try:
                save(self.root, step, host_tree, extras)
                gc_old(self.root, self.keep)
            except BaseException as e:   # surfaced on next save/close
                self._errors.append(e)

    def save(self, step: int, tree: Any, extras: dict | None = None) -> None:
        if self._errors:
            raise RuntimeError("async checkpoint failed") from self._errors[0]
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self._q.put((step, host_tree, extras))

    def close(self) -> None:
        self._q.put(_SENTINEL)
        self._thread.join()
        if self._errors:
            raise RuntimeError("async checkpoint failed") from self._errors[0]
