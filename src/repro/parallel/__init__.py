from .plan import Planner, dp_axes

__all__ = ["Planner", "dp_axes"]
