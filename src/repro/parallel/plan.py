"""Sharding plan: parameter PartitionSpecs + activation rules per mesh.

Strategy (DESIGN.md §5):

- **TP** over ``model``: attention heads / FFN hidden / experts / vocab.
- **FSDP** over ``data``: the *other* large dimension of every 2D+ weight
  (ZeRO-3-style parameter sharding; optimizer states inherit → ZeRO-1 is
  implied for free).
- **DP** over ``pod`` (multi-pod): pure data parallelism — parameters
  replicated across pods, gradients all-reduced hierarchically by GSPMD
  (reduce-scatter intra-pod on ``data``, all-reduce inter-pod on ``pod``).
- Every spec degrades gracefully: a dimension is sharded only when the
  mesh axis divides it (GSPMD would pad otherwise; we keep specs clean).

Specs are assigned by parameter *path pattern* — the table below is the
single source of truth for how every weight in the zoo is laid out.
"""
from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig


def dp_axes(mesh: Mesh) -> Any:
    """Data-parallel axes: ('pod','data') on the multi-pod mesh."""
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


def _axis_size(mesh: Mesh, name) -> int:
    if isinstance(name, tuple):
        return int(np.prod([mesh.shape[n] for n in name]))
    return int(mesh.shape[name])


class Planner:
    """Builds NamedShardings for params/optimizer/batch/cache of one arch."""

    def __init__(self, cfg: ArchConfig, mesh: Mesh):
        self.cfg, self.mesh = cfg, mesh
        self.model = "model" if "model" in mesh.axis_names else None
        self.data = "data" if "data" in mesh.axis_names else None
        self.dp = dp_axes(mesh) if self.data else None

    # -- helpers ---------------------------------------------------------
    def _fit(self, dim: int, axis) -> Any:
        """axis if it divides dim, else None (replicate)."""
        if axis is None or dim <= 0:
            return None
        return axis if dim % _axis_size(self.mesh, axis) == 0 else None

    def _spec2d(self, shape, shard_out_last: bool, n_lead: int) -> P:
        """(lead..., d_in, d_out): TP on one matmul dim, FSDP on the other."""
        d_in, d_out = shape[-2], shape[-1]
        if shard_out_last:
            tp, fsdp = self._fit(d_out, self.model), self._fit(d_in, self.data)
            dims = [None] * n_lead + [fsdp, tp]
        else:
            tp, fsdp = self._fit(d_in, self.model), self._fit(d_out, self.data)
            dims = [None] * n_lead + [tp, fsdp]
        return P(*dims)

    # -- the path-pattern table -------------------------------------------
    def param_spec(self, path: str, shape: tuple[int, ...]) -> P:
        lead2 = max(len(shape) - 2, 0)   # leading stack dims before a matmul
        rules: list[tuple[str, Any]] = [
            # embeddings & heads: vocab over model, d_model over data
            (r"(^|/)embed$", lambda: P(self._fit(shape[0], self.model),
                                       self._fit(shape[1], self.data))),
            (r"lm_head/w$", lambda: self._spec2d(shape, True, lead2)),
            (r"dec_pos$", lambda: P(None, self._fit(shape[1], self.model))),
            # attention: q/k/v column-parallel, o row-parallel
            (r"attn/[qkv]/w$", lambda: self._spec2d(shape, True, lead2)),
            (r"attn/o/w$", lambda: self._spec2d(shape, False, lead2)),
            (r"attn/[qkvo]/b$", lambda: P(*([None] * (len(shape) - 1)),
                                          self._fit(shape[-1], self.model))),
            # dense mlp: up/gate column-parallel, down row-parallel
            (r"mlp/(up|gate)/w$", lambda: self._spec2d(shape, True, lead2)),
            (r"mlp/down/w$", lambda: self._spec2d(shape, False, lead2)),
            (r"mlp/(up|gate|down)/b$", lambda: P(*([None] * (len(shape) - 1)),
                                                 None)),
            # MoE: experts over model (EP), d_model over data
            (r"moe/router$", lambda: P(*([None] * (len(shape) - 2)),
                                       self._fit(shape[-2], self.data), None)),
            (r"moe/(gate|up|down)$", lambda: P(
                *([None] * (len(shape) - 3)),
                self._fit(shape[-3], self.model),
                self._fit(shape[-2], self.data), None)),
            # mamba
            (r"in_proj$", lambda: self._spec2d(shape, True, lead2)),
            (r"out_proj$", lambda: self._spec2d(shape, False, lead2)),
            (r"conv_[wb]$", lambda: P(*([None] * (len(shape) - 1)),
                                      self._fit(shape[-1], self.model))),
            (r"(A_log|/D|dt_bias)$", lambda: P(*([None] * len(shape)))),
        ]
        for pat, fn in rules:
            if re.search(pat, path):
                return fn()
        # norms / scalars / anything else: replicate
        return P(*([None] * len(shape)))

    # -- pytree-level APIs -------------------------------------------------
    def params_sharding(self, param_tree: Any) -> Any:
        paths = _tree_paths(param_tree)
        return jax.tree.map(
            lambda pth, leaf: NamedSharding(
                self.mesh, self.param_spec(pth, leaf.shape)),
            paths, param_tree)

    def batch_sharding(self, batch_tree: Any) -> Any:
        def spec(leaf):
            dims = [self._fit(leaf.shape[0], self.dp)] + \
                   [None] * (len(leaf.shape) - 1)
            return NamedSharding(self.mesh, P(*dims))
        return jax.tree.map(spec, batch_tree)

    def cache_sharding(self, cache_tree: Any) -> Any:
        """KV/state caches: batch over dp when divisible, else seq over
        data (long-context decode); head dims over model."""
        def spec_dispatch(path, leaf):
            shp = leaf.shape
            dims: list[Any] = [None] * len(shp)
            if len(shp) == 0:
                return NamedSharding(self.mesh, P())
            # batch index: hybrid group caches are (G, g, B, ...), else (L, B, ...)
            b_idx = 2 if "ssm_groups" in path else 1
            is_kv = re.search(r"(^|/)(k|v|xk|xv)$", path) is not None
            if is_kv and len(shp) == 5:      # (L, B, T, KV, hd)
                dims[1] = self._fit(shp[1], self.dp)
                if dims[1] is None:
                    dims[2] = self._fit(shp[2], self.data)   # seq-shard
                dims[3] = self._fit(shp[3], self.model)
            elif "conv" in path:             # (..., B, W, conv_dim)
                if b_idx < len(shp):
                    dims[b_idx] = self._fit(shp[b_idx], self.dp)
                dims[-1] = self._fit(shp[-1], self.model)
            elif "ssm" in path and len(shp) >= b_idx + 2:
                # (..., B, H, P, N) ssd state: batch over dp, heads over model
                dims[b_idx] = self._fit(shp[b_idx], self.dp)
                if dims[b_idx] is None:
                    dims[b_idx + 1] = self._fit(shp[b_idx + 1], self.model)
                elif len(shp) > b_idx + 1:
                    dims[b_idx + 1] = self._fit(shp[b_idx + 1], self.model)
            elif len(shp) >= 2:
                dims[min(b_idx, len(shp) - 1)] = self._fit(
                    shp[min(b_idx, len(shp) - 1)], self.dp)
            return NamedSharding(self.mesh, P(*dims))
        paths = _tree_paths(cache_tree)
        return jax.tree.map(spec_dispatch, paths, cache_tree)

    # -- activation rules (models.common.shard) -----------------------------
    def act_rules(self) -> dict:
        m, dp = self.model, self.dp
        mesh = self.mesh
        def ns(*dims):
            return NamedSharding(mesh, P(*dims))
        # §Perf-B: heads that don't divide the TP degree (starcoder2: 36
        # heads on model=16) force GSPMD into padded/uneven head tiles.
        # Both alternatives were tried and MEASURED WORSE (see §Perf-B):
        # q-sequence sharding hits lax.scan's sliced-operand full-remat
        # (t_mem 2.95→8.10 s); full replication over `model` pays 16×
        # redundant attention traffic (8.07 s). GSPMD's padded sharding
        # is byte-optimal among pjit-expressible layouts — kept. The real
        # hardware fix is a shard_map'd Pallas splash-attention kernel.
        heads_fit = (self.cfg.n_heads == 0
                     or (m is not None and self.cfg.n_heads
                         % _axis_size(self.mesh, m) == 0))
        # None → defer to GSPMD propagation (measured best for uneven heads)
        act_heads = ns(dp, None, m, None) if heads_fit else None
        return {
            # §Perf-A: residual stream sharded over model too — the
            # per-layer saved residuals (the scan-carry stack the backward
            # needs) shrink by the TP degree, which is what lets 94-layer
            # train cells fit HBM; layers all-gather D on entry (cheap
            # relative to the saved-activation traffic it removes).
            "act_resid": ns(dp, None, self._fit(self.cfg.d_model, m)),
            "act_heads": act_heads,
            # §Perf-D (REFUTED, kept for the record): pinning norm outputs
            # to replicated-D halved the f32 layer-entry all-gathers
            # (t_coll 19.7→14.5 s on command-r train) but forced an extra
            # bf16 materialization that RAISED the dominant memory term
            # (30.1→33.0 s) — net worse, rule removed; the shard() call
            # sites remain as no-ops for future experiments.
            # "act_norm_out": ns(dp, None, None),
            "act_kv_heads": (ns(dp, None,
                                self._fit(max(self.cfg.n_kv_heads, 1), m),
                                None) if heads_fit else None),
            "act_ff": ns(dp, None, m),
            "act_logits": ns(dp, None, m),
            "moe_expert_in": ns(m, dp, None, None),
            "moe_expert_out": ns(m, dp, None, None),
        }


def _tree_paths(tree: Any) -> Any:
    """Same-structure pytree whose leaves are '/'-joined path strings."""
    paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    strs = ["/".join(_key_str(k) for k in kp) for kp, _ in paths]
    return jax.tree_util.tree_unflatten(treedef, strs)


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)
