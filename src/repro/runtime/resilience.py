"""Fault-tolerant serving fabric: injection, degradation, hardening.

The paper's processor targets safety-critical deployments (self-driving,
autonomous drones) where the serving stack must keep answering queries
while the hardware misbehaves. This module supplies the three layers the
hardened :class:`~repro.runtime.server.Server` request path is built on:

**Deterministic fault injection** — a seeded :class:`FaultPlan` of
:class:`FaultEvent`\\ s on a virtual tick clock (one tick per batched
execute). Events can *kill cores*, *kill or slow NoC links* (threaded
into :class:`~repro.core.multicore.comm.InterconnectConfig` occupancy so
degraded routing actually pays), and *flip a transient execute result*
(modeled as a detected machine-check: the corrupt result is discarded
and a :class:`TransientFault` raised — never silently returned). The
:class:`FaultInjector` applies due events before each execute and raises
a typed fabric error when the executing artifact depends on a resource
that has died.

**Graceful degradation** — on a :class:`CoreFault` / :class:`LinkFault`
the server rebuilds the ``vliw-mc`` substrate restricted to the
surviving physical cores (``allowed_cores`` through the partitioner,
dead links through the interconnect config — both land in the substrate
fingerprint, so degraded artifacts are content-addressed like any
other). When no feasible compile exists the request falls down the
:data:`FALLBACK_CHAIN` (vliw-mc → vliw-sim → numpy oracle).

**Hardened request path** — per-request deadline, bounded retry with
exponential backoff, a :class:`CircuitBreaker` per (substrate, semiring)
with half-open probing, and admission-control backpressure. All failure
events flow through :mod:`repro.obs` (error spans + ``fault.*``
counters) and surface in ``Server.stats()["resilience"]``.
"""
from __future__ import annotations

import copy
import dataclasses
import re
import time

import numpy as np

from ..obs import metrics, trace

__all__ = [
    "FabricError", "CoreFault", "LinkFault", "TransientFault",
    "RequestTimeout", "CircuitOpen", "Backpressure", "ResilienceExhausted",
    "FaultEvent", "FaultPlan", "FabricState", "FaultInjector",
    "CircuitBreaker", "ResiliencePolicy", "ResilienceManager",
    "FALLBACK_CHAIN",
]


# --------------------------------------------------------------------------- #
# typed fabric errors — "honest errors, never silent corruption"
# --------------------------------------------------------------------------- #
class FabricError(RuntimeError):
    """Base of every injected/detected serving-fabric failure."""


class CoreFault(FabricError):
    """A core the executing artifact is placed on has died."""

    def __init__(self, core: int, msg: str | None = None):
        super().__init__(msg or f"core {core} is dead")
        self.core = int(core)


class LinkFault(FabricError):
    """A NoC link the executing artifact routes over has died."""

    def __init__(self, link: tuple, msg: str | None = None):
        super().__init__(msg or f"NoC link {link[0]}->{link[1]} is down")
        self.link = (int(link[0]), int(link[1]))


class TransientFault(FabricError):
    """One-shot datapath corruption, detected (machine-check) and
    discarded — a retry on the same artifact heals it."""


class RequestTimeout(FabricError):
    """The per-request deadline elapsed before a healthy answer."""


class CircuitOpen(FabricError):
    """The (substrate, semiring) circuit breaker is open — the request
    was rejected without touching the failing backend."""


class Backpressure(FabricError):
    """Admission control rejected the request: accepting it would push
    in-flight rows past the server's ``max_rows`` high-water mark."""


class ResilienceExhausted(FabricError):
    """Retries, degradation and every fallback substrate failed; chains
    the last real failure (``raise ... from exc``)."""


# --------------------------------------------------------------------------- #
# fault plans
# --------------------------------------------------------------------------- #
_SPEC = re.compile(
    r"^(?:"
    r"core=(?P<core>\d+)"
    r"|link=(?P<la>\d+)-(?P<lb>\d+)"
    r"|slow=(?P<sa>\d+)-(?P<sb>\d+)x(?P<factor>\d+)"
    r"|(?P<flip>flip)"
    r")(?:@t(?P<at>\d+))?$")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fabric fault on the virtual tick clock.

    ``kind``: ``"core"`` (kill ``core``), ``"link"`` (kill ``link`` in
    both directions), ``"link_slow"`` (serialize ``link`` ``factor``×
    slower, both directions), ``"flip"`` (corrupt the next hardware
    execute's result — one-shot). Core/link faults are persistent.
    """
    at: int
    kind: str
    core: int = -1
    link: tuple = ()
    factor: int = 4

    def spec(self) -> str:
        """The ``serve --inject-faults`` spelling of this event."""
        if self.kind == "core":
            body = f"core={self.core}"
        elif self.kind == "link":
            body = f"link={self.link[0]}-{self.link[1]}"
        elif self.kind == "link_slow":
            body = f"slow={self.link[0]}-{self.link[1]}x{self.factor}"
        else:
            body = "flip"
        return f"{body}@t{self.at}"


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A deterministic, seeded schedule of :class:`FaultEvent`\\ s."""

    events: tuple = ()
    seed: int = 0

    @classmethod
    def parse(cls, specs) -> "FaultPlan":
        """Build a plan from ``core=1@t0``-style spec strings.

        Grammar: ``core=<id>[@t<tick>]``, ``link=<a>-<b>[@t<tick>]``,
        ``slow=<a>-<b>x<factor>[@t<tick>]``, ``flip[@t<tick>]``; the
        tick defaults to 0. A single string may carry several
        comma-separated specs.
        """
        if isinstance(specs, str):
            specs = specs.split(",")
        events = []
        for raw in specs:
            s = raw.strip()
            if not s:
                continue
            m = _SPEC.match(s)
            if m is None:
                raise ValueError(
                    f"bad fault spec {raw!r}; expected core=N[@tT], "
                    "link=A-B[@tT], slow=A-BxF[@tT] or flip[@tT]")
            at = int(m.group("at") or 0)
            if m.group("core") is not None:
                events.append(FaultEvent(at, "core", core=int(m["core"])))
            elif m.group("la") is not None:
                events.append(FaultEvent(
                    at, "link", link=(int(m["la"]), int(m["lb"]))))
            elif m.group("sa") is not None:
                events.append(FaultEvent(
                    at, "link_slow", link=(int(m["sa"]), int(m["sb"])),
                    factor=int(m["factor"])))
            else:
                events.append(FaultEvent(at, "flip"))
        return cls(events=tuple(sorted(events, key=lambda e: e.at)))

    @classmethod
    def random(cls, seed: int, *, n_cores: int, n_events: int = 3,
               ticks: int = 8, kinds: tuple = ("core", "link",
                                               "link_slow", "flip")
               ) -> "FaultPlan":
        """A reproducible random plan for chaos drills. Never kills the
        whole machine: at most ``n_cores - 1`` distinct core kills."""
        rng = np.random.default_rng(seed)
        events, killed = [], set()
        for _ in range(n_events):
            kind = kinds[int(rng.integers(len(kinds)))]
            at = int(rng.integers(ticks))
            if kind == "core":
                alive = [c for c in range(n_cores) if c not in killed]
                if len(alive) <= 1:
                    continue
                core = int(alive[int(rng.integers(len(alive)))])
                killed.add(core)
                events.append(FaultEvent(at, "core", core=core))
            elif kind in ("link", "link_slow"):
                if n_cores < 2:
                    continue
                a, b = rng.choice(n_cores, size=2, replace=False)
                if kind == "link":
                    events.append(FaultEvent(at, "link",
                                             link=(int(a), int(b))))
                else:
                    events.append(FaultEvent(
                        at, "link_slow", link=(int(a), int(b)),
                        factor=int(rng.integers(2, 9))))
            else:
                events.append(FaultEvent(at, "flip"))
        return cls(events=tuple(sorted(events, key=lambda e: e.at)),
                   seed=seed)

    def specs(self) -> list:
        return [e.spec() for e in self.events]


# --------------------------------------------------------------------------- #
# fabric state + injector
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class FabricState:
    """What is currently broken, in *physical* resource ids."""

    total_cores: int
    dead_cores: set = dataclasses.field(default_factory=set)
    dead_links: set = dataclasses.field(default_factory=set)   # directed
    slow_links: dict = dataclasses.field(default_factory=dict)  # link->factor
    epoch: int = 0          # bumped on every applied core/link event

    @property
    def healthy(self) -> list:
        return [c for c in range(self.total_cores)
                if c not in self.dead_cores]

    @property
    def faulty(self) -> bool:
        return bool(self.dead_cores or self.dead_links or self.slow_links)

    def snapshot(self) -> dict:
        return {"total_cores": self.total_cores,
                "healthy_cores": self.healthy,
                "dead_cores": sorted(self.dead_cores),
                "dead_links": sorted(self.dead_links),
                "slow_links": {f"{a}-{b}": f
                               for (a, b), f in sorted(self.slow_links.items())},
                "epoch": self.epoch}


#: substrates immune to fabric faults (host software, not the modeled
#: hardware): the oracle must stay trustworthy for parity checking
_HOST_SUBSTRATES = ("numpy", "leveled-jax", "pallas")


class FaultInjector:
    """Applies a :class:`FaultPlan` on a tick clock of batched executes.

    ``before_execute(artifact)`` advances the clock, applies every due
    event, and raises :class:`CoreFault` / :class:`LinkFault` when the
    artifact is placed on a now-dead resource. ``after_execute`` fires
    an armed ``flip`` as a detected :class:`TransientFault` (one-shot:
    the immediate retry heals). Host substrates (numpy, leveled-jax,
    pallas) are immune — they model software, not the fabric.
    """

    def __init__(self, plan: FaultPlan, n_cores: int):
        self.plan = plan
        self.state = FabricState(total_cores=max(int(n_cores), 1))
        self.tick = 0
        self._pending = sorted(plan.events, key=lambda e: e.at)
        self._armed_flips = 0
        self.applied: list = []          # [(tick, spec), ...]

    # -- clock ---------------------------------------------------------- #
    def _apply_due(self) -> None:
        st = self.state
        while self._pending and self._pending[0].at <= self.tick:
            ev = self._pending.pop(0)
            if ev.kind == "core":
                if len(st.healthy) > 1:     # never kill the last core
                    st.dead_cores.add(ev.core % st.total_cores)
                    st.epoch += 1
                    metrics.counter("fault.injected.core").inc()
            elif ev.kind == "link":
                a, b = ev.link
                st.dead_links.update({(a, b), (b, a)})
                st.epoch += 1
                metrics.counter("fault.injected.link").inc()
            elif ev.kind == "link_slow":
                a, b = ev.link
                f = max(int(ev.factor), 2)
                st.slow_links[(a, b)] = f
                st.slow_links[(b, a)] = f
                st.epoch += 1
                metrics.counter("fault.injected.link_slow").inc()
            else:                           # flip
                self._armed_flips += 1
                metrics.counter("fault.injected.flip").inc()
            self.applied.append((self.tick, ev.spec()))
            trace.instant("fault.inject", {"tick": self.tick,
                                           "event": ev.spec()})
        metrics.gauge("fault.healthy_cores").set(len(st.healthy))

    # -- artifact resource footprint ------------------------------------ #
    @staticmethod
    def _footprint(artifact) -> tuple[set, set]:
        """(cores, directed links) the artifact's execution occupies."""
        if artifact.substrate in _HOST_SUBSTRATES:
            return set(), set()
        mc = artifact.meta.get("multicore")
        if mc is None:          # single-core VLIW machine: core 0
            return {0}, set()
        return (set(int(c) for c in mc.get("core_labels", [])),
                {(int(a), int(b)) for a, b in mc.get("links_used", [])})

    # -- hooks ---------------------------------------------------------- #
    def before_execute(self, artifact) -> None:
        self.tick += 1
        self._apply_due()
        cores, links = self._footprint(artifact)
        hit_cores = cores & self.state.dead_cores
        if hit_cores:
            core = min(hit_cores)
            metrics.counter("fault.core_faults").inc()
            raise CoreFault(core, f"core {core} died under artifact "
                            f"{artifact.substrate}/{artifact.semiring}")
        hit_links = links & self.state.dead_links
        if hit_links:
            link = min(hit_links)
            metrics.counter("fault.link_faults").inc()
            raise LinkFault(link, f"NoC link {link[0]}->{link[1]} died "
                            f"under artifact {artifact.substrate}/"
                            f"{artifact.semiring}")

    def after_execute(self, artifact, values) -> None:
        if self._armed_flips and artifact.substrate not in _HOST_SUBSTRATES:
            self._armed_flips -= 1
            metrics.counter("fault.transients").inc()
            raise TransientFault(
                "transient datapath corruption detected (machine check) "
                f"on {artifact.substrate}; result discarded")


# --------------------------------------------------------------------------- #
# circuit breaker
# --------------------------------------------------------------------------- #
class CircuitBreaker:
    """Consecutive-failure breaker with half-open probing.

    Closed → (``threshold`` consecutive failures) → open; after
    ``cooldown_s`` the next ``allow()`` transitions to half-open and
    admits exactly one probe. Probe success re-closes, probe failure
    re-opens and restarts the cooldown. ``clock`` is injectable for
    deterministic tests.
    """

    def __init__(self, threshold: int = 5, cooldown_s: float = 30.0,
                 clock=time.monotonic):
        self.threshold = max(int(threshold), 1)
        self.cooldown_s = float(cooldown_s)
        self.clock = clock
        self.state = "closed"
        self.failures = 0
        self.opened_at = 0.0
        self.trips = 0

    def allow(self) -> bool:
        if self.state == "closed":
            return True
        if self.state == "open":
            if self.clock() - self.opened_at >= self.cooldown_s:
                self.state = "half-open"
                return True          # the probe
            return False
        return False                 # half-open: probe already in flight

    def record_success(self) -> None:
        self.state = "closed"
        self.failures = 0

    def record_failure(self) -> None:
        self.failures += 1
        if self.state == "half-open" or self.failures >= self.threshold:
            if self.state != "open":
                self.trips += 1
                metrics.counter("fault.breaker_trips").inc()
            self.state = "open"
            self.opened_at = self.clock()


# --------------------------------------------------------------------------- #
# policy + manager
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class ResiliencePolicy:
    """Knobs of the hardened request path (all deterministic)."""

    timeout_s: float = 30.0          # per-request deadline
    max_attempts: int = 3            # per substrate in the chain
    backoff_s: float = 0.02          # first retry sleep
    backoff_mult: float = 2.0        # exponential growth
    breaker_threshold: int = 5
    breaker_cooldown_s: float = 30.0
    fallback: bool = True            # walk FALLBACK_CHAIN on hard failure


#: substrate fallback chain walked when recompilation is infeasible or a
#: backend keeps failing — ending at the numpy oracle, which is host
#: software and immune to fabric faults
FALLBACK_CHAIN = {
    "vliw-mc": ("vliw-sim", "numpy"),
    "vliw-sim": ("numpy",),
    "pallas": ("numpy",),
    "leveled-jax": ("numpy",),
}


class ResilienceManager:
    """Per-server resilience bookkeeping: breakers, fabric state,
    degradation history, fallback routing. The Server owns the actual
    orchestration (it holds the substrates and the cache); this object
    holds the state and the decisions."""

    def __init__(self, policy: ResiliencePolicy | None = None,
                 n_cores: int = 1,
                 injector: FaultInjector | None = None,
                 clock=time.monotonic, sleep=time.sleep):
        self.policy = policy or ResiliencePolicy()
        self.injector = injector
        self.state = (injector.state if injector is not None
                      else FabricState(total_cores=max(int(n_cores), 1)))
        self.clock = clock
        self.sleep = sleep
        self._breakers: dict = {}
        #: substrate name -> substitute serving name (after hard failure)
        self.redirects: dict = {}
        #: chronological degradation / fallback records
        self.history: list = []

    # -- breakers -------------------------------------------------------- #
    def breaker(self, substrate: str, semiring: str) -> CircuitBreaker:
        key = (substrate, semiring)
        br = self._breakers.get(key)
        if br is None:
            br = self._breakers[key] = CircuitBreaker(
                self.policy.breaker_threshold,
                self.policy.breaker_cooldown_s, clock=self.clock)
        return br

    # -- chain ----------------------------------------------------------- #
    def chain(self, substrate: str, available) -> list:
        """The substrate itself plus its enabled fallbacks, in order."""
        names = [substrate]
        if self.policy.fallback:
            names += [n for n in FALLBACK_CHAIN.get(substrate, ())
                      if n in available]
        return names

    # -- degradation ------------------------------------------------------ #
    def degraded_substrate(self, sub, alive=None):
        """A replacement substrate instance for the current fabric state
        (``None`` when the substrate cannot repartition). ``alive``
        overrides the surviving-core set (used while descending)."""
        if not hasattr(sub, "degraded"):
            return None
        alive = list(self.state.healthy if alive is None else alive)
        if not alive:
            return None
        return sub.degraded(
            tuple(alive),
            dead_links=tuple(sorted(self.state.dead_links)),
            slow_links=tuple((a, b, f) for (a, b), f
                             in sorted(self.state.slow_links.items())))

    def record(self, kind: str, **info) -> None:
        entry = {"kind": kind,
                 "tick": self.injector.tick if self.injector else 0,
                 **info}
        self.history.append(entry)
        trace.instant("fault." + kind, entry)
        metrics.gauge("fault.degraded").set(
            1.0 if (self.state.dead_cores or self.state.dead_links
                    or self.redirects) else 0.0)

    # -- introspection ---------------------------------------------------- #
    def stats(self) -> dict:
        return {
            "enabled": self.injector is not None,
            "tick": self.injector.tick if self.injector else 0,
            "fabric": self.state.snapshot(),
            "plan": (self.injector.plan.specs()
                     if self.injector else []),
            "applied": list(self.injector.applied) if self.injector else [],
            "breakers": {f"{s}/{q}": {"state": b.state,
                                      "failures": b.failures,
                                      "trips": b.trips}
                         for (s, q), b in sorted(self._breakers.items())},
            "redirects": dict(self.redirects),
            # deep copy: history entries are dicts — callers mutating a
            # stats() snapshot must never corrupt the live record
            "history": copy.deepcopy(self.history),
        }
