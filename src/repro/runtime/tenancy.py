"""Multi-tenant serving: tenants, model registry, core co-scheduling.

One :class:`~repro.runtime.server.Server` can host many SPNs. Each
resident model is a :class:`Tenant` — a lowered program plus a QoS
weight — tracked by a :class:`ModelRegistry`. On the ``vliw-mc``
substrate, co-resident tenants are *co-scheduled*: the machine's cores
are apportioned into disjoint contiguous blocks (QoS-weighted largest
remainder, at least one core each) and every tenant compiles against
its own ``allowed_cores`` restriction through the same partitioner
path the fault-tolerant degraded mode uses. Disjoint core sets mean
tenants never share a core's issue slots; they still share the NoC,
whose contention the PR 5 occupancy model prices per link.

:func:`allocate_cores` is the pure apportionment; :func:`plan_rebalance`
proposes the serving-time one-core move the Server's repartitioner
evaluates against the weighted-makespan objective.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Mapping, Sequence

from ..core import program as program_mod
from ..core.program import TensorProgram


@dataclasses.dataclass
class Tenant:
    """One resident model: a lowered program plus serving policy.

    ``qos_weight`` scales both the tenant's share of ``vliw-mc`` cores
    and its term in the rebalancer's weighted-makespan objective — a
    weight-2 tenant gets roughly twice the cores of a weight-1 tenant
    and its modeled cycles count double when deciding who is the
    bottleneck. ``cores`` is the currently assigned physical core
    subset (``None`` until co-scheduled, or when the fabric fell back
    to time-sliced full-machine sharing).
    """
    name: str
    prog: TensorProgram
    spn: object | None = None
    qos_weight: float = 1.0
    batch_tile: int | None = None
    cores: tuple[int, ...] | None = None

    def __post_init__(self):
        if not self.name or "/" in self.name or ":" in self.name:
            raise ValueError(
                f"tenant name must be non-empty without '/' or ':', "
                f"got {self.name!r}")
        if not (self.qos_weight > 0):
            raise ValueError(
                f"qos_weight must be > 0, got {self.qos_weight}")
        if self.prog is None:
            if self.spn is None:
                raise ValueError(f"tenant {self.name!r} needs a prog "
                                 "or an spn to lower")
            self.prog = program_mod.lower(self.spn)


def as_tenant(name: str, spec) -> Tenant:
    """Coerce a registry entry to a :class:`Tenant`.

    Accepts a ready ``Tenant`` (name must match), a lowered
    ``TensorProgram``, an SPN node, or a dict of Tenant fields.
    """
    if isinstance(spec, Tenant):
        if spec.name != name:
            raise ValueError(f"tenant name mismatch: key {name!r} vs "
                             f"Tenant.name {spec.name!r}")
        return spec
    if isinstance(spec, TensorProgram):
        return Tenant(name, prog=spec)
    if isinstance(spec, Mapping):
        return Tenant(name, **spec)
    # anything else is treated as an SPN root node
    return Tenant(name, prog=program_mod.lower(spec), spn=spec)


class ModelRegistry:
    """Insertion-ordered name -> :class:`Tenant` map with digest
    reverse lookup (for attributing cached artifacts to tenants)."""

    def __init__(self, tenants: Iterable[Tenant] = ()):
        self._tenants: dict[str, Tenant] = {}
        for t in tenants:
            self.register(t)

    def register(self, tenant: Tenant) -> Tenant:
        if tenant.name in self._tenants:
            raise ValueError(f"tenant {tenant.name!r} already registered")
        self._tenants[tenant.name] = tenant
        return tenant

    def get(self, name: str) -> Tenant:
        try:
            return self._tenants[name]
        except KeyError:
            raise KeyError(f"unknown tenant {name!r}; registered: "
                           f"{sorted(self._tenants)}") from None

    def names(self) -> list[str]:
        return list(self._tenants)

    def tenant_of_digest(self, digest: str) -> str | None:
        """Name of the tenant whose program has this digest (first
        match in registration order), or None."""
        for name, t in self._tenants.items():
            if t.prog.digest() == digest:
                return name
        return None

    def __len__(self) -> int:
        return len(self._tenants)

    def __iter__(self):
        return iter(self._tenants.values())

    def __contains__(self, name: str) -> bool:
        return name in self._tenants


def allocate_cores(weights: Mapping[str, float],
                   core_ids: Sequence[int] | int,
                   ) -> dict[str, tuple[int, ...]]:
    """QoS-weighted apportionment of physical cores to tenants.

    ``core_ids`` is the pool to divide — a core count (meaning
    ``range(n)``) or an explicit id list (the degraded path passes the
    surviving cores). Largest-remainder apportionment on the weights
    with a floor of one core per tenant; each tenant gets a contiguous
    block of the (sorted) pool so XY-routed traffic stays local.
    Returns ``{}`` when there are fewer cores than tenants —
    co-residency is infeasible and the caller falls back to time-sliced
    full-machine sharing. Deterministic: ties break by registration
    order (dict order of ``weights``).
    """
    if isinstance(core_ids, int):
        core_ids = range(core_ids)
    pool = sorted(int(c) for c in core_ids)
    names = list(weights)
    if not names or len(pool) < len(names):
        return {}
    total_w = sum(float(weights[n]) for n in names)
    n_cores = len(pool)
    # ideal shares, floored at 1; largest remainder distributes the rest
    quota = {n: n_cores * float(weights[n]) / total_w for n in names}
    counts = {n: max(1, int(quota[n])) for n in names}
    spare = n_cores - sum(counts.values())
    if spare < 0:
        # floors overshot (many tiny-weight tenants): strip from the
        # largest blocks until feasible, never below 1
        for n in sorted(names, key=lambda n: -counts[n]):
            take = min(counts[n] - 1, -spare)
            counts[n] -= take
            spare += take
            if spare == 0:
                break
    else:
        remainders = sorted(
            names, key=lambda n: (-(quota[n] - int(quota[n])),
                                  names.index(n)))
        i = 0
        while spare > 0:
            counts[remainders[i % len(remainders)]] += 1
            spare -= 1
            i += 1
    alloc: dict[str, tuple[int, ...]] = {}
    off = 0
    for n in names:
        alloc[n] = tuple(pool[off: off + counts[n]])
        off += counts[n]
    return alloc


def plan_rebalance(allocation: Mapping[str, Sequence[int]],
                   pressure: Mapping[str, float],
                   avoid: Iterable[str] = (),
                   ) -> dict | None:
    """Propose moving ONE core from the least-pressured tenant to the
    most-pressured one.

    ``pressure`` is the weighted cost the Server computed (QoS weight x
    modeled cycles). ``avoid`` lists tenants that should not RECEIVE a
    core (e.g. the attribution engine says they are comm-bound: more
    cores means more NoC traffic, not less makespan). Returns
    ``{"from": donor, "to": receiver, "counts": {name: n}}`` or ``None``
    when no legal move exists (donor needs >1 core, receiver must
    differ from donor).
    """
    names = [n for n in allocation if n in pressure]
    if len(names) < 2:
        return None
    avoid = set(avoid)
    receivers = sorted(
        (n for n in names if n not in avoid),
        key=lambda n: (-pressure[n], names.index(n)))
    if not receivers:
        receivers = sorted(names,
                           key=lambda n: (-pressure[n], names.index(n)))
    receiver = receivers[0]
    donors = sorted(
        (n for n in names
         if n != receiver and len(allocation[n]) > 1),
        key=lambda n: (pressure[n], names.index(n)))
    if not donors:
        return None
    donor = donors[0]
    counts = {n: len(allocation[n]) for n in allocation}
    counts[donor] -= 1
    counts[receiver] += 1
    return {"from": donor, "to": receiver, "counts": counts}


def blocks_from_counts(counts: Mapping[str, int],
                       core_ids: Sequence[int] | int,
                       ) -> dict[str, tuple[int, ...]]:
    """Contiguous disjoint blocks over the pool matching exact per-
    tenant core counts (the rebalancer's adjusted counts)."""
    if isinstance(core_ids, int):
        core_ids = range(core_ids)
    pool = sorted(int(c) for c in core_ids)
    if sum(counts.values()) != len(pool):
        raise ValueError(f"counts {dict(counts)} do not cover pool of "
                         f"{len(pool)} cores")
    alloc: dict[str, tuple[int, ...]] = {}
    off = 0
    for n, k in counts.items():
        if k < 1:
            raise ValueError(f"tenant {n!r} needs >= 1 core, got {k}")
        alloc[n] = tuple(pool[off: off + k])
        off += k
    return alloc
