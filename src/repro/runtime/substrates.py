"""Pluggable execution substrates behind one compile/execute interface.

The paper's claim is architectural: the *same* SPN instruction stream can
be served by very different machines. The seed repo had the four
execution paths hand-wired across ``core/executors.py``,
``kernels/spn_eval``, ``queries/engine.py`` and ``launch/serve.py``;
this module extracts them behind a single :class:`Substrate` interface —

``compile(prog, *, query, log_domain, batch_tile) -> Artifact``
    one-time work: semiring rewrite for MPE, levelization, kernel
    builds, VLIW compilation, fast-sim decode;
``execute(artifact, leaves) -> values``
    the per-request hot path: (batch, m_ind) linear indicator inputs →
    (batch,) root values (log-domain when the artifact says so).

Five registered implementations:

==============  ==========================================================
``numpy``       float64 alg.-1 oracle (:func:`~repro.core.executors.eval_ops_numpy`)
``leveled-jax`` group-decomposed jit'd JAX executor
``pallas``      Pallas TPU kernel (interpret-mode off-TPU)
``vliw-sim``    VLIW compile + vectorized fast-sim (checked sim as oracle)
``vliw-mc``     N-core partitioned VLIW: DAG min-cut, SEND/RECV streams,
                lockstep checked sim + merged fast-sim (``cores=N``)
==============  ==========================================================

Artifacts are content-addressed via :meth:`TensorProgram.digest` *plus*
each substrate's :meth:`~Substrate.config_fingerprint` (core count,
interconnect, Pallas interpret mode, processor geometry — anything that
changes the compiled artifact without changing the program) and cached
by :class:`repro.runtime.cache.ArtifactCache`; the registry is open —
new backends (sharded, async, remote) register themselves with
:func:`register` and every consumer (query engine, server, benchmarks)
picks them up by name.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from ..core import executors, multicore, program, segments
from ..core.processor import fastsim, sim
from ..core.processor.config import PTREE, ProcessorConfig
from ..obs.attr import attribute_multicore, attribute_single

LANE = 128    # kernel lane tile — the batcher's padding unit

#: accepted spellings -> canonical substrate name (legacy QueryEngine
#: backend names and the ISSUE's long names both resolve)
ALIASES = {
    "numpy-oracle": "numpy",
    "oracle": "numpy",
    "leveled": "leveled-jax",
    "kernel": "pallas",
    "pallas-kernel": "pallas",
    "sim": "vliw-sim",
}

QUERIES = ("joint", "marginal", "mpe", "sample")

#: which semiring a query's program runs under — joint/marginal/sample
#: all execute the *same* sum-product program (they differ only in the
#: evidence mask / where the rows come from), so compiled artifacts are
#: shared across them; only MPE needs the max-product twin
SEMIRING_OF_QUERY = {"joint": "sum", "marginal": "sum", "sample": "sum",
                     "mpe": "max"}


def canonical(name: str) -> str:
    return ALIASES.get(name, name)


@dataclasses.dataclass(eq=False)
class Artifact:
    """One compiled (program, semiring, substrate, batch_tile) artifact."""
    substrate: str
    query: str                        # query that triggered the compile
    semiring: str                     # "sum" | "max" — the real identity
    log_domain: bool
    batch_tile: int
    digest: str                       # base-program content hash
    prog: program.TensorProgram       # derived program actually executed
    payload: object                   # substrate-specific compiled object
    meta: dict = dataclasses.field(default_factory=dict)


class Substrate:
    """Base class: derive the query's program, delegate the real build."""

    name: str = "abstract"

    def __init__(self) -> None:
        self.compile_count = 0        # asserted on by cache-hit tests

    def compile(self, prog: program.TensorProgram, *, query: str = "joint",
                log_domain: bool = True,
                batch_tile: int = LANE) -> Artifact:
        if query not in QUERIES:
            raise ValueError(f"unknown query {query!r}; pick from {QUERIES}")
        self.compile_count += 1
        digest = prog.digest()
        semiring = SEMIRING_OF_QUERY[query]
        # MPE rides the max-product (tropical) twin; every other query
        # the sum-product program itself
        derived = program.to_max_product(prog) if semiring == "max" else prog
        payload, meta = self._build(derived, log_domain, batch_tile)
        return Artifact(substrate=self.name, query=query, semiring=semiring,
                        log_domain=log_domain, batch_tile=batch_tile,
                        digest=digest, prog=derived, payload=payload,
                        meta=meta)

    def pad_tile(self, batch_tile: int) -> int:
        """Row multiple the micro-batcher should pad requests to."""
        return 1    # most substrates take any batch; the kernel overrides

    def config_fingerprint(self) -> str:
        """Stable id of every configuration knob that changes the
        compiled artifact. Part of the :class:`ArtifactCache` key: the
        same program compiled under a different substrate configuration
        (core count, interpret mode, processor geometry) must MISS, not
        return a stale artifact."""
        return ""

    def _build(self, prog: program.TensorProgram, log_domain: bool,
               batch_tile: int):
        raise NotImplementedError

    def execute(self, artifact: Artifact, leaves: np.ndarray) -> np.ndarray:
        raise NotImplementedError


_REGISTRY: dict[str, type[Substrate]] = {}


def register(cls: type[Substrate]) -> type[Substrate]:
    _REGISTRY[cls.name] = cls
    return cls


def available_substrates() -> tuple[str, ...]:
    return tuple(_REGISTRY)


def get_substrate(name: str, **kwargs) -> Substrate:
    """Instantiate a registered substrate by (aliased) name."""
    cname = canonical(name)
    if cname not in _REGISTRY:
        raise ValueError(f"unknown substrate {name!r}; "
                         f"pick from {available_substrates()}")
    return _REGISTRY[cname](**kwargs)


def make_substrate(name: str, *, processor: ProcessorConfig = PTREE,
                   interpret: bool | None = None,
                   cores: int = 2,
                   interconnect=None,
                   autotune: str | None = None,
                   autotune_seed: int = 0) -> Substrate:
    """Instantiate a substrate, routing the shared runtime options to the
    constructors that take them (the one place this mapping lives)."""
    cname = canonical(name)
    kwargs = {"pallas": {"interpret": interpret},
              "vliw-sim": {"processor": processor},
              "vliw-mc": {"processor": processor, "cores": cores,
                          "autotune": autotune,
                          "autotune_seed": autotune_seed,
                          **({"interconnect": interconnect}
                             if interconnect is not None else {})},
              }.get(cname, {})
    return get_substrate(cname, **kwargs)


# --------------------------------------------------------------------------- #
# implementations
# --------------------------------------------------------------------------- #
@register
class NumpySubstrate(Substrate):
    """Float64 alg.-1 oracle — the reference every other backend chases."""

    name = "numpy"

    def _build(self, prog, log_domain, batch_tile):
        return None, {}

    def execute(self, artifact, leaves):
        return executors.eval_ops_numpy(artifact.prog, leaves,
                                        log_domain=artifact.log_domain)


@register
class LeveledJaxSubstrate(Substrate):
    """Segment-scheduled jit'd JAX executor (production CPU/TPU path)."""

    name = "leveled-jax"

    def _build(self, prog, log_domain, batch_tile):
        meta = {"segments": segments.segment_program(prog).stats()}
        return executors.make_leveled_eval(prog, log_domain), meta

    def execute(self, artifact, leaves):
        return np.asarray(artifact.payload(leaves), np.float64)


@register
class PallasSubstrate(Substrate):
    """Pallas TPU kernel with VMEM-resident value buffer.

    ``interpret=None`` auto-detects the backend at compile time —
    compiled kernel on TPU, Pallas interpreter elsewhere — and the mode
    actually used is recorded in the artifact meta so interpreter-mode
    numbers are never mistaken for compiled-kernel numbers.
    """

    name = "pallas"

    def __init__(self, interpret: bool | None = None) -> None:
        super().__init__()
        self.interpret = interpret

    def config_fingerprint(self) -> str:
        # None resolves at build time via the backend — the *backend* is
        # the stable fact, so fingerprint what auto mode will pick
        from ..kernels.spn_eval.kernel import default_interpret
        interpret = (default_interpret() if self.interpret is None
                     else bool(self.interpret))
        return f"interpret={interpret}"

    def _build(self, prog, log_domain, batch_tile):
        from ..kernels.spn_eval import build_eval
        from ..kernels.spn_eval.kernel import default_interpret
        interpret = (default_interpret() if self.interpret is None
                     else bool(self.interpret))
        run = build_eval(prog, batch_tile=batch_tile, log_domain=log_domain,
                         interpret=interpret)
        meta = {"interpret": interpret,
                "backend": jax.default_backend(),
                "segments": segments.segment_program(prog).stats()}
        return run, meta

    def execute(self, artifact, leaves):
        return np.asarray(artifact.payload(leaves, None), np.float64)

    def pad_tile(self, batch_tile: int) -> int:
        return batch_tile    # VMEM kernel wants whole 128-lane tiles


@register
class VliwSimSubstrate(Substrate):
    """VLIW compile + vectorized fast-sim of the paper's processor.

    The artifact payload is ``(vliw_program, dense_program, workspace)``:
    the compiled instruction stream, its pre-decoded dense encoding and a
    reusable value-buffer workspace. ``execute`` runs the vectorized
    fast-sim; :meth:`execute_checked` runs the cycle-accurate checked
    simulator on the same artifact — the conformance oracle fast-sim
    results are asserted bit-identical against.
    """

    name = "vliw-sim"

    def __init__(self, processor: ProcessorConfig = PTREE) -> None:
        super().__init__()
        self.processor = processor

    def config_fingerprint(self) -> str:
        return self.processor.name

    def _build(self, prog, log_domain, batch_tile):
        from ..core.compiler.pipeline import compile_program
        vprog = compile_program(prog, self.processor)
        dense = fastsim.decode(vprog, self.processor)
        attribution = attribute_single(vprog.num_cycles,
                                       vprog.n_useful_ops,
                                       self.processor.num_pes)
        meta = {"cycles": vprog.num_cycles,
                "ops_per_cycle": vprog.ops_per_cycle,
                "n_useful_ops": vprog.n_useful_ops,
                "processor": self.processor.name,
                "attribution": attribution.to_dict(),
                "bottleneck": attribution.bottleneck}
        return (vprog, dense, {}), meta

    def _finish(self, artifact, root_f32: np.ndarray) -> np.ndarray:
        vals = root_f32.astype(np.float64)
        if artifact.log_domain:
            with np.errstate(divide="ignore"):
                vals = np.log(vals)
        return vals

    def execute(self, artifact, leaves):
        _, dense, workspace = artifact.payload
        return self._finish(artifact, fastsim.run(dense, leaves, workspace))

    def execute_checked(self, artifact, leaves):
        """Cycle-accurate checked simulation (structural-rule oracle)."""
        vprog, _, _ = artifact.payload
        res = sim.simulate_leaves(vprog, np.asarray(leaves, np.float32),
                                  self.processor)
        return self._finish(artifact, res.root_values)


@register
class VliwMultiCoreSubstrate(VliwSimSubstrate):
    """N replicated VLIW cores + modeled NoC interconnect (``cores=N``).

    The SPN DAG is min-cut partitioned across ``cores`` copies of the
    paper's processor (:mod:`repro.core.multicore`); cut values travel
    as shared-register-window rows with explicit SEND/RECV instructions
    and cycle-accounted latency over the configured topology (ideal
    ``xbar``, or a physical ``ring``/``mesh``/``torus`` with per-link
    contention and topology-aware core placement). The artifact payload is
    ``(MultiCoreProgram, merged DenseProgram, workspace)``:

    - ``execute`` runs the *merged* fast-sim — all cores' streams
      decoded into one dense numpy program, bit-identical to both the
      lockstep checked simulator and the single-core fast-sim oracle;
    - ``execute_checked`` clocks the N checked cores in lockstep with
      flow-control stalls — the conformance oracle, whose calibrated
      cycle count (value-independent) is recorded in the artifact meta
      as the serving cycle cost.
    """

    name = "vliw-mc"

    def __init__(self, processor: ProcessorConfig = PTREE, cores: int = 2,
                 interconnect: multicore.InterconnectConfig = multicore.comm.XBAR,
                 seed: int = 0, strategy: str = "subtree",
                 eta_iters: int = 2, placement: str = "aware",
                 autotune: str | None = None, autotune_seed: int = 0,
                 tune_config=None,
                 allowed_cores: tuple | None = None,
                 restrict_reason: str = "degraded") -> None:
        super().__init__(processor)
        if cores < 1:
            raise ValueError(f"cores must be >= 1, got {cores}")
        self.cores = cores
        self.interconnect = interconnect
        self.seed = seed
        self.strategy = strategy
        self.eta_iters = eta_iters
        self.placement = placement
        mode = autotune or "off"
        if mode not in ("off", "cached") and not mode.startswith("budget="):
            raise ValueError(f"autotune must be 'off', 'cached' or "
                             f"'budget=N', got {autotune!r}")
        self.autotune = mode
        self.autotune_seed = autotune_seed
        self.tune_config = tune_config    # explicit TuneConfig (tests)
        # degraded mode: restrict compiles to the surviving physical
        # core subset (None / the full set = the healthy machine)
        if allowed_cores is not None:
            alive = tuple(sorted({int(c) for c in allowed_cores}))
            if alive == tuple(range(cores)):
                alive = None
            elif alive and (alive[0] < 0 or alive[-1] >= cores):
                raise ValueError(f"allowed_cores {alive} outside the "
                                 f"{cores}-core machine")
            elif not alive:
                raise ValueError("allowed_cores must name at least one core")
            allowed_cores = alive
        self.allowed_cores = allowed_cores
        # why the restriction exists — "degraded" (fault recovery) or
        # "co-resident" (multi-tenant co-scheduling); label only, but
        # kept distinct in the fingerprint so a degraded artifact is
        # never served as a co-resident one (or vice versa)
        self.restrict_reason = restrict_reason

    def config_fingerprint(self) -> str:
        fp = (f"{self.processor.name}/cores={self.cores}"
              f"/{self.interconnect.fingerprint()}"
              f"/{self.strategy}/seed={self.seed}"
              f"/eta={self.eta_iters}/place={self.placement}")
        # conditional suffixes keep untuned fingerprints (and therefore
        # cache keys) identical to previous releases
        if self.autotune != "off":
            fp += f"/tune={self.autotune}:{self.autotune_seed}"
        if self.tune_config is not None:
            fp += f"/cfg={self.tune_config.fingerprint()}"
        if self.allowed_cores is not None:
            fp += "/alive=" + ".".join(str(c) for c in self.allowed_cores)
            if self.restrict_reason != "degraded":
                fp += f"/as={self.restrict_reason}"
        return fp

    def restricted(self, alive, dead_links=(), slow_links=(), *,
                   reason: str = "degraded"):
        """A new substrate instance compiling onto a core subset.

        ``alive`` are the physical core ids to use; dead/slow links are
        merged into the interconnect config (so they show in the
        fingerprint → distinct cache key, and routing avoids them).
        ``reason`` labels the restriction in the artifact's
        ``core_decision`` — ``"degraded"`` for fault recovery,
        ``"co-resident"`` for multi-tenant co-scheduling. Autotuning is
        intentionally dropped: restricted artifacts compile the plain
        comm-aware pipeline (the tuner's probe machine would not see
        the restriction).
        """
        return type(self)(
            processor=self.processor, cores=self.cores,
            interconnect=self.interconnect.degraded(
                dead_links=dead_links, slow_links=slow_links),
            seed=self.seed, strategy=self.strategy,
            eta_iters=self.eta_iters, placement=self.placement,
            allowed_cores=tuple(alive), restrict_reason=reason)

    def degraded(self, alive, dead_links=(), slow_links=()):
        """A new substrate instance targeting the surviving fabric
        (see :meth:`restricted`)."""
        return self.restricted(alive, dead_links, slow_links,
                               reason="degraded")

    def _resolve_tuning(self, prog):
        """The TuneConfig to compile with, or (None, None) when untuned.

        The autotuner is deterministic in (program digest, budget, seed),
        so the mode string in :meth:`config_fingerprint` is a sufficient
        cache-key proxy for the winning config itself.
        """
        if self.tune_config is not None:
            tc = self.tune_config.canonical(self.tune_config.cores)
            return tc, {"mode": "manual", "config": tc.fingerprint()}
        if self.autotune == "off":
            return None, None
        from ..core.autotune import DEFAULT_BUDGET, tune_program
        from ..core.autotune.search import lookup_cached
        if self.autotune == "cached":
            hit = lookup_cached(prog.digest())
            if hit is not None:
                return hit.config, dict(hit.summary(), mode="cached")
            budget = DEFAULT_BUDGET
        else:
            budget = int(self.autotune.split("=", 1)[1])
        result = tune_program(
            prog, self.processor, max_cores=self.cores,
            icfg=self.interconnect, budget=budget,
            seed=self.autotune_seed, placement=self.placement)
        return result.config, dict(result.summary(), mode=self.autotune)

    def _build(self, prog, log_domain, batch_tile):
        alive = self.allowed_cores
        # degraded compiles never autotune: degraded mode optimizes for
        # serving *at all* on the surviving fabric, not the last cycle,
        # and the tuner's probe machine would not see the faults anyway
        tc, tune_summary = ((None, None) if alive is not None
                            else self._resolve_tuning(prog))
        if tc is not None:
            return self._build_tuned(prog, tc, tune_summary)
        mcp = multicore.compile_multicore(
            prog, self.processor, self.cores, self.interconnect,
            seed=self.seed, strategy=self.strategy,
            eta_iters=self.eta_iters, placement=self.placement,
            allowed_cores=alive)
        decision = {"requested": self.cores, "chosen": self.cores,
                    "reason": "multicore"}
        if alive is not None:
            decision.update(chosen=len(alive),
                            reason=self.restrict_reason,
                            alive=list(alive))
        if self.cores > 1 and (alive is None or len(alive) > 1):
            # cheap single-core probe: when SEND/RECV overhead makes the
            # partitioned program *slower* than one core (tiny SPNs),
            # serve the single-core compile instead of paying comm for a
            # slowdown — and record the decision either way (degraded
            # machines probe one *surviving* core: no routes, so always
            # feasible even with dead links)
            single = multicore.compile_multicore(
                prog, self.processor,
                1 if alive is None else self.cores, self.interconnect,
                eta_iters=0,
                allowed_cores=None if alive is None else (alive[0],))
            decision["single_core_cycles"] = single.meta["cycles"]
            decision["multicore_cycles"] = mcp.meta["cycles"]
            if single.meta["cycles"] < mcp.meta["cycles"]:
                mcp = single
                decision.update(
                    chosen=1, reason="single-core-fallback"
                    if alive is None
                    else f"{self.restrict_reason}-single-core")
        dense = multicore.decode_multicore(mcp, cycles=mcp.meta["cycles"])
        attribution = attribute_multicore(mcp)
        meta = {"cycles": mcp.meta["cycles"],
                "ops_per_cycle": mcp.meta["ops_per_cycle"],
                "n_useful_ops": dense.n_useful_ops,
                "processor": self.processor.name,
                "core_decision": decision,
                "multicore": mcp.meta,
                "attribution": attribution.to_dict(),
                "bottleneck": attribution.bottleneck}
        return (mcp, dense, {}), meta

    def _build_tuned(self, prog, tc, tune_summary):
        """Compile the tuned configuration (functional/timing split).

        The *timing model* is the tuned machine: ``tc.cores`` cores
        running the ``tc.interleave``-way interleaved program — its
        calibrated lockstep cycle count is the artifact's serving cost
        and :meth:`execute_checked` clocks exactly that machine. The
        *functional model* serving values is the cheapest bit-identical
        program — the base program's single-core dense decode (the
        merged interleaved multicore fast-sim computes, op for op, the
        same f32 dataflow per instance; the conformance tests assert the
        bit-equality this split relies on).
        """
        from ..core.compiler.pipeline import compile_program
        k = tc.interleave
        built = program.interleave(prog, k) if k > 1 else prog
        mcp = multicore.compile_multicore(
            built, self.processor, tc.cores, self.interconnect,
            seed=tc.seed, strategy=tc.strategy, eta_iters=tc.eta_iters,
            passes=tc.passes, placement=self.placement, grain=tc.grain,
            max_arity=tc.max_arity)
        dense = fastsim.decode(compile_program(prog, self.processor),
                               self.processor)
        attribution = attribute_multicore(mcp, interleave=k)
        meta = {"cycles": mcp.meta["cycles"],
                "cycles_per_eval": mcp.meta["cycles"] / k,
                "interleave": k,
                "ops_per_cycle": mcp.meta["ops_per_cycle"],
                "n_useful_ops": dense.n_useful_ops,
                "processor": self.processor.name,
                "autotune": tune_summary,
                "core_decision": {"requested": self.cores,
                                  "chosen": tc.cores,
                                  "reason": "autotune"},
                "multicore": mcp.meta,
                "attribution": attribution.to_dict(),
                "bottleneck": attribution.bottleneck}
        return (mcp, dense, {}), meta

    def execute(self, artifact, leaves):
        _, dense, workspace = artifact.payload
        return self._finish(artifact, fastsim.run(dense, leaves, workspace))

    def execute_checked(self, artifact, leaves):
        """Lockstep N-core cycle-accurate simulation of the artifact's
        timing-model machine — for tuned interleaved artifacts the batch
        is packed ``k`` evals per row (zero-padded, de-interleaved and
        trimmed afterwards), so the checked result stays comparable
        bit-for-bit with :meth:`execute`."""
        mcp, _, _ = artifact.payload
        leaves = np.atleast_2d(np.asarray(leaves, np.float32))
        k = int(artifact.meta.get("interleave", 1))
        if k == 1:
            res = multicore.simulate_multicore(mcp, leaves)
            return self._finish(artifact, res.root_values)
        b, m = leaves.shape
        pad = (-b) % k
        if pad:
            leaves = np.concatenate(
                [leaves, np.zeros((pad, m), np.float32)])
        packed = leaves.reshape(-1, k * m)
        res = multicore.simulate_multicore(mcp, packed)
        flat = res.root_values.T.reshape(-1)[:b]
        return self._finish(artifact, flat)
