"""Unified substrate runtime: pluggable backends, artifact cache,
dynamic micro-batching, serving — plus the fault-tolerance harness.

See :mod:`repro.runtime.substrates` for the backend registry,
:mod:`repro.runtime.server` for the serving entry point.
"""
from .batcher import MicroBatcher, PendingResult
from .cache import ArtifactCache
from .fault import (FailureInjector, Heartbeat, RestartPolicy,
                    TrainingAborted, Watchdog, run_with_restarts)
from .resilience import (FALLBACK_CHAIN, Backpressure, CircuitBreaker,
                         CircuitOpen, CoreFault, FabricError, FabricState,
                         FaultEvent, FaultInjector, FaultPlan, LinkFault,
                         RequestTimeout, ResilienceExhausted,
                         ResilienceManager, ResiliencePolicy,
                         TransientFault)
from .server import DEFAULT_SUBSTRATES, ParityError, Server, verify_parity
from .substrates import (ALIASES, LANE, QUERIES, SEMIRING_OF_QUERY, Artifact,
                         Substrate, available_substrates, canonical,
                         get_substrate, make_substrate, register)
from .tenancy import (ModelRegistry, Tenant, allocate_cores,
                      plan_rebalance)

__all__ = [
    # fault tolerance
    "FailureInjector", "Heartbeat", "RestartPolicy", "TrainingAborted",
    "Watchdog", "run_with_restarts",
    # serving-fabric resilience
    "FALLBACK_CHAIN", "Backpressure", "CircuitBreaker", "CircuitOpen",
    "CoreFault", "FabricError", "FabricState", "FaultEvent",
    "FaultInjector", "FaultPlan", "LinkFault", "RequestTimeout",
    "ResilienceExhausted", "ResilienceManager", "ResiliencePolicy",
    "TransientFault",
    # substrate runtime
    "ALIASES", "LANE", "QUERIES", "SEMIRING_OF_QUERY", "Artifact",
    "Substrate", "available_substrates", "canonical", "get_substrate",
    "make_substrate", "register",
    "ArtifactCache", "MicroBatcher", "PendingResult",
    "DEFAULT_SUBSTRATES", "ParityError", "Server", "verify_parity",
    # multi-tenant serving
    "ModelRegistry", "Tenant", "allocate_cores", "plan_rebalance",
]
