from .fault import (FailureInjector, Heartbeat, RestartPolicy,
                    TrainingAborted, Watchdog, run_with_restarts)

__all__ = ["FailureInjector", "Heartbeat", "RestartPolicy", "TrainingAborted",
           "Watchdog", "run_with_restarts"]
