"""Content-addressed LRU cache of compiled substrate artifacts.

Repeated requests for the same (SPN, query, substrate + configuration,
batch tile) tuple must never re-levelize, re-pad, re-trace or re-run the
VLIW compiler: keys are built from :meth:`TensorProgram.digest` — a
*content* hash — so even a structurally identical program re-learned
into a fresh object hits. The key also carries the substrate's
:meth:`~repro.runtime.substrates.Substrate.config_fingerprint`:
recompiling the same program under a different substrate configuration
(``vliw-mc`` core count, Pallas interpret mode, processor geometry) is a
*different* artifact and must miss instead of returning a stale one.
Autotuning rides the same mechanism: the ``vliw-mc`` fingerprint grows a
``/tune=<mode>:<seed>`` suffix when autotuning is on, and because the
search itself is deterministic in (program digest, budget, seed) that
suffix content-addresses the winning :class:`TuneConfig` too — untuned
fingerprints are unchanged, so existing cache keys stay valid.
Capacity-bounded LRU with hit/miss/eviction counters (`stats()`), shared
by the query engine, the server and the benchmarks.
"""
from __future__ import annotations

from collections import OrderedDict

from ..core.program import TensorProgram
from ..obs import metrics, trace
from .substrates import LANE, SEMIRING_OF_QUERY, Substrate


class ArtifactCache:
    def __init__(self, capacity: int = 64):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self._entries: OrderedDict[tuple, object] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    @staticmethod
    def key(prog: TensorProgram, query: str, substrate: Substrate,
            batch_tile: int, log_domain: bool) -> tuple:
        # the query component is normalized to its semiring: joint,
        # marginal and sample all execute the identical sum-product
        # program, so they share one compiled artifact per substrate;
        # the substrate contributes its name AND its config fingerprint
        # (a bare name would build keys that can never match a stored
        # entry for any substrate with a non-empty fingerprint)
        return (prog.digest(), SEMIRING_OF_QUERY.get(query, query),
                substrate.name, substrate.config_fingerprint(),
                batch_tile, log_domain)

    def get_or_compile(self, substrate: Substrate, prog: TensorProgram, *,
                       query: str = "joint", log_domain: bool = True,
                       batch_tile: int = LANE):
        k = self.key(prog, query, substrate, batch_tile, log_domain)
        with trace.span("cache.lookup",
                        lambda: {"substrate": substrate.name,
                                 "semiring": k[1]}) as sp:
            art = self._entries.get(k)
            if art is not None:
                self.hits += 1
                metrics.counter("cache.hits").inc()
                sp.set("hit", True)
                self._entries.move_to_end(k)
                return art
            self.misses += 1
            metrics.counter("cache.misses").inc()
            sp.set("hit", False)
        with trace.span(f"compile.{substrate.name}",
                        lambda: {"digest": k[0][:12], "semiring": k[1],
                                 "config": k[3]}):
            art = substrate.compile(prog, query=query, log_domain=log_domain,
                                    batch_tile=batch_tile)
        self._entries[k] = art
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.evictions += 1
            metrics.counter("cache.evictions").inc()
        metrics.gauge("cache.size").set(len(self._entries))
        return art

    def artifacts(self):
        """Resident artifacts, LRU order (introspection, e.g. stats).

        Returns a materialized snapshot, not a live view: callers
        iterate while serving continues, and a concurrent
        ``get_or_compile`` eviction mutating the underlying dict must
        not blow up (or silently skip) the iteration.
        """
        return list(self._entries.values())

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: tuple) -> bool:
        return key in self._entries

    def clear(self) -> None:
        self._entries.clear()

    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "size": len(self._entries),
                "capacity": self.capacity}
