"""The unified substrate runtime server.

``Server`` owns everything between a raw SPN and a stream of answered
queries: the lowered :class:`TensorProgram`, one instance of every
requested substrate, the content-addressed :class:`ArtifactCache`, and a
dynamic :class:`MicroBatcher` per live artifact. The serving driver
(``repro.launch.serve``) is a thin CLI over this class, and later
scaling layers (sharding, async dispatch, multi-model) stack on the same
interface.

Request path::

    submit(x, query, substrate)          # evidence -> leaves -> enqueue
      -> flush() / result()              # coalesce, pad to tile, execute
    query(x, query, substrate)           # synchronous convenience

:func:`verify_parity` is the reusable cross-substrate agreement check —
every substrate's root values against the float64 numpy oracle, plus the
vliw fast-sim against the cycle-accurate checked simulator (bit-exact).
It replaces the asserts previously inlined in ``serve_spn()`` and is
shared by serve and the tests.
"""
from __future__ import annotations

import copy
import time
import weakref

import numpy as np

from ..core import program as program_mod
from ..core.multicore.comm import LinkDownError
from ..core.processor.config import PTREE, ProcessorConfig
from ..core.spn import SPN
from ..obs import metrics, trace
from ..obs.slo import SLObjective, SLOTracker
from .batcher import MicroBatcher, PendingResult
from .cache import ArtifactCache
from .resilience import (Backpressure, CircuitOpen, CoreFault, FabricError,
                         FaultInjector, FaultPlan, LinkFault,
                         RequestTimeout, ResilienceExhausted,
                         ResilienceManager, ResiliencePolicy, TransientFault)
from .substrates import (LANE, QUERIES, SEMIRING_OF_QUERY, Artifact,
                         Substrate, canonical, make_substrate)

DEFAULT_SUBSTRATES = ("numpy", "leveled-jax", "pallas", "vliw-sim",
                      "vliw-mc")


class ParityError(AssertionError):
    pass


class Server:
    """Multi-substrate, multi-query SPN inference server."""

    def __init__(self, spn: SPN | None = None, *,
                 prog: program_mod.TensorProgram | None = None,
                 substrates: tuple[str, ...] | None = None,
                 processor: ProcessorConfig = PTREE,
                 interpret: bool | None = None,
                 cores: int = 2,
                 topology: str | None = None,
                 interconnect=None,
                 autotune: str | None = None,
                 autotune_seed: int = 0,
                 cache_capacity: int = 32,
                 batch_tile: int = LANE,
                 max_rows: int = 4096,
                 faults=None,
                 resilience: ResiliencePolicy | None = None,
                 slo: SLObjective | dict | None = None):
        if prog is None:
            if spn is None:
                raise ValueError("need an SPN or a lowered TensorProgram")
            prog = program_mod.lower(spn)
        self.spn = spn
        self.prog = prog
        self.batch_tile = batch_tile
        self.max_rows = max_rows
        self.cache = ArtifactCache(cache_capacity)
        self._processor = processor
        self._interpret = interpret
        self._cores = cores
        if interconnect is None and topology is not None:
            from ..core.multicore import named_interconnect
            interconnect = named_interconnect(topology)
        self._interconnect = interconnect
        names = tuple(canonical(n)
                      for n in (substrates or DEFAULT_SUBSTRATES))
        self.substrates: dict[str, Substrate] = {
            n: make_substrate(n, processor=processor, interpret=interpret,
                              cores=cores, interconnect=interconnect,
                              autotune=autotune, autotune_seed=autotune_seed)
            for n in names}
        self._batchers: weakref.WeakKeyDictionary[Artifact, MicroBatcher] = \
            weakref.WeakKeyDictionary()
        # ---- resilience layer (see repro.runtime.resilience) ----------
        # ``faults`` injects a deterministic FaultPlan (a plan object,
        # one spec string, or a list of spec strings); ``resilience``
        # overrides the retry/timeout/breaker policy. The manager is
        # always present (breaker bookkeeping is cheap); hardened
        # admission control only engages when either knob is set, so a
        # plain Server behaves exactly as before.
        if faults is not None and not isinstance(faults, FaultPlan):
            faults = FaultPlan.parse(faults)
        self._injector = (FaultInjector(faults, cores)
                          if faults is not None else None)
        self._hardened = faults is not None or resilience is not None
        self.resilience = ResilienceManager(
            resilience, n_cores=cores, injector=self._injector)
        # ---- SLO tracking (see repro.obs.slo) -------------------------
        # The tracker always records (``stats()["slo"]`` is free), but
        # burn-rate *shedding* only engages when the caller passed an
        # explicit objective: a plain Server never rejects work it used
        # to accept.
        if isinstance(slo, dict):
            slo = SLObjective(**slo)
        self._slo_shedding = slo is not None
        self.slo = SLOTracker(slo)

    # ---------------- compilation ----------------------------------------- #
    def substrate(self, name: str) -> Substrate:
        cname = canonical(name)
        if cname not in self.substrates:
            raise ValueError(f"substrate {name!r} not enabled; have "
                             f"{tuple(self.substrates)}")
        return self.substrates[cname]

    def artifact(self, query: str = "joint",
                 substrate: str = "leveled-jax") -> Artifact:
        """Compiled artifact for (this SPN, query, substrate) — cached."""
        return self.cache.get_or_compile(
            self.substrate(substrate), self.prog, query=query,
            log_domain=True, batch_tile=self.batch_tile)

    def _batcher_for(self, art: Artifact) -> MicroBatcher:
        batcher = self._batchers.get(art)
        if batcher is None:
            sub = self.substrate(art.substrate)
            # the closure must hold the artifact weakly, or this entry's
            # value would pin its own key and the WeakKeyDictionary could
            # never release evicted artifacts (payloads included)
            aref = weakref.ref(art)
            inj = self._injector

            def _execute(leaves, _s=sub, _r=aref, _inj=inj):
                a = _r()
                # an execute failure is recorded as an error span (the
                # exception type lands in the span attrs) and counted —
                # never a silently dropped span (see runtime.fault)
                with trace.span(
                        "exec." + _s.name,
                        lambda: {"rows": int(leaves.shape[0]),
                                 "semiring": a.semiring}):
                    try:
                        if _inj is not None:
                            _inj.before_execute(a)
                        values = _s.execute(a, leaves)
                        if _inj is not None:
                            _inj.after_execute(a, values)
                        return values
                    except Exception:
                        metrics.counter("serve.errors").inc()
                        raise

            # split-retry only under fault injection: the classic
            # fail-the-whole-batch contract (and its errored batch.flush
            # span) is what healthy servers and their tests rely on
            batcher = MicroBatcher(
                _execute, tile=sub.pad_tile(art.batch_tile),
                max_rows=self.max_rows, split_retry=inj is not None)
            self._batchers[art] = batcher
        return batcher

    # ---------------- request path ----------------------------------------- #
    def submit(self, x: np.ndarray, query: str = "joint",
               substrate: str = "leveled-jax") -> PendingResult:
        """Enqueue evidence rows ``x``; returns a :class:`PendingResult`.

        ``x``: (batch, num_vars) with ``-1`` marginalizing (or, for MPE,
        maximizing over) a variable. The result is the (batch,) root log
        value of the query's program on the chosen substrate.
        """
        x = np.atleast_2d(x)
        if self._hardened:
            # admission control: a single request larger than the
            # high-water mark can never be served atomically — reject it
            # honestly; and drain queued in-flight rows before admitting
            # work that would push past the mark
            rows = int(x.shape[0])
            if rows > self.max_rows:
                metrics.counter("fault.backpressure").inc()
                raise Backpressure(
                    f"request of {rows} rows exceeds the server's "
                    f"max_rows={self.max_rows} admission limit")
            queued = sum(b._queued_rows for b in self._batchers.values())
            if queued and queued + rows > self.max_rows:
                self.flush()
        # one root span per request: a fresh trace id is minted here and
        # propagated via PendingResult into the batch-flush span, so a
        # coalesced execution is attributable to every member request
        with trace.span("serve.request",
                        lambda: {"query": query, "substrate": substrate,
                                 "rows": int(x.shape[0])},
                        root=True) as sp:
            if query == "joint" and (x < 0).any():
                raise ValueError("joint queries need full evidence; "
                                 "use query='marginal' for rows "
                                 "containing -1")
            art = self.artifact(query, substrate)
            with trace.span("serve.leaves"):
                leaves = art.prog.leaves_from_evidence(x)
            pending = self._batcher_for(art).submit(leaves)
            pending.trace_id = sp.trace_id
        metrics.counter("serve.requests").inc()
        metrics.counter("serve.rows").inc(int(x.shape[0]))
        return pending

    def flush(self) -> None:
        for batcher in list(self._batchers.values()):
            batcher.flush()

    def query(self, x: np.ndarray, query: str = "joint",
              substrate: str = "leveled-jax") -> np.ndarray:
        """Synchronous submit + flush: (batch,) root log values.

        The request path is *hardened*: bounded retry with exponential
        backoff on transient faults, degraded-mode recompilation on
        core/link faults, substrate fallback (vliw-mc → vliw-sim →
        numpy) when recompilation is infeasible, a circuit breaker per
        (substrate, semiring), and a per-request deadline. Non-fabric
        exceptions (software bugs, bad input) propagate unchanged —
        hardening never masks a real error, and on a healthy fabric the
        behaviour is identical to the classic path.

        End-to-end latency (admission through execute) is observed into
        the per-substrate ``serve.latency_us.<name>`` histogram — the
        p50/p95/p99 source for ``Server.stats()["metrics"]`` and
        ``BENCH_serve.json`` — and into the SLO tracker
        (``stats()["slo"]``): failures and over-target latencies burn
        the (substrate, query-kind) error budget, and a server
        constructed with an explicit ``slo=`` objective sheds load
        (:class:`Backpressure`) once the burn rate crosses the
        objective's threshold — *before* the budget is gone.
        """
        t0 = time.perf_counter()
        name = canonical(substrate)
        semiring = SEMIRING_OF_QUERY.get(query, query)
        try:
            values = self._query_resilient(x, query, name, t0)
        except (ValueError, TypeError):
            raise               # client errors don't burn the budget
        except Backpressure:
            raise               # shed work was never admitted
        except Exception:
            self.slo.record(name, semiring,
                            (time.perf_counter() - t0) * 1e6, ok=False)
            raise
        latency_us = (time.perf_counter() - t0) * 1e6
        metrics.histogram("serve.latency_us." + name).observe(latency_us)
        self.slo.record(name, semiring, latency_us)
        return values

    def query_once(self, x: np.ndarray, query: str = "joint",
                   substrate: str = "leveled-jax") -> np.ndarray:
        """One direct submit + result on exactly the named substrate —
        no retry, no fallback, no breaker. :func:`verify_parity` uses
        this so a faulty substrate can never hide behind the oracle
        fallback and compare the oracle against itself."""
        return self.submit(x, query, substrate).result()

    # ---------------- resilient dispatch ----------------------------------- #
    def _query_resilient(self, x: np.ndarray, query: str, name: str,
                         t0: float) -> np.ndarray:
        mgr = self.resilience
        pol = mgr.policy
        deadline = t0 + pol.timeout_s
        serving = mgr.redirects.get(name, name)
        semiring = SEMIRING_OF_QUERY.get(query, query)
        if self._slo_shedding and self.slo.should_shed(name, semiring):
            # burn-rate admission control: shed before the breaker pays
            # a failed attempt and before the window's budget is gone
            metrics.counter("fault.slo_shed").inc()
            raise Backpressure(
                f"SLO burn rate for {name}/{semiring} exceeds the "
                "shedding threshold; retry after the window cools")
        last_exc: Exception | None = None
        attempted = False
        for target in mgr.chain(serving, self.substrates):
            breaker = mgr.breaker(target, semiring)
            if not breaker.allow():
                metrics.counter("fault.breaker_rejects").inc()
                last_exc = CircuitOpen(
                    f"circuit breaker open for {target}/{semiring}")
                continue
            backoff = pol.backoff_s
            attempt = 0
            while attempt < pol.max_attempts:
                attempt += 1
                if time.perf_counter() > deadline:
                    metrics.counter("fault.timeouts").inc()
                    raise RequestTimeout(
                        f"request exceeded its {pol.timeout_s:.3f}s "
                        "deadline") from last_exc
                try:
                    values = self.submit(x, query, target).result()
                except (CoreFault, LinkFault) as exc:
                    last_exc, attempted = exc, True
                    breaker.record_failure()
                    mgr.record("fabric_fault", substrate=target,
                               error=f"{type(exc).__name__}: {exc}")
                    if self._degrade(target, query):
                        continue        # retry on the degraded substrate
                    break               # cannot degrade → walk the chain
                except TransientFault as exc:
                    last_exc, attempted = exc, True
                    breaker.record_failure()
                    metrics.counter("fault.retries").inc()
                    if attempt < pol.max_attempts and backoff > 0:
                        mgr.sleep(backoff)
                        backoff *= pol.backoff_mult
                    continue            # one-shot: the retry heals it
                except Backpressure:
                    raise               # the caller must shed load
                except FabricError as exc:
                    last_exc, attempted = exc, True
                    breaker.record_failure()
                    break
                except (ValueError, TypeError):
                    raise               # client error: not the fabric's
                except Exception:
                    # non-fabric: a software bug — honest propagation of
                    # the original exception, unretried and unmasked
                    breaker.record_failure()
                    raise
                breaker.record_success()
                if target != name:
                    metrics.counter("fault.fallbacks").inc()
                    if last_exc is not None:
                        if isinstance(last_exc, (CoreFault, LinkFault)):
                            # the requested backend's hardware is gone —
                            # route future requests straight here
                            mgr.redirects[name] = target
                        mgr.record("fallback", requested=name,
                                   served=target,
                                   error=(f"{type(last_exc).__name__}: "
                                          f"{last_exc}"))
                return values
        if not attempted and last_exc is not None:
            raise last_exc              # e.g. every breaker open
        raise ResilienceExhausted(
            f"substrate {name!r} ({query}) failed after retries, "
            "degradation and fallback") from last_exc

    def _degrade(self, name: str, query: str) -> bool:
        """Recompile substrate ``name`` for the surviving fabric.

        Descends on infeasibility: starts from every healthy core and
        drops the highest-numbered survivor until the comm plan routes
        around the dead links (one core has no routes, so the descent
        always terminates at a feasible compile — or the substrate
        cannot degrade at all and the caller falls down the chain).
        Swaps the serving substrate in place on success; the degraded
        artifact is content-addressed like any other (``/alive=``,
        ``/dead=`` fingerprint suffixes) and annotated with
        ``meta["degraded"]``.
        """
        mgr = self.resilience
        sub = self.substrates.get(name)
        if sub is None:
            return False
        alive = list(mgr.state.healthy)
        while alive:
            cand = mgr.degraded_substrate(sub, alive)
            if cand is None:
                return False            # substrate cannot repartition
            try:
                with trace.span("fault.degrade",
                                lambda: {"substrate": name,
                                         "alive": list(alive)}):
                    art = self.cache.get_or_compile(
                        cand, self.prog, query=query, log_domain=True,
                        batch_tile=self.batch_tile)
            except LinkDownError:
                alive = alive[:-1]      # fewer cores ⇒ fewer routes
                continue
            except Exception:
                return False
            art.meta["degraded"] = dict(
                mgr.state.snapshot(), substrate=name,
                from_cores=self._cores, to_cores=len(alive))
            metrics.counter("fault.degraded_compiles").inc()
            self.substrates[name] = cand
            mgr.record("degrade", substrate=name, alive=list(alive),
                       fingerprint=cand.config_fingerprint())
            return True
        return False

    # ---------------- introspection ---------------------------------------- #
    def stats(self) -> dict:
        """Serving statistics (backward-compatible keys) + a read-only
        snapshot of the process-global metrics registry (``"metrics"``:
        request counters, per-substrate latency percentiles, batch fill,
        cache hit counters — see :mod:`repro.obs.metrics`) + the SLO
        burn-rate status (``"slo"``, see :mod:`repro.obs.slo`).

        The returned structure is a **deep copy**: mutating it can never
        corrupt the server's live registries or resilience history.
        """
        out = {"metrics": metrics.snapshot(),
               "cache": self.cache.stats(),
               "compiles": {n: s.compile_count
                            for n, s in self.substrates.items()},
               "padded_rows": 0,
               "batchers": {},
               "multicore": {},
               "autotune": {},
               "slo": self.slo.snapshot(),
               "resilience": self.resilience.stats()}
        for art, b in self._batchers.items():
            out["batchers"][f"{art.semiring}/{art.substrate}"] = dict(
                b.stats, pad_waste=round(b.pad_waste, 4))
            out["padded_rows"] += b.stats["padded_rows"]
        # ONE materialized pass over the resident artifacts (safe
        # against concurrent eviction — see ArtifactCache.artifacts)
        # feeds the multicore, autotune and degraded-artifact sections
        degraded: dict = {}
        for art in self.cache.artifacts():
            key = f"{art.semiring}/{art.substrate}"
            # per-core utilization / communication / barrier accounting
            # of multi-core artifacts (calibrated at compile time)
            mc = art.meta.get("multicore")
            if mc:
                cycles = max(int(mc["cycles"]), 1)
                ops = mc["core_ops"]
                peak = self._processor.num_pes
                out["multicore"][key] = {
                    "cores": mc["effective_cores"],
                    "cycles": mc["cycles"],
                    "core_utilization": [round(o / cycles / peak, 4)
                                         for o in ops],
                    "comm_values_per_batch": mc["comm"]["values"],
                    "comm_rows": mc["comm"]["rows"],
                    "stall_cycles": mc["stall_cycles"],
                    "barrier_idle_cycles": mc["barrier_idle"],
                    "cut_values": mc["cut_values"],
                    # NoC accounting (all zeros under the ideal crossbar)
                    "topology": mc.get("topology", "xbar"),
                    "hop_cut": mc.get("hop_cut", mc["cut_values"]),
                    "busiest_link_occupancy":
                        mc["comm"].get("busiest_link_occupancy", 0.0),
                    "link_stall_cycles":
                        mc["comm"].get("link_stall_cycles", 0),
                    "inject_stall_cycles":
                        mc["comm"].get("inject_stall_cycles", 0),
                    # cycle-attribution verdict (see repro.obs.attr)
                    "bottleneck": art.meta.get("bottleneck"),
                }
            # autotune outcomes: winning config, tuned vs default
            # cycles/eval, and the core-count fallback decisions
            tune = art.meta.get("autotune")
            decision = art.meta.get("core_decision")
            if tune is not None or decision is not None:
                entry: dict = {}
                if tune is not None:
                    entry.update(tune)
                    entry["interleave"] = art.meta.get("interleave", 1)
                if decision is not None:
                    entry["core_decision"] = decision
                out["autotune"][key] = entry
            if art.meta.get("degraded") is not None:
                degraded[key] = art.meta["degraded"]
        if degraded:
            out["resilience"]["degraded_artifacts"] = degraded
        return copy.deepcopy(out)


def verify_parity(server: Server, x: np.ndarray, *, query: str = "marginal",
                  substrates: tuple[str, ...] | None = None,
                  atol: float = 1e-4) -> dict[str, float]:
    """Cross-substrate agreement on ``x`` against the numpy oracle.

    Returns ``{substrate: max_abs_deviation}`` (fast-vs-checked VLIW
    conformance reported as ``vliw-sim/checked``, compared bit-exactly).
    Raises :class:`ParityError` on any disagreement — and also when a
    substrate's execute *throws*: a failing backend is a parity failure,
    reported as a typed error (chaining the real cause) instead of a
    hang or a bare crash. Queries go through :meth:`Server.query_once`,
    the direct non-resilient path, so a faulty substrate can never hide
    behind the fallback chain and compare the oracle against itself.
    """
    if query not in QUERIES:
        raise ValueError(f"unknown query {query!r}")
    names = tuple(canonical(n) for n in (substrates or server.substrates))
    x = np.atleast_2d(x)

    def run(name: str, fn, what: str):
        try:
            return fn()
        except ParityError:
            raise
        except Exception as exc:
            raise ParityError(
                f"substrate {name!r} failed to {what}: "
                f"{type(exc).__name__}: {exc}") from exc

    if "numpy" in server.substrates:
        ref = run("numpy", lambda: server.query_once(x, query, "numpy"),
                  "execute")
    else:   # the oracle is the point of the check — build one on demand
        oracle = make_substrate("numpy")
        art = server.cache.get_or_compile(
            oracle, server.prog, query=query, log_domain=True,
            batch_tile=server.batch_tile)
        ref = run("numpy", lambda: oracle.execute(
            art, art.prog.leaves_from_evidence(x)), "execute")
    devs: dict[str, float] = {}

    def against_ref(name: str, vals: np.ndarray) -> None:
        both_inf = np.isneginf(vals) & np.isneginf(ref)
        dev = float(np.abs(np.where(both_inf, 0.0, vals - ref)).max())
        devs[name] = dev
        if not np.isfinite(dev) or dev > atol:
            raise ParityError(f"substrate {name!r} deviates from the "
                              f"numpy oracle by {dev:.3e} (atol={atol})")

    for name in names:
        if name == "numpy":
            devs[name] = 0.0
            continue
        vals = run(name, lambda: server.query_once(x, query, name),
                   "execute")
        against_ref(name, vals)
        sub = server.substrate(name)
        if hasattr(sub, "execute_checked"):
            # vliw-sim / vliw-mc: the vectorized fast-sim must be
            # bit-identical to the cycle-accurate checked simulator
            art = server.artifact(query, name)
            leaves = art.prog.leaves_from_evidence(np.atleast_2d(x))
            checked = run(name, lambda: sub.execute_checked(art, leaves),
                          "execute (checked sim)")
            fast = run(name, lambda: sub.execute(art, leaves), "execute")
            if not np.array_equal(checked, fast):
                raise ParityError(
                    f"{name} fast-sim root values are not bit-identical "
                    "to the checked cycle-accurate simulator")
            devs[f"{name}/checked"] = 0.0
    return devs
