"""The unified substrate runtime server.

``Server`` owns everything between raw SPNs and a stream of answered
queries: a :class:`~repro.runtime.tenancy.ModelRegistry` of resident
lowered :class:`TensorProgram`\\ s (one tenant by default, many under
multi-tenant serving), one instance of every requested substrate, the
content-addressed :class:`ArtifactCache`, and a dynamic
:class:`MicroBatcher` per live artifact. The serving driver
(``repro.launch.serve``) is a thin CLI over this class, and later
scaling layers (sharding, async dispatch) stack on the same interface.

Request path::

    submit(x, query, substrate, tenant)  # evidence -> leaves -> enqueue
      -> pump() / flush() / result()     # coalesce, pad to tile, execute
    query(x, query, substrate, tenant)   # synchronous convenience

Multi-tenant co-scheduling: with several tenants and the ``vliw-mc``
substrate enabled, the machine's cores are apportioned into disjoint
QoS-weighted blocks (:func:`repro.runtime.tenancy.allocate_cores`) and
each tenant compiles through its own ``allowed_cores``-restricted
substrate — tenants never contend for issue slots, only for the NoC,
which the occupancy model prices. :meth:`rebalance` is the serving-time
repartitioner: it reads the artifacts' cycle attribution and moves one
core from the least- to the most-pressured tenant when that strictly
improves the QoS-weighted makespan.

Continuous batching: requests park in per-(tenant, artifact) micro-
batchers; a flush happens at the rows high-water mark, when
:meth:`pump` finds the oldest queued request past ``flush_max_age_s``
(``start_pump`` runs that check on a background thread so a pending
resolves with *no* explicit ``flush()``/``result()`` call), or
synchronously on first ``result()``.

:func:`verify_parity` is the reusable cross-substrate agreement check —
every substrate's root values against the float64 numpy oracle, plus the
vliw fast-sim against the cycle-accurate checked simulator (bit-exact).
It replaces the asserts previously inlined in ``serve_spn()`` and is
shared by serve and the tests.
"""
from __future__ import annotations

import copy
import threading
import time
import weakref
from typing import Mapping

import numpy as np

from ..core import program as program_mod
from ..core.multicore.comm import LinkDownError
from ..core.processor.config import PTREE, ProcessorConfig
from ..core.spn import SPN
from ..obs import metrics, trace
from ..obs.slo import SLObjective, SLOTracker
from . import tenancy
from .batcher import MicroBatcher, PendingResult
from .cache import ArtifactCache
from .resilience import (Backpressure, CircuitOpen, CoreFault, FabricError,
                         FaultInjector, FaultPlan, LinkFault,
                         RequestTimeout, ResilienceExhausted,
                         ResilienceManager, ResiliencePolicy, TransientFault)
from .substrates import (LANE, QUERIES, SEMIRING_OF_QUERY, Artifact,
                         Substrate, canonical, make_substrate)

DEFAULT_SUBSTRATES = ("numpy", "leveled-jax", "pallas", "vliw-sim",
                      "vliw-mc")
DEFAULT_TENANT = "default"


class ParityError(AssertionError):
    pass


class Server:
    """Multi-substrate, multi-query, multi-tenant SPN inference server."""

    def __init__(self, spn: SPN | None = None, *,
                 prog: program_mod.TensorProgram | None = None,
                 tenants=None,
                 substrates: tuple[str, ...] | None = None,
                 processor: ProcessorConfig = PTREE,
                 interpret: bool | None = None,
                 cores: int = 2,
                 topology: str | None = None,
                 interconnect=None,
                 autotune: str | None = None,
                 autotune_seed: int = 0,
                 cache_capacity: int = 32,
                 batch_tile: int = LANE,
                 max_rows: int = 4096,
                 flush_max_age_s: float | None = None,
                 faults=None,
                 resilience: ResiliencePolicy | None = None,
                 slo: SLObjective | dict | None = None):
        # ---- resident models (see repro.runtime.tenancy) --------------
        # Single-model construction (spn/prog) registers one tenant named
        # "default" so every internal path is uniformly tenant-keyed;
        # ``tenants`` registers many (dict name -> Tenant/SPN/prog/dict,
        # or an iterable of Tenants). ``self.prog``/``self.spn`` keep
        # pointing at the first tenant's model for backward compat.
        self.registry = tenancy.ModelRegistry()
        if tenants is not None:
            if spn is not None or prog is not None:
                raise ValueError("pass either spn/prog or tenants=, "
                                 "not both")
            if isinstance(tenants, Mapping):
                for name, spec in tenants.items():
                    self.registry.register(tenancy.as_tenant(name, spec))
            else:
                for t in tenants:
                    self.registry.register(t)
            if not len(self.registry):
                raise ValueError("tenants= must name at least one model")
            first = self.registry.get(self.registry.names()[0])
            self.prog, self.spn = first.prog, first.spn
        else:
            if prog is None:
                if spn is None:
                    raise ValueError(
                        "need an SPN or a lowered TensorProgram")
                prog = program_mod.lower(spn)
            self.registry.register(
                tenancy.Tenant(DEFAULT_TENANT, prog=prog, spn=spn))
            self.prog, self.spn = prog, spn
        self.batch_tile = batch_tile
        self.max_rows = max_rows
        self.flush_max_age_s = flush_max_age_s
        self.cache = ArtifactCache(cache_capacity)
        self._processor = processor
        self._interpret = interpret
        self._cores = cores
        if interconnect is None and topology is not None:
            from ..core.multicore import named_interconnect
            interconnect = named_interconnect(topology)
        self._interconnect = interconnect
        names = tuple(canonical(n)
                      for n in (substrates or DEFAULT_SUBSTRATES))
        self.substrates: dict[str, Substrate] = {
            n: make_substrate(n, processor=processor, interpret=interpret,
                              cores=cores, interconnect=interconnect,
                              autotune=autotune, autotune_seed=autotune_seed)
            for n in names}
        self._batchers: weakref.WeakKeyDictionary[Artifact, MicroBatcher] = \
            weakref.WeakKeyDictionary()
        self._pump_thread: threading.Thread | None = None
        self._pump_stop: threading.Event | None = None
        # ---- resilience layer (see repro.runtime.resilience) ----------
        # ``faults`` injects a deterministic FaultPlan (a plan object,
        # one spec string, or a list of spec strings); ``resilience``
        # overrides the retry/timeout/breaker policy. The manager is
        # always present (breaker bookkeeping is cheap); hardened
        # admission control only engages when either knob is set, so a
        # plain Server behaves exactly as before.
        if faults is not None and not isinstance(faults, FaultPlan):
            faults = FaultPlan.parse(faults)
        self._injector = (FaultInjector(faults, cores)
                          if faults is not None else None)
        self._hardened = faults is not None or resilience is not None
        self.resilience = ResilienceManager(
            resilience, n_cores=cores, injector=self._injector)
        # ---- SLO tracking (see repro.obs.slo) -------------------------
        # The tracker always records (``stats()["slo"]`` is free), but
        # burn-rate *shedding* only engages when the caller passed an
        # explicit objective: a plain Server never rejects work it used
        # to accept.
        if isinstance(slo, dict):
            slo = SLObjective(**slo)
        self._slo_shedding = slo is not None
        self.slo = SLOTracker(slo)
        # ---- multi-tenant co-scheduling on the vliw-mc fabric ---------
        self._tenant_mc: dict[str, Substrate] = {}
        self._tenant_pool: tuple[int, ...] = tuple(range(cores))
        self._tenancy_events: list[dict] = []
        self._tenancy_mode = ("single" if len(self.registry) == 1
                              else "shared")
        if len(self.registry) > 1 and "vliw-mc" in self.substrates:
            self._coschedule(self._tenant_pool)

    # ---------------- tenancy ---------------------------------------------- #
    def _tenancy_event(self, kind: str, **info) -> None:
        self._tenancy_events.append({"kind": kind, **info})
        trace.instant("tenancy." + kind, info)
        metrics.counter("tenancy." + kind).inc()

    def _coschedule(self, core_ids, dead_links=(), slow_links=()) -> None:
        """(Re)apportion ``core_ids`` across tenants and rebuild each
        tenant's restricted ``vliw-mc`` substrate.

        Infeasible pools (fewer cores than tenants) fall back to
        time-sliced sharing: every tenant serves on the full surviving
        machine through the shared substrate instance.
        """
        pool = tuple(sorted(int(c) for c in core_ids))
        weights = {t.name: t.qos_weight for t in self.registry}
        alloc = tenancy.allocate_cores(weights, pool)
        self._tenant_pool = pool
        if not alloc:
            self._tenant_mc = {}
            for t in self.registry:
                t.cores = None
            self._tenancy_mode = "time-sliced"
            self._tenancy_event("time-sliced", cores=list(pool),
                                tenants=sorted(weights))
            return
        self._apply_allocation(alloc, dead_links, slow_links)
        self._tenancy_mode = "co-resident"
        self._tenancy_event(
            "co-schedule", cores=list(pool),
            allocation={n: list(c) for n, c in alloc.items()})

    def _apply_allocation(self, alloc, dead_links=(),
                          slow_links=()) -> None:
        base = self.substrates["vliw-mc"]
        mc: dict[str, Substrate] = {}
        for name, subset in alloc.items():
            self.registry.get(name).cores = tuple(subset)
            mc[name] = base.restricted(
                subset, dead_links=dead_links, slow_links=slow_links,
                reason="co-resident")
        self._tenant_mc = mc

    def _sub_for(self, tenant: str, cname: str) -> Substrate:
        """The substrate instance serving ``tenant`` on ``cname`` — the
        tenant's core-restricted ``vliw-mc`` when co-scheduled, the
        shared instance otherwise."""
        if cname == "vliw-mc":
            sub = self._tenant_mc.get(tenant)
            if sub is not None:
                return sub
        return self.substrates[cname]

    def rebalance(self, *, query: str = "marginal",
                  apply: bool = True) -> dict | None:
        """Serving-time repartitioner: one core, donor -> receiver.

        Reads each tenant's resident ``vliw-mc`` artifact (compiling
        ``query`` if none is resident yet), prices tenant pressure as
        ``qos_weight x modeled cycles``, and asks
        :func:`tenancy.plan_rebalance` for a one-core move — skipping
        comm-bound receivers (their cycle attribution says more cores
        means more NoC traffic, not less makespan). The candidate
        allocation is compiled (content-addressed, so re-proposals are
        free) and adopted only when the QoS-weighted makespan
        ``max_t(w_t x cycles_t)`` strictly improves — a monotone
        ratchet that can never thrash the fabric. Returns the decision
        record (also appended to ``stats()["tenancy"]["events"]``), or
        ``None`` when fewer than two tenants are co-scheduled.
        """
        if len(self._tenant_mc) < 2:
            return None
        st = self.resilience.state
        dead = tuple(sorted(st.dead_links))
        slow = tuple((a, b, f) for (a, b), f
                     in sorted(st.slow_links.items()))
        cycles: dict[str, int] = {}
        pressure: dict[str, float] = {}
        avoid: list[str] = []
        for name, sub in self._tenant_mc.items():
            t = self.registry.get(name)
            art = self.cache.get_or_compile(
                sub, t.prog, query=query, log_domain=True,
                batch_tile=t.batch_tile or self.batch_tile)
            cycles[name] = int(art.meta["cycles"])
            pressure[name] = t.qos_weight * cycles[name]
            attribution = art.meta.get("attribution") or {}
            if attribution.get("bottleneck_group") == "comm":
                avoid.append(name)
        allocation = {n: self.registry.get(n).cores or ()
                      for n in self._tenant_mc}
        plan = tenancy.plan_rebalance(allocation, pressure, avoid)
        record = {"kind": "rebalance", "pressure": dict(pressure),
                  "makespan": max(pressure.values()), "applied": False}
        if plan is None:
            record["reason"] = "no-legal-move"
            self._tenancy_events.append(record)
            return record
        alloc = tenancy.blocks_from_counts(plan["counts"],
                                           self._tenant_pool)
        base = self.substrates["vliw-mc"]
        cand_pressure: dict[str, float] = {}
        for name, subset in alloc.items():
            t = self.registry.get(name)
            cand = base.restricted(subset, dead_links=dead,
                                   slow_links=slow, reason="co-resident")
            art = self.cache.get_or_compile(
                cand, t.prog, query=query, log_domain=True,
                batch_tile=t.batch_tile or self.batch_tile)
            cand_pressure[name] = t.qos_weight * int(art.meta["cycles"])
        record.update({"from": plan["from"], "to": plan["to"],
                       "candidate_makespan": max(cand_pressure.values())})
        if apply and record["candidate_makespan"] < record["makespan"]:
            self._apply_allocation(alloc, dead_links=dead,
                                   slow_links=slow)
            record["applied"] = True
            record["allocation"] = {n: list(c) for n, c in alloc.items()}
            metrics.counter("tenancy.rebalances").inc()
        self._tenancy_events.append(record)
        return record

    # ---------------- compilation ----------------------------------------- #
    def substrate(self, name: str) -> Substrate:
        cname = canonical(name)
        if cname not in self.substrates:
            raise ValueError(f"substrate {name!r} not enabled; have "
                             f"{tuple(self.substrates)}")
        return self.substrates[cname]

    def artifact(self, query: str = "joint",
                 substrate: str = "leveled-jax",
                 tenant: str = DEFAULT_TENANT) -> Artifact:
        """Compiled artifact for (tenant's SPN, query, substrate) —
        cached (content-addressed, so shared across tenants with
        identical programs *and* substrate fingerprints)."""
        cname = canonical(substrate)
        self.substrate(cname)       # membership check + error message
        t = self.registry.get(tenant)
        return self.cache.get_or_compile(
            self._sub_for(tenant, cname), t.prog, query=query,
            log_domain=True, batch_tile=t.batch_tile or self.batch_tile)

    def _batcher_for(self, art: Artifact, sub: Substrate,
                     base_prog, query: str) -> MicroBatcher:
        batcher = self._batchers.get(art)
        if batcher is None:
            # the closure must hold the artifact weakly, or this entry's
            # value would pin its own key and the WeakKeyDictionary could
            # never release evicted artifacts (payloads included); the
            # batcher pins it strongly only while rows are queued, and
            # the closure re-resolves through the cache as a last resort
            aref = weakref.ref(art)
            inj = self._injector

            def _execute(leaves, _s=sub, _r=aref, _inj=inj,
                         _prog=base_prog, _query=query, _tile=art.batch_tile):
                a = _r()
                if a is None:
                    # evicted while queued and the pin somehow released:
                    # recompile through the cache instead of crashing on
                    # a dangling weakref (content-addressed — identical
                    # artifact, possibly a fresh compile)
                    a = self.cache.get_or_compile(
                        _s, _prog, query=_query, log_domain=True,
                        batch_tile=_tile)
                # an execute failure is recorded as an error span (the
                # exception type lands in the span attrs) and counted —
                # never a silently dropped span (see runtime.fault)
                with trace.span(
                        "exec." + _s.name,
                        lambda: {"rows": int(leaves.shape[0]),
                                 "semiring": a.semiring}):
                    try:
                        if _inj is not None:
                            _inj.before_execute(a)
                        values = _s.execute(a, leaves)
                        if _inj is not None:
                            _inj.after_execute(a, values)
                        return values
                    except Exception:
                        metrics.counter("serve.errors").inc()
                        raise

            # split-retry only under fault injection: the classic
            # fail-the-whole-batch contract (and its errored batch.flush
            # span) is what healthy servers and their tests rely on
            batcher = MicroBatcher(
                _execute, tile=sub.pad_tile(art.batch_tile),
                max_rows=self.max_rows, split_retry=inj is not None,
                pin=art)
            self._batchers[art] = batcher
        return batcher

    # ---------------- request path ----------------------------------------- #
    def submit(self, x: np.ndarray, query: str = "joint",
               substrate: str = "leveled-jax",
               tenant: str = DEFAULT_TENANT) -> PendingResult:
        """Enqueue evidence rows ``x``; returns a :class:`PendingResult`.

        ``x``: (batch, num_vars) with ``-1`` marginalizing (or, for MPE,
        maximizing over) a variable. The result is the (batch,) root log
        value of the query's program on the chosen substrate, for the
        named tenant's model.
        """
        x = np.atleast_2d(x)
        if self._hardened:
            # admission control: a single request larger than the
            # high-water mark can never be served atomically — reject it
            # honestly; and drain queued in-flight rows before admitting
            # work that would push past the mark
            rows = int(x.shape[0])
            if rows > self.max_rows:
                metrics.counter("fault.backpressure").inc()
                raise Backpressure(
                    f"request of {rows} rows exceeds the server's "
                    f"max_rows={self.max_rows} admission limit")
            queued = sum(b._queued_rows for b in self._batchers.values())
            if queued and queued + rows > self.max_rows:
                self.flush()
        # one root span per request: a fresh trace id is minted here and
        # propagated via PendingResult into the batch-flush span, so a
        # coalesced execution is attributable to every member request
        multi = len(self.registry) > 1
        with trace.span("serve.request",
                        lambda: dict({"query": query,
                                      "substrate": substrate,
                                      "rows": int(x.shape[0])},
                                     **({"tenant": tenant} if multi
                                        else {})),
                        root=True) as sp:
            if query == "joint" and (x < 0).any():
                raise ValueError("joint queries need full evidence; "
                                 "use query='marginal' for rows "
                                 "containing -1")
            t = self.registry.get(tenant)
            art = self.artifact(query, substrate, tenant)
            with trace.span("serve.leaves"):
                leaves = art.prog.leaves_from_evidence(x)
            cname = canonical(substrate)
            batcher = self._batcher_for(
                art, self._sub_for(tenant, cname), t.prog, query)
            pending = batcher.submit(leaves)
            pending.trace_id = sp.trace_id
        metrics.counter("serve.requests").inc()
        metrics.counter("serve.rows").inc(int(x.shape[0]))
        if multi:
            metrics.counter(f"serve.requests.{tenant}").inc()
        return pending

    def flush(self) -> None:
        for batcher in list(self._batchers.values()):
            batcher.flush()

    def pump(self, now: float | None = None,
             max_age_s: float | None = None) -> int:
        """Flush every batcher whose queued work is *due* — rows at the
        high-water mark or oldest request past the age deadline.

        ``max_age_s`` defaults to the server's ``flush_max_age_s``
        (a server constructed without one treats every queued row as
        due, so a bare ``pump()`` is "drain now"). ``now`` overrides
        the clock for deterministic deadline tests. Returns the number
        of batchers flushed.
        """
        age = self.flush_max_age_s if max_age_s is None else max_age_s
        if age is None:
            age = 0.0
        flushed = 0
        for batcher in list(self._batchers.values()):
            if batcher.due(age, now):
                batcher.flush()
                flushed += 1
        if flushed:
            metrics.counter("serve.pump_flushes").inc(flushed)
        return flushed

    def start_pump(self, interval_s: float | None = None) -> None:
        """Run :meth:`pump` on a daemon thread every ``interval_s``
        (default: half the age deadline) — the continuous-batching
        pump: submitted requests resolve without any caller invoking
        ``flush()``/``result()``. Idempotent."""
        if self._pump_thread is not None:
            return
        if interval_s is None:
            interval_s = (self.flush_max_age_s / 2
                          if self.flush_max_age_s else 0.005)
        stop = threading.Event()

        def _loop():
            while not stop.wait(interval_s):
                try:
                    self.pump()
                except Exception:
                    metrics.counter("serve.pump_errors").inc()

        self._pump_stop = stop
        self._pump_thread = threading.Thread(
            target=_loop, name="server-pump", daemon=True)
        self._pump_thread.start()

    def stop_pump(self) -> None:
        """Stop the background pump thread (idempotent)."""
        if self._pump_thread is None:
            return
        assert self._pump_stop is not None
        self._pump_stop.set()
        self._pump_thread.join(timeout=2.0)
        self._pump_thread = None
        self._pump_stop = None

    def query(self, x: np.ndarray, query: str = "joint",
              substrate: str = "leveled-jax",
              tenant: str = DEFAULT_TENANT) -> np.ndarray:
        """Synchronous submit + flush: (batch,) root log values.

        The request path is *hardened*: bounded retry with exponential
        backoff on transient faults, degraded-mode recompilation on
        core/link faults (multi-tenant servers reapportion every
        tenant's cores over the surviving fabric), substrate fallback
        (vliw-mc → vliw-sim → numpy) when recompilation is infeasible,
        a circuit breaker per (substrate, semiring), and a per-request
        deadline. Non-fabric exceptions (software bugs, bad input)
        propagate unchanged — hardening never masks a real error, and
        on a healthy fabric the behaviour is identical to the classic
        path.

        End-to-end latency (admission through execute) is observed into
        the per-substrate ``serve.latency_us.<name>`` histogram — the
        p50/p95/p99 source for ``Server.stats()["metrics"]`` and
        ``BENCH_serve.json`` — plus a per-tenant
        ``serve.latency_us.<tenant>.<name>`` histogram on multi-tenant
        servers — and into the SLO tracker (``stats()["slo"]``, keyed
        both aggregate and ``<tenant>:<substrate>``): failures and
        over-target latencies burn the (substrate, query-kind) error
        budget, and a server constructed with an explicit ``slo=``
        objective sheds load (:class:`Backpressure`) once the burn rate
        crosses the objective's threshold — *before* the budget is gone.
        """
        t0 = time.perf_counter()
        name = canonical(substrate)
        semiring = SEMIRING_OF_QUERY.get(query, query)
        multi = len(self.registry) > 1
        try:
            values = self._query_resilient(x, query, name, t0, tenant)
        except (ValueError, TypeError, KeyError):
            raise               # client errors don't burn the budget
        except Backpressure:
            raise               # shed work was never admitted
        except Exception:
            lat = (time.perf_counter() - t0) * 1e6
            self.slo.record(name, semiring, lat, ok=False)
            if multi:
                self.slo.record(f"{tenant}:{name}", semiring, lat,
                                ok=False)
            raise
        latency_us = (time.perf_counter() - t0) * 1e6
        metrics.histogram("serve.latency_us." + name).observe(latency_us)
        self.slo.record(name, semiring, latency_us)
        if multi:
            metrics.histogram(
                f"serve.latency_us.{tenant}.{name}").observe(latency_us)
            self.slo.record(f"{tenant}:{name}", semiring, latency_us)
        return values

    def query_once(self, x: np.ndarray, query: str = "joint",
                   substrate: str = "leveled-jax",
                   tenant: str = DEFAULT_TENANT) -> np.ndarray:
        """One direct submit + result on exactly the named substrate —
        no retry, no fallback, no breaker. :func:`verify_parity` uses
        this so a faulty substrate can never hide behind the oracle
        fallback and compare the oracle against itself."""
        return self.submit(x, query, substrate, tenant).result()

    # ---------------- resilient dispatch ----------------------------------- #
    def _query_resilient(self, x: np.ndarray, query: str, name: str,
                         t0: float, tenant: str) -> np.ndarray:
        mgr = self.resilience
        pol = mgr.policy
        deadline = t0 + pol.timeout_s
        serving = mgr.redirects.get(name, name)
        semiring = SEMIRING_OF_QUERY.get(query, query)
        if self._slo_shedding and (
                self.slo.should_shed(name, semiring)
                or (len(self.registry) > 1 and self.slo.should_shed(
                    f"{tenant}:{name}", semiring))):
            # burn-rate admission control: shed before the breaker pays
            # a failed attempt and before the window's budget is gone
            metrics.counter("fault.slo_shed").inc()
            raise Backpressure(
                f"SLO burn rate for {name}/{semiring} exceeds the "
                "shedding threshold; retry after the window cools")
        last_exc: Exception | None = None
        attempted = False
        for target in mgr.chain(serving, self.substrates):
            breaker = mgr.breaker(target, semiring)
            if not breaker.allow():
                metrics.counter("fault.breaker_rejects").inc()
                last_exc = CircuitOpen(
                    f"circuit breaker open for {target}/{semiring}")
                continue
            backoff = pol.backoff_s
            attempt = 0
            while attempt < pol.max_attempts:
                attempt += 1
                if time.perf_counter() > deadline:
                    metrics.counter("fault.timeouts").inc()
                    raise RequestTimeout(
                        f"request exceeded its {pol.timeout_s:.3f}s "
                        "deadline") from last_exc
                try:
                    values = self.submit(x, query, target, tenant).result()
                except (CoreFault, LinkFault) as exc:
                    last_exc, attempted = exc, True
                    breaker.record_failure()
                    mgr.record("fabric_fault", substrate=target,
                               error=f"{type(exc).__name__}: {exc}")
                    if self._degrade(target, query, tenant):
                        continue        # retry on the degraded substrate
                    break               # cannot degrade → walk the chain
                except TransientFault as exc:
                    last_exc, attempted = exc, True
                    breaker.record_failure()
                    metrics.counter("fault.retries").inc()
                    if attempt < pol.max_attempts and backoff > 0:
                        mgr.sleep(backoff)
                        backoff *= pol.backoff_mult
                    continue            # one-shot: the retry heals it
                except Backpressure:
                    raise               # the caller must shed load
                except FabricError as exc:
                    last_exc, attempted = exc, True
                    breaker.record_failure()
                    break
                except (ValueError, TypeError, KeyError):
                    raise               # client error: not the fabric's
                except Exception:
                    # non-fabric: a software bug — honest propagation of
                    # the original exception, unretried and unmasked
                    breaker.record_failure()
                    raise
                breaker.record_success()
                if target != name:
                    metrics.counter("fault.fallbacks").inc()
                    if last_exc is not None:
                        if isinstance(last_exc, (CoreFault, LinkFault)):
                            # the requested backend's hardware is gone —
                            # route future requests straight here
                            mgr.redirects[name] = target
                        mgr.record("fallback", requested=name,
                                   served=target,
                                   error=(f"{type(last_exc).__name__}: "
                                          f"{last_exc}"))
                return values
        if not attempted and last_exc is not None:
            raise last_exc              # e.g. every breaker open
        raise ResilienceExhausted(
            f"substrate {name!r} ({query}) failed after retries, "
            "degradation and fallback") from last_exc

    def _degrade(self, name: str, query: str,
                 tenant: str = DEFAULT_TENANT) -> bool:
        """Recompile substrate ``name`` for the surviving fabric.

        Multi-tenant co-scheduled servers reapportion *every* tenant's
        cores over the healthy set (:meth:`_degrade_tenants`); a
        single-tenant server swaps the shared substrate in place.
        Descends on infeasibility: starts from every healthy core and
        drops the highest-numbered survivor until the comm plan routes
        around the dead links (one core has no routes, so the descent
        always terminates at a feasible compile — or the substrate
        cannot degrade at all and the caller falls down the chain).
        The degraded artifact is content-addressed like any other
        (``/alive=``, ``/dead=`` fingerprint suffixes) and annotated
        with ``meta["degraded"]``.
        """
        if name == "vliw-mc" and self._tenant_mc:
            return self._degrade_tenants(query, tenant)
        mgr = self.resilience
        sub = self.substrates.get(name)
        if sub is None:
            return False
        alive = list(mgr.state.healthy)
        while alive:
            cand = mgr.degraded_substrate(sub, alive)
            if cand is None:
                return False            # substrate cannot repartition
            try:
                with trace.span("fault.degrade",
                                lambda: {"substrate": name,
                                         "alive": list(alive)}):
                    art = self.cache.get_or_compile(
                        cand, self.prog, query=query, log_domain=True,
                        batch_tile=self.batch_tile)
            except LinkDownError:
                alive = alive[:-1]      # fewer cores ⇒ fewer routes
                continue
            except Exception:
                return False
            art.meta["degraded"] = dict(
                mgr.state.snapshot(), substrate=name,
                from_cores=self._cores, to_cores=len(alive))
            metrics.counter("fault.degraded_compiles").inc()
            self.substrates[name] = cand
            mgr.record("degrade", substrate=name, alive=list(alive),
                       fingerprint=cand.config_fingerprint())
            return True
        return False

    def _degrade_tenants(self, query: str, tenant: str) -> bool:
        """Reapportion all co-scheduled tenants over the healthy cores.

        The requesting tenant's artifact is compiled eagerly to prove
        the new plan feasible (descending past dead links like the
        single-tenant path); the other tenants recompile lazily on
        their next request through the same hardened path.
        """
        mgr = self.resilience
        dead = tuple(sorted(mgr.state.dead_links))
        slow = tuple((a, b, f)
                     for (a, b), f in sorted(mgr.state.slow_links.items()))
        alive = list(mgr.state.healthy)
        t = self.registry.get(tenant)
        base = self.substrates["vliw-mc"]
        while alive:
            # the shared base must stay the original full-machine
            # substrate across descent iterations (restricting an
            # already-restricted instance would stack link degradations)
            self.substrates["vliw-mc"] = base
            self._coschedule(alive, dead_links=dead, slow_links=slow)
            if not self._tenant_mc:
                # time-sliced fallback: everyone shares the surviving
                # machine through one degraded shared instance
                self.substrates["vliw-mc"] = base.restricted(
                    alive, dead_links=dead, slow_links=slow)
            cand = self._sub_for(tenant, "vliw-mc")
            try:
                with trace.span("fault.degrade",
                                lambda: {"substrate": "vliw-mc",
                                         "tenant": tenant,
                                         "alive": list(alive)}):
                    art = self.cache.get_or_compile(
                        cand, t.prog, query=query, log_domain=True,
                        batch_tile=t.batch_tile or self.batch_tile)
            except LinkDownError:
                alive = alive[:-1]      # fewer cores ⇒ fewer routes
                continue
            except Exception:
                return False
            art.meta["degraded"] = dict(
                mgr.state.snapshot(), substrate="vliw-mc", tenant=tenant,
                from_cores=self._cores, to_cores=len(alive))
            metrics.counter("fault.degraded_compiles").inc()
            mgr.record("degrade", substrate="vliw-mc",
                       alive=list(alive), tenant=tenant,
                       mode=self._tenancy_mode)
            return True
        return False

    # ---------------- introspection ---------------------------------------- #
    def _stats_key(self, art: Artifact, used: set[str]) -> str:
        """Unique, readable stats key for a resident artifact.

        Single-tenant servers keep the classic ``semiring/substrate``
        key; multi-tenant servers prefix the owning tenant. Residual
        collisions (same tenant, semiring and substrate — e.g. healthy
        vs degraded compiles of one program) append the program digest
        prefix and, if still colliding, an ordinal — two artifacts can
        never silently overwrite each other's stats entry.
        """
        base = f"{art.semiring}/{art.substrate}"
        if len(self.registry) > 1:
            tenant = self.registry.tenant_of_digest(art.digest)
            if tenant is not None:
                base = f"{tenant}/{base}"
        key = base
        if key in used:
            key = f"{base}@{art.digest[:8]}"
        n = 2
        while key in used:
            key = f"{base}@{art.digest[:8]}#{n}"
            n += 1
        used.add(key)
        return key

    def stats(self) -> dict:
        """Serving statistics (backward-compatible keys) + a read-only
        snapshot of the process-global metrics registry (``"metrics"``:
        request counters, per-substrate latency percentiles, batch fill,
        cache hit counters — see :mod:`repro.obs.metrics`) + the SLO
        burn-rate status (``"slo"``, see :mod:`repro.obs.slo`).

        Multi-tenant servers prefix per-artifact section keys with the
        owning tenant (``tenant/semiring/substrate``) and add a
        ``"tenancy"`` section (mode, per-tenant core allocation and QoS
        weights, co-scheduling/rebalance events).

        The returned structure is a **deep copy**: mutating it can never
        corrupt the server's live registries or resilience history.
        """
        out = {"metrics": metrics.snapshot(),
               "cache": self.cache.stats(),
               "compiles": {n: s.compile_count
                            for n, s in self.substrates.items()},
               "padded_rows": 0,
               "batchers": {},
               "multicore": {},
               "autotune": {},
               "slo": self.slo.snapshot(),
               "resilience": self.resilience.stats()}
        used_b: set[str] = set()
        for art, b in self._batchers.items():
            out["batchers"][self._stats_key(art, used_b)] = dict(
                b.stats, pad_waste=round(b.pad_waste, 4))
            out["padded_rows"] += b.stats["padded_rows"]
        # ONE materialized pass over the resident artifacts (safe
        # against concurrent eviction — see ArtifactCache.artifacts)
        # feeds the multicore, autotune and degraded-artifact sections
        degraded: dict = {}
        used_a: set[str] = set()
        for art in self.cache.artifacts():
            key = self._stats_key(art, used_a)
            # per-core utilization / communication / barrier accounting
            # of multi-core artifacts (calibrated at compile time)
            mc = art.meta.get("multicore")
            if mc:
                cycles = max(int(mc["cycles"]), 1)
                ops = mc["core_ops"]
                peak = self._processor.num_pes
                out["multicore"][key] = {
                    "cores": mc["effective_cores"],
                    "cycles": mc["cycles"],
                    "core_utilization": [round(o / cycles / peak, 4)
                                         for o in ops],
                    "comm_values_per_batch": mc["comm"]["values"],
                    "comm_rows": mc["comm"]["rows"],
                    "stall_cycles": mc["stall_cycles"],
                    "barrier_idle_cycles": mc["barrier_idle"],
                    "cut_values": mc["cut_values"],
                    # NoC accounting (all zeros under the ideal crossbar)
                    "topology": mc.get("topology", "xbar"),
                    "hop_cut": mc.get("hop_cut", mc["cut_values"]),
                    "busiest_link_occupancy":
                        mc["comm"].get("busiest_link_occupancy", 0.0),
                    "link_stall_cycles":
                        mc["comm"].get("link_stall_cycles", 0),
                    "inject_stall_cycles":
                        mc["comm"].get("inject_stall_cycles", 0),
                    # cycle-attribution verdict (see repro.obs.attr)
                    "bottleneck": art.meta.get("bottleneck"),
                }
                labels = mc.get("core_labels")
                if labels is not None:
                    out["multicore"][key]["core_labels"] = list(labels)
            # autotune outcomes: winning config, tuned vs default
            # cycles/eval, and the core-count fallback decisions
            tune = art.meta.get("autotune")
            decision = art.meta.get("core_decision")
            if tune is not None or decision is not None:
                entry: dict = {}
                if tune is not None:
                    entry.update(tune)
                    entry["interleave"] = art.meta.get("interleave", 1)
                if decision is not None:
                    entry["core_decision"] = decision
                out["autotune"][key] = entry
            if art.meta.get("degraded") is not None:
                degraded[key] = art.meta["degraded"]
        if degraded:
            out["resilience"]["degraded_artifacts"] = degraded
        if len(self.registry) > 1:
            out["tenancy"] = {
                "mode": self._tenancy_mode,
                "pool": list(self._tenant_pool),
                "tenants": {
                    t.name: {"qos_weight": t.qos_weight,
                             "cores": (list(t.cores)
                                       if t.cores is not None else None),
                             "digest": t.prog.digest()[:12]}
                    for t in self.registry},
                "events": list(self._tenancy_events)}
        return copy.deepcopy(out)


def verify_parity(server: Server, x: np.ndarray, *, query: str = "marginal",
                  substrates: tuple[str, ...] | None = None,
                  atol: float = 1e-4,
                  tenant: str = DEFAULT_TENANT) -> dict[str, float]:
    """Cross-substrate agreement on ``x`` against the numpy oracle.

    Returns ``{substrate: max_abs_deviation}`` (fast-vs-checked VLIW
    conformance reported as ``vliw-sim/checked``, compared bit-exactly).
    Raises :class:`ParityError` on any disagreement — and also when a
    substrate's execute *throws*: a failing backend is a parity failure,
    reported as a typed error (chaining the real cause) instead of a
    hang or a bare crash. Queries go through :meth:`Server.query_once`,
    the direct non-resilient path, so a faulty substrate can never hide
    behind the fallback chain and compare the oracle against itself.
    ``tenant`` checks one resident model of a multi-tenant server.
    """
    if query not in QUERIES:
        raise ValueError(f"unknown query {query!r}")
    names = tuple(canonical(n) for n in (substrates or server.substrates))
    x = np.atleast_2d(x)
    prog = server.registry.get(tenant).prog

    def run(name: str, fn, what: str):
        try:
            return fn()
        except ParityError:
            raise
        except Exception as exc:
            raise ParityError(
                f"substrate {name!r} failed to {what}: "
                f"{type(exc).__name__}: {exc}") from exc

    if "numpy" in server.substrates:
        ref = run("numpy",
                  lambda: server.query_once(x, query, "numpy", tenant),
                  "execute")
    else:   # the oracle is the point of the check — build one on demand
        oracle = make_substrate("numpy")
        art = server.cache.get_or_compile(
            oracle, prog, query=query, log_domain=True,
            batch_tile=server.batch_tile)
        ref = run("numpy", lambda: oracle.execute(
            art, art.prog.leaves_from_evidence(x)), "execute")
    devs: dict[str, float] = {}

    def against_ref(name: str, vals: np.ndarray) -> None:
        both_inf = np.isneginf(vals) & np.isneginf(ref)
        dev = float(np.abs(np.where(both_inf, 0.0, vals - ref)).max())
        devs[name] = dev
        if not np.isfinite(dev) or dev > atol:
            raise ParityError(f"substrate {name!r} deviates from the "
                              f"numpy oracle by {dev:.3e} (atol={atol})")

    for name in names:
        if name == "numpy":
            devs[name] = 0.0
            continue
        vals = run(name,
                   lambda: server.query_once(x, query, name, tenant),
                   "execute")
        against_ref(name, vals)
        sub = server._sub_for(tenant, name)
        if hasattr(sub, "execute_checked"):
            # vliw-sim / vliw-mc: the vectorized fast-sim must be
            # bit-identical to the cycle-accurate checked simulator
            art = server.artifact(query, name, tenant)
            leaves = art.prog.leaves_from_evidence(np.atleast_2d(x))
            checked = run(name, lambda: sub.execute_checked(art, leaves),
                          "execute (checked sim)")
            fast = run(name, lambda: sub.execute(art, leaves), "execute")
            if not np.array_equal(checked, fast):
                raise ParityError(
                    f"{name} fast-sim root values are not bit-identical "
                    "to the checked cycle-accurate simulator")
            devs[f"{name}/checked"] = 0.0
    return devs
