"""The unified substrate runtime server.

``Server`` owns everything between a raw SPN and a stream of answered
queries: the lowered :class:`TensorProgram`, one instance of every
requested substrate, the content-addressed :class:`ArtifactCache`, and a
dynamic :class:`MicroBatcher` per live artifact. The serving driver
(``repro.launch.serve``) is a thin CLI over this class, and later
scaling layers (sharding, async dispatch, multi-model) stack on the same
interface.

Request path::

    submit(x, query, substrate)          # evidence -> leaves -> enqueue
      -> flush() / result()              # coalesce, pad to tile, execute
    query(x, query, substrate)           # synchronous convenience

:func:`verify_parity` is the reusable cross-substrate agreement check —
every substrate's root values against the float64 numpy oracle, plus the
vliw fast-sim against the cycle-accurate checked simulator (bit-exact).
It replaces the asserts previously inlined in ``serve_spn()`` and is
shared by serve and the tests.
"""
from __future__ import annotations

import time
import weakref

import numpy as np

from ..core import program as program_mod
from ..core.processor.config import PTREE, ProcessorConfig
from ..core.spn import SPN
from ..obs import metrics, trace
from .batcher import MicroBatcher, PendingResult
from .cache import ArtifactCache
from .substrates import (LANE, QUERIES, Artifact, Substrate, canonical,
                         make_substrate)

DEFAULT_SUBSTRATES = ("numpy", "leveled-jax", "pallas", "vliw-sim",
                      "vliw-mc")


class ParityError(AssertionError):
    pass


class Server:
    """Multi-substrate, multi-query SPN inference server."""

    def __init__(self, spn: SPN | None = None, *,
                 prog: program_mod.TensorProgram | None = None,
                 substrates: tuple[str, ...] | None = None,
                 processor: ProcessorConfig = PTREE,
                 interpret: bool | None = None,
                 cores: int = 2,
                 topology: str | None = None,
                 interconnect=None,
                 autotune: str | None = None,
                 autotune_seed: int = 0,
                 cache_capacity: int = 32,
                 batch_tile: int = LANE,
                 max_rows: int = 4096):
        if prog is None:
            if spn is None:
                raise ValueError("need an SPN or a lowered TensorProgram")
            prog = program_mod.lower(spn)
        self.spn = spn
        self.prog = prog
        self.batch_tile = batch_tile
        self.max_rows = max_rows
        self.cache = ArtifactCache(cache_capacity)
        self._processor = processor
        self._interpret = interpret
        self._cores = cores
        if interconnect is None and topology is not None:
            from ..core.multicore import named_interconnect
            interconnect = named_interconnect(topology)
        self._interconnect = interconnect
        names = tuple(canonical(n)
                      for n in (substrates or DEFAULT_SUBSTRATES))
        self.substrates: dict[str, Substrate] = {
            n: make_substrate(n, processor=processor, interpret=interpret,
                              cores=cores, interconnect=interconnect,
                              autotune=autotune, autotune_seed=autotune_seed)
            for n in names}
        self._batchers: weakref.WeakKeyDictionary[Artifact, MicroBatcher] = \
            weakref.WeakKeyDictionary()

    # ---------------- compilation ----------------------------------------- #
    def substrate(self, name: str) -> Substrate:
        cname = canonical(name)
        if cname not in self.substrates:
            raise ValueError(f"substrate {name!r} not enabled; have "
                             f"{tuple(self.substrates)}")
        return self.substrates[cname]

    def artifact(self, query: str = "joint",
                 substrate: str = "leveled-jax") -> Artifact:
        """Compiled artifact for (this SPN, query, substrate) — cached."""
        return self.cache.get_or_compile(
            self.substrate(substrate), self.prog, query=query,
            log_domain=True, batch_tile=self.batch_tile)

    def _batcher_for(self, art: Artifact) -> MicroBatcher:
        batcher = self._batchers.get(art)
        if batcher is None:
            sub = self.substrate(art.substrate)
            # the closure must hold the artifact weakly, or this entry's
            # value would pin its own key and the WeakKeyDictionary could
            # never release evicted artifacts (payloads included)
            aref = weakref.ref(art)

            def _execute(leaves, _s=sub, _r=aref):
                a = _r()
                # an execute failure is recorded as an error span (the
                # exception type lands in the span attrs) and counted —
                # never a silently dropped span (see runtime.fault)
                with trace.span(
                        "exec." + _s.name,
                        lambda: {"rows": int(leaves.shape[0]),
                                 "semiring": a.semiring}):
                    try:
                        return _s.execute(a, leaves)
                    except Exception:
                        metrics.counter("serve.errors").inc()
                        raise

            batcher = MicroBatcher(
                _execute, tile=sub.pad_tile(art.batch_tile),
                max_rows=self.max_rows)
            self._batchers[art] = batcher
        return batcher

    # ---------------- request path ----------------------------------------- #
    def submit(self, x: np.ndarray, query: str = "joint",
               substrate: str = "leveled-jax") -> PendingResult:
        """Enqueue evidence rows ``x``; returns a :class:`PendingResult`.

        ``x``: (batch, num_vars) with ``-1`` marginalizing (or, for MPE,
        maximizing over) a variable. The result is the (batch,) root log
        value of the query's program on the chosen substrate.
        """
        x = np.atleast_2d(x)
        # one root span per request: a fresh trace id is minted here and
        # propagated via PendingResult into the batch-flush span, so a
        # coalesced execution is attributable to every member request
        with trace.span("serve.request",
                        lambda: {"query": query, "substrate": substrate,
                                 "rows": int(x.shape[0])},
                        root=True) as sp:
            if query == "joint" and (x < 0).any():
                raise ValueError("joint queries need full evidence; "
                                 "use query='marginal' for rows "
                                 "containing -1")
            art = self.artifact(query, substrate)
            with trace.span("serve.leaves"):
                leaves = art.prog.leaves_from_evidence(x)
            pending = self._batcher_for(art).submit(leaves)
            pending.trace_id = sp.trace_id
        metrics.counter("serve.requests").inc()
        metrics.counter("serve.rows").inc(int(x.shape[0]))
        return pending

    def flush(self) -> None:
        for batcher in list(self._batchers.values()):
            batcher.flush()

    def query(self, x: np.ndarray, query: str = "joint",
              substrate: str = "leveled-jax") -> np.ndarray:
        """Synchronous submit + flush: (batch,) root log values.

        End-to-end latency (admission through execute) is observed into
        the per-substrate ``serve.latency_us.<name>`` histogram — the
        p50/p95/p99 source for ``Server.stats()["metrics"]`` and
        ``BENCH_serve.json``.
        """
        t0 = time.perf_counter()
        pending = self.submit(x, query, substrate)
        values = pending.result()
        metrics.histogram(
            "serve.latency_us." + canonical(substrate)).observe(
            (time.perf_counter() - t0) * 1e6)
        return values

    # ---------------- introspection ---------------------------------------- #
    def stats(self) -> dict:
        """Serving statistics (backward-compatible keys) + a read-only
        snapshot of the process-global metrics registry (``"metrics"``:
        request counters, per-substrate latency percentiles, batch fill,
        cache hit counters — see :mod:`repro.obs.metrics`)."""
        out = {"metrics": metrics.snapshot(),
               "cache": self.cache.stats(),
               "compiles": {n: s.compile_count
                            for n, s in self.substrates.items()},
               "padded_rows": 0,
               "batchers": {},
               "multicore": {},
               "autotune": {}}
        for art, b in self._batchers.items():
            out["batchers"][f"{art.semiring}/{art.substrate}"] = dict(
                b.stats, pad_waste=round(b.pad_waste, 4))
            out["padded_rows"] += b.stats["padded_rows"]
        # per-core utilization / communication / barrier accounting of
        # every resident multi-core artifact (calibrated at compile time)
        for art in self.cache.artifacts():
            mc = art.meta.get("multicore")
            if not mc:
                continue
            cycles = max(int(mc["cycles"]), 1)
            ops = mc["core_ops"]
            peak = self._processor.num_pes
            out["multicore"][f"{art.semiring}/{art.substrate}"] = {
                "cores": mc["effective_cores"],
                "cycles": mc["cycles"],
                "core_utilization": [round(o / cycles / peak, 4)
                                     for o in ops],
                "comm_values_per_batch": mc["comm"]["values"],
                "comm_rows": mc["comm"]["rows"],
                "stall_cycles": mc["stall_cycles"],
                "barrier_idle_cycles": mc["barrier_idle"],
                "cut_values": mc["cut_values"],
                # NoC accounting (all zeros under the ideal crossbar)
                "topology": mc.get("topology", "xbar"),
                "hop_cut": mc.get("hop_cut", mc["cut_values"]),
                "busiest_link_occupancy":
                    mc["comm"].get("busiest_link_occupancy", 0.0),
                "link_stall_cycles":
                    mc["comm"].get("link_stall_cycles", 0),
                "inject_stall_cycles":
                    mc["comm"].get("inject_stall_cycles", 0),
            }
        # per-artifact autotune outcomes: winning config, tuned vs
        # default cycles/eval, and the core-count fallback decisions
        for art in self.cache.artifacts():
            tune = art.meta.get("autotune")
            decision = art.meta.get("core_decision")
            if tune is None and decision is None:
                continue
            entry: dict = {}
            if tune is not None:
                entry.update(tune)
                entry["interleave"] = art.meta.get("interleave", 1)
            if decision is not None:
                entry["core_decision"] = decision
            out["autotune"][f"{art.semiring}/{art.substrate}"] = entry
        return out


def verify_parity(server: Server, x: np.ndarray, *, query: str = "marginal",
                  substrates: tuple[str, ...] | None = None,
                  atol: float = 1e-4) -> dict[str, float]:
    """Cross-substrate agreement on ``x`` against the numpy oracle.

    Returns ``{substrate: max_abs_deviation}`` (fast-vs-checked VLIW
    conformance reported as ``vliw-sim/checked``, compared bit-exactly).
    Raises :class:`ParityError` on any disagreement.
    """
    if query not in QUERIES:
        raise ValueError(f"unknown query {query!r}")
    names = tuple(canonical(n) for n in (substrates or server.substrates))
    x = np.atleast_2d(x)
    if "numpy" in server.substrates:
        ref = server.query(x, query, "numpy")
    else:   # the oracle is the point of the check — build one on demand
        oracle = make_substrate("numpy")
        art = server.cache.get_or_compile(
            oracle, server.prog, query=query, log_domain=True,
            batch_tile=server.batch_tile)
        ref = oracle.execute(art, art.prog.leaves_from_evidence(x))
    devs: dict[str, float] = {}

    def against_ref(name: str, vals: np.ndarray) -> None:
        both_inf = np.isneginf(vals) & np.isneginf(ref)
        dev = float(np.abs(np.where(both_inf, 0.0, vals - ref)).max())
        devs[name] = dev
        if not np.isfinite(dev) or dev > atol:
            raise ParityError(f"substrate {name!r} deviates from the "
                              f"numpy oracle by {dev:.3e} (atol={atol})")

    for name in names:
        if name == "numpy":
            devs[name] = 0.0
            continue
        vals = server.query(x, query, name)
        against_ref(name, vals)
        sub = server.substrate(name)
        if hasattr(sub, "execute_checked"):
            # vliw-sim / vliw-mc: the vectorized fast-sim must be
            # bit-identical to the cycle-accurate checked simulator
            art = server.artifact(query, name)
            leaves = art.prog.leaves_from_evidence(np.atleast_2d(x))
            checked = sub.execute_checked(art, leaves)
            fast = sub.execute(art, leaves)
            if not np.array_equal(checked, fast):
                raise ParityError(
                    f"{name} fast-sim root values are not bit-identical "
                    "to the checked cycle-accurate simulator")
            devs[f"{name}/checked"] = 0.0
    return devs
