"""Fault tolerance: watchdog, straggler detection, restart-from-checkpoint.

At 1000+ nodes the failure model is: (a) a worker dies (process exit /
network partition) — detected by missed heartbeats; (b) a worker limps
(thermal throttle, flaky HBM, slow NIC) — detected as a step-time outlier
vs. the fleet median; (c) the job process itself crashes — handled by the
restart harness re-entering from the last committed checkpoint.

Single-host notes: heartbeats are files (one per simulated worker) so the
mechanism is testable here; on a real cluster the same Watchdog consumes
per-host heartbeat RPCs. The restart harness is topology-agnostic.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from collections import deque
from typing import Any, Callable

from ..obs import metrics, trace


# ---------------------------------------------------------------------------
# heartbeats
# ---------------------------------------------------------------------------
class Heartbeat:
    """Worker side: touch a heartbeat file with step/timestamp."""

    def __init__(self, dirpath: str, worker: int):
        self.path = os.path.join(dirpath, f"worker_{worker:05d}.hb")
        os.makedirs(dirpath, exist_ok=True)

    def beat(self, step: int) -> None:
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"step": step, "t": time.time()}, f)
        os.rename(tmp, self.path)


class Watchdog:
    """Coordinator side: flag dead (stale heartbeat) and straggler workers."""

    def __init__(self, dirpath: str, *, timeout_s: float = 60.0,
                 straggler_factor: float = 2.0, window: int = 32):
        self.dir = dirpath
        self.timeout_s = timeout_s
        self.straggler_factor = straggler_factor
        self.step_times: dict[int, deque] = {}
        self.window = window

    def _workers(self) -> list[tuple[int, dict]]:
        out = []
        if not os.path.isdir(self.dir):
            return out
        for f in os.listdir(self.dir):
            if not f.endswith(".hb"):
                continue
            # filenames come from a directory shared with the workers —
            # a malformed name (crash mid-rename, stray file) must be
            # skipped and counted, never crash the coordinator
            try:
                wid = int(f.split("_")[1].split(".")[0])
            except (IndexError, ValueError):
                metrics.counter("fault.heartbeat_corrupt").inc()
                continue
            try:
                with open(os.path.join(self.dir, f)) as fh:
                    hb = json.load(fh)
            except (json.JSONDecodeError, OSError):
                metrics.counter("fault.heartbeat_corrupt").inc()
                continue
            if not isinstance(hb, dict) or "t" not in hb:
                metrics.counter("fault.heartbeat_corrupt").inc()
                continue
            out.append((wid, hb))
        return out

    def dead_workers(self, now: float | None = None) -> list[int]:
        now = time.time() if now is None else now
        return [wid for wid, hb in self._workers()
                if now - hb["t"] > self.timeout_s]

    def record_step_time(self, worker: int, seconds: float) -> None:
        self.step_times.setdefault(worker, deque(maxlen=self.window)).append(
            seconds)

    def stragglers(self) -> list[int]:
        """Workers whose median step time exceeds fleet median × factor."""
        med = {w: sorted(t)[len(t) // 2] for w, t in self.step_times.items()
               if len(t) >= 4}
        if len(med) < 2:
            return []
        fleet = sorted(med.values())[len(med) // 2]
        return [w for w, m in med.items() if m > fleet * self.straggler_factor]


# ---------------------------------------------------------------------------
# restart harness
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class RestartPolicy:
    max_failures: int = 3
    backoff_s: float = 0.0          # 0 for tests; seconds on real clusters


class TrainingAborted(RuntimeError):
    pass


def run_with_restarts(make_state: Callable[[], Any],
                      resume_state: Callable[[], Any | None],
                      run: Callable[[Any], Any],
                      policy: RestartPolicy = RestartPolicy()) -> Any:
    """Drive ``run(state)`` to completion with restart-on-failure.

    - ``resume_state()`` returns state restored from the last committed
      checkpoint, or None on a cold start (then ``make_state()`` is used);
    - ``run`` either returns the finished result or raises. On raise, we
      restore and retry (the raised step's work is lost back to the last
      checkpoint — exactly the paper-scale deployment contract).

    Every attempt runs inside a ``fault.attempt`` span, so a failure is
    recorded as an *error span* carrying the exception type — never a
    silently dropped span — and counted in the ``fault.restarts``
    metric. When the restart budget is exhausted the final
    :class:`TrainingAborted` chains the last real exception (``from
    exc``) instead of discarding it: the root cause stays in the
    traceback.
    """
    failures = 0
    while True:
        state = resume_state()
        if state is None:
            state = make_state()
        try:
            with trace.span("fault.attempt", {"attempt": failures}):
                return run(state)
        except TrainingAborted:
            raise
        except Exception as exc:
            failures += 1
            metrics.counter("fault.restarts").inc()
            trace.instant("fault.failure",
                          {"attempt": failures,
                           "error": type(exc).__name__,
                           "message": str(exc)[:200]})
            if failures > policy.max_failures:
                raise TrainingAborted(
                    f"exceeded {policy.max_failures} restarts "
                    f"(last: {type(exc).__name__}: {exc})") from exc
            if policy.backoff_s:
                time.sleep(policy.backoff_s)


# ---------------------------------------------------------------------------
# failure injection (tests / chaos drills)
# ---------------------------------------------------------------------------
class FailureInjector:
    """Deterministically raise at given steps — chaos-test the harness."""

    def __init__(self, fail_at_steps: set[int]):
        self.fail_at = set(fail_at_steps)
        self.tripped: set[int] = set()

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at and step not in self.tripped:
            self.tripped.add(step)
            raise RuntimeError(f"injected failure at step {step}")
