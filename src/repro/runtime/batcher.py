"""Dynamic micro-batching of heterogeneous inference requests.

The kernel substrate pads every call to the 128-lane tile, and the
leveled/VLIW paths amortize fixed per-call cost over the batch — so
serving many small requests one by one wastes most of the machine. The
:class:`MicroBatcher` coalesces submitted requests (any mix of row
counts) into one leaf matrix, pads the row count up to the executor's
tile with neutral all-marginalized rows (indicator 1.0 — finite in both
domains), executes once, and scatters result slices back to each
caller's :class:`PendingResult`.

Flushes happen when the accumulated rows reach ``max_rows`` (the
high-water mark), when the oldest queued request exceeds an age
deadline (``Server.pump`` polls :meth:`due`), or explicitly
(``flush()`` / first ``result()`` call). The batcher is safe to flush
from a pump thread concurrently with submitting threads: the queue
swap is lock-guarded, and a :class:`PendingResult` whose rows are
in-flight on another thread waits on its completion event instead of
racing the flush.
"""
from __future__ import annotations

import threading
import time
import weakref

import numpy as np

from ..obs import metrics, trace


class PendingResult:
    """Handle for a submitted request; materializes on first access.

    ``trace_id`` is the request's trace id (0 when tracing is off) —
    assigned at submit time and carried into the batch-flush span so a
    coalesced execution is attributable back to every request in it.

    A failed coalesced execute *rejects* the handle: the original
    exception is stored on every member and re-raised from
    :meth:`result` — a request can never hang unresolved behind a
    failed flush. :meth:`exception` peeks at the failure without
    raising.
    """

    def __init__(self, batcher: "MicroBatcher"):
        self._batcher = batcher
        self._value: np.ndarray | None = None
        self._exc: BaseException | None = None
        self._done = threading.Event()
        self.trace_id = 0

    def _resolve(self, value: np.ndarray | None = None,
                 exc: BaseException | None = None) -> None:
        if value is not None:
            self._value = value
        if exc is not None:
            self._exc = exc
        self._done.set()

    def ready(self) -> bool:
        """Resolved — either with a value or with a failure."""
        return self._done.is_set()

    def wait(self, timeout: float | None = None) -> bool:
        """Block until resolved (a pump thread may be executing the
        batch); True iff resolved within ``timeout``."""
        return self._done.wait(timeout)

    def result(self, timeout: float | None = None) -> np.ndarray:
        if not self.ready():
            # Synchronous path: drain the queue ourselves. If another
            # thread already swapped the queue and is mid-execute, this
            # is a no-op and we wait on the completion event instead.
            self._batcher.flush()
        if not self._done.wait(timeout):
            raise TimeoutError("request still in flight after "
                               f"{timeout}s")
        if self._exc is not None:
            raise self._exc
        assert self._value is not None
        return self._value

    def exception(self,
                  timeout: float | None = None) -> BaseException | None:
        """The failure that rejected this request (flushing first if
        still queued), or ``None`` if it succeeded / is healthy."""
        if not self.ready():
            try:
                self._batcher.flush()
            except Exception:
                pass    # the flush stored itself on every member
            self._done.wait(timeout)
        return self._exc


class MicroBatcher:
    def __init__(self, execute, *, tile: int = 1, max_rows: int = 4096,
                 split_retry: bool = False, pin=None,
                 clock=time.monotonic):
        """``execute``: (rows, m_ind) linear leaves -> (rows,) values.

        ``tile`` is the executor's declared row multiple — the substrate's
        ``pad_tile(artifact.batch_tile)``, NOT a hardwired 128: substrates
        that take any batch (numpy, leveled-jax, vliw-sim) declare 1 and
        are never padded. ``stats['padded_rows']`` counts the rows of
        padding waste, reported by :meth:`Server.stats` next to the
        artifact-cache hit/miss counters.

        ``split_retry`` changes what a failed *multi-member* coalesced
        execute does: instead of rejecting every member with the batch
        exception, each member is re-executed individually so non-faulty
        rows still get correct results and only the actually-failing
        members carry an exception (the resilient server turns this on
        when fault injection is live; default off keeps the classic
        fail-the-batch contract).

        ``pin`` names an object (the compiled artifact) that must stay
        alive while rows are queued. The batcher holds it weakly when
        idle — so the server's artifact-keyed WeakKeyDictionary can
        still collect evicted artifacts — but takes a strong reference
        from submit until the flush that drains those rows completes.
        Without the pin, a cache eviction between submit and flush
        leaves the execute closure's weakref dangling and the flush
        crashes instead of serving queued work.

        ``clock`` is injectable for deterministic age-deadline tests.
        """
        if tile < 1:
            raise ValueError(f"tile must be >= 1, got {tile}")
        if max_rows % tile:
            max_rows = (max_rows // tile + 1) * tile
        self.execute = execute
        self.tile = tile
        self.max_rows = max_rows
        self.split_retry = split_retry
        self.clock = clock
        self._pin_ref = weakref.ref(pin) if pin is not None else None
        self._pin = None            # strong ref while rows are queued
        self._lock = threading.Lock()
        self._oldest_t: float | None = None
        self._queue: list[tuple[np.ndarray, PendingResult]] = []
        self._queued_rows = 0
        self.stats = {"requests": 0, "rows": 0, "batches": 0,
                      "padded_rows": 0}

    @property
    def pad_waste(self) -> float:
        """Fraction of executed rows that were padding."""
        total = self.stats["rows"] + self.stats["padded_rows"]
        return self.stats["padded_rows"] / total if total else 0.0

    def age(self, now: float | None = None) -> float:
        """Seconds the oldest queued request has been waiting (0 when
        the queue is empty). ``now`` overrides the clock so callers can
        probe hypothetical deadlines deterministically."""
        oldest = self._oldest_t
        if oldest is None:
            return 0.0
        return max(0.0, (self.clock() if now is None else now) - oldest)

    def due(self, max_age_s: float, now: float | None = None) -> bool:
        """True when queued work should be flushed by the pump: the
        rows high-water is reached or the oldest request aged out."""
        if not self._queued_rows:
            return False
        return (self._queued_rows >= self.max_rows
                or self.age(now) >= max_age_s)

    def submit(self, leaves: np.ndarray) -> PendingResult:
        leaves = np.atleast_2d(np.asarray(leaves))
        pending = PendingResult(self)
        with self._lock:
            if not self._queue:
                self._oldest_t = self.clock()
            if self._pin_ref is not None:
                # the caller holds the artifact right now, so the deref
                # cannot fail; the strong ref lives until the flush that
                # drains this row completes
                self._pin = self._pin_ref()
            self._queue.append((leaves, pending))
            self._queued_rows += leaves.shape[0]
        self.stats["requests"] += 1
        self.stats["rows"] += leaves.shape[0]
        if self._queued_rows >= self.max_rows:
            self.flush()
        return pending

    def flush(self) -> None:
        with self._lock:
            if not self._queue:
                return
            queue, self._queue, self._queued_rows = self._queue, [], 0
            self._oldest_t = None
            # keep the artifact alive for the duration of this execute
            # (local ref), but release the batcher-held pin so an
            # evicted artifact can be collected once we return
            pin, self._pin = self._pin, None
        rows = np.concatenate([leaves for leaves, _ in queue], axis=0)
        n = rows.shape[0]
        n_pad = (n + self.tile - 1) // self.tile * self.tile
        if n_pad > n:   # neutral rows: every indicator 1 (marginalize-all)
            pad = np.ones((n_pad - n, rows.shape[1]), rows.dtype)
            rows = np.concatenate([rows, pad], axis=0)
        # the coalesce span links every member request by trace id, so a
        # batched execution is attributable request-by-request in the
        # trace view (attrs stay lazy: nothing built when tracing is off)
        with trace.span("batch.flush",
                        lambda: {"requests": len(queue), "rows": n,
                                 "padded_rows": n_pad - n,
                                 "trace_ids": [p.trace_id
                                               for _, p in queue]}):
            try:
                values = np.asarray(self.execute(rows))[:n]
            except Exception as exc:
                self.stats["batches"] += 1
                metrics.counter("batch.flush_errors").inc()
                if self.split_retry and len(queue) > 1:
                    # the coalesced attempt still padded and executed
                    # n_pad - n waste rows; account for them before the
                    # per-member retries add their own padding
                    self.stats["padded_rows"] += n_pad - n
                    metrics.counter("batch.padded_rows").inc(n_pad - n)
                    self._flush_split(queue)
                    del pin
                    return
                # reject every member with the ORIGINAL exception — a
                # failed flush must never leave a pending unresolved
                for _, pending in queue:
                    pending._resolve(exc=exc)
                raise
        self.stats["batches"] += 1
        self.stats["padded_rows"] += n_pad - n
        metrics.counter("batch.flushes").inc()
        metrics.counter("batch.padded_rows").inc(n_pad - n)
        metrics.histogram("batch.fill").observe(n / n_pad if n_pad else 1.0)
        off = 0
        for leaves, pending in queue:
            k = leaves.shape[0]
            pending._resolve(value=values[off: off + k])
            off += k
        del pin

    def _flush_split(self, queue) -> None:
        """Per-member retry after a failed coalesced execute: rows from
        non-faulty requests still get correct results; only the members
        that fail on their own carry an exception.

        Every retried member gets its own ``batch.flush`` span carrying
        the member's ORIGINAL ``trace_id`` (and a ``split_retry`` mark),
        so in the trace view the re-execution still links back to the
        request that submitted the rows — the coalesced flush's error
        span alone would orphan them. Each successful retry is a real
        flush: it counts in ``batch.flushes`` and observes its fill, so
        the telemetry doesn't undercount exactly when faults are live
        (``stats['batches']`` still counts the coalesced attempt once)."""
        metrics.counter("batch.split_retries").inc()
        trace.instant("batch.split_retry", {"requests": len(queue)})
        for leaves, pending in queue:
            k = leaves.shape[0]
            k_pad = (k + self.tile - 1) // self.tile * self.tile
            rows = leaves
            if k_pad > k:
                pad = np.ones((k_pad - k, leaves.shape[1]), leaves.dtype)
                rows = np.concatenate([leaves, pad], axis=0)
            try:
                # the span wraps only the execute: a failing member's
                # error span is recorded first, then the exception is
                # stored on the pending (not propagated)
                with trace.span("batch.flush",
                                lambda: {"requests": 1, "rows": k,
                                         "padded_rows": k_pad - k,
                                         "trace_ids": [pending.trace_id],
                                         "split_retry": True}):
                    vals = np.asarray(self.execute(rows))[:k]
            except Exception as exc:
                pending._resolve(exc=exc)
            else:
                pending._resolve(value=vals)
                self.stats["padded_rows"] += k_pad - k
                metrics.counter("batch.flushes").inc()
                metrics.counter("batch.padded_rows").inc(k_pad - k)
                metrics.histogram("batch.fill").observe(
                    k / k_pad if k_pad else 1.0)
