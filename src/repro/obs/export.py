"""Telemetry export: OpenMetrics text, JSONL event streams, reports.

Three ways out of the process for the observability state PR 6-9 built
up in-memory:

- :func:`render_openmetrics` — the metrics registry as OpenMetrics
  text exposition (counters as ``_total``, gauges, histograms as
  summaries with ``quantile`` labels, terminated by ``# EOF``), plus
  :func:`parse_openmetrics`, a minimal line parser used by the
  round-trip test to prove the rendering is well-formed.
- :class:`JsonlExporter` — append-only JSONL event stream: each
  :meth:`~JsonlExporter.tick` writes one self-describing line
  ``{"seq", "ts", "metrics": snapshot}``; ``maybe_tick`` rate-limits
  to a configured interval for use inside serving loops. The clock is
  injectable for deterministic tests.
- :func:`observatory_report` / :func:`write_observatory_report` — one
  self-contained JSON observatory report for a live
  :class:`~repro.runtime.server.Server`: cycle-attribution tables and
  roofline points for every VLIW artifact (:mod:`repro.obs.attr`),
  SLO/burn-rate status (:mod:`repro.obs.slo`), the resilience
  snapshot, autotune decisions, the full metrics snapshot, and the
  OpenMetrics text — what ``serve --observe report.json`` emits.
"""
from __future__ import annotations

import json
import time

from . import metrics as _metrics
from .attr import CLASSES

__all__ = ["render_openmetrics", "parse_openmetrics", "JsonlExporter",
           "observatory_report", "write_observatory_report",
           "attribution_table"]

_QUANTILES = (("0.5", 50), ("0.95", 95), ("0.99", 99))


def _om_name(name: str) -> str:
    """OpenMetrics metric name: dots and dashes become underscores."""
    out = []
    for ch in name:
        out.append(ch if ch.isalnum() or ch == "_" else "_")
    s = "".join(out)
    return s if not s[:1].isdigit() else "_" + s


# --------------------------------------------------------------------- #
# OpenMetrics text exposition
# --------------------------------------------------------------------- #
def render_openmetrics(registry: _metrics.Registry | None = None) -> str:
    """The registry as OpenMetrics text exposition format.

    Counters render as ``<name>_total``, gauges as plain samples, and
    histograms as OpenMetrics summaries (``quantile`` labels plus
    ``_sum``/``_count``). The output always ends with the mandatory
    ``# EOF`` terminator.
    """
    reg = registry if registry is not None else _metrics.REGISTRY
    lines: list[str] = []
    for name in reg.names():
        m = reg._metrics[name]
        om = _om_name(name)
        if isinstance(m, _metrics.Counter):
            lines.append(f"# TYPE {om} counter")
            lines.append(f"{om}_total {m.value}")
        elif isinstance(m, _metrics.Gauge):
            lines.append(f"# TYPE {om} gauge")
            lines.append(f"{om} {m.value}")
        elif isinstance(m, _metrics.Histogram):
            lines.append(f"# TYPE {om} summary")
            if m.count:
                for label, p in _QUANTILES:
                    lines.append(f'{om}{{quantile="{label}"}} '
                                 f"{m.percentile(p)}")
            lines.append(f"{om}_sum {m.sum}")
            lines.append(f"{om}_count {m.count}")
    lines.append("# EOF")
    return "\n".join(lines) + "\n"


def parse_openmetrics(text: str) -> dict:
    """Minimal OpenMetrics parser (the subset we render).

    Returns ``{family: {"type": t, "samples": [(name, labels, value)]}}``
    and raises :class:`ValueError` on malformed lines or a missing
    ``# EOF`` terminator — the round-trip check in
    ``tests/test_observatory.py``.
    """
    families: dict[str, dict] = {}
    saw_eof = False
    for raw in text.splitlines():
        line = raw.strip()
        if not line:
            continue
        if saw_eof:
            raise ValueError("content after # EOF")
        if line == "# EOF":
            saw_eof = True
            continue
        if line.startswith("# TYPE "):
            parts = line.split()
            if len(parts) != 4:
                raise ValueError(f"malformed TYPE line: {raw!r}")
            _h, _t, fam, typ = parts
            families.setdefault(fam, {"type": typ, "samples": []})
            continue
        if line.startswith("#"):
            continue
        # sample line: name[{labels}] value
        labels: dict[str, str] = {}
        rest = line
        if "{" in line:
            name, rest = line.split("{", 1)
            labelstr, rest = rest.split("}", 1)
            for pair in labelstr.split(","):
                if not pair:
                    continue
                k, v = pair.split("=", 1)
                labels[k.strip()] = v.strip().strip('"')
        else:
            name, rest = line.split(None, 1)
        try:
            value = float(rest.strip())
        except ValueError as e:
            raise ValueError(f"malformed sample line: {raw!r}") from e
        name = name.strip()
        fam = name
        for suffix in ("_total", "_sum", "_count"):
            if fam.endswith(suffix) and fam[:-len(suffix)] in families:
                fam = fam[:-len(suffix)]
                break
        if fam not in families:
            raise ValueError(f"sample before TYPE declaration: {raw!r}")
        families[fam]["samples"].append((name, labels, value))
    if not saw_eof:
        raise ValueError("missing # EOF terminator")
    return families


# --------------------------------------------------------------------- #
# JSONL event stream
# --------------------------------------------------------------------- #
class JsonlExporter:
    """Append-only JSONL stream of registry snapshots.

    Each :meth:`tick` appends one line; :meth:`maybe_tick` only fires
    when at least ``interval_s`` has elapsed since the last tick —
    suitable for calling from inside a serving loop unconditionally.
    """

    def __init__(self, path, *, registry: _metrics.Registry | None = None,
                 interval_s: float = 0.0, clock=time.time):
        self.path = str(path)
        self.registry = registry if registry is not None \
            else _metrics.REGISTRY
        self.interval_s = float(interval_s)
        self.clock = clock
        self.seq = 0
        self._last: float | None = None

    def tick(self) -> dict:
        """Snapshot the registry and append one JSONL line."""
        now = self.clock()
        event = {"seq": self.seq, "ts": round(float(now), 6),
                 "metrics": self.registry.snapshot()}
        with open(self.path, "a") as fh:
            fh.write(json.dumps(event, sort_keys=True) + "\n")
        self.seq += 1
        self._last = now
        return event

    def maybe_tick(self) -> dict | None:
        """Tick only if the interval elapsed; ``None`` when skipped."""
        now = self.clock()
        if self._last is not None and now - self._last < self.interval_s:
            return None
        return self.tick()

    @staticmethod
    def read(path) -> list[dict]:
        with open(path) as fh:
            return [json.loads(line) for line in fh if line.strip()]


# --------------------------------------------------------------------- #
# the observatory report
# --------------------------------------------------------------------- #
def attribution_table(attr: dict) -> str:
    """Fixed-width text table from a serialized attribution dict
    (``Attribution.to_dict()`` shape, as stored in artifact meta)."""
    head = f"{'core':>6} " + " ".join(f"{c:>9}" for c in CLASSES)
    lines = [head]
    for core in sorted(attr["per_core"], key=int):
        tot = attr["per_core"][core]
        lines.append(f"{core:>6} "
                     + " ".join(f"{tot[c]:>9}" for c in CLASSES))
    lines.append(f"{'total':>6} "
                 + " ".join(f"{attr['totals'][c]:>9}" for c in CLASSES))
    lines.append(f"bottleneck: {attr['bottleneck']} "
                 f"({attr['bottleneck_group']}-bound)")
    return "\n".join(lines)


def observatory_report(server) -> dict:
    """One self-contained observatory report for a live server.

    Sections: per-artifact cycle attribution (tables + rooflines +
    named bottlenecks), SLO status, resilience snapshot, autotune
    decisions, the metrics snapshot, and the OpenMetrics rendering —
    everything JSON-serializable.
    """
    stats = server.stats()
    artifacts = []
    registry = getattr(server, "registry", None)
    for art in server.cache.artifacts():
        attr = art.meta.get("attribution")
        if not attr:
            continue
        artifacts.append({
            "substrate": art.substrate,
            "semiring": getattr(art, "semiring", None),
            "tenant": (registry.tenant_of_digest(art.digest)
                       if registry is not None else None),
            "bottleneck": art.meta.get("bottleneck"),
            "attribution": attr,
            "table": attribution_table(attr),
        })
    return {
        "version": 1,
        "config": {name: sub.config_fingerprint()
                   for name, sub in server.substrates.items()},
        "attribution": artifacts,
        "slo": stats.get("slo", {}),
        "resilience": stats.get("resilience", {}),
        "autotune": stats.get("autotune", {}),
        "multicore": stats.get("multicore", {}),
        "metrics": stats.get("metrics", {}),
        "openmetrics": render_openmetrics(),
    }


def write_observatory_report(path, server) -> dict:
    """Write :func:`observatory_report` as JSON; returns the report."""
    report = observatory_report(server)
    with open(path, "w") as fh:
        json.dump(report, fh, indent=2, sort_keys=True)
        fh.write("\n")
    return report
