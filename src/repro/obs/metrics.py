"""Process-global metrics registry: counters, gauges, histograms.

Replaces the ad-hoc stat dicts that used to live in ``Server.stats()``
and one-off bench prints with one uniform, named instrument space:

- :class:`Counter` — monotone event counts (``serve.requests``,
  ``cache.hits``, ``fault.restarts``);
- :class:`Gauge` — last-written values (``cache.size``);
- :class:`Histogram` — sample distributions with exact
  linearly-interpolated percentiles over a bounded ring of recent
  samples (``serve.latency_us.<substrate>``, ``batch.fill``) — the
  p50/p95/p99 source for ``BENCH_serve.json`` and ``metrics.dump()``.

The registry is deliberately zero-dependency and cheap: instruments are
plain attribute updates, and the whole registry can be switched off
(``REGISTRY.enabled = False``) making every ``inc``/``observe`` a no-op
— asserted by the overhead guard in ``benchmarks/serve_bench.py``.
``Server.stats()`` exposes :meth:`Registry.snapshot` read-only under the
``"metrics"`` key; ``serve --metrics-dump`` prints :meth:`Registry.dump`.
"""
from __future__ import annotations

import json
import math

__all__ = ["Counter", "Gauge", "Histogram", "Registry", "REGISTRY",
           "counter", "gauge", "histogram", "snapshot", "dump", "reset"]


class Counter:
    __slots__ = ("name", "value", "_reg")

    def __init__(self, name: str, reg: "Registry"):
        self.name, self.value, self._reg = name, 0, reg

    def inc(self, n: int = 1) -> None:
        if self._reg.enabled:
            self.value += n


class Gauge:
    __slots__ = ("name", "value", "_reg")

    def __init__(self, name: str, reg: "Registry"):
        self.name, self.value, self._reg = name, 0.0, reg

    def set(self, v: float) -> None:
        if self._reg.enabled:
            self.value = v


class Histogram:
    """Exact percentiles over a bounded ring of the newest samples.

    Running ``count``/``sum``/``min``/``max`` cover the full stream;
    percentiles are computed from the newest ``max_samples`` values
    (ring overwrite — deterministic, no random reservoir), sorted on
    demand with numpy-style linear interpolation between order
    statistics.
    """

    __slots__ = ("name", "count", "sum", "min", "max",
                 "_ring", "_cap", "_head", "_reg")

    def __init__(self, name: str, reg: "Registry", max_samples: int = 8192):
        self.name = name
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._ring: list[float] = []
        self._cap = max_samples
        self._head = 0
        self._reg = reg

    def observe(self, v: float) -> None:
        if not self._reg.enabled:
            return
        v = float(v)
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        if len(self._ring) < self._cap:
            self._ring.append(v)
        else:
            self._ring[self._head] = v
            self._head = (self._head + 1) % self._cap

    def percentile(self, p: float) -> float:
        """Linearly-interpolated percentile ``p`` in [0, 100].

        Matches ``numpy.percentile(xs, p)`` over the retained samples.
        Defined at every edge: ``p`` outside [0, 100] raises
        :class:`ValueError` (it used to wrap around via negative
        indexing), zero samples return ``nan``, one sample returns that
        sample for every ``p``, and ``p=0``/``p=100`` return the exact
        min/max with no interpolation roundoff.
        """
        if not 0.0 <= p <= 100.0:
            raise ValueError(f"percentile out of range: {p!r}")
        if not self._ring:
            return math.nan
        xs = sorted(self._ring)
        if len(xs) == 1:
            return xs[0]
        if p == 0.0:
            return xs[0]
        if p == 100.0:
            return xs[-1]
        rank = (p / 100.0) * (len(xs) - 1)
        lo = int(math.floor(rank))
        hi = min(lo + 1, len(xs) - 1)
        frac = rank - lo
        return xs[lo] * (1.0 - frac) + xs[hi] * frac

    def summary(self) -> dict:
        if not self.count:
            return {"count": 0}
        return {"count": self.count,
                "sum": round(self.sum, 6),
                "min": round(self.min, 6),
                "max": round(self.max, 6),
                "mean": round(self.sum / self.count, 6),
                "p50": round(self.percentile(50), 6),
                "p95": round(self.percentile(95), 6),
                "p99": round(self.percentile(99), 6)}


class Registry:
    """Named instrument store; get-or-create, type-checked."""

    def __init__(self) -> None:
        self._metrics: dict[str, object] = {}
        self.enabled = True

    def _get(self, name: str, cls, **kwargs):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name, self, **kwargs)
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{type(m).__name__}, not {cls.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, max_samples: int = 8192) -> Histogram:
        return self._get(name, Histogram, max_samples=max_samples)

    def names(self) -> tuple[str, ...]:
        return tuple(sorted(self._metrics))

    def snapshot(self, prefix: str = "") -> dict:
        """Read-only value snapshot: name -> number | histogram summary.

        ``prefix`` filters to one instrument family (e.g. ``"fault."``
        for the resilience counters/gauges) without copying the rest.
        """
        out: dict = {}
        for name in sorted(self._metrics):
            if prefix and not name.startswith(prefix):
                continue
            m = self._metrics[name]
            out[name] = m.summary() if isinstance(m, Histogram) else m.value
        return out

    def dump(self, fmt: str = "text") -> str:
        """Render the registry: ``text`` (one line per metric) or ``json``."""
        if fmt == "json":
            return json.dumps(self.snapshot(), indent=2)
        if fmt != "text":
            raise ValueError(f"unknown dump format {fmt!r}")
        lines = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if isinstance(m, Histogram):
                s = m.summary()
                if s["count"]:
                    lines.append(
                        f"hist    {name:40s} count={s['count']} "
                        f"mean={s['mean']:.3f} p50={s['p50']:.3f} "
                        f"p95={s['p95']:.3f} p99={s['p99']:.3f}")
                else:
                    lines.append(f"hist    {name:40s} count=0")
            elif isinstance(m, Gauge):
                lines.append(f"gauge   {name:40s} {m.value}")
            else:
                lines.append(f"counter {name:40s} {m.value}")
        return "\n".join(lines)

    def reset(self) -> None:
        self._metrics.clear()


#: the process-global registry every layer of the serving stack writes to
REGISTRY = Registry()


def counter(name: str) -> Counter:
    return REGISTRY.counter(name)


def gauge(name: str) -> Gauge:
    return REGISTRY.gauge(name)


def histogram(name: str, max_samples: int = 8192) -> Histogram:
    return REGISTRY.histogram(name, max_samples)


def snapshot(prefix: str = "") -> dict:
    return REGISTRY.snapshot(prefix)


def dump(fmt: str = "text") -> str:
    return REGISTRY.dump(fmt)


def reset() -> None:
    REGISTRY.reset()
