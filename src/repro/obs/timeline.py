"""Cycle-level timeline profiling of the multi-core lockstep simulator.

The checked lockstep sim already *counts* stall/barrier/link/inject
cycles; this module promotes the counts into a per-core, per-cycle
timeline: every global cycle each core is in exactly one of the states

- ``issue``   — the core executed one VLIW instruction,
- ``stall``   — flow-control stall (a crossbar read hit a
  shared-register-window cell still in flight),
- ``barrier`` — finished, idling at the implicit end-of-program barrier,

recorded as run-length intervals, plus instant SEND/RECV markers and the
per-link busy intervals / channel-row transit windows charged by the
NoC contention model (:class:`repro.core.multicore.comm.Interconnect`).

:meth:`TimelineRecorder.to_chrome_events` renders it all as Chrome
``trace_event`` rows on a **virtual "cycles" clock** (1 simulated cycle
= 1 trace microsecond) under a second process track, so one perfetto
view shows wall-clock request spans and simulated-core timelines side
by side (see ``serve --trace``).

Because the lockstep cycle count is value-independent, a 1-row probe
(:func:`record_multicore`) yields the exact serving timeline; the
per-core interval sums are asserted against the checked sim's cycle
count — and the golden ``tests/golden_cycles.json`` fixtures — exactly.
"""
from __future__ import annotations

__all__ = ["TimelineRecorder", "record_multicore"]

#: tid offsets inside the cycles process track
_LINK_TID0 = 1000
_NOC_TID = 900


class TimelineRecorder:
    """Collects per-core states, comm markers and link occupancy."""

    STATES = ("issue", "stall", "barrier")

    def __init__(self) -> None:
        # core -> [[state, start_cycle, end_cycle], ...] run-length runs
        self._runs: dict[int, list[list]] = {}
        # (core, cycle, kind, row_id, members)
        self.comm_events: list[tuple] = []
        # ((src_node, dst_node), start, end, row_id)
        self.link_intervals: list[tuple] = []
        # (row_id, src_core, dst_core, send_cycle, arrival_cycle,
        #  members, inject_wait_cycles)
        self.row_transits: list[tuple] = []
        self.cycles = 0

    # ------------- recording hooks (called by the sims) ----------------- #
    def core_state(self, core: int, cycle: int, state: str) -> None:
        runs = self._runs.setdefault(core, [])
        if runs and runs[-1][0] == state and runs[-1][2] == cycle:
            runs[-1][2] = cycle + 1
        else:
            runs.append([state, cycle, cycle + 1])
        if cycle + 1 > self.cycles:
            self.cycles = cycle + 1

    def comm_event(self, core: int, cycle: int, kind: str,
                   row_id: int, members: int) -> None:
        self.comm_events.append((core, cycle, kind, row_id, members))

    def link_busy(self, link: tuple, start: int, end: int,
                  row_id: int) -> None:
        self.link_intervals.append((link, start, end, row_id))

    def row_transit(self, row_id: int, src: int, dst: int,
                    send: int, arrival: int, members: int,
                    inject: int = 0) -> None:
        """``inject`` is the injection-port arbitration wait the
        transfer paid at the source NIC — the attribution engine
        (:mod:`repro.obs.attr`) splits it from link contention."""
        self.row_transits.append((row_id, src, dst, send, arrival,
                                  members, inject))

    # ------------- aggregation ------------------------------------------ #
    @property
    def cores(self) -> tuple[int, ...]:
        return tuple(sorted(self._runs))

    def intervals(self, core: int) -> list[tuple]:
        """[(state, start, end), ...] covering [0, cycles) for ``core``."""
        return [tuple(r) for r in self._runs.get(core, [])]

    def core_totals(self) -> dict[int, dict[str, int]]:
        """Per-core cycles in each state; states sum to ``self.cycles``."""
        out: dict[int, dict[str, int]] = {}
        for core, runs in sorted(self._runs.items()):
            tot = {s: 0 for s in self.STATES}
            for state, start, end in runs:
                tot[state] += end - start
            out[core] = tot
        return out

    # ------------- Chrome trace_event rendering ------------------------- #
    def to_chrome_events(self, *, pid: int = 2,
                         process_name: str = "vliw-mc (simulated cycles)",
                         clock_label: str = "cycles") -> list[dict]:
        """Chrome events on a virtual clock: 1 cycle = 1 trace us.

        Per-core tracks carry the issue/stall/barrier intervals and
        SEND/RECV instants; NoC traffic lands on a row-transit track
        plus one track per physical link.
        """
        events: list[dict] = [{
            "ph": "M", "name": "process_name", "pid": pid, "tid": 0,
            "args": {"name": f"{process_name} [{clock_label}]"},
        }]
        for core in self.cores:
            events.append({"ph": "M", "name": "thread_name", "pid": pid,
                           "tid": core, "args": {"name": f"core {core}"}})
            for state, start, end in self._runs[core]:
                events.append({
                    "name": state, "ph": "X", "ts": float(start),
                    "dur": float(end - start), "pid": pid, "tid": core,
                    "cat": "cycles", "args": {"cycles": end - start},
                })
        for core, cycle, kind, row_id, members in self.comm_events:
            events.append({
                "name": f"{kind} row {row_id}", "ph": "i",
                "ts": float(cycle), "pid": pid, "tid": core, "s": "t",
                "cat": "comm",
                "args": {"row": row_id, "members": members, "kind": kind},
            })
        if self.row_transits:
            events.append({"ph": "M", "name": "thread_name", "pid": pid,
                           "tid": _NOC_TID, "args": {"name": "NoC rows"}})
            for transit in self.row_transits:
                row_id, src, dst, send, arrival, members = transit[:6]
                inject = transit[6] if len(transit) > 6 else 0
                events.append({
                    "name": f"row {row_id}: {src}->{dst}", "ph": "X",
                    "ts": float(send), "dur": float(max(arrival - send, 1)),
                    "pid": pid, "tid": _NOC_TID, "cat": "noc",
                    "args": {"row": row_id, "src": src, "dst": dst,
                             "members": members,
                             "latency": arrival - send,
                             "inject_wait": inject},
                })
        link_tid: dict[tuple, int] = {}
        for link, start, end, row_id in self.link_intervals:
            tid = link_tid.get(link)
            if tid is None:
                tid = link_tid[link] = _LINK_TID0 + len(link_tid)
                events.append({
                    "ph": "M", "name": "thread_name", "pid": pid, "tid": tid,
                    "args": {"name": f"link {link[0]}->{link[1]}"},
                })
            events.append({
                "name": f"row {row_id}", "ph": "X", "ts": float(start),
                "dur": float(max(end - start, 1)), "pid": pid, "tid": tid,
                "cat": "link", "args": {"row": row_id},
            })
        return events


def record_multicore(mcp, recorder: TimelineRecorder | None = None):
    """Exact cycle timeline of ``mcp`` from a 1-row lockstep probe.

    Returns ``(recorder, MCSimResult)``. Cycle counts are
    value-independent, so this single probe run IS the serving timeline
    (the same property the compile-time ETA calibration relies on).
    """
    import numpy as np

    from ..core.multicore.sim import simulate_multicore

    recorder = recorder or TimelineRecorder()
    leaves = np.ones((1, mcp.prog.m_ind), np.float32)
    res = simulate_multicore(mcp, leaves, recorder=recorder)
    return recorder, res
