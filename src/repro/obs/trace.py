"""Structured tracing: lightweight spans + Chrome ``trace_event`` export.

A *span* is one named, timed section of the request lifecycle
(``admission -> batcher coalesce -> cache lookup -> compile -> execute
-> decode``), carrying a trace id that groups every span of one request.
The API is built for a hot serving path:

- **Disabled is free.** ``span()`` returns a cached no-op context
  manager when no tracer is installed — no object allocation, no attr
  dict construction, no clock reads. The serving stack stays
  instrumented permanently and ``serve_bench`` asserts the disabled
  overhead stays under 2% of a request (see ``obs_overhead_check``).
- **Attrs are lazy.** ``attrs`` may be a zero-arg callable; it is only
  evaluated when a tracer is actually recording, so expensive attribute
  construction (row lists, digests) costs nothing when tracing is off.
- **Errors are recorded, not dropped.** A span whose body raises is
  still emitted, with ``error``/``message`` attrs naming the exception
  type — a substrate failure inside a traced request shows up as a red
  span instead of vanishing (see ``runtime.fault`` and the regression
  test in ``tests/test_obs.py``).

Export is the Chrome ``trace_event`` JSON format (one ``X`` complete
event per span), loadable in https://ui.perfetto.dev or
``chrome://tracing``; :mod:`repro.obs.timeline` merges simulated
per-core cycle timelines into the same file on a second process track.

    tracer = trace.install()
    with trace.span("compile.partition", {"cores": 4}):
        ...
    trace.write_chrome_trace("out.json", tracer)
"""
from __future__ import annotations

import itertools
import json
import threading
import time

__all__ = ["Tracer", "install", "uninstall", "active", "get_tracer",
           "span", "instant", "current_span", "chrome_trace",
           "write_chrome_trace"]


class _NullSpan:
    """The disabled fast path: one shared, allocation-free no-op span."""

    __slots__ = ()
    trace_id = 0
    span_id = 0

    def __enter__(self):
        return self

    def __exit__(self, et, ev, tb):
        return False

    def set(self, key, value):  # noqa: ARG002 - intentional no-op
        return self


_NULL = _NullSpan()


class Span:
    """One in-flight span. Use via ``with trace.span(...) as sp``."""

    __slots__ = ("_tracer", "name", "_attrs", "_extra", "root",
                 "trace_id", "span_id", "parent_id", "t0")

    def __init__(self, tracer: "Tracer", name: str, attrs, root: bool):
        self._tracer = tracer
        self.name = name
        self._attrs = attrs          # dict | callable | None — kept lazy
        self._extra: dict | None = None
        self.root = root
        self.trace_id = 0
        self.span_id = 0
        self.parent_id = 0
        self.t0 = 0

    def set(self, key, value) -> "Span":
        """Attach one attribute from inside the span body."""
        if self._extra is None:
            self._extra = {}
        self._extra[key] = value
        return self

    def __enter__(self) -> "Span":
        tr = self._tracer
        stack = tr._stack()
        parent = stack[-1] if stack else None
        if parent is not None and not self.root:
            self.trace_id = parent.trace_id
            self.parent_id = parent.span_id
        else:
            self.trace_id = next(tr._next_trace)
            self.parent_id = parent.span_id if parent is not None else 0
        self.span_id = next(tr._next_span)
        stack.append(self)
        self.t0 = tr.clock()
        return self

    def __exit__(self, et, ev, tb) -> bool:
        tr = self._tracer
        t1 = tr.clock()
        stack = tr._stack()
        if stack and stack[-1] is self:
            stack.pop()
        elif self in stack:            # defensive: unbalanced exits
            stack.remove(self)
        attrs = self._attrs
        attrs = dict(attrs() if callable(attrs) else (attrs or {}))
        if self._extra:
            attrs.update(self._extra)
        if et is not None:
            # the error span IS the record — never silently dropped
            attrs["error"] = et.__name__
            attrs["message"] = str(ev)[:200]
        tr.events.append({
            "name": self.name,
            "ts_us": (self.t0 - tr.t_origin) / 1e3,
            "dur_us": max((t1 - self.t0) / 1e3, 0.0),
            "trace_id": self.trace_id,
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "tid": threading.get_ident() & 0x7FFFFFFF,
            "error": et is not None,
            "args": attrs,
        })
        return False                   # always propagate the exception


class Tracer:
    """Collects finished span records; one per ``install()``.

    ``clock`` is injectable (defaults to ``time.perf_counter_ns``) so
    tests can pin deterministic timestamps.
    """

    def __init__(self, clock=time.perf_counter_ns):
        self.clock = clock
        self.events: list[dict] = []
        self._tls = threading.local()
        self._next_span = itertools.count(1)
        self._next_trace = itertools.count(1)
        self.t_origin = clock()

    def _stack(self) -> list:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    def span(self, name: str, attrs=None, *, root: bool = False) -> Span:
        return Span(self, name, attrs, root)

    def instant(self, name: str, attrs=None) -> None:
        attrs = dict(attrs() if callable(attrs) else (attrs or {}))
        stack = self._stack()
        parent = stack[-1] if stack else None
        self.events.append({
            "name": name,
            "ts_us": (self.clock() - self.t_origin) / 1e3,
            "dur_us": 0.0,
            "trace_id": parent.trace_id if parent else 0,
            "span_id": next(self._next_span),
            "parent_id": parent.span_id if parent else 0,
            "tid": threading.get_ident() & 0x7FFFFFFF,
            "error": False,
            "instant": True,
            "args": attrs,
        })

    def spans(self, name: str | None = None) -> list[dict]:
        """Finished records, optionally filtered by span name."""
        if name is None:
            return list(self.events)
        return [e for e in self.events if e["name"] == name]


# --------------------------------------------------------------------------- #
# process-global tracer (None = tracing disabled, spans are no-ops)
# --------------------------------------------------------------------------- #
_TRACER: Tracer | None = None


def install(tracer: Tracer | None = None) -> Tracer:
    """Attach ``tracer`` (or a fresh one) as the process tracer."""
    global _TRACER
    _TRACER = tracer if tracer is not None else Tracer()
    return _TRACER


def uninstall() -> Tracer | None:
    """Detach and return the process tracer (tracing becomes free)."""
    global _TRACER
    tracer, _TRACER = _TRACER, None
    return tracer


def active() -> bool:
    return _TRACER is not None


def get_tracer() -> Tracer | None:
    return _TRACER


def span(name: str, attrs=None, *, root: bool = False):
    """A traced section, or the shared no-op when tracing is disabled.

    ``attrs``: dict, or a zero-arg callable evaluated only when
    recording. ``root=True`` starts a new trace id (one per request).
    """
    tracer = _TRACER
    if tracer is None:
        return _NULL
    return tracer.span(name, attrs, root=root)


def instant(name: str, attrs=None) -> None:
    tracer = _TRACER
    if tracer is not None:
        tracer.instant(name, attrs)


def current_span():
    tracer = _TRACER
    if tracer is None:
        return _NULL
    stack = tracer._stack()
    return stack[-1] if stack else _NULL


# --------------------------------------------------------------------------- #
# Chrome trace_event export
# --------------------------------------------------------------------------- #
WALL_PID = 1      #: process track of wall-clock spans
CYCLES_PID = 2    #: process track of simulated-cycle timelines


def chrome_trace(tracer: Tracer | None, extra_events=(), *,
                 pid: int = WALL_PID,
                 process_name: str = "serve (wall-clock)") -> dict:
    """Chrome ``trace_event`` JSON object (perfetto-loadable).

    Wall-clock spans land on process ``pid``; ``extra_events`` (e.g.
    :meth:`repro.obs.timeline.TimelineRecorder.to_chrome_events`) are
    appended verbatim so simulated-cycle tracks share the file.
    """
    events: list[dict] = [{
        "ph": "M", "name": "process_name", "pid": pid, "tid": 0,
        "args": {"name": process_name},
    }]
    tids = set()
    for rec in (tracer.events if tracer is not None else ()):
        tids.add(rec["tid"])
        args = dict(rec["args"])
        args["trace_id"] = rec["trace_id"]
        args["span_id"] = rec["span_id"]
        args["parent_id"] = rec["parent_id"]
        events.append({
            "name": rec["name"],
            "ph": "i" if rec.get("instant") else "X",
            "ts": rec["ts_us"],
            "pid": pid,
            "tid": rec["tid"],
            "cat": "error" if rec["error"] else "span",
            "args": args,
            **({} if rec.get("instant")
               else {"dur": max(rec["dur_us"], 0.001)}),
            **({"s": "t"} if rec.get("instant") else {}),
        })
    for tid in sorted(tids):
        events.append({"ph": "M", "name": "thread_name", "pid": pid,
                       "tid": tid, "args": {"name": f"requests (tid {tid})"}})
    events.extend(extra_events)
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, tracer: Tracer | None,
                       extra_events=(), **kwargs) -> int:
    """Write the Chrome trace JSON; returns the event count."""
    doc = chrome_trace(tracer, extra_events, **kwargs)
    with open(path, "w") as f:
        json.dump(doc, f)
    return len(doc["traceEvents"])
