"""Cycle-attribution engine: where do a VLIW artifact's cycles go?

PR 6's :class:`~repro.obs.timeline.TimelineRecorder` records *what* each
core did every global cycle (issue / stall / barrier run-lengths that
sum exactly to the lockstep cycle count); this module turns the raw
timeline plus the NoC transit log into an *answer*: a per-core
decomposition of every cycle into five attribution classes

``issue``
    the core executed one VLIW instruction (useful work + SEND/RECV
    slot occupancy — the compute axis);
``stall``
    flow-control stall charged to *latency*: the core waited on a row
    whose transfer was still inside its uncontended hop+serialization
    window;
``link``
    flow-control stall charged to *link contention*: the wait extended
    past the uncontended window because route links were busy with
    other transfers (includes degraded slow-link serialization);
``inject``
    flow-control stall charged to injection-port arbitration at the
    producing core's NIC;
``barrier``
    finished, idling at the implicit end-of-program barrier (load
    imbalance).

The decomposition is **exact by construction**: ``issue + stall +
barrier`` per core comes from the recorder's run-lengths (asserted
against the checked sim's cycle count by ``tests/test_obs.py`` and the
golden fixtures), and the ``link``/``inject`` classes are carved *out
of* each destination core's recorded stall total (clamped, never
invented), so the five classes still sum bit-exactly to ``cycles`` for
every core — the acceptance criterion ``tests/test_observatory.py``
pins for every ``golden_cycles.json`` point.

On top of the decomposition the engine computes a compute-vs-comm
**roofline point** (achieved ops/cycle vs the machine's peak and the
NoC's modeled delivery ceiling at the artifact's operational intensity)
and names the **dominant bottleneck** — the knob prior
:func:`repro.core.autotune.search.tune_program` seeds its guided
candidates from (comm-bound → placement passes / interleave;
issue-bound → max_arity / interleave; barrier → repartition).
"""
from __future__ import annotations

import dataclasses

__all__ = ["CLASSES", "Attribution", "attribute_multicore",
           "attribute_single", "attribute_artifact", "GROUP_OF_CLASS"]

#: the attribution classes; per core they sum exactly to ``cycles``
CLASSES = ("issue", "stall", "barrier", "link", "inject")

#: class -> coarse bottleneck group (the autotuner's prior vocabulary)
GROUP_OF_CLASS = {"issue": "compute", "stall": "comm", "link": "comm",
                  "inject": "comm", "barrier": "imbalance"}

#: an artifact is called compute-bound ("issue") when less than this
#: fraction of its core-cycles is overhead, regardless of which
#: overhead class is largest — a 95%-utilized machine is not
#: "barrier-bound" because 3% of its cycles idle at the barrier
_OVERHEAD_THRESHOLD = 0.25


@dataclasses.dataclass
class Attribution:
    """Exact per-core cycle decomposition of one compiled artifact."""
    substrate: str
    cycles: int                      # lockstep global cycles (per batch)
    interleave: int                  # evals packed per row (tuned artifacts)
    per_core: dict                   # core -> {class: cycles}
    totals: dict                     # class -> cycles summed over cores
    fractions: dict                  # class -> share of cores * cycles
    bottleneck: str                  # dominant class (one of CLASSES)
    bottleneck_group: str            # compute | comm | imbalance
    roofline: dict                   # achieved/peak/ceiling ops-per-cycle

    @property
    def cycles_per_eval(self) -> float:
        return self.cycles / max(self.interleave, 1)

    def to_dict(self) -> dict:
        return {"substrate": self.substrate,
                "cycles": self.cycles,
                "interleave": self.interleave,
                "cycles_per_eval": self.cycles_per_eval,
                "per_core": {str(c): dict(t)
                             for c, t in sorted(self.per_core.items())},
                "totals": dict(self.totals),
                "fractions": dict(self.fractions),
                "bottleneck": self.bottleneck,
                "bottleneck_group": self.bottleneck_group,
                "roofline": dict(self.roofline)}

    def table(self) -> str:
        """Fixed-width text table (one row per core + totals)."""
        head = f"{'core':>6} " + " ".join(f"{c:>9}" for c in CLASSES)
        lines = [head]
        for core, tot in sorted(self.per_core.items()):
            lines.append(f"{core:>6} "
                         + " ".join(f"{tot[c]:>9}" for c in CLASSES))
        lines.append(f"{'total':>6} "
                     + " ".join(f"{self.totals[c]:>9}" for c in CLASSES))
        lines.append(f"bottleneck: {self.bottleneck} "
                     f"({self.bottleneck_group}-bound, "
                     f"{self.fractions[self.bottleneck]:.1%} of "
                     f"core-cycles)")
        return "\n".join(lines)


def _finalize(substrate: str, cycles: int, interleave: int,
              per_core: dict, roofline: dict) -> Attribution:
    n_cores = max(len(per_core), 1)
    totals = {c: sum(t[c] for t in per_core.values()) for c in CLASSES}
    denom = max(n_cores * cycles, 1)
    fractions = {c: round(totals[c] / denom, 6) for c in CLASSES}
    overhead = sum(totals[c] for c in CLASSES if c != "issue")
    if overhead == 0 or overhead / denom < _OVERHEAD_THRESHOLD:
        bottleneck = "issue"
    else:
        # dominant overhead class; ties break in CLASSES order so the
        # name is deterministic
        bottleneck = max((c for c in CLASSES if c != "issue"),
                         key=lambda c: (totals[c], -CLASSES.index(c)))
    return Attribution(
        substrate=substrate, cycles=int(cycles),
        interleave=max(int(interleave), 1),
        per_core=per_core, totals=totals, fractions=fractions,
        bottleneck=bottleneck,
        bottleneck_group=GROUP_OF_CLASS[bottleneck],
        roofline=roofline)


def _roofline(cycles: int, useful_ops: int, comm_values: int,
              num_pes: int, n_cores: int, link_width: int) -> dict:
    """Compute-vs-comm roofline point of one artifact.

    ``intensity`` is operational intensity in ops per communicated
    value; the comm ceiling is the modeled NoC delivery bound at that
    intensity (every core's injection port admits ``link_width`` values
    per cycle). ``bound`` names which roof is lower at this point —
    independent corroboration of the cycle-level bottleneck classes.
    """
    cycles = max(int(cycles), 1)
    achieved = useful_ops / cycles
    peak = float(num_pes * max(n_cores, 1))
    intensity = useful_ops / max(comm_values, 1)
    comm_ceiling = (float("inf") if comm_values == 0
                    else intensity * link_width * max(n_cores, 1))
    return {"achieved_ops_per_cycle": round(achieved, 4),
            "peak_ops_per_cycle": peak,
            "intensity_ops_per_value": round(intensity, 4),
            "comm_values_per_batch": int(comm_values),
            "comm_ceiling_ops_per_cycle": (
                None if comm_ceiling == float("inf")
                else round(comm_ceiling, 4)),
            "utilization": round(achieved / peak, 4),
            "bound": ("communication" if comm_ceiling < peak
                      else "compute")}


def attribute_multicore(mcp, interleave: int = 1) -> Attribution:
    """Exact attribution of a compiled ``MultiCoreProgram``.

    Runs one recorded 1-row lockstep probe (cycle counts are
    value-independent, so the probe IS the serving timeline), splits
    each core's recorded stall total into latency / link-contention /
    injection-arbitration shares using the NoC transit log, and returns
    the five-class decomposition plus the roofline point.
    """
    from .timeline import record_multicore

    recorder, res = record_multicore(mcp)
    totals = recorder.core_totals()

    # ---- carve link/inject waits out of each destination core's stall -
    # Per transit the recorder logged (send, arrival, inject-wait); the
    # uncontended window is hops * hop_latency + serial cycles, so the
    # excess beyond it is contention: inject-wait at the source NIC plus
    # link serialization conflicts along the route. Both delay exactly
    # the rows the *destination* core flow-control stalls on, so they
    # are charged there — clamped to the stall cycles actually recorded
    # (attribution never invents cycles; the residual stays ``stall``).
    icfg = mcp.plan.icfg
    n_geom = mcp.plan.n_geom
    eff_of_phys = {mcp.plan.geometry(cp.core): cp.core for cp in mcp.cores}
    inject_raw: dict[int, int] = {}
    link_raw: dict[int, int] = {}
    for transit in recorder.row_transits:
        row_id, src, dst, send, arrival, members = transit[:6]
        inject = int(transit[6]) if len(transit) > 6 else 0
        base = (icfg.hops(src, dst, n_geom) * icfg.hop_latency
                + icfg.serial_cycles(members))
        excess = max(int(arrival - send) - base, 0)
        dst_eff = eff_of_phys.get(int(dst), int(dst))
        inject_raw[dst_eff] = inject_raw.get(dst_eff, 0) + min(inject,
                                                               excess)
        link_raw[dst_eff] = (link_raw.get(dst_eff, 0)
                             + max(excess - inject, 0))

    per_core: dict[int, dict[str, int]] = {}
    for core, tot in totals.items():
        stall = tot["stall"]
        inject = min(inject_raw.get(core, 0), stall)
        link = min(link_raw.get(core, 0), stall - inject)
        per_core[core] = {"issue": tot["issue"],
                          "stall": stall - inject - link,
                          "barrier": tot["barrier"],
                          "link": link, "inject": inject}

    roofline = _roofline(res.cycles, res.useful_ops,
                         mcp.plan.volume, mcp.cfg.num_pes,
                         len(mcp.cores), icfg.link_width)
    return _finalize("vliw-mc", res.cycles, interleave, per_core, roofline)


def attribute_single(cycles: int, useful_ops: int,
                     num_pes: int) -> Attribution:
    """Trivial attribution of a single-core ``vliw-sim`` artifact.

    One core, no interconnect: every global cycle issues exactly one
    VLIW instruction — no flow-control stalls, no barrier, no NoC.
    """
    per_core = {0: {"issue": int(cycles), "stall": 0, "barrier": 0,
                    "link": 0, "inject": 0}}
    roofline = _roofline(cycles, useful_ops, 0, num_pes, 1, 0)
    return _finalize("vliw-sim", cycles, 1, per_core, roofline)


def attribute_artifact(artifact) -> Attribution | None:
    """Attribution of a compiled runtime artifact, or ``None`` when the
    substrate has no cycle model (numpy / leveled-jax / pallas).

    ``vliw-mc``/``vliw-sim`` artifacts carry their attribution in
    ``meta["attribution"]`` (attached at compile time); this re-derives
    it from the payload — the from-scratch path the tests cross-check
    the cached meta against.
    """
    if artifact.substrate == "vliw-mc":
        mcp = artifact.payload[0]
        return attribute_multicore(
            mcp, interleave=int(artifact.meta.get("interleave", 1)))
    if artifact.substrate == "vliw-sim":
        from ..core.processor.config import PTREE, PVECT
        vprog = artifact.payload[0]
        by_name = {c.name: c for c in (PTREE, PVECT)}
        cfg = by_name.get(artifact.meta.get("processor"), PTREE)
        return attribute_single(vprog.num_cycles, vprog.n_useful_ops,
                                num_pes=cfg.num_pes)
    return None
