"""SLO tracking: latency/error-budget objectives with burn rates.

One :class:`SLOTracker` per server watches every served
``(substrate, semiring)`` pair over a rolling time window and answers
the operational questions the raw latency histograms can't:

- **Am I meeting the objective?** An event *breaches* when the request
  failed or its latency exceeded the objective's target; the window's
  breach fraction is compared against the allowed ``error_budget``.
- **How fast am I burning budget?** ``burn_rate`` is the classic SRE
  ratio *breach-fraction / error-budget*: 1.0 means breaching at
  exactly the allowed rate (the budget lasts precisely one window),
  10.0 means the window's budget is gone in a tenth of the window.
- **Should I shed load now?** :meth:`SLOTracker.should_shed` fires when
  the burn rate crosses the objective's ``shed_burn_rate`` with enough
  samples in the window — the *before the budget burns* signal
  :meth:`repro.runtime.Server.query` turns into
  :class:`~repro.runtime.resilience.Backpressure` (only when the
  server was constructed with an explicit ``slo=`` objective; plain
  servers track and report but never shed).

The clock is injectable, so the burn-rate math is unit-testable on a
fake clock (``tests/test_observatory.py``), and every structure is
bounded: one deque per served key, pruned to the window on touch.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque

__all__ = ["SLObjective", "SLOTracker", "DEFAULT_OBJECTIVE"]


@dataclasses.dataclass(frozen=True)
class SLObjective:
    """One latency/error objective for a (substrate, query-kind) pair."""
    latency_target_us: float = 250_000.0   # request latency objective
    error_budget: float = 0.01             # allowed breach fraction
    window_s: float = 60.0                 # rolling window length
    min_samples: int = 20                  # below this, never shed
    shed_burn_rate: float = 10.0           # shed when burning this fast

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


DEFAULT_OBJECTIVE = SLObjective()


class SLOTracker:
    """Rolling-window SLO state for every served (substrate, semiring).

    ``objectives`` maps keys to per-pair overrides; a key is either a
    ``(substrate, semiring)`` tuple, a bare substrate name (applies to
    every semiring on it), or ``"default"``. ``objective`` is a
    shorthand for ``{"default": objective}``.
    """

    def __init__(self, objective: SLObjective | None = None, *,
                 objectives: dict | None = None,
                 clock=time.monotonic):
        self.clock = clock
        self._objectives: dict = dict(objectives or {})
        if objective is not None:
            self._objectives.setdefault("default", objective)
        self._objectives.setdefault("default", DEFAULT_OBJECTIVE)
        # (substrate, semiring) -> deque[(t, latency_us, breached)]
        self._events: dict[tuple, deque] = {}

    # ---------------- configuration ------------------------------------ #
    def objective_for(self, substrate: str, semiring: str) -> SLObjective:
        keys = [(substrate, semiring), substrate]
        if ":" in substrate:
            # per-tenant key ("tenant:substrate") falls back to the
            # substrate's aggregate objective before "default"
            base = substrate.split(":", 1)[1]
            keys += [(base, semiring), base]
        keys.append("default")
        for key in keys:
            obj = self._objectives.get(key)
            if obj is not None:
                return obj
        return DEFAULT_OBJECTIVE

    # ---------------- recording ---------------------------------------- #
    def record(self, substrate: str, semiring: str, latency_us: float,
               ok: bool = True) -> None:
        """One finished request: latency + outcome.

        A breach is a failed request or one over the latency target —
        evaluated against the pair's objective at record time.
        """
        obj = self.objective_for(substrate, semiring)
        breached = (not ok) or latency_us > obj.latency_target_us
        key = (substrate, semiring)
        dq = self._events.get(key)
        if dq is None:
            dq = self._events[key] = deque()
        now = self.clock()
        dq.append((now, float(latency_us), breached))
        self._prune(dq, now - obj.window_s)

    @staticmethod
    def _prune(dq: deque, horizon: float) -> None:
        while dq and dq[0][0] < horizon:
            dq.popleft()

    # ---------------- the SLO math -------------------------------------- #
    def status(self, substrate: str, semiring: str) -> dict:
        """Window snapshot: counts, breach fraction, burn rate, verdict.

        ``burn_rate`` = breach-fraction / error-budget over the rolling
        window (1.0 = consuming the budget exactly as fast as allowed);
        ``budget_remaining`` is the fraction of the window's budget left
        (clamped at 0 — a burn rate over 1 exhausts it).
        """
        obj = self.objective_for(substrate, semiring)
        dq = self._events.get((substrate, semiring))
        now = self.clock()
        if dq is not None:
            self._prune(dq, now - obj.window_s)
        events = list(dq or ())
        total = len(events)
        breaches = sum(1 for _t, _l, b in events if b)
        frac = breaches / total if total else 0.0
        burn = frac / obj.error_budget if obj.error_budget > 0 \
            else (float("inf") if breaches else 0.0)
        return {
            "objective": obj.to_dict(),
            "window_events": total,
            "breaches": breaches,
            "breach_fraction": round(frac, 6),
            "burn_rate": round(burn, 4),
            "budget_remaining": round(max(0.0, 1.0 - burn), 4),
            "healthy": frac <= obj.error_budget,
            "shedding": self._should_shed(obj, total, burn),
        }

    @staticmethod
    def _should_shed(obj: SLObjective, total: int, burn: float) -> bool:
        return total >= obj.min_samples and burn >= obj.shed_burn_rate

    def should_shed(self, substrate: str, semiring: str) -> bool:
        """True when the pair is burning its error budget fast enough
        that admitting more load would torch the rest of the window —
        the admission-control consult in the hardened request path."""
        obj = self.objective_for(substrate, semiring)
        dq = self._events.get((substrate, semiring))
        if not dq:
            return False
        now = self.clock()
        self._prune(dq, now - obj.window_s)
        total = len(dq)
        if total < obj.min_samples:
            return False
        breaches = sum(1 for _t, _l, b in dq if b)
        frac = breaches / total
        burn = frac / obj.error_budget if obj.error_budget > 0 \
            else (float("inf") if breaches else 0.0)
        return burn >= obj.shed_burn_rate

    # ---------------- introspection ------------------------------------- #
    def snapshot(self) -> dict:
        """``{"substrate/semiring": status, ...}`` for every tracked
        pair — the ``Server.stats()["slo"]`` section."""
        return {f"{s}/{q}": self.status(s, q)
                for (s, q) in sorted(self._events)}
