"""End-to-end observability: tracing, metrics, timelines, observatory.

Zero-dependency (stdlib + numpy only at the edges) subsystem threaded
through the whole serving path. Six pillars:

:mod:`repro.obs.trace`
    Lightweight span API with per-request trace ids and a Chrome
    ``trace_event`` JSON exporter (perfetto-loadable). Disabled spans
    are allocation-free no-ops; attrs are lazily evaluated.
:mod:`repro.obs.metrics`
    Process-global registry of counters / gauges / histograms
    (p50/p95/p99) replacing the ad-hoc stat dicts; ``Server.stats()``
    snapshots it read-only and ``serve --metrics-dump`` renders it.
:mod:`repro.obs.timeline`
    Per-core, per-cycle timelines (issue / stall / barrier, SEND/RECV
    markers, NoC link occupancy) of the multi-core lockstep simulator,
    exported into the same Chrome trace on a virtual cycles clock.
:mod:`repro.obs.attr`
    Cycle-attribution engine: exact per-core decomposition of every
    VLIW artifact's cycles into issue / stall / barrier / link /
    inject, a compute-vs-comm roofline point, and a named dominant
    bottleneck that seeds the autotuner's guided candidates.
:mod:`repro.obs.slo`
    Per-(substrate, query-kind) latency/error-budget objectives over
    rolling windows with burn-rate computation; feeds the server's
    admission control (shed before the budget burns).
:mod:`repro.obs.export`
    OpenMetrics text exposition, JSONL snapshot streams, and the
    self-contained observatory report behind ``serve --observe``.

Quick use::

    from repro import obs
    tracer = obs.trace.install()             # start recording spans
    ... serve requests ...
    obs.trace.write_chrome_trace("out.json", tracer)
    print(obs.metrics.dump())
    print(obs.export.render_openmetrics())
"""
from . import attr, export, metrics, slo, timeline, trace
from .metrics import REGISTRY
from .trace import active, install, instant, span, uninstall

__all__ = ["trace", "metrics", "timeline", "attr", "slo", "export",
           "REGISTRY", "span", "instant", "install", "uninstall", "active"]
