"""End-to-end observability: tracing, metrics, cycle-level timelines.

Zero-dependency (stdlib + numpy only at the edges) subsystem threaded
through the whole serving path. Three pillars:

:mod:`repro.obs.trace`
    Lightweight span API with per-request trace ids and a Chrome
    ``trace_event`` JSON exporter (perfetto-loadable). Disabled spans
    are allocation-free no-ops; attrs are lazily evaluated.
:mod:`repro.obs.metrics`
    Process-global registry of counters / gauges / histograms
    (p50/p95/p99) replacing the ad-hoc stat dicts; ``Server.stats()``
    snapshots it read-only and ``serve --metrics-dump`` renders it.
:mod:`repro.obs.timeline`
    Per-core, per-cycle timelines (issue / stall / barrier, SEND/RECV
    markers, NoC link occupancy) of the multi-core lockstep simulator,
    exported into the same Chrome trace on a virtual cycles clock.

Quick use::

    from repro import obs
    tracer = obs.trace.install()             # start recording spans
    ... serve requests ...
    obs.trace.write_chrome_trace("out.json", tracer)
    print(obs.metrics.dump())
"""
from . import metrics, timeline, trace
from .metrics import REGISTRY
from .trace import active, install, instant, span, uninstall

__all__ = ["trace", "metrics", "timeline", "REGISTRY",
           "span", "instant", "install", "uninstall", "active"]
