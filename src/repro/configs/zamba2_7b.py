"""Zamba2-7B [hybrid] — 81L d3584, Mamba2 backbone (ssm_state=64) with a
shared attention block (32H MHA kv=32, d_ff 14336) applied every 6 mamba
layers (13 applications + 3 tail layers). Sub-quadratic prefix: runs
long_500k. [arXiv:2411.15242; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, head_dim=112,
    d_ff=14336, vocab=32000, rope_theta=10_000.0,
    ssm_state=64, ssm_expand=2, ssm_headdim=64, ssm_chunk=256,
    conv_width=4, attn_every=6, sub_quadratic=True,
    notes="Zamba2 embedding-concat + per-application LoRA simplified away "
          "(DESIGN.md §4)",
)

SMOKE = ArchConfig(
    name="zamba2-smoke", family="hybrid",
    n_layers=7, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=256, ssm_state=16, ssm_expand=2, ssm_headdim=16,
    ssm_chunk=8, conv_width=4, attn_every=3, sub_quadratic=True,
)
