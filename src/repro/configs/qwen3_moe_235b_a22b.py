"""Qwen3-MoE 235B-A22B [moe] — 94L d4096 64H (GQA kv=4) expert-ff 1536,
vocab 151936, 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B family; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
    d_ff=1536, vocab=151936, rope_theta=1_000_000.0,
    n_experts=128, top_k=8, moe_group_size=2048,
    notes="MoE SwiGLU experts; expert d_ff=1536 per assignment",
)

SMOKE = ArchConfig(
    name="qwen3-moe-smoke", family="moe",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=64, vocab=512, rope_theta=1_000_000.0,
    n_experts=8, top_k=2, moe_group_size=64,
)
