"""Granite-3.0 1B-A400M [moe] — 24L d1024 16H (GQA kv=8) expert-ff 512,
vocab 49155, 32 experts top-8. [hf:ibm-granite/granite-3.0-1b-a400m-base]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab=49155, rope_theta=10_000.0, tie_embeddings=True,
    n_experts=32, top_k=8, moe_group_size=1024,
)

SMOKE = ArchConfig(
    name="granite-moe-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=32, vocab=256, tie_embeddings=True,
    n_experts=8, top_k=2, moe_group_size=64,
)
