"""Architecture + shape registries.

Every assigned architecture is a frozen :class:`ArchConfig`; the four
assigned input shapes are :class:`ShapeConfig` entries. ``registry()``
maps ``--arch`` ids to configs; each ``configs/<id>.py`` defines ``CONFIG``
(full geometry) and ``SMOKE`` (reduced same-family geometry for CPU tests).
"""
from __future__ import annotations

import dataclasses
import importlib


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | encdec | ssm | hybrid | vlm
    n_layers: int
    d_model: int
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab: int = 0
    qkv_bias: bool = False
    attn_out_bias: bool = False
    mlp_bias: bool = False
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    rope_theta: float | None = 10000.0
    act: str = "swiglu"             # swiglu | gelu
    parallel_block: bool = False    # attn+FFN in parallel (command-r)
    tie_embeddings: bool = False
    logit_softcap: float | None = None
    # moe
    n_experts: int = 0
    top_k: int = 0
    moe_group_size: int = 1024
    moe_impl: str = "dense"         # dense (EP) | ragged (serving)
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01
    # ssm (mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 256
    conv_width: int = 4
    # hybrid (zamba2): shared attn+MLP block applied every N ssm layers
    attn_every: int = 0
    # encdec (whisper)
    n_enc_layers: int = 0
    enc_ctx: int = 1500             # audio frames after the (stubbed) conv frontend
    # vlm (internvl2)
    n_img_tokens: int = 0           # patch embeddings prepended to the text
    # capability flags
    sub_quadratic: bool = False     # may run long_500k
    has_decoder: bool = True        # encoder-only archs skip decode shapes
    notes: str = ""

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = [
    "qwen3-moe-235b-a22b", "granite-moe-1b-a400m", "internvl2-2b",
    "command-r-plus-104b", "starcoder2-7b", "qwen2-0.5b", "glm4-9b",
    "whisper-medium", "mamba2-780m", "zamba2-7b",
]


def _module(arch_id: str):
    return importlib.import_module(
        f"repro.configs.{arch_id.replace('-', '_').replace('.', '_')}")


def get_config(arch_id: str) -> ArchConfig:
    return _module(arch_id).CONFIG


def get_smoke_config(arch_id: str) -> ArchConfig:
    return _module(arch_id).SMOKE


def registry() -> dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def applicable_shapes(cfg: ArchConfig) -> list[str]:
    """The assigned shape cells this architecture runs (DESIGN.md §4)."""
    out = ["train_4k", "prefill_32k"]
    if cfg.has_decoder:
        out.append("decode_32k")
        if cfg.sub_quadratic:
            out.append("long_500k")
    return out
