"""InternVL2-2B [vlm] — InternLM2-1.8B backbone: 24L d2048 16H (GQA kv=8)
d_ff 8192, vocab 92553; InternViT frontend STUBBED to precomputed patch
embeddings (256 tokens after pixel-shuffle). [arXiv:2404.16821; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab=92553, rope_theta=1_000_000.0,
    n_img_tokens=256,
    notes="ViT tower stubbed: input_specs feeds (B,256,2048) patch embeds",
)

SMOKE = ArchConfig(
    name="internvl2-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256, n_img_tokens=8,
)
