from .base import (ARCH_IDS, SHAPES, ArchConfig, ShapeConfig,
                   applicable_shapes, get_config, get_smoke_config, registry)

__all__ = ["ARCH_IDS", "SHAPES", "ArchConfig", "ShapeConfig",
           "applicable_shapes", "get_config", "get_smoke_config", "registry"]
