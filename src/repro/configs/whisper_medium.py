"""Whisper-medium [audio/encdec] — 24L enc + 24L dec, d1024 16H (MHA)
d_ff 4096, vocab 51865; conv frontend STUBBED to precomputed frame
embeddings (1500 frames). [arXiv:2212.04356; unverified]

The assignment's "24L" is read as 24 encoder + 24 decoder layers (the
actual whisper-medium geometry); noted in DESIGN.md §4.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium", family="encdec",
    n_layers=24, n_enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    head_dim=64, d_ff=4096, vocab=51865, norm="layernorm", act="gelu",
    qkv_bias=True, rope_theta=None, enc_ctx=1500, tie_embeddings=True,
    notes="conv frontend stubbed: input_specs feeds (B,1500,1024) frames",
)

SMOKE = ArchConfig(
    name="whisper-smoke", family="encdec",
    n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    head_dim=16, d_ff=128, vocab=256, norm="layernorm", act="gelu",
    qkv_bias=True, rope_theta=None, enc_ctx=32, tie_embeddings=True,
)
