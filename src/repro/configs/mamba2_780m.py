"""Mamba2-780M [ssm] — 48L d1536, attention-free SSD (state-space
duality), ssm_state=128, vocab 50280. Sub-quadratic: runs long_500k.
[arXiv:2405.21060; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, d_ff=0, vocab=50280,
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_chunk=256,
    conv_width=4, sub_quadratic=True,
)

SMOKE = ArchConfig(
    name="mamba2-smoke", family="ssm",
    n_layers=2, d_model=64, d_ff=0, vocab=256,
    ssm_state=16, ssm_expand=2, ssm_headdim=16, ssm_chunk=8,
    conv_width=4, sub_quadratic=True,
)
