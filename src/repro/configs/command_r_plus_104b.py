"""Command R+ 104B [dense] — 64L d12288 96H (GQA kv=8) d_ff 33792,
vocab 256000, parallel attn+FFN blocks, LayerNorm, no biases, tied
embeddings. [hf:CohereForAI/c4ai-command-r-plus family; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="command-r-plus-104b", family="dense",
    n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8, head_dim=128,
    d_ff=33792, vocab=256000, norm="layernorm", rope_theta=75_000_000.0,
    parallel_block=True, tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="command-r-smoke", family="dense",
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, head_dim=16,
    d_ff=256, vocab=512, norm="layernorm", parallel_block=True,
    tie_embeddings=True,
)
