"""StarCoder2-7B [dense] — 32L d4608 36H (GQA kv=4) d_ff 18432,
vocab 49152, GELU MLP with biases, LayerNorm, RoPE, QKV bias.
[arXiv:2402.19173; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-7b", family="dense",
    n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4, head_dim=128,
    d_ff=18432, vocab=49152, norm="layernorm", act="gelu",
    qkv_bias=True, attn_out_bias=True, mlp_bias=True,
    rope_theta=100_000.0,
)

SMOKE = ArchConfig(
    name="starcoder2-smoke", family="dense",
    n_layers=2, d_model=72, n_heads=6, n_kv_heads=2, head_dim=12,
    d_ff=288, vocab=256, norm="layernorm", act="gelu",
    qkv_bias=True, attn_out_bias=True, mlp_bias=True,
)
