"""GLM-4 9B [dense] — 40L d4096 32H (GQA kv=2) d_ff 13696, vocab 151552,
QKV bias, RoPE. [hf:THUDM/glm-4-9b; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="glm4-9b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2, head_dim=128,
    d_ff=13696, vocab=151552, qkv_bias=True, rope_theta=10_000.0,
    notes="GLM4 partial-rotary (0.5) approximated as full rotary",
)

SMOKE = ArchConfig(
    name="glm4-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
    d_ff=192, vocab=256, qkv_bias=True,
)
