"""Group decomposition of SPN op graphs (paper fig. 2a).

Nodes in a *group* (topological level) are mutually independent, so they can
execute on any thread / PE / vector lane without synchronization; barriers
are only needed between groups.  This is the scheduling substrate shared by
the paper's GPU baseline, the custom processor compiler and the TPU
executors.
"""
from __future__ import annotations

import numpy as np


def op_levels(b: np.ndarray, c: np.ndarray, m: int) -> np.ndarray:
    """ASAP level of each binary op.

    Ops are indexed 0..n-1 producing slots m..m+n-1; operands ``b``/``c``
    reference earlier slots (leaf slots < m are level 0).
    """
    n = len(b)
    lvl = np.zeros(n, dtype=np.int32)
    for i in range(n):
        lb = lvl[b[i] - m] if b[i] >= m else 0
        lc = lvl[c[i] - m] if c[i] >= m else 0
        lvl[i] = max(lb, lc) + 1
    return lvl


def alap_levels(b: np.ndarray, c: np.ndarray, m: int, n_levels: int | None = None) -> np.ndarray:
    """ALAP level of each op (latest level that still meets dependents)."""
    n = len(b)
    asap = op_levels(b, c, m)
    depth = int(asap.max()) if n else 0
    n_levels = depth if n_levels is None else n_levels
    alap = np.full(n, n_levels, dtype=np.int32)
    for i in range(n - 1, -1, -1):
        for o in (b[i], c[i]):
            if o >= m:
                alap[o - m] = min(alap[o - m], alap[i] - 1)
    return alap


def level_sort(b: np.ndarray, c: np.ndarray, m: int):
    """Renumber ops so each level's outputs occupy contiguous slots.

    Returns ``(perm, new_b, new_c, level_offsets)`` where ``perm[j]`` is the
    original op index of the new op ``j`` and ``level_offsets`` has length
    ``num_levels+1`` delimiting ops per level in the new order.
    """
    n = len(b)
    lvl = op_levels(b, c, m)
    perm = np.argsort(lvl, kind="stable").astype(np.int32)
    # old slot -> new slot
    new_slot_of_old = np.empty(n, dtype=np.int64)
    new_slot_of_old[perm] = np.arange(n)
    remap = lambda x: np.where(x >= m, new_slot_of_old[np.maximum(x - m, 0)] + m, x)
    new_b = remap(b[perm]).astype(np.int32)
    new_c = remap(c[perm]).astype(np.int32)
    sorted_lvl = lvl[perm]
    num_levels = int(sorted_lvl.max()) if n else 0
    # ops are level 1..num_levels (leaves occupy level 0); one range per level
    offsets = np.searchsorted(sorted_lvl, np.arange(2, num_levels + 2)).astype(np.int32)
    offsets = np.concatenate([[0], offsets]).astype(np.int32)
    return perm, new_b, new_c, offsets
