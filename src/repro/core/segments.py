"""Segment scheduler: opcode-homogeneous, tile-aligned level segments
with fused n-ary reductions (the REASON / custom-processor schedule).

The binary :class:`~repro.core.program.TensorProgram` interleaves
sum/prod/max rows inside every level, so every vectorized executor used
to resolve the opcode *per element* with ``where``-select chains — each
level paid for all three semiring ops plus two selects. The paper's
datapath does the opposite: each step executes ONE homogeneous operation
across a PE group. This module rewrites the program into that form:

1. **N-ary fusion** — the balanced binary reduction trees that
   :func:`repro.core.program.lower` emits for k-ary sum/product/max
   nodes are detected (same opcode, every interior value consumed
   exactly once, shape-verified against :func:`balanced_reduce`'s
   pairing) and collapsed into single *fused nodes* of arity k: one
   ``SUM_N``/``PROD_N``/``MAX_N`` segmented reduction instead of k-1
   predicated binary ops.
2. **Opcode-homogeneous segments** — fused nodes are levelized over the
   fused dependence graph and, within a level, grouped into contiguous
   *segments* of equal opcode and equal padded arity, described by a
   ``(seg_off, arity, op)`` descriptor table. An executor runs one
   unpredicated vector ufunc per halving step per segment — no masks,
   no ``where``.
3. **Tile alignment** — every level's output block starts 8-aligned and
   is padded to a multiple of 8 slots with neutral dummy nodes, so the
   Pallas kernel can consume the representation directly (f32 sublane
   tile = 8) and slot ranges stay friendly for every vector ISA.

Bit-exactness invariant
-----------------------
The fused execution is **bit-identical** to the binary program (hence to
the numpy oracle, at matching precision). Two facts make this work:

- a balanced bottom-up pairwise reduction over ``k`` operands equals the
  same reduction over the operands padded to ``2^d`` with the op's
  neutral element (``x op neutral == x`` exactly in IEEE arithmetic, and
  the trailing neutrals reproduce the "odd leftover carried" behaviour
  of :func:`~repro.core.program.lower`'s ``balanced_reduce``);
- laying the ``2^d`` operands out in **bit-reversed position order**
  (position-major, nodes minor) turns every halving step into a
  contiguous split — ``op(G[:h], G[h:])`` — pairing exactly the adjacent
  operands the binary tree paired, with no strided access and no gather
  beyond the initial one.

Groups whose tree shape does not match ``balanced_reduce`` (hand-built
programs, exotic rewrites) are conservatively split back into arity-2
fused nodes, which are trivially exact.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .program import OP_MAX, OP_PROD, OP_SUM, TensorProgram

SUBLANE = 8        # f32 sublane tile: level offsets/widths are 8-aligned

#: display names of the fused n-ary opcodes (same numeric codes as the
#: binary ops — arity lives in the segment descriptor, not the opcode)
NARY_NAMES = {OP_SUM: "SUM_N", OP_PROD: "PROD_N", OP_MAX: "MAX_N"}


def _round_up(x: int, k: int) -> int:
    return (x + k - 1) // k * k


def _bitrev(d: int) -> np.ndarray:
    """Bit-reversal permutation of ``[0, 2**d)``."""
    r = np.arange(1 << d)
    out = np.zeros_like(r)
    for i in range(d):
        out = (out << 1) | ((r >> i) & 1)
    return out


def neutral_values(log_domain: bool) -> np.ndarray:
    """(3,) neutral element per opcode (index = OP_*), float64.

    ``x op neutral == x`` bit-exactly: 0/-inf for SUM (linear/log),
    1/0 for PROD, -inf for MAX in both domains (log is monotone).
    """
    out = np.empty(3, np.float64)
    out[OP_SUM] = -np.inf if log_domain else 0.0
    out[OP_PROD] = 0.0 if log_domain else 1.0
    out[OP_MAX] = -np.inf
    return out


# --------------------------------------------------------------------------- #
# fusion-group detection
# --------------------------------------------------------------------------- #
def _balanced_shape(k: int):
    """Pairing tree ``balanced_reduce`` builds over ``k`` leaf tokens."""
    items: list = list(range(k))
    while len(items) > 1:
        nxt = [(items[i], items[i + 1]) for i in range(0, len(items) - 1, 2)]
        if len(items) % 2:
            nxt.append(items[-1])
        items = nxt
    return items[0]


@dataclasses.dataclass
class FusionInfo:
    """Per-op fusion structure of a binary program.

    ``root_of[i]`` is the *balanced-decomposed* fused-node root of op
    ``i`` — the unit the segment scheduler executes as one n-ary
    reduction. ``leaves[r]`` lists fused node ``r``'s operand slots in
    the binary tree's left-to-right order (original slot numbering; a
    slot ``>= m`` names another fused node by its root op).

    ``parent[i]`` is the *raw* same-opcode single-consumer chain (-1
    where it stops) — a superset of the balanced decomposition, used by
    the VLIW compiler, whose tree bundles don't need balanced shapes.
    """
    root_of: np.ndarray
    parent: np.ndarray
    leaves: dict[int, list[int]]

    def group_arity(self, r: int) -> int:
        return len(self.leaves[r])


def fusion_info(prog: TensorProgram,
                max_arity: int | None = None) -> FusionInfo:
    """Detect maximal fusable reduction trees of ``prog``.

    An op joins its consumer's chain when they share an opcode and the
    op's value is consumed exactly once (interior values of a reduction
    tree never escape). Each maximal chain tree is then *decomposed into
    maximal balanced subtrees* — only subtrees whose pairing matches
    :func:`_balanced_shape` (the shape ``lower()``'s ``balanced_reduce``
    emits) become n-ary fused nodes, so halving execution is
    bit-identical to the binary program; the glue ops above them (e.g.
    where a sum-of-sums chain merged two original SPN nodes) become
    small fused nodes over the sub-results.

    ``max_arity`` (autotuning knob) caps a fused node's operand count:
    wider trees split into their child subtrees recursively. Splitting
    is always bit-exact (the subtrees of a balanced tree pair the same
    operands), it only changes the *granularity* — the multicore
    partitioner places fused nodes whole, so a cap lets it cut inside
    what would otherwise be an unsplittable wide reduction.
    """
    # memoized on the program instance (not a module-level cache) so the
    # analysis dies with its program — a long-lived server churning many
    # SPNs must not pin every one it ever saw; capped variants live in a
    # small per-program dict keyed by the cap
    if max_arity is None:
        cached = getattr(prog, "_fusion_info", None)
        if cached is not None:
            return cached
    else:
        max_arity = int(max_arity)
        if max_arity < 2:
            raise ValueError(f"max_arity must be >= 2, got {max_arity}")
        cached = getattr(prog, "_fusion_info_capped", {}).get(max_arity)
        if cached is not None:
            return cached
    m, n = prog.m, prog.n_ops
    b, c, opcode = prog.b, prog.c, prog.opcode
    refcnt = np.zeros(m + n, np.int64)
    consumer = np.full(m + n, -1, np.int64)
    for i in range(n):
        for s in (int(b[i]), int(c[i])):
            refcnt[s] += 1
            consumer[s] = i
    refcnt[prog.root_slot] += 1   # the epilogue read pins the root op
    if prog.root_slots is not None:
        for s in prog.root_slots:   # multi-root: every root is pinned
            refcnt[int(s)] += 1

    parent = np.full(n, -1, np.int64)
    chain_root = np.arange(n, dtype=np.int64)
    # ops are level-sorted, so a consumer always has a larger index:
    # scanning downward sees the parent's root before the child's
    for i in range(n - 1, -1, -1):
        if refcnt[m + i] == 1 and consumer[m + i] >= 0:
            p = int(consumer[m + i])
            if opcode[p] == opcode[i]:
                parent[i] = p
                chain_root[i] = chain_root[p]

    members: dict[int, list[int]] = {}
    for i in range(n):
        members.setdefault(int(chain_root[i]), []).append(i)

    root_of = np.arange(n, dtype=np.int64)
    leaves: dict[int, list[int]] = {}
    for r, mem in members.items():
        memset = set(mem)

        def in_order(op: int, lv: list[int], interior: list[int]):
            kids = []
            interior.append(op)
            for s in (int(b[op]), int(c[op])):
                if s >= m and (s - m) in memset:
                    kids.append(in_order(s - m, lv, interior))
                else:
                    kids.append(len(lv))
                    lv.append(int(s))
            return (kids[0], kids[1])

        def build(op: int) -> None:
            lv: list[int] = []
            interior: list[int] = []
            tree = in_order(op, lv, interior)
            if (max_arity is None or len(lv) <= max_arity) \
                    and tree == _balanced_shape(len(lv)):
                leaves[op] = lv
                for j in interior:
                    root_of[j] = op
                return
            # unbalanced at this root: split into the two child subtrees
            kids = []
            for s in (int(b[op]), int(c[op])):
                if s >= m and (s - m) in memset:
                    build(s - m)
                    kids.append(int(s))   # refer to the sub-node's output
                else:
                    kids.append(int(s))
            leaves[op] = kids
            root_of[op] = op

        build(r)
    info = FusionInfo(root_of=root_of, parent=parent, leaves=leaves)
    if max_arity is None:
        prog._fusion_info = info
    else:
        if not hasattr(prog, "_fusion_info_capped"):
            prog._fusion_info_capped = {}
        prog._fusion_info_capped[max_arity] = info
    return info


# --------------------------------------------------------------------------- #
# the segmented program
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(eq=False)   # identity hash: static jit arg
class SegmentedProgram:
    """Opcode-homogeneous, tile-aligned segment schedule of a program.

    Slot layout (all executors share it):

    - ``[0, m)``              : leaf slots (indicators + parameters),
    - ``[m, m+3)``            : neutral pad slots, index = opcode,
    - ``[m+3, node_base)``    : dead alignment slots,
    - ``[node_base, num_slots)``: fused-node outputs, level-contiguous;
      each level's block starts 8-aligned and spans a multiple of 8
      slots (trailing slots produced by neutral dummy nodes).

    Segments are contiguous runs of nodes with one ``(op, arity)``; the
    descriptor table is the ``(seg_off, seg_arity, seg_op)`` columns plus
    the derived output offsets. The gather stream holds each segment's
    operand slots position-major in bit-reversed order (see module doc),
    padded to the segment arity with the op's neutral pad slot.
    """
    base: TensorProgram
    m: int                       # leaf slots (== base.m)
    node_base: int               # 8-aligned first fused-node output slot
    num_slots: int               # 8-aligned total
    gather: np.ndarray           # (G,) int32 operand slot stream
    seg_off: np.ndarray          # (S,) int32 gather offset per segment
    seg_op: np.ndarray           # (S,) uint8 opcode per segment
    seg_arity: np.ndarray        # (S,) int32 padded (power-of-two) arity
    seg_nodes: np.ndarray        # (S,) int32 node count (incl. dummies)
    seg_out: np.ndarray          # (S,) int32 output slot of node 0
    level_offsets: np.ndarray    # (L+1,) int32 segment ranges per level
    root_slot: int
    n_nodes: int                 # real fused nodes (excluding dummies)
    n_pad_nodes: int             # alignment dummy nodes

    @property
    def num_segments(self) -> int:
        return len(self.seg_op)

    @property
    def num_levels(self) -> int:
        return len(self.level_offsets) - 1

    @property
    def pad_slots(self) -> np.ndarray:
        """(3,) neutral pad slot per opcode (index = OP_*)."""
        return np.arange(self.m, self.m + 3, dtype=np.int32)

    def level_out_range(self, level: int) -> tuple[int, int]:
        """Output slot range ``[lo, hi)`` of a level (both 8-aligned)."""
        s0, s1 = int(self.level_offsets[level]), int(self.level_offsets[level + 1])
        lo = int(self.seg_out[s0])
        hi = int(self.seg_out[s1 - 1] + self.seg_nodes[s1 - 1])
        return lo, hi

    def init_rows(self, log_domain: bool) -> np.ndarray:
        """(node_base,) float64 initial values of the non-node slot rows:
        zeros for leaves (overwritten per request) and alignment slots,
        the domain's neutral elements in the three pad rows."""
        rows = np.zeros(self.node_base, np.float64)
        rows[self.m: self.m + 3] = neutral_values(log_domain)
        return rows

    def stats(self) -> dict:
        """Descriptor-level stats (recorded in artifact/bench metadata)."""
        return {
            "levels": self.num_levels,
            "segments": self.num_segments,
            "n_nodes": self.n_nodes,
            "pad_nodes": self.n_pad_nodes,
            "max_arity": int(self.seg_arity.max()),
            "binary_levels": self.base.num_levels,
            "binary_ops": self.base.n_ops,
        }


def segment_program(prog: TensorProgram) -> SegmentedProgram:
    """Build the segment schedule of ``prog``.

    Memoized on the program instance, so the schedule's lifetime is its
    program's lifetime (no global cache pinning evicted programs).
    """
    cached = getattr(prog, "_segments", None)
    if cached is not None:
        return cached
    m = prog.m
    info = fusion_info(prog)
    roots = sorted(info.leaves)            # ascending = topological
    node_of_root = {r: j for j, r in enumerate(roots)}

    # fused-graph levelization ------------------------------------------------
    lvl_of_node = np.zeros(len(roots), np.int64)
    for j, r in enumerate(roots):
        lv = 0
        for s in info.leaves[r]:
            if s >= m:
                lv = max(lv, int(lvl_of_node[node_of_root[int(info.root_of[s - m])]]))
        lvl_of_node[j] = lv + 1
    num_levels = int(lvl_of_node.max()) if len(roots) else 0

    # slot numbering: leaves, pads, alignment, then level blocks --------------
    node_base = _round_up(m + 3, SUBLANE)
    pad_slot = np.arange(m, m + 3, dtype=np.int64)

    # order nodes by (level, opcode, padded arity) so segments are contiguous
    arity = np.array([len(info.leaves[r]) for r in roots], np.int64)
    pow2 = np.array([1 << (int(a) - 1).bit_length() for a in arity], np.int64)
    ops = np.array([prog.opcode[r] for r in roots], np.uint8)
    order = np.lexsort((pow2, ops, lvl_of_node))

    slot_of_node = np.empty(len(roots), np.int64)
    gather: list[np.ndarray] = []
    seg_off: list[int] = []
    seg_op: list[int] = []
    seg_arity: list[int] = []
    seg_nodes: list[int] = []
    seg_out: list[int] = []
    level_offsets = [0]
    goff = 0
    out = node_base
    n_pad_nodes = 0

    def slot_of(s: int) -> int:
        """Operand slot in the new numbering (leaf or fused-node output)."""
        if s < m:
            return s
        return int(slot_of_node[node_of_root[int(info.root_of[s - m])]])

    pos = 0
    for level in range(1, num_levels + 1):
        idx = [int(j) for j in order if lvl_of_node[j] == level]
        level_start = out
        # contiguous (op, arity) runs inside the level
        run_start = 0
        runs: list[list[int]] = []
        for k in range(1, len(idx) + 1):
            if (k == len(idx) or ops[idx[k]] != ops[idx[run_start]]
                    or pow2[idx[k]] != pow2[idx[run_start]]):
                runs.append(idx[run_start:k])
                run_start = k
        for run_i, run in enumerate(runs):
            o = int(ops[run[0]])
            A = int(pow2[run[0]])
            d = A.bit_length() - 1
            ns = len(run)
            # the level's last segment absorbs the 8-alignment dummies
            pad_nodes = 0
            if run_i == len(runs) - 1:
                width = (out - level_start) + ns
                pad_nodes = _round_up(width, SUBLANE) - width
            rev = _bitrev(d)
            block = np.full((A, ns + pad_nodes), pad_slot[o], np.int64)
            for col, j in enumerate(run):
                lv = info.leaves[roots[j]]
                src = np.full(A, pad_slot[o], np.int64)
                src[: len(lv)] = [slot_of(s) for s in lv]
                block[:, col] = src[rev]
                slot_of_node[j] = out + col
            gather.append(block.reshape(-1))
            seg_off.append(goff)
            seg_op.append(o)
            seg_arity.append(A)
            seg_nodes.append(ns + pad_nodes)
            seg_out.append(out)
            goff += block.size
            out += ns + pad_nodes
            n_pad_nodes += pad_nodes
            pos += 1
        level_offsets.append(pos)

    root_op = prog.root_slot - m
    assert root_op >= 0, "lower() always emits at least one op"
    root_slot = int(slot_of_node[node_of_root[int(info.root_of[root_op])]])

    seg = SegmentedProgram(
        base=prog, m=m, node_base=node_base, num_slots=out,
        gather=(np.concatenate(gather) if gather
                else np.zeros(0, np.int64)).astype(np.int32),
        seg_off=np.asarray(seg_off, np.int32),
        seg_op=np.asarray(seg_op, np.uint8),
        seg_arity=np.asarray(seg_arity, np.int32),
        seg_nodes=np.asarray(seg_nodes, np.int32),
        seg_out=np.asarray(seg_out, np.int32),
        level_offsets=np.asarray(level_offsets, np.int32),
        root_slot=root_slot,
        n_nodes=len(roots), n_pad_nodes=n_pad_nodes)
    validate(seg)
    prog._segments = seg
    return seg


def validate(seg: SegmentedProgram) -> None:
    """Structural invariants every consumer relies on."""
    assert seg.node_base % SUBLANE == 0 and seg.num_slots % SUBLANE == 0
    assert (seg.seg_arity >= 2).all()
    assert ((seg.seg_arity & (seg.seg_arity - 1)) == 0).all(), "arity pow2"
    goff = 0
    out = seg.node_base
    for s in range(seg.num_segments):
        assert int(seg.seg_off[s]) == goff, "gather stream is contiguous"
        assert int(seg.seg_out[s]) == out, "node outputs are contiguous"
        goff += int(seg.seg_arity[s]) * int(seg.seg_nodes[s])
        out += int(seg.seg_nodes[s])
    assert goff == len(seg.gather) and out == seg.num_slots
    for level in range(seg.num_levels):
        lo, hi = seg.level_out_range(level)
        assert lo % SUBLANE == 0 and hi % SUBLANE == 0, "8-aligned levels"
        s0, s1 = int(seg.level_offsets[level]), int(seg.level_offsets[level + 1])
        for s in range(s0, s1):
            g0 = int(seg.seg_off[s])
            g1 = g0 + int(seg.seg_arity[s]) * int(seg.seg_nodes[s])
            assert (seg.gather[g0:g1] < lo).all(), "operands from the past"
    assert seg.node_base <= seg.root_slot < seg.num_slots


# --------------------------------------------------------------------------- #
# the one halving-reduction rule every substrate shares
# --------------------------------------------------------------------------- #
def combine_fn(op: int, log_domain: bool, xp, logaddexp=None):
    """Elementwise combine of one segment opcode in one domain.

    ``xp`` is the array namespace (numpy or jax.numpy); ``logaddexp``
    overrides ``xp.logaddexp`` where a substrate needs its own stable
    implementation (the Pallas kernel's Mosaic-safe one). Keeping this
    resolution in one place is what keeps every substrate pairing and
    combining operands identically — the bit-exactness contract.
    """
    if op == OP_PROD:
        return (lambda a, b: a + b) if log_domain else (lambda a, b: a * b)
    if op == OP_MAX:
        return xp.maximum
    if log_domain:
        return logaddexp if logaddexp is not None else xp.logaddexp
    return lambda a, b: a + b


def halving_reduce(vals, combine, n_nodes: int):
    """Reduce ``(arity * n_nodes, batch)`` segment operand rows to
    ``(n_nodes, batch)`` by repeated contiguous halving.

    Correct ONLY on the bit-reversed position-major layout
    :func:`segment_program` emits — each split pairs exactly the
    adjacent operands the original binary tree paired.
    """
    while vals.shape[0] > n_nodes:
        h = vals.shape[0] // 2
        vals = combine(vals[:h], vals[h:])
    return vals


# --------------------------------------------------------------------------- #
# float64 reference executor (the parity anchor for every substrate)
# --------------------------------------------------------------------------- #
def eval_segmented_numpy(seg: SegmentedProgram, leaf_ind: np.ndarray,
                         log_domain: bool = False) -> np.ndarray:
    """Float64 segmented evaluation; bit-identical to
    :func:`repro.core.executors.eval_ops_numpy` on the base program.

    ``leaf_ind``: (batch, m_ind) indicator values → (batch,) root values.
    """
    prog = seg.base
    leaf_ind = np.atleast_2d(np.asarray(leaf_ind, np.float64))
    batch = leaf_ind.shape[0]
    A = np.zeros((seg.num_slots, batch), np.float64)
    A[: prog.m_ind] = leaf_ind.T
    A[prog.m_ind: prog.m] = prog.param_values[:, None]
    if log_domain:
        with np.errstate(divide="ignore"):
            A[: prog.m] = np.log(A[: prog.m])
    A[seg.m: seg.node_base] = seg.init_rows(log_domain)[seg.m:, None]
    with np.errstate(invalid="ignore"):
        for s in range(seg.num_segments):
            g0 = int(seg.seg_off[s])
            ns = int(seg.seg_nodes[s])
            G = A[seg.gather[g0: g0 + int(seg.seg_arity[s]) * ns]]
            G = halving_reduce(
                G, combine_fn(int(seg.seg_op[s]), log_domain, np), ns)
            out = int(seg.seg_out[s])
            A[out: out + ns] = G
    return A[seg.root_slot]
