"""End-to-end SPN → VLIW compilation (paper §IV "Compilation").

Cycle-by-cycle list scheduler implementing all four compiler duties named
by the paper:

1. *operation placement on PE trees* — greedy deepest-subtree bundle
   packing (:mod:`treepack`), so producer→consumer chains stay inside the
   datapath and skip the register file;
2. *register-bank allocation in tandem with placement* — a level-ℓ PE can
   only write its 2^ℓ private banks, so the writeback bank is chosen when
   the op is placed (balance + write-port feasibility at the commit cycle);
3. *RAW-hazard-aware reordering* — values become readable ``level``
   cycles after issue (pipelined trees); the ready/active machinery issues
   whatever independent work fits while dependents wait;
4. *careful spilling* — leaf rows stream in on demand into a reserved
   load region (prefetched in first-use order); full intermediate rows
   spill to data memory LRU-style when banks fill and reload on demand.

The register file is a compiler-managed resource: rows ``[0, load_region)``
stage vector loads (leaf inputs + reloads), rows ``[load_region, R)`` hold
per-bank allocated intermediates.

Multi-core programs (``comm`` argument, see
:mod:`repro.core.multicore`) add two compiler duties:

5. *communication scheduling* — cut values whose consumers live on other
   cores are pinned in registers until their shared-register-window row
   is complete, then flushed with a ``SEND`` on the network-interface
   port; remote values are ``RECV``-ed into load-region rows (window
   rows are re-readable, so eviction/reload works as for leaf rows);
6. *deadlock-freedom ordering* — an op reading remote values of
   producer binary level ``λ`` may only issue after every local send
   row of level ``≤ λ`` has issued. Channel rows are level-homogeneous,
   so this grading makes lockstep execution provably deadlock-free (a
   frozen core always awaits a strictly lower level than anything it
   still owes, and the minimal awaited level is always deliverable).
"""
from __future__ import annotations

import heapq
from collections import defaultdict

import numpy as np

from ..processor.config import ProcessorConfig
from ..program import OP_MAX, OP_PROD, OP_SUM, TensorProgram
from ..segments import fusion_info
from . import isa, regalloc, treepack

_NOWHERE, _MEM, _REG, _PENDING = 0, 1, 2, 3
_ALL_BANKS = -1  # write_res sentinel: vector load occupies every bank
_INF = 1 << 60

#: pseudo data-memory row space for interconnect channel rows — a recv
#: slot "lives" at row ``RECV_BASE + channel_row_id`` so the on-demand
#: load machinery (want/prefetch/evict/reload) applies unchanged
RECV_BASE = 1 << 20

# TensorProgram opcode -> PE opcode (the compiler is semiring-agnostic:
# scheduling only looks at the dependence structure, not the op identity)
_PE_OF_OPCODE = {OP_SUM: isa.PE_ADD, OP_PROD: isa.PE_MUL, OP_MAX: isa.PE_MAX}


class _Scheduler:
    def __init__(self, prog: TensorProgram, cfg: ProcessorConfig, *,
                 load_region: int, candidate_scan: int, max_cycles: int,
                 comm: isa.CommSpec | None = None, store_root: bool = True):
        self.prog, self.cfg = prog, cfg
        self.load_region = load_region
        self.candidate_scan = candidate_scan
        self.max_cycles = max_cycles
        self.comm = comm if comm is not None and not comm.empty else None
        self.store_root = store_root
        self.last_commit = 0
        m, n = prog.m, prog.n_ops
        self.m, self.n = m, n
        self.b, self.c, self.opcode = prog.b, prog.c, prog.opcode

        # static analysis ------------------------------------------------
        self.consumers: list[list[int]] = [[] for _ in range(m + n)]
        for i in range(n):
            self.consumers[self.b[i]].append(i)
            self.consumers[self.c[i]].append(i)
        self.refcnt = np.array([len(cs) for cs in self.consumers], np.int64)
        self.root_op = prog.root_slot - m
        assert self.root_op >= 0
        rs = getattr(prog, "root_slots", None)
        self.root_slots = ([int(s) for s in rs] if rs is not None
                           else [prog.root_slot])
        assert all(s >= m for s in self.root_slots)
        self.root_rows_used: set[int] = set()
        if store_root:
            for s in self.root_slots:
                self.refcnt[s] += 1      # epilogue store pins every root
        self.height = np.ones(n, np.int64)
        for j in range(n - 1, -1, -1):
            for s in (self.b[j], self.c[j]):
                if s >= m:
                    self.height[s - m] = max(self.height[s - m],
                                             self.height[j] + 1)
        if self.comm:
            # cut values: the critical path continues on the consumer
            # cores — schedule by the global height, not the local stub
            for i, h in self.comm.op_height.items():
                self.height[i] = max(self.height[i], h)
            for j in range(n - 1, -1, -1):
                for s in (self.b[j], self.c[j]):
                    if s >= m:
                        self.height[s - m] = max(self.height[s - m],
                                                 self.height[j] + 1)
        # segment scheduler's fusion chains: op -> same-opcode single
        # consumer (-1 where the chain stops). Bundle growth climbs these
        # chains directly, so a whole k-ary reduction issues as one
        # homogeneous tree bundle instead of being rediscovered op by op.
        self.fuse_parent = fusion_info(prog).parent
        # issue priority: height first (critical path), then the smaller
        # operand slot — ops of one segment share broadcast-friendly
        # operands (e.g. every weight-prod of one indicator leaf), so
        # clustering them in the scan coalesces crossbar reads of the
        # shared slot into a single bank address
        self.prio = [(-int(self.height[i]), int(min(self.b[i], self.c[i])))
                     for i in range(n)]

        # leaf layout ------------------------------------------------------
        recv_slots = self.comm.recv_slots if self.comm else {}
        fixed = {s: pos for s, (_row, pos) in recv_slots.items()}
        (self.leaf_bank, self.leaf_row, self.n_in_rows,
         self.images) = regalloc.layout_leaves(prog, cfg,
                                               fixed_banks=fixed or None)

        # value state ------------------------------------------------------
        self.state = np.zeros(m + n, np.int8)
        self.state[:m] = _MEM
        self.reg_of: dict[int, tuple[int, int]] = {}
        self.mem_of: dict[int, tuple[int, int]] = {
            s: (int(self.leaf_row[s]), int(self.leaf_bank[s]))
            for s in range(m) if s not in recv_slots}
        # recv slots live in window rows of the pseudo channel row space
        for s, (row, pos) in recv_slots.items():
            self.mem_of[s] = (RECV_BASE + row, pos)
        self.ready_cycle = np.full(m + n, 1 << 60, np.int64)

        # op readiness -----------------------------------------------------
        self.nmat = np.zeros(n, np.int32)
        self.issued = np.zeros(n, bool)
        self.ready_heap: list[tuple[int, int, int]] = []
        self.active: dict[int, int] = {}

        # load-region rows ---------------------------------------------------
        self.loaded_row_of: dict[int, int] = {}     # reg row -> mem row
        self.resident_mem_rows: set[int] = set()
        self.row_live: dict[int, int] = defaultdict(int)
        self.row_slots: dict[int, list[int]] = defaultdict(list)
        self.free_load_rows = list(range(load_region - 1, -1, -1))
        self.row_last_use: dict[int, int] = {}
        self.row_loaded_at: dict[int, int] = {}

        # data-memory rows ---------------------------------------------------
        self.mem_row_slots: dict[int, list[int]] = defaultdict(list)
        for s in range(m):
            self.mem_row_slots[self.mem_of[s][0]].append(s)
        self.mem_free_rows = list(range(cfg.data_mem_rows - 1,
                                        self.n_in_rows - 1, -1))
        self.want_rows: dict[int, int] = {}
        # leaf/window-row prefetch order: by first consuming op (recv rows
        # prefetch through the comm port, leaf rows through the mem port)
        first_use = {}
        for i in range(n):
            for s in (self.b[i], self.c[i]):
                if s < m:
                    r = self.mem_of[s][0]
                    if r not in first_use:
                        first_use[r] = i
        order = sorted(first_use, key=lambda r: first_use[r])
        self.prefetch = [r for r in order if r < RECV_BASE]
        self.prefetch_ptr = 0
        self.recv_prefetch = [r for r in order if r >= RECV_BASE]
        self.recv_prefetch_ptr = 0

        # communication state -------------------------------------------------
        # producer side: per channel row, remaining un-issued members,
        # latest member commit cycle, and the member -> (bank, reg) spec
        self.send_rows_of_op: dict[int, list] = {}
        self.row_members: dict[int, list] = {}       # row -> [(op, pos), ...]
        self.row_remaining: dict[int, int] = {}
        self.row_commit: dict[int, int] = {}
        self.send_ready: list[tuple[int, int, int]] = []   # (commit, lvl, row)
        self.send_pinned: set[int] = set()           # slots held for a send
        self.send_pin_count: dict[int, int] = defaultdict(int)
        self.unsent_level_count: dict[int, int] = defaultdict(int)
        self.send_specs: dict[int, list] = {}
        self.recv_level = {s: self.comm.row_level[row]
                           for s, (row, _pos) in recv_slots.items()} \
            if self.comm else {}
        # per-op gate level: the highest recv-row level among its operands
        # (-1 = no remote operand). Gated ops are skipped in the candidate
        # scan without consuming scan budget, or they would starve the
        # very ops whose sends will eventually unblock them.
        self.op_gate_level = np.full(n, -1, np.int64)
        for s, lvl in self.recv_level.items():
            for i in self.consumers[s]:
                self.op_gate_level[i] = max(self.op_gate_level[i], lvl)
        if self.comm:
            for op, entries in self.comm.send_ops.items():
                self.send_rows_of_op[op] = list(entries)
                # pin the value until every destination's send has issued
                self.refcnt[m + op] += len(entries)
                self.send_pinned.add(m + op)
                self.send_pin_count[m + op] = len(entries)
                for (row, pos) in entries:
                    self.row_members.setdefault(row, []).append((op, pos))
            for row, members in self.row_members.items():
                self.row_remaining[row] = len(members)
                self.row_commit[row] = 0
                self.unsent_level_count[self.comm.row_level[row]] += 1

        # intermediate registers ---------------------------------------------
        self.bank_free: list[list[int]] = [
            list(range(cfg.regs_per_bank - 1, load_region - 1, -1))
            for _ in range(cfg.banks)]
        self.cell_slot: dict[tuple[int, int], int] = {}
        self.write_res: dict[int, set[int]] = defaultdict(set)
        self.pending_rows: dict[int, int] = defaultdict(int)
        self.pending_heap: list[tuple[int, int]] = []   # (commit, reg row)

        self.instrs: list[isa.VLIWInstr] = []
        self.t = 0
        self.remaining = n
        self.stats = {"stall_cycles": 0, "loads": 0, "stores": 0,
                      "spills": 0, "evictions": 0, "max_live_regs": 0,
                      "bundles": 0, "bundle_ops": 0}

    # ---------------- value state helpers ------------------------------ #
    def readable(self, s: int) -> bool:
        # _PENDING becomes readable once its commit cycle has passed
        return (self.state[s] in (_REG, _PENDING)
                and self.ready_cycle[s] <= self.t)

    def mat(self, s: int) -> bool:
        return self.state[s] in (_REG, _PENDING)

    def try_enqueue(self, i: int) -> None:
        if self.issued[i] or self.nmat[i] < 2:
            return
        t_ready = max(self.ready_cycle[self.b[i]], self.ready_cycle[self.c[i]])
        heapq.heappush(self.ready_heap,
                       (int(t_ready), int(-self.height[i]), i))

    def mark_materialized(self, s: int, bank: int, reg: int, at: int) -> None:
        newly = not self.mat(s)
        self.state[s] = _PENDING if at > self.t else _REG
        self.reg_of[s] = (bank, reg)
        self.ready_cycle[s] = at
        if newly:
            for i in self.consumers[s]:
                if not self.issued[i]:
                    self.nmat[i] += 1
                    # one operand just arrived — pull the other from data
                    # memory if that is where it lives
                    other = int(self.c[i]) if int(self.b[i]) == s else int(self.b[i])
                    if self.state[other] == _MEM and self.refcnt[other] > 0:
                        self.want(other, int(self.height[i]))
                    self.try_enqueue(i)

    def unmaterialize(self, s: int) -> None:
        if not self.mat(s):
            return
        self.state[s] = _MEM if s in self.mem_of else _NOWHERE
        self.reg_of.pop(s, None)
        self.ready_cycle[s] = 1 << 60
        for i in self.consumers[s]:
            if not self.issued[i]:
                self.nmat[i] -= 1

    def free_cell(self, s: int) -> None:
        if s not in self.reg_of:
            self.state[s] = _NOWHERE if s not in self.mem_of else self.state[s]
            return
        bank, reg = self.reg_of.pop(s)
        if reg < self.load_region:
            self.row_live[reg] -= 1
        else:
            self.bank_free[bank].append(reg)
            self.cell_slot.pop((bank, reg), None)
        self.state[s] = _NOWHERE
        self.ready_cycle[s] = 1 << 60

    def want(self, s: int, prio: int) -> None:
        if s in self.mem_of and self.state[s] == _MEM:
            row = self.mem_of[s][0]
            if row not in self.resident_mem_rows:
                self.want_rows[row] = max(self.want_rows.get(row, -1), prio)

    # ---------------- communication -------------------------------------- #
    def _min_unsent_level(self) -> int:
        """Lowest producer level among this core's un-issued send rows."""
        levels = [lv for lv, cnt in self.unsent_level_count.items() if cnt]
        return min(levels) if levels else _INF

    def _recv_gated(self, slot: int) -> bool:
        """The deadlock-freedom rule: reading remote level-λ data requires
        all own send rows of level ≤ λ to have issued already."""
        lvl = self.recv_level.get(slot)
        return lvl is not None and self._min_unsent_level() <= lvl

    def _note_send_member_issued(self, op: int, commit: int) -> None:
        for (row, _pos) in self.send_rows_of_op.get(op, ()):
            self.row_commit[row] = max(self.row_commit[row], commit)
            self.row_remaining[row] -= 1
            if self.row_remaining[row] == 0:
                heapq.heappush(self.send_ready,
                               (self.row_commit[row],
                                self.comm.row_level[row], row))

    def pop_ready_send(self) -> isa.MemInstr | None:
        """Flush the lowest-level complete window row, if any."""
        ready: list[tuple[int, int]] = []
        while self.send_ready and self.send_ready[0][0] <= self.t:
            _, lvl, row = heapq.heappop(self.send_ready)
            if self.row_commit[row] > self.t:
                # a member moved banks since completion (copy) — its new
                # cell commits later; re-arm at the updated commit cycle
                heapq.heappush(self.send_ready,
                               (self.row_commit[row], lvl, row))
                continue
            ready.append((lvl, row))
        if not ready:
            return None
        ready.sort()
        lvl, row = ready[0]
        for (_l, r) in ready[1:]:      # push the rest back, commit passed
            heapq.heappush(self.send_ready, (self.t, _l, r))
        spec = []
        for (op, pos) in self.row_members[row]:
            bank, reg = self.reg_of[self.m + op]
            spec.append((pos, bank, reg))
        self.send_specs[row] = spec
        self.unsent_level_count[lvl] -= 1
        # release the pins; a value sent to every destination whose local
        # consumers are also done frees its register cell
        for (op, _pos) in self.row_members[row]:
            s = self.m + op
            self.send_pin_count[s] -= 1
            if self.send_pin_count[s] == 0:
                self.send_pinned.discard(s)
            self.refcnt[s] -= 1
            if self.refcnt[s] == 0:
                self.free_cell(s)
                self.refcnt[s] = -1
        self.stats["sends"] = self.stats.get("sends", 0) + 1
        return isa.MemInstr("send", row, -1)

    # ---------------- memory ops ---------------------------------------- #
    def evict_load_row(self) -> int | None:
        best, best_key = None, None
        for r, mrow in self.loaded_row_of.items():
            if self.pending_rows[r]:
                continue
            # a row loaded this or last cycle hasn't had a chance to feed
            # an issue yet — evicting it now is how two loads staging the
            # operands of ONE op thrash each other forever on machines
            # with a tiny load region
            if self.row_loaded_at.get(r, -(1 << 30)) >= self.t - 1:
                continue
            key = (self.row_live[r], self.row_last_use.get(r, -1))
            if best_key is None or key < best_key:
                best, best_key = r, key
        if best is None:
            return None
        for s in self.row_slots[best]:
            if self.reg_of.get(s, (None, None))[1] == best:
                self.unmaterialize(s)
        self.row_slots[best] = []
        self.row_live[best] = 0
        self.resident_mem_rows.discard(self.loaded_row_of.pop(best))
        self.stats["evictions"] += 1
        return best

    def issue_load(self, mrow: int) -> isa.MemInstr | None:
        is_recv = mrow >= RECV_BASE
        if mrow in self.resident_mem_rows:
            self.want_rows.pop(mrow, None)
            return None
        if not is_recv and self.write_res[self.t + 1]:
            return None   # vload writes every bank at t+1; recv rows land
            # through the window's dedicated fill port instead
        if self.free_load_rows:
            rrow = self.free_load_rows.pop()
        else:
            rrow = self.evict_load_row()
            if rrow is None:
                return None
        self.loaded_row_of[rrow] = mrow
        self.resident_mem_rows.add(mrow)
        self.row_loaded_at[rrow] = self.t
        self.last_commit = max(self.last_commit, self.t + 1)
        if not is_recv:
            self.write_res[self.t + 1].add(_ALL_BANKS)
        # recv rows become readable at max(landing, interconnect ETA):
        # scheduling consumers at the measured arrival converts lockstep
        # flow-control stalls into overlapped local work
        at = self.t + 1
        if is_recv:
            at = max(at, self.comm.row_eta.get(mrow - RECV_BASE, 0))
        live = 0
        for s in self.mem_row_slots[mrow]:
            if self.refcnt[s] > 0 and not self.mat(s):
                bank = self.mem_of[s][1]
                self.mark_materialized(s, bank, rrow, at)
                self.row_slots[rrow].append(s)
                live += 1
        self.row_live[rrow] = live
        self.want_rows.pop(mrow, None)
        if is_recv:
            self.stats["recvs"] = self.stats.get("recvs", 0) + 1
            return isa.MemInstr("recv", mrow - RECV_BASE, rrow)
        self.stats["loads"] += 1
        return isa.MemInstr("load", mrow, rrow)

    def spill_intermediate(self) -> isa.MemInstr | None:
        if not self.mem_free_rows:
            return None
        rows_use: dict[int, list[int]] = defaultdict(list)
        for (bank, reg), s in self.cell_slot.items():
            rows_use[reg].append(s)
        best, best_key = None, None
        for reg, slots in rows_use.items():
            if self.pending_rows[reg]:
                continue
            if any(self.ready_cycle[s] > self.t for s in slots):
                continue
            # values awaiting a SEND must stay in their register cells —
            # the window snapshots them when the row flushes
            if any(s in self.send_pinned for s in slots):
                continue
            key = self.row_last_use.get(reg, 0)
            if best_key is None or key < best_key:
                best, best_key = reg, key
        if best is None:
            return None
        mrow = self.mem_free_rows.pop()
        for s in list(rows_use[best]):
            bank, _ = self.reg_of[s]
            self.free_cell(s)
            self.unmaterialize(s)
            self.mem_of[s] = (mrow, bank)
            self.mem_row_slots[mrow].append(s)
            self.state[s] = _MEM
        self.stats["stores"] += 1
        self.stats["spills"] += 1
        return isa.MemInstr("store", mrow, best)

    # ---------------- bundle issue --------------------------------------- #
    def try_issue(self, op: int, tree: int, buddy: treepack.Buddy,
                  ti: isa.TreeInstr, reads_cycle: dict[int, int]):
        """Returns (issued op ids, pressure_flag)."""
        m = self.m
        maxd = buddy.max_depth()
        if maxd < 1:
            return [], False

        def incl(j: int) -> bool:
            return not self.issued[j]

        # segment-aware growth: climb the fusion chain first and try to
        # issue the whole homogeneous reduction (up to the chain's highest
        # un-issued ancestor) as one bundle — the paper's "one operation
        # per PE group per step". Falls back to growing from ``op`` when
        # the fused subtree exceeds the depth budget or operands of the
        # wider tree aren't readable yet.
        start = op
        while True:
            p = int(self.fuse_parent[start])
            if p < 0 or self.issued[p]:
                break
            start = p
        grown = treepack.grow(op, maxd, b=self.b, c=self.c, m=m,
                              readable=self.readable, includable=incl)
        if grown is None:
            # operand stuck in data memory? register a want so loads flow
            for s in (int(self.b[op]), int(self.c[op])):
                if self.state[s] == _MEM:
                    self.want(s, int(self.height[op]))
            return [], False
        # climb: deepest packable ancestor gives bigger bundles; keep the
        # whole history so crossbar/writeback conflicts can fall back to a
        # smaller bundle instead of deferring the op entirely
        history = [grown]
        cur = op
        if start != op:
            whole = treepack.grow(start, maxd, b=self.b, c=self.c, m=m,
                                  readable=self.readable, includable=incl)
            if whole is not None and (treepack.count_ops(whole[0])
                                      > treepack.count_ops(grown[0])):
                history.append(whole)
                cur = start
        improved = True
        while improved and history[-1][1] < maxd:
            improved = False
            for j in self.consumers[m + cur]:
                if self.issued[j]:
                    continue
                cand = treepack.grow(j, maxd, b=self.b, c=self.c, m=m,
                                     readable=self.readable, includable=incl)
                if cand and (treepack.count_ops(cand[0])
                             > treepack.count_ops(history[-1][0])):
                    history.append(cand)
                    cur = j
                    improved = True
                    break

        pressure_any = False
        for tree_dict, depth in reversed(history):
            res = self._attempt_bundle(tree_dict, max(depth, 1), tree,
                                       buddy, ti, reads_cycle)
            if res is None:
                pressure_any = True
                continue
            if res:
                return res, False

        # persistent crossbar conflict: both operands of the op live in the
        # same bank under different addresses — no schedule can ever read
        # them together. Use the VLIW copy capability (read -> FWD -> write
        # to another bank) to break the conflict; the op issues next cycle.
        bs, cs = int(self.b[op]), int(self.c[op])
        if (self.readable(bs) and self.readable(cs)
                and bs in self.reg_of and cs in self.reg_of):
            (bb, br), (cb, cr) = self.reg_of[bs], self.reg_of[cs]
            if bb == cb and br != cr:
                self._emit_copy(bs, cb, tree, buddy, ti, reads_cycle)
        return [], pressure_any

    def _emit_copy(self, slot: int, avoid_bank: int, tree: int,
                   buddy: treepack.Buddy, ti: isa.TreeInstr,
                   reads_cycle: dict[int, int]) -> bool:
        """Move ``slot`` to a different bank via a FWD-only level-1 PE."""
        if self._recv_gated(slot):
            return False   # gated remote values may not be consumed yet
        src_bank, src_reg = self.reg_of[slot]
        prev = reads_cycle.get(src_bank)
        if prev is not None and prev != src_reg:
            return False          # can't even read the source this cycle
        commit = self.t + self.cfg.pe_latency
        res = self.write_res[commit]
        if _ALL_BANKS in res:
            return False
        tree_base = tree * self.cfg.banks_per_tree
        tried: list[tuple[int, int]] = []
        chosen = None
        while True:
            base = buddy.alloc(1)
            if base is None:
                break
            p = base >> 1
            banks = [tree_base + lb for lb in self.cfg.write_banks(1, p)]
            good = [bk for bk in banks
                    if bk != avoid_bank and bk != src_bank
                    and self.bank_free[bk] and bk not in res]
            if good:
                chosen = (base, p, good[0])
                break
            tried.append((base, 1))
        for (b0, d0) in tried:
            buddy.free(b0, d0)
        if chosen is None:
            return False
        base, p, bk = chosen
        reg = self.bank_free[bk].pop()
        port = base
        ti.reads[port] = isa.ReadSrc(bank=src_bank, reg=src_reg)
        reads_cycle[src_bank] = src_reg
        ti.pe_ops[(1, p)] = isa.PE_FWD_A
        ti.writes.append(isa.WriteBack(level=1, pos=p, bank=bk, reg=reg,
                                       op_id=-1))
        self.write_res[commit].add(bk)
        # release the old cell and point the value at its new home
        if src_reg < self.load_region:
            self.row_live[src_reg] -= 1
        else:
            self.bank_free[src_bank].append(src_reg)
            self.cell_slot.pop((src_bank, src_reg), None)
        self.reg_of[slot] = (bk, reg)
        self.ready_cycle[slot] = commit
        self.state[slot] = _PENDING
        self.cell_slot[(bk, reg)] = slot
        self.pending_rows[reg] += 1
        heapq.heappush(self.pending_heap, (commit, reg))
        self.last_commit = max(self.last_commit, commit)
        # a send-pinned value that moved banks commits later in its new
        # cell — push the window snapshot past the copy's commit
        if slot in self.send_pinned:
            for (row, _pos) in self.send_rows_of_op.get(slot - self.m, ()):
                if row not in self.send_specs:
                    self.row_commit[row] = max(self.row_commit[row], commit)
        self.stats["copies"] = self.stats.get("copies", 0) + 1
        return True

    def _attempt_bundle(self, tree_dict, depth: int, tree: int,
                        buddy: treepack.Buddy, ti: isa.TreeInstr,
                        reads_cycle: dict[int, int]):
        """Feasibility + commit for one grown bundle.

        Returns issued ops on success, [] on structural conflict, None on
        register pressure (spill wanted).
        """
        m = self.m
        ops: list[int] = []
        inside = defaultdict(int)
        reads: dict[int, int] = {}   # slot -> None (set semantics)

        def collect(nd):
            if "val" in nd:
                reads[nd["val"]] = None
                return
            ops.append(nd["op"])
            for kid in (nd["l"], nd["r"]):
                if "op" in kid:
                    inside[kid["op"]] += 1
                collect(kid)
        collect(tree_dict)

        # deadlock-freedom gate: remote values may only be consumed after
        # every lower-or-equal-level send of our own has issued
        if self.recv_level and any(self._recv_gated(s) for s in reads):
            return []

        # crossbar feasibility (≤1 address per bank per cycle, broadcast ok)
        local_banks: dict[int, int] = {}
        for s in reads:
            bank, reg = self.reg_of[s]
            prev = reads_cycle.get(bank, local_banks.get(bank))
            if prev is not None and prev != reg:
                return []
            local_banks[bank] = reg

        base = buddy.alloc(depth)
        if base is None:
            return []

        def needs_wb(j: int) -> bool:
            return self.refcnt[m + j] > inside[j]

        bundle = treepack.place(tree, tree_dict, depth, base, needs_wb)

        # writeback allocation — "in tandem with the placement": avoid the
        # banks already holding the *other* operands of this value's future
        # consumers, so the consumer's two reads land in different banks
        wb_alloc: list[tuple[int, int, int, int, int]] = []  # lvl,pos,bank,reg,op
        ok, pressure = True, False
        for (level, pos, j) in bundle.writes:
            commit = self.t + level * self.cfg.pe_latency
            res = self.write_res[commit]
            tree_base = tree * self.cfg.banks_per_tree
            cands = [tree_base + lb for lb in self.cfg.write_banks(level, pos)]
            usable = [bk for bk in cands
                      if self.bank_free[bk] and bk not in res
                      and _ALL_BANKS not in res]
            if not usable:
                ok = False
                pressure = all(not self.bank_free[bk] for bk in cands)
                break
            avoid = set()
            for k in self.consumers[m + j]:
                if self.issued[k]:
                    continue
                for s2 in (int(self.b[k]), int(self.c[k])):
                    if s2 != m + j and s2 in self.reg_of:
                        avoid.add(self.reg_of[s2][0])
            preferred = [bk for bk in usable if bk not in avoid] or usable
            bk = max(preferred, key=lambda x: len(self.bank_free[x]))
            reg = self.bank_free[bk].pop()
            wb_alloc.append((level, pos, bk, reg, j))
        if not ok:
            for (_, _, bk, reg, _) in wb_alloc:
                self.bank_free[bk].append(reg)
            buddy.free(base, depth)
            return None if pressure else []

        # ---- commit the bundle ----
        for port, s in bundle.reads.items():
            bank, reg = self.reg_of[s]
            ti.reads[port] = isa.ReadSrc(bank=bank, reg=reg)
            reads_cycle[bank] = reg
            if reg < self.load_region:
                self.row_last_use[reg] = self.t
        for (lvlpos, opid) in bundle.nodes.items():
            ti.pe_ops[lvlpos] = _PE_OF_OPCODE[int(self.opcode[opid])]
        for lvlpos, code in bundle.fwds.items():
            ti.pe_ops[lvlpos] = code
        for (level, pos, bk, reg, j) in wb_alloc:
            commit = self.t + level * self.cfg.pe_latency
            ti.writes.append(isa.WriteBack(level=level, pos=pos, bank=bk,
                                           reg=reg, op_id=j))
            self.write_res[commit].add(bk)
            self.cell_slot[(bk, reg)] = m + j
            self.mark_materialized(m + j, bk, reg, commit)
            if j in self.send_rows_of_op:
                self._note_send_member_issued(j, commit)
            self.pending_rows[reg] += 1
            heapq.heappush(self.pending_heap, (commit, reg))
            self.last_commit = max(self.last_commit, commit)
        ti.op_ids.extend(ops)
        self.stats["bundles"] += 1
        self.stats["bundle_ops"] += len(ops)
        return ops

    def _alloc_root_row(self) -> int:
        """Data-memory row for the epilogue root store.

        Must never alias a row still holding live values: the root store
        is the program's last write, but a consumer of this VLIWProgram
        (multi-output extensions, debug dumps) may read any row the
        compiler claims is still valid. Prefer a free row, else recycle a
        spill row whose every slot is dead; a machine with no safe row
        left fails loudly instead of silently clobbering a live one.
        """
        if self.mem_free_rows:
            row = self.mem_free_rows.pop()
            self.root_rows_used.add(row)
            return row
        for row in sorted(self.mem_row_slots):
            if row < self.n_in_rows:
                continue   # leaf/constant image rows are never recycled
            if row in self.root_rows_used:
                continue   # already claimed by an earlier root store
            if all(self.refcnt[s] <= 0 for s in self.mem_row_slots[row]):
                self.root_rows_used.add(row)
                return row
        raise RuntimeError(
            "no data-memory row available for the root store: "
            f"{len(self.mem_row_slots)} rows all hold live values "
            "(data_mem_rows too small for this program)")

    # ---------------- main loop ------------------------------------------ #
    def run(self) -> isa.VLIWProgram:
        cfg, prog, m = self.cfg, self.prog, self.m
        stalled = 0
        while self.remaining > 0:
            if self.t >= self.max_cycles:
                raise RuntimeError(
                    f"exceeded {self.max_cycles} cycles; "
                    f"{self.remaining}/{self.n} ops left")
            t = self.t
            while self.pending_heap and self.pending_heap[0][0] <= t:
                _, reg = heapq.heappop(self.pending_heap)
                self.pending_rows[reg] -= 1
            # activate ready ops
            while self.ready_heap and self.ready_heap[0][0] <= t:
                _, negh, i = heapq.heappop(self.ready_heap)
                if self.issued[i]:
                    continue
                if self.nmat[i] < 2:
                    # an operand was evicted/spilled back to memory since
                    # enqueue: request its row; the op re-enqueues via
                    # mark_materialized when the load lands
                    for s in (int(self.b[i]), int(self.c[i])):
                        if self.state[s] == _MEM and self.refcnt[s] > 0:
                            self.want(s, int(self.height[i]))
                    continue
                self.active[i] = self.prio[i]

            tree_instrs: list[isa.TreeInstr | None] = [None] * cfg.num_trees
            reads_cycle: dict[int, int] = {}
            issued_now: list[int] = []
            need_spill = False

            cand = sorted(self.active.items(), key=lambda kv: kv[1])
            min_unsent = self._min_unsent_level() if self.comm else _INF
            for tree in range(cfg.num_trees):
                buddy = treepack.Buddy(cfg.tree_levels)
                ti = isa.TreeInstr(tree=tree)
                scanned = 0
                for op, _ in cand:
                    if buddy.max_depth() < 1 or scanned >= self.candidate_scan:
                        break
                    if self.issued[op]:
                        continue
                    if self.op_gate_level[op] >= min_unsent:
                        continue   # gated remote read: free skip
                    scanned += 1
                    ops, pressure = self.try_issue(op, tree, buddy, ti,
                                                   reads_cycle)
                    need_spill |= pressure
                    for j in ops:
                        self.issued[j] = True
                        issued_now.append(j)
                if ti.op_ids or ti.writes:
                    tree_instrs[tree] = ti
                cand = [(o, p) for (o, p) in cand if not self.issued[o]]

            # comm slot (network-interface port): completed sends flush
            # first (the deadlock-freedom rule wants low levels out early),
            # then demanded window recvs, then recv prefetch
            comm_instr = None
            if self.comm:
                comm_instr = self.pop_ready_send()
                if comm_instr is None:
                    row, best = None, -1
                    for r, p in self.want_rows.items():
                        if r >= RECV_BASE and p > best:
                            best, row = p, r
                    if row is not None:
                        comm_instr = self.issue_load(row)
                if comm_instr is None:
                    while self.recv_prefetch_ptr < len(self.recv_prefetch):
                        row = self.recv_prefetch[self.recv_prefetch_ptr]
                        if row in self.resident_mem_rows:
                            self.recv_prefetch_ptr += 1
                            continue
                        # only prefetch into a clean row (don't thrash) —
                        # unless the machine is otherwise idle, where
                        # eviction is the only way forward (the load
                        # region can be smaller than leaf + window rows)
                        if self.free_load_rows or not issued_now:
                            comm_instr = self.issue_load(row)
                            if comm_instr:
                                self.recv_prefetch_ptr += 1
                        break

            # memory slot: spill > wanted reload > leaf prefetch
            mem_instr = None
            if need_spill:
                mem_instr = self.spill_intermediate()
            if mem_instr is None:
                row, best = None, -1
                for r, p in self.want_rows.items():
                    if r < RECV_BASE and p > best:
                        best, row = p, r
                if row is not None:
                    mem_instr = self.issue_load(row)
            if mem_instr is None and not self.write_res[t + 1]:
                while self.prefetch_ptr < len(self.prefetch):
                    row = self.prefetch[self.prefetch_ptr]
                    if row in self.resident_mem_rows:
                        self.prefetch_ptr += 1
                        continue
                    # only prefetch if a clean row is free (don't thrash)
                    # — unless the machine is idle and prefetch is the
                    # only way to feed starved ops (multi-core programs
                    # can have more leaf + window rows than load rows)
                    if self.free_load_rows or not issued_now:
                        mem_instr = self.issue_load(row)
                        if mem_instr:
                            self.prefetch_ptr += 1
                    break

            # bookkeeping for issued ops
            for op in issued_now:
                self.active.pop(op, None)
                self.remaining -= 1
                for s in (int(self.b[op]), int(self.c[op])):
                    self.refcnt[s] -= 1
            for op in issued_now:
                for s in (int(self.b[op]), int(self.c[op])):
                    if self.refcnt[s] == 0:
                        self.free_cell(s)
                        self.refcnt[s] = -1   # freed once

            self.instrs.append(isa.VLIWInstr(trees=tree_instrs, mem=mem_instr,
                                             comm=comm_instr))
            copies_done = any(ti and ti.writes and not ti.op_ids
                              for ti in tree_instrs)
            if (not issued_now and mem_instr is None and comm_instr is None
                    and not copies_done):
                self.stats["stall_cycles"] += 1
                if (self.comm and any(self.state[s] == _PENDING
                                      and self.ready_cycle[s] > t
                                      for s in self.recv_level)) \
                        or (self.ready_heap
                            and self.ready_heap[0][0] > t):
                    # an ETA-scheduled remote row is still on its way, or
                    # an op is parked in the ready heap for a known future
                    # cycle (its recv row may have been evicted meanwhile;
                    # the pop re-requests it) — this idle cycle is the
                    # schedule working as designed, not a deadlock
                    # (max_cycles still bounds the wait)
                    stalled = 0
                else:
                    stalled += 1
                if stalled > 256 + cfg.tree_levels:
                    stuck = [(i, [(s, int(self.state[s]),
                                   int(self.refcnt[s]), self.mat(s))
                                  for s in (int(self.b[i]), int(self.c[i]))])
                             for i in range(self.n)
                             if not self.issued[i]][:4]
                    raise RuntimeError(
                        f"deadlock at cycle {t}: {self.remaining} ops left, "
                        f"active={len(self.active)} wants={len(self.want_rows)}"
                        f"; stuck (op, [(slot, state, refcnt, mat)]): {stuck}")
            elif not issued_now:
                # copies/loads alone are progress only for a bounded while —
                # a machine too small to ever issue must fail loudly, not spin
                stalled += 1
                if stalled > 4096:
                    raise RuntimeError(
                        f"live-lock at cycle {t}: memory traffic but no op "
                        f"issued for {stalled} cycles; {self.remaining} ops "
                        f"left (machine too small for this program?)")
            else:
                stalled = 0
            self.t += 1
            self.write_res.pop(t, None)

        # epilogue: flush remaining sends, then either store the root row
        # (root-owning cores) or just drain the pipeline — a multi-core
        # worker's outputs are its SENDs, so waiting for a pseudo-root
        # commit and storing it would be pure fixed overhead on streams
        # a quarter the single-core length
        t_end = (max(int(self.ready_cycle[s]) for s in self.root_slots)
                 if self.store_root else self.last_commit)

        def unsent() -> bool:
            return any(self.unsent_level_count.values())

        while self.t < t_end or unsent():
            ci = self.pop_ready_send() if self.comm else None
            self.instrs.append(isa.VLIWInstr(trees=[None] * cfg.num_trees,
                                             comm=ci))
            self.t += 1
        root_locs: list[tuple[int, int]] | None = None
        if self.store_root:
            # one store dumps ONE register index across ALL banks into a
            # memory row, so roots sharing a register index (multi-root
            # interleaved programs land instance roots in distinct banks)
            # share a single store cycle
            row_of_reg: dict[int, int] = {}
            locs: list[tuple[int, int]] = []
            for s in self.root_slots:
                bank, reg = self.reg_of[s]
                if reg not in row_of_reg:
                    row_of_reg[reg] = self._alloc_root_row()
                    self.instrs.append(isa.VLIWInstr(
                        trees=[None] * cfg.num_trees,
                        mem=isa.MemInstr("store", row_of_reg[reg], reg)))
                    self.stats["stores"] += 1
                    self.t += 1
                locs.append((row_of_reg[reg], bank))
            out_row, root_bank = locs[0]
            if len(locs) > 1:
                root_locs = locs
        else:
            out_row, root_bank = -1, -1
            while self.t <= self.last_commit:    # drain pipelined commits
                self.instrs.append(
                    isa.VLIWInstr(trees=[None] * cfg.num_trees))
                self.t += 1

        self.stats["cycles"] = self.t
        self.stats["n_in_rows"] = self.n_in_rows
        self.stats["ops_per_cycle"] = self.n / self.t
        # indicator slots that are recv'd from another core have no input
        # row; the multi-core runtime feeds them over the interconnect
        recv_slots = self.comm.recv_slots if self.comm else {}
        return isa.VLIWProgram(
            instrs=self.instrs,
            input_rows=self.n_in_rows,
            input_layout=[(int(self.leaf_row[s]), int(self.leaf_bank[s]))
                          for s in range(prog.m_ind)
                          if s not in recv_slots],
            const_rows={r: self.images[r].tolist()
                        for r in range(self.n_in_rows)},
            root_loc=(out_row, root_bank),
            root_locs=root_locs,
            n_useful_ops=self.n,
            stats=dict(self.stats),
            send_specs=self.send_specs)


def compile_program(prog: TensorProgram, cfg: ProcessorConfig, *,
                    load_region: int = 16, candidate_scan: int = 24,
                    max_cycles: int = 4_000_000,
                    comm: isa.CommSpec | None = None,
                    store_root: bool = True) -> isa.VLIWProgram:
    # the load region stages vector rows; it must leave intermediate
    # registers in every bank or no op output can ever be written back
    load_region = max(1, min(load_region, cfg.regs_per_bank // 2))
    return _Scheduler(prog, cfg, load_region=load_region,
                      candidate_scan=candidate_scan,
                      max_cycles=max_cycles, comm=comm,
                      store_root=store_root).run()
