"""Custom VLIW instruction set for the SPN processor (paper §IV).

One :class:`VLIWInstr` configures the whole machine for one clock cycle:

- per-tree: the crossbar read for every leaf port, the opcode of every PE
  and the register-writeback list,
- one optional vector load/store between a register row and data memory.

PE opcodes follow the paper: sum, product, or *forward* of either input
(forwarding is what lets a crossbar operand ride up the tree to meet a
deeper op, and is not counted as a useful arithmetic op). ``PE_MAX``
extends the paper's ALU with a comparator-select — the one-gate delta
that upgrades the processor from a likelihood engine to an MPE engine
(max-product sweeps for :mod:`repro.queries`).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

# PE opcodes
PE_NOP = 0
PE_ADD = 1
PE_MUL = 2
PE_FWD_A = 3   # forward left input
PE_FWD_B = 4   # forward right input
PE_MAX = 5     # comparator-select: max-product (MPE/Viterbi) sweeps

OP_NAMES = {PE_NOP: "nop", PE_ADD: "add", PE_MUL: "mul",
            PE_FWD_A: "fwda", PE_FWD_B: "fwdb", PE_MAX: "max"}


@dataclasses.dataclass
class ReadSrc:
    """Crossbar read feeding one leaf port: register (bank, reg)."""
    bank: int   # global bank id
    reg: int


@dataclasses.dataclass
class WriteBack:
    """Writeback of PE (level, pos) output into (bank, reg).

    Commits ``level * pe_latency`` cycles after issue (pipelined tree).
    ``op_id`` tags the SPN op whose value is produced (-1 for forwards).
    """
    level: int
    pos: int
    bank: int   # global bank id (must lie in the tree's private slice)
    reg: int
    op_id: int = -1


@dataclasses.dataclass
class TreeInstr:
    """One tree's configuration for one cycle."""
    tree: int
    reads: dict[int, ReadSrc] = dataclasses.field(default_factory=dict)   # port -> src
    pe_ops: dict[tuple[int, int], int] = dataclasses.field(default_factory=dict)  # (level,pos) -> opcode
    writes: list[WriteBack] = dataclasses.field(default_factory=list)
    op_ids: list[int] = dataclasses.field(default_factory=list)  # useful ops this issue

    @property
    def num_useful_ops(self) -> int:
        return len(self.op_ids)


@dataclasses.dataclass
class MemInstr:
    """Vector row transfer: data_mem[addr] <-> regfile[:, reg]."""
    kind: str   # "load" | "store"
    addr: int   # data-memory row
    reg: int    # register row (same index in every bank)


@dataclasses.dataclass
class VLIWInstr:
    trees: list[Optional[TreeInstr]]
    mem: Optional[MemInstr] = None

    @property
    def num_useful_ops(self) -> int:
        return sum(t.num_useful_ops for t in self.trees if t is not None)


@dataclasses.dataclass
class VLIWProgram:
    """Compiled SPN: instruction stream + I/O layout metadata."""
    instrs: list[VLIWInstr]
    # leaf input layout: data-memory rows holding the input vector;
    # input_layout[i] = (row, bank) for indicator slot i of the TensorProgram
    input_rows: int
    input_layout: list[tuple[int, int]]
    # constants (parameter leaves): preloaded data-memory image rows
    const_rows: dict[int, list[float]]   # row -> 32 values
    root_loc: tuple[int, int]            # (row, bank) of the root in data memory
    n_useful_ops: int
    stats: dict = dataclasses.field(default_factory=dict)

    @property
    def num_cycles(self) -> int:
        return len(self.instrs)

    @property
    def ops_per_cycle(self) -> float:
        return self.n_useful_ops / max(self.num_cycles, 1)
