"""Custom VLIW instruction set for the SPN processor (paper §IV).

One :class:`VLIWInstr` configures the whole machine for one clock cycle:

- per-tree: the crossbar read for every leaf port, the opcode of every PE
  and the register-writeback list,
- one optional vector load/store between a register row and data memory.

PE opcodes follow the paper: sum, product, or *forward* of either input
(forwarding is what lets a crossbar operand ride up the tree to meet a
deeper op, and is not counted as a useful arithmetic op). ``PE_MAX``
extends the paper's ALU with a comparator-select — the one-gate delta
that upgrades the processor from a likelihood engine to an MPE engine
(max-product sweeps for :mod:`repro.queries`).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

# PE opcodes
PE_NOP = 0
PE_ADD = 1
PE_MUL = 2
PE_FWD_A = 3   # forward left input
PE_FWD_B = 4   # forward right input
PE_MAX = 5     # comparator-select: max-product (MPE/Viterbi) sweeps

OP_NAMES = {PE_NOP: "nop", PE_ADD: "add", PE_MUL: "mul",
            PE_FWD_A: "fwda", PE_FWD_B: "fwdb", PE_MAX: "max"}


@dataclasses.dataclass
class ReadSrc:
    """Crossbar read feeding one leaf port: register (bank, reg)."""
    bank: int   # global bank id
    reg: int


@dataclasses.dataclass
class WriteBack:
    """Writeback of PE (level, pos) output into (bank, reg).

    Commits ``level * pe_latency`` cycles after issue (pipelined tree).
    ``op_id`` tags the SPN op whose value is produced (-1 for forwards).
    """
    level: int
    pos: int
    bank: int   # global bank id (must lie in the tree's private slice)
    reg: int
    op_id: int = -1


@dataclasses.dataclass
class TreeInstr:
    """One tree's configuration for one cycle."""
    tree: int
    reads: dict[int, ReadSrc] = dataclasses.field(default_factory=dict)   # port -> src
    pe_ops: dict[tuple[int, int], int] = dataclasses.field(default_factory=dict)  # (level,pos) -> opcode
    writes: list[WriteBack] = dataclasses.field(default_factory=list)
    op_ids: list[int] = dataclasses.field(default_factory=list)  # useful ops this issue

    @property
    def num_useful_ops(self) -> int:
        return len(self.op_ids)


@dataclasses.dataclass
class MemInstr:
    """Vector row transfer: data_mem[addr] <-> regfile[:, reg]."""
    kind: str   # "load" | "store"
    addr: int   # data-memory row
    reg: int    # register row (same index in every bank)


@dataclasses.dataclass
class VLIWInstr:
    trees: list[Optional[TreeInstr]]
    mem: Optional[MemInstr] = None

    @property
    def num_useful_ops(self) -> int:
        return sum(t.num_useful_ops for t in self.trees if t is not None)


@dataclasses.dataclass
class VLIWProgram:
    """Compiled SPN: instruction stream + I/O layout metadata."""
    instrs: list[VLIWInstr]
    # leaf input layout: data-memory rows holding the input vector;
    # input_layout[i] = (row, bank) for indicator slot i of the TensorProgram
    input_rows: int
    input_layout: list[tuple[int, int]]
    # constants (parameter leaves): preloaded data-memory image rows
    const_rows: dict[int, list[float]]   # row -> 32 values
    root_loc: tuple[int, int]            # (row, bank) of the root in data memory
    n_useful_ops: int
    stats: dict = dataclasses.field(default_factory=dict)

    @property
    def num_cycles(self) -> int:
        return len(self.instrs)

    @property
    def ops_per_cycle(self) -> float:
        return self.n_useful_ops / max(self.num_cycles, 1)


# --------------------------------------------------------------------------- #
# Dense encoding — the fast-sim instruction format
# --------------------------------------------------------------------------- #
# Dense opcodes (forwards are resolved away at decode time, so only the
# three arithmetic PE ops survive).
D_ADD = 0
D_MUL = 1
D_MAX = 2

_D_OF_PE = {PE_ADD: D_ADD, PE_MUL: D_MUL, PE_MAX: D_MAX}


@dataclasses.dataclass
class DenseProgram:
    """Pre-decoded VLIW instruction stream as dense numpy arrays.

    The sparse per-cycle :class:`VLIWInstr` stream (dict-of-dicts reads,
    PE maps, pipelined writebacks) is replayed once, symbolically, into a
    flat SSA value space: values ``[0, n_init)`` are the initial
    data-memory image cells (constants + leaf-input overlay points),
    values ``[n_init, n_init + n_ops)`` are PE outputs in dependence
    (level-sorted) order. Crossbar reads, register-file traffic and
    load/store rows are all resolved into the ``a``/``b`` operand index
    vectors, so executing the program is a handful of vectorized
    gather→op→scatter passes (:func:`repro.core.processor.fastsim.run`)
    instead of a per-cycle Python interpretation — same arithmetic on the
    same f32 values, hence bit-identical roots to the checked simulator.
    """
    n_init: int                 # initial SSA values (memory-image cells)
    init_values: np.ndarray     # (n_init,) f32 constant image
    input_cells: np.ndarray     # (m_ind,) int32 SSA id of each leaf slot
    opcode: np.ndarray          # (n_ops,) uint8 D_* codes
    a: np.ndarray               # (n_ops,) int32 first operand SSA id
    b: np.ndarray               # (n_ops,) int32 second operand SSA id
    level_offsets: np.ndarray   # (L+1,) int32 independent-op ranges
    # ops are sorted by (level, opcode), so each level decomposes into ≤3
    # contiguous single-opcode runs — executed as one ufunc call each,
    # writing straight into the value-buffer slice; the fourth element
    # fuses both operand vectors into a single gather index
    segments: list              # [(lo, hi, D_* code, concat(a, b)), ...]
    root: int                   # SSA id of the root value
    cycles: int                 # source VLIW cycle count (throughput acct.)
    n_useful_ops: int           # arithmetic ops excluding decode-time fwds

    @property
    def n_ops(self) -> int:
        return len(self.opcode)

    @property
    def num_levels(self) -> int:
        return len(self.level_offsets) - 1
