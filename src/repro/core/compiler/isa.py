"""Custom VLIW instruction set for the SPN processor (paper §IV).

One :class:`VLIWInstr` configures the whole machine for one clock cycle:

- per-tree: the crossbar read for every leaf port, the opcode of every PE
  and the register-writeback list,
- one optional vector load/store between a register row and data memory.

PE opcodes follow the paper: sum, product, or *forward* of either input
(forwarding is what lets a crossbar operand ride up the tree to meet a
deeper op, and is not counted as a useful arithmetic op). ``PE_MAX``
extends the paper's ALU with a comparator-select — the one-gate delta
that upgrades the processor from a likelihood engine to an MPE engine
(max-product sweeps for :mod:`repro.queries`).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

# PE opcodes
PE_NOP = 0
PE_ADD = 1
PE_MUL = 2
PE_FWD_A = 3   # forward left input
PE_FWD_B = 4   # forward right input
PE_MAX = 5     # comparator-select: max-product (MPE/Viterbi) sweeps

OP_NAMES = {PE_NOP: "nop", PE_ADD: "add", PE_MUL: "mul",
            PE_FWD_A: "fwda", PE_FWD_B: "fwdb", PE_MAX: "max"}


@dataclasses.dataclass
class ReadSrc:
    """Crossbar read feeding one leaf port: register (bank, reg)."""
    bank: int   # global bank id
    reg: int


@dataclasses.dataclass
class WriteBack:
    """Writeback of PE (level, pos) output into (bank, reg).

    Commits ``level * pe_latency`` cycles after issue (pipelined tree).
    ``op_id`` tags the SPN op whose value is produced (-1 for forwards).
    """
    level: int
    pos: int
    bank: int   # global bank id (must lie in the tree's private slice)
    reg: int
    op_id: int = -1


@dataclasses.dataclass
class TreeInstr:
    """One tree's configuration for one cycle."""
    tree: int
    reads: dict[int, ReadSrc] = dataclasses.field(default_factory=dict)   # port -> src
    pe_ops: dict[tuple[int, int], int] = dataclasses.field(default_factory=dict)  # (level,pos) -> opcode
    writes: list[WriteBack] = dataclasses.field(default_factory=list)
    op_ids: list[int] = dataclasses.field(default_factory=list)  # useful ops this issue

    @property
    def num_useful_ops(self) -> int:
        return len(self.op_ids)


@dataclasses.dataclass
class MemInstr:
    """Vector row transfer: data_mem[addr] <-> regfile[:, reg].

    Two additional kinds exist only in multi-core programs and execute on
    the core's *network interface port* (``VLIWInstr.comm``), not the
    data-memory port:

    - ``"send"``: flush one completed shared-register-window row onto the
      interconnect. ``addr`` is the global channel-row id; the values are
      snapshotted from the register cells recorded in
      :attr:`VLIWProgram.send_specs` (the window latches writebacks
      AIA-style, so no bank gather is needed).
    - ``"recv"``: read a window row into load-region register row
      ``reg`` (member *position i* lands in *bank i*). Non-blocking: if
      the row has not arrived yet the cells are marked in-flight
      (full/empty bits) and the core stalls only when a PE actually
      reads one — flow control at use, not at issue.
    """
    kind: str   # "load" | "store" | "send" | "recv"
    addr: int   # data-memory row (load/store) or channel-row id (send/recv)
    reg: int    # register row (same index in every bank); -1 for send


@dataclasses.dataclass
class VLIWInstr:
    trees: list[Optional[TreeInstr]]
    mem: Optional[MemInstr] = None      # data-memory port: load/store
    comm: Optional[MemInstr] = None     # network-interface port: send/recv

    @property
    def num_useful_ops(self) -> int:
        return sum(t.num_useful_ops for t in self.trees if t is not None)


@dataclasses.dataclass
class CommSpec:
    """One core's side of a multi-core communication plan.

    Channel rows are level-homogeneous groups of cut values between one
    (src, dst) core pair — see :mod:`repro.core.multicore.comm`. The
    compiler consumes this spec to lay recv slots out in window rows,
    pin producer values until their send issues, and order sends before
    dependent remote reads (the deadlock-freedom invariant).
    """
    # consumer side: local leaf slot -> (channel row id, position/bank)
    recv_slots: dict[int, tuple[int, int]] = dataclasses.field(
        default_factory=dict)
    # producer side: local op idx -> [(channel row id, position), ...]
    # (one entry per destination core — multicast is unrolled)
    send_ops: dict[int, list] = dataclasses.field(default_factory=dict)
    # channel row id -> producer binary level (the deadlock grading)
    row_level: dict[int, int] = dataclasses.field(default_factory=dict)
    # channel row id -> member count
    row_size: dict[int, int] = dataclasses.field(default_factory=dict)
    # local op idx -> GLOBAL critical-path height: a cut value's local
    # height ends at the send, but its consumers on other cores continue
    # the path — without this the list scheduler starves cut producers
    op_height: dict[int, int] = dataclasses.field(default_factory=dict)
    # channel row id -> estimated global arrival cycle (ETA), measured by
    # a prior lockstep timing probe. The scheduler treats recv'd values
    # as ready no earlier than the ETA, so remote-dependent ops are
    # scheduled where their data can actually be — own work fills the
    # gap instead of a head-of-line flow-control stall
    row_eta: dict[int, int] = dataclasses.field(default_factory=dict)

    @property
    def empty(self) -> bool:
        return not self.recv_slots and not self.send_ops


@dataclasses.dataclass
class VLIWProgram:
    """Compiled SPN: instruction stream + I/O layout metadata."""
    instrs: list[VLIWInstr]
    # leaf input layout: data-memory rows holding the input vector;
    # input_layout[i] = (row, bank) for indicator slot i of the TensorProgram
    input_rows: int
    input_layout: list[tuple[int, int]]
    # constants (parameter leaves): preloaded data-memory image rows
    const_rows: dict[int, list[float]]   # row -> 32 values
    root_loc: tuple[int, int]            # (row, bank) of the root in data memory
    n_useful_ops: int
    # multi-root (interleaved) programs: (row, bank) per instance root, in
    # instance order; None for ordinary single-root programs. root_loc
    # always equals root_locs[0] when present.
    root_locs: list[tuple[int, int]] | None = None
    stats: dict = dataclasses.field(default_factory=dict)
    # multi-core only: channel row id -> [(position, bank, reg), ...] —
    # the register cells the window snapshots when the row's SEND issues
    send_specs: dict[int, list] = dataclasses.field(default_factory=dict)

    @property
    def num_cycles(self) -> int:
        return len(self.instrs)

    @property
    def ops_per_cycle(self) -> float:
        return self.n_useful_ops / max(self.num_cycles, 1)


# --------------------------------------------------------------------------- #
# Dense encoding — the fast-sim instruction format
# --------------------------------------------------------------------------- #
# Dense opcodes (forwards are resolved away at decode time, so only the
# three arithmetic PE ops survive).
D_ADD = 0
D_MUL = 1
D_MAX = 2

_D_OF_PE = {PE_ADD: D_ADD, PE_MUL: D_MUL, PE_MAX: D_MAX}


@dataclasses.dataclass
class DenseProgram:
    """Pre-decoded VLIW instruction stream as dense numpy arrays.

    The sparse per-cycle :class:`VLIWInstr` stream (dict-of-dicts reads,
    PE maps, pipelined writebacks) is replayed once, symbolically, into a
    flat SSA value space: values ``[0, n_init)`` are the initial
    data-memory image cells (constants + leaf-input overlay points),
    values ``[n_init, n_init + n_ops)`` are PE outputs in dependence
    (level-sorted) order. Crossbar reads, register-file traffic and
    load/store rows are all resolved into the ``a``/``b`` operand index
    vectors, so executing the program is a handful of vectorized
    gather→op→scatter passes (:func:`repro.core.processor.fastsim.run`)
    instead of a per-cycle Python interpretation — same arithmetic on the
    same f32 values, hence bit-identical roots to the checked simulator.
    """
    n_init: int                 # initial SSA values (memory-image cells)
    init_values: np.ndarray     # (n_init,) f32 constant image
    input_cells: np.ndarray     # (m_ind,) int32 SSA id of each leaf slot
    opcode: np.ndarray          # (n_ops,) uint8 D_* codes
    a: np.ndarray               # (n_ops,) int32 first operand SSA id
    b: np.ndarray               # (n_ops,) int32 second operand SSA id
    level_offsets: np.ndarray   # (L+1,) int32 independent-op ranges
    # ops are sorted by (level, opcode), so each level decomposes into ≤3
    # contiguous single-opcode runs — executed as one ufunc call each,
    # writing straight into the value-buffer slice; the fourth element
    # fuses both operand vectors into a single gather index
    segments: list              # [(lo, hi, D_* code, concat(a, b)), ...]
    root: int                   # SSA id of the root value
    cycles: int                 # source VLIW cycle count (throughput acct.)
    n_useful_ops: int           # arithmetic ops excluding decode-time fwds
    # leaf column feeding each input cell; None means ``arange(m_ind)``
    # (single-core). Multi-core merged programs duplicate leaf cells per
    # core, so several cells may map to one leaf column.
    input_slots: np.ndarray | None = None
    # multi-root (interleaved) programs: SSA id per instance root, in
    # instance order; None for single-root. roots[0] == root when present.
    roots: np.ndarray | None = None

    @property
    def n_ops(self) -> int:
        return len(self.opcode)

    @property
    def num_levels(self) -> int:
        return len(self.level_offsets) - 1
