from .pipeline import compile_program  # noqa: F401
from .isa import VLIWProgram  # noqa: F401
