"""PE-tree bundle packing (paper §IV: "trees of PEs that enable local reuse
of data, by avoiding frequent writebacks to the register file").

A *bundle* maps a subtree of SPN ops onto one PE tree for one issue slot:
producers feed consumers directly through the pipelined tree, so values
consumed only inside the bundle never touch the register file. Operands
that are already-computed values enter at the crossbar leaf ports and ride
up through PEs in *forward* mode.

Positions: at tree level ℓ (1 = bottom) position ``p`` covers leaf ports
``[p·2^ℓ, (p+1)·2^ℓ)``. A depth-``d`` bundle owns an aligned block of
``2^d`` leaf ports handed out by a per-cycle buddy allocator.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from . import isa


@dataclasses.dataclass
class Bundle:
    tree: int
    depth: int
    base_port: int                      # aligned block start (tree-local)
    # op placement: (level, global pos within tree) -> op id
    nodes: dict[tuple[int, int], int]
    # forward chains: (level, pos) -> PE_FWD_A (value rides leftmost edge)
    fwds: dict[tuple[int, int], int]
    # crossbar reads: port (tree-local) -> value slot
    reads: dict[int, int]
    # ops that need a register writeback: list of (level, pos, op_id)
    writes: list[tuple[int, int, int]]
    ops: list[int]                      # all op ids included (useful ops)


class Buddy:
    """Per-cycle buddy allocator over one tree's 2^L leaf ports."""

    def __init__(self, levels: int):
        self.levels = levels
        self.blocks: dict[int, set[int]] = {l: set() for l in range(levels + 1)}
        self.blocks[levels].add(0)

    def max_depth(self) -> int:
        for l in range(self.levels, -1, -1):
            if self.blocks[l]:
                return l
        return -1

    def alloc(self, depth: int) -> int | None:
        for l in range(depth, self.levels + 1):
            if self.blocks[l]:
                base = min(self.blocks[l])
                self.blocks[l].remove(base)
                # split down to requested depth
                while l > depth:
                    l -= 1
                    self.blocks[l].add(base + (1 << l))
                return base
        return None

    def free(self, base: int, depth: int) -> None:
        """Return a block (no buddy-merging; fine within one cycle)."""
        self.blocks[depth].add(base)


def grow(root_op: int, max_depth: int, *,
         b, c, m: int,
         readable: Callable[[int], bool],
         includable: Callable[[int], bool]) -> tuple[dict, int] | None:
    """Try to build the op subtree rooted at ``root_op``.

    Returns ``(tree, depth)`` or None if infeasible. Tree representation:
    nested dict ``{"op": op_id, "l": left, "r": right}`` with leaves
    ``{"val": slot}``. An operand that is an unmaterialized op MUST be
    included (otherwise the bundle cannot issue); if it cannot be included
    within the depth budget the whole bundle fails. Each op is included at
    most once per bundle (DAG diamonds fall back to a register read of the
    already-scheduled value, or defer the bundle).
    """
    claimed: set[int] = set()

    def rec(op: int, budget: int):
        if budget < 1 or op in claimed:
            return None
        snap = set(claimed)
        claimed.add(op)
        kids = []
        for s in (int(b[op]), int(c[op])):
            sub = None
            if s >= m and (s - m) not in claimed and includable(s - m):
                sub = rec(s - m, budget - 1)  # restores claims on failure
            if sub is not None:
                kids.append(sub)
            elif readable(s):
                kids.append({"val": s})
            else:
                claimed.clear()
                claimed.update(snap)
                return None
        return {"op": op, "l": kids[0], "r": kids[1]}

    tree = rec(root_op, max_depth)
    if tree is None:
        return None
    return tree, _depth(tree)


def _depth(tree: dict) -> int:
    if "val" in tree:
        return 0
    return 1 + max(_depth(tree["l"]), _depth(tree["r"]))


def place(tree_id: int, tree: dict, depth: int, base_port: int,
          needs_wb: Callable[[int], bool]) -> Bundle:
    """Assign tree slots/ports for a grown subtree of ``depth`` at ``base_port``."""
    bundle = Bundle(tree=tree_id, depth=depth, base_port=base_port,
                    nodes={}, fwds={}, reads={}, writes=[], ops=[])

    def assign(node: dict, level: int, pos: int) -> None:
        # ``pos`` is the global position at ``level`` (port block = pos·2^level)
        if "val" in node:
            port = pos * (1 << level)
            # record read; forward chain from port up to (level, pos)
            prev = bundle.reads.get(port)
            assert prev is None or prev == node["val"]
            bundle.reads[port] = node["val"]
            for l in range(1, level + 1):
                bundle.fwds[(l, port >> l)] = isa.PE_FWD_A
            return
        op = node["op"]
        bundle.nodes[(level, pos)] = op
        bundle.ops.append(op)
        assign(node["l"], level - 1, pos * 2)
        assign(node["r"], level - 1, pos * 2 + 1)

    root_pos = base_port >> depth
    assign(tree, depth, root_pos)
    for (level, pos), op in bundle.nodes.items():
        if needs_wb(op):
            bundle.writes.append((level, pos, op))
    return bundle


def count_ops(tree: dict) -> int:
    if "val" in tree:
        return 0
    return 1 + count_ops(tree["l"]) + count_ops(tree["r"])
