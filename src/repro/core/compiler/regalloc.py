"""Register-bank allocation helpers (paper §IV: "The compiler allocates
register banks ... while trying to minimize the register read/write bank
conflicts").

Leaf inputs live in data memory as 32-wide vector rows; the *bank* a leaf
lands in is a compiler choice. Two leaves that are operands of the same op
are read in the same cycle, so same-bank placement is a crossbar conflict —
exactly the structure the paper attacks with graph coloring on the GPU.
We greedy-color the leaf conflict graph onto banks, balancing bank loads
(row count = max per-bank load, and rows are what vector loads move).
"""
from __future__ import annotations

from collections import defaultdict

import numpy as np

from .. import segments
from ..program import TensorProgram
from ..processor.config import ProcessorConfig


def layout_leaves(prog: TensorProgram, cfg: ProcessorConfig,
                  fixed_banks: dict[int, int] | None = None):
    """Color leaf slots onto banks; returns (bank_of, row_of, n_rows, images).

    ``images`` is the (n_rows, banks) float32 constant image of the input
    region of data memory: parameter values baked in, indicator cells 0.

    Conflict edges come from two sources: the classic pairwise rule (two
    operands of one binary op are read in the same cycle) and the
    segment scheduler's fused n-ary nodes — all leaf operands of a fused
    reduction that fits one PE tree issue as ONE bundle, so they form a
    read *clique* (≤1 address per bank per cycle). Without the clique the
    scheduler's whole-segment bundles would immediately trip crossbar
    conflicts and fall back to fragmented issue.

    ``fixed_banks`` pre-pins slots whose bank the compiler may not
    choose — multi-core recv slots land in the bank equal to their
    position in the shared-register-window row. Pinned slots still
    participate in the conflict graph (free slots are steered away from
    their banks) but get ``row_of = -1`` and no input-image cell.
    """
    m = prog.m
    fixed_banks = fixed_banks or {}
    conflicts: dict[int, set[int]] = defaultdict(set)
    for i in range(prog.n_ops):
        b, c = int(prog.b[i]), int(prog.c[i])
        if b < m and c < m and b != c:
            conflicts[b].add(c)
            conflicts[c].add(b)
    info = segments.fusion_info(prog)
    for leaves in info.leaves.values():
        group = sorted({s for s in leaves if s < m})
        if len(group) <= cfg.leaf_ports_per_tree:   # one-bundle candidates
            for a in group:
                for b2 in group:
                    if a != b2:
                        conflicts[a].add(b2)

    order = sorted(range(m), key=lambda s: -len(conflicts.get(s, ())))
    bank_of = np.full(m, -1, np.int32)
    for s, bk in fixed_banks.items():
        bank_of[s] = bk
    load = np.zeros(cfg.banks, np.int64)
    for s in order:
        if s in fixed_banks:
            continue
        banned = {int(bank_of[x]) for x in conflicts.get(s, ()) if bank_of[x] >= 0}
        # least-loaded bank, strongly preferring conflict-free ones
        best, best_key = 0, None
        for bk in range(cfg.banks):
            key = (bk in banned, int(load[bk]))
            if best_key is None or key < best_key:
                best, best_key = bk, key
        bank_of[s] = best
        load[best] += 1

    row_of = np.full(m, -1, np.int32)
    counter = np.zeros(cfg.banks, np.int64)
    for s in range(m):
        if s in fixed_banks:
            continue
        bk = int(bank_of[s])
        row_of[s] = counter[bk]
        counter[bk] += 1
    n_rows = int(counter.max()) if m else 0

    images = np.zeros((n_rows, cfg.banks), np.float32)
    for s in range(prog.m_ind, m):  # parameter leaves: bake values
        images[int(row_of[s]), int(bank_of[s])] = prog.param_values[s - prog.m_ind]
    return bank_of, row_of, n_rows, images
