"""Serialization of SPNs in a simple `.ac` (arithmetic circuit) text format.

Line-oriented, one node per line, children-before-parents:

    ind <var> <value>
    param <float>
    sum <k> <child...> [w <weight...>]
    prod <k> <child...>
    root <node-id>          (last line)

This mirrors the AC files emitted by PSDD/AC learning tools (paper ref
[5]) closely enough that real circuit files are a small shim away.
"""
from __future__ import annotations

import io as _io

from .spn import LEAF_IND, LEAF_PARAM, PROD, SUM, SPN, SPNBuilder


def dumps(spn: SPN) -> str:
    out = _io.StringIO()
    for i in range(spn.num_nodes):
        t = spn.node_type[i]
        if t == LEAF_IND:
            out.write(f"ind {int(spn.leaf_var[i])} {int(spn.leaf_value[i])}\n")
        elif t == LEAF_PARAM:
            out.write(f"param {float(spn.param_value[i])!r}\n")
        elif t == SUM:
            ch = " ".join(map(str, spn.children[i]))
            w = spn.weights[i]
            if w is None:
                out.write(f"sum {len(spn.children[i])} {ch}\n")
            else:
                ws = " ".join(repr(float(x)) for x in w)
                out.write(f"sum {len(spn.children[i])} {ch} w {ws}\n")
        else:
            ch = " ".join(map(str, spn.children[i]))
            out.write(f"prod {len(spn.children[i])} {ch}\n")
    out.write(f"root {spn.root}\n")
    return out.getvalue()


def loads(text: str) -> SPN:
    b = SPNBuilder()
    root = None
    for line in text.strip().splitlines():
        tok = line.split()
        if not tok:
            continue
        kind = tok[0]
        if kind == "ind":
            b.indicator(int(tok[1]), int(tok[2]))
        elif kind == "param":
            b.param(float(tok[1]))
        elif kind == "sum":
            k = int(tok[1])
            ch = [int(x) for x in tok[2: 2 + k]]
            w = None
            if len(tok) > 2 + k and tok[2 + k] == "w":
                w = [float(x) for x in tok[3 + k: 3 + k + k]]
            b.sum(ch, w)
        elif kind == "prod":
            k = int(tok[1])
            b.product([int(x) for x in tok[2: 2 + k]])
        elif kind == "root":
            root = int(tok[1])
        else:
            raise ValueError(f"bad .ac line: {line!r}")
    return b.build(root)


def save(spn: SPN, path: str) -> None:
    with open(path, "w") as f:
        f.write(dumps(spn))


def load(path: str) -> SPN:
    with open(path) as f:
        return loads(f.read())
