"""SPN structure + parameter learning.

Two structure learners, mirroring how the paper's benchmark SPNs arise
("SPNs trained on a suite of standard benchmarks [3], [7] using the
algorithm in [5]"):

- :func:`random_spn` — RAT-SPN-style random region-graph structure
  (random variable partitions, sums over cross-products of sub-regions),
- :func:`learn_spn` — LearnSPN-lite over binary data: recursive
  independence splits (pairwise MI + connected components → product) and
  row clustering (→ mixture sum).

Parameter learning:

- :func:`em_step` / :func:`fit_em` — soft-count EM on sum weights (exact
  SPN EM via the gradient identity n_k = w_k · ∂logP/∂w_k),
- :func:`fit_sgd` — Adam on per-sum softmax logits (maximum likelihood),
  differentiating straight through the leveled log-domain executor.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from . import executors, program
from .spn import SPN, SPNBuilder


# --------------------------------------------------------------------------- #
# random (RAT-style) structure
# --------------------------------------------------------------------------- #
def random_spn(num_vars: int, *, depth: int = 3, num_sums: int = 4,
               num_input_dists: int = 4, repetitions: int = 2,
               seed: int = 0) -> SPN:
    """Random region-graph SPN over ``num_vars`` binary variables.

    Each *region* (variable subset) carries ``num_sums`` mixture nodes;
    a region is split into two random halves whose node sets combine via
    cross-products. Leaves are Bernoulli distributions (sum over the two
    indicators). ``repetitions`` independent region graphs are mixed at
    the root (the RAT-SPN construction).
    """
    rng = np.random.default_rng(seed)
    b = SPNBuilder()
    ind = [[b.indicator(v, 1), b.indicator(v, 0)] for v in range(num_vars)]

    def leaf_nodes(v: int, k: int) -> list[int]:
        out = []
        for _ in range(k):
            p = float(rng.uniform(0.05, 0.95))
            out.append(b.sum(ind[v], [p, 1.0 - p]))
        return out

    def region(scope: np.ndarray, d: int, k: int) -> list[int]:
        if len(scope) == 1:
            return leaf_nodes(int(scope[0]), k)
        if d <= 0:
            # factorize fully: product of Bernoullis, k mixture components
            out = []
            for _ in range(k):
                parts = [leaf_nodes(int(v), 1)[0] for v in scope]
                out.append(b.product(parts))
            return out
        perm = rng.permutation(scope)
        left, right = perm[: len(perm) // 2], perm[len(perm) // 2:]
        ln = region(left, d - 1, k)
        rn = region(right, d - 1, k)
        prods = [b.product([l, r]) for l in ln for r in rn]
        out = []
        for _ in range(k):
            take = rng.choice(len(prods), size=min(len(prods), 2 * k), replace=False)
            w = rng.dirichlet(np.ones(len(take)))
            out.append(b.sum([prods[t] for t in take], w.tolist()))
        return out

    roots = []
    for _ in range(repetitions):
        roots.extend(region(np.arange(num_vars), depth, num_sums))
    w = rng.dirichlet(np.ones(len(roots)))
    root = b.sum(roots, w.tolist())
    return b.build(root)


# --------------------------------------------------------------------------- #
# LearnSPN-lite
# --------------------------------------------------------------------------- #
def _mutual_info(data: np.ndarray, alpha: float = 0.1) -> np.ndarray:
    """Pairwise MI matrix for binary data (Laplace-smoothed)."""
    n, d = data.shape
    x = data.astype(np.float64)
    p1 = (x.sum(0) + 2 * alpha) / (n + 4 * alpha)
    p11 = (x.T @ x + alpha) / (n + 4 * alpha)
    mi = np.zeros((d, d))
    for a in range(2):
        pa = p1 if a else 1 - p1
        for bb in range(2):
            pb = p1 if bb else 1 - p1
            if a and bb:
                pj = p11
            elif a and not bb:
                pj = p1[:, None] - p11
            elif not a and bb:
                pj = p1[None, :] - p11
            else:
                pj = 1 - p1[:, None] - p1[None, :] + p11
            pj = np.clip(pj, 1e-12, 1)
            mi += pj * np.log(pj / np.clip(pa[:, None] * pb[None, :], 1e-12, 1))
    np.fill_diagonal(mi, 0)
    return mi


def _components(adj: np.ndarray) -> list[np.ndarray]:
    d = adj.shape[0]
    seen = np.zeros(d, bool)
    comps = []
    for s in range(d):
        if seen[s]:
            continue
        stack, comp = [s], []
        seen[s] = True
        while stack:
            u = stack.pop()
            comp.append(u)
            for v in np.flatnonzero(adj[u]):
                if not seen[v]:
                    seen[v] = True
                    stack.append(v)
        comps.append(np.asarray(sorted(comp)))
    return comps


def _cluster_rows(data: np.ndarray, rng: np.random.Generator, k: int = 2,
                  iters: int = 8) -> np.ndarray:
    """k-means on binary rows (Hamming); returns cluster labels."""
    n = data.shape[0]
    cent = data[rng.choice(n, size=k, replace=False)].astype(np.float64)
    lab = np.zeros(n, np.int64)
    for _ in range(iters):
        dist = np.abs(data[:, None, :] - cent[None, :, :]).sum(-1)
        lab = dist.argmin(1)
        for j in range(k):
            sel = data[lab == j]
            if len(sel):
                cent[j] = sel.mean(0)
    return lab


def hmm_spn(num_vars: int, *, n_states: int = 4, seed: int = 0) -> SPN:
    """HMM as an SPN (forward algorithm unrolled) — a DEEP, narrow circuit.

    The paper's benchmark circuits (decision-tree Markov nets [7] compiled
    with [5]) are deep and narrow, unlike LearnSPN's wide mixtures; this
    generator produces that regime: depth grows linearly in ``num_vars``.
    """
    rng = np.random.default_rng(seed)
    b = SPNBuilder()
    K = n_states
    ind = [[b.indicator(v, 1), b.indicator(v, 0)] for v in range(num_vars)]

    def emission(v: int, k: int) -> int:
        p = float(rng.uniform(0.1, 0.9))
        return b.sum(ind[v], [p, 1.0 - p])

    pi = rng.dirichlet(np.ones(K))
    alpha = [b.product([b.sum([emission(0, k)], [1.0]), ])
             for k in range(K)]
    # weight initial states: alpha_k = pi_k * P(x_0|k)
    alpha = [b.sum([a], [float(pi[k])]) for k, a in enumerate(alpha)]
    for t in range(1, num_vars):
        A = rng.dirichlet(np.ones(K), size=K)      # transition rows
        new = []
        for k in range(K):
            mix = b.sum(alpha, [float(A[j][k]) for j in range(K)])
            new.append(b.product([mix, emission(t, k)]))
        alpha = new
    root = b.sum(alpha, [1.0 / K] * K)
    return b.build(root)


def learn_spn(data: np.ndarray, *, mi_threshold: float = 0.02,
              min_instances: int = 40, max_depth: int = 20,
              alpha: float = 0.2, seed: int = 0) -> SPN:
    """LearnSPN-lite on binary ``data`` (rows = samples)."""
    rng = np.random.default_rng(seed)
    b = SPNBuilder()
    num_vars = data.shape[1]
    ind = [[b.indicator(v, 1), b.indicator(v, 0)] for v in range(num_vars)]

    def bern(rows: np.ndarray, v: int) -> int:
        p = float((rows.sum() + alpha) / (len(rows) + 2 * alpha))
        return b.sum(ind[v], [p, 1.0 - p])

    def factorized(rows: np.ndarray, scope: np.ndarray) -> int:
        parts = [bern(rows[:, j], int(scope[j])) for j in range(len(scope))]
        return parts[0] if len(parts) == 1 else b.product(parts)

    def rec(rows: np.ndarray, scope: np.ndarray, depth: int, try_split: bool) -> int:
        if len(scope) == 1:
            return bern(rows[:, 0], int(scope[0]))
        if len(rows) < min_instances or depth >= max_depth:
            return factorized(rows, scope)
        if try_split:
            mi = _mutual_info(rows)
            comps = _components(mi > mi_threshold)
            if len(comps) > 1:
                parts = [rec(rows[:, comp], scope[comp], depth + 1, False)
                         for comp in comps]
                return b.product(parts)
        lab = _cluster_rows(rows, rng)
        groups = [np.flatnonzero(lab == j) for j in range(lab.max() + 1)]
        groups = [g for g in groups if len(g) > 0]
        if len(groups) < 2:  # clustering failed to split
            return factorized(rows, scope)
        parts = [rec(rows[g], scope, depth + 1, True) for g in groups]
        w = [(len(g) + alpha) / (len(rows) + alpha * len(groups)) for g in groups]
        s = sum(w)
        return b.sum(parts, [wi / s for wi in w])

    root = rec(data, np.arange(num_vars), 0, True)
    return b.build(root)


# --------------------------------------------------------------------------- #
# parameter learning
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class ParamState:
    """Learnable view of a program's parameters (sum weights only move)."""
    prog: program.TensorProgram
    params: jnp.ndarray         # (m_param,) current parameter leaf values
    group_idx: jnp.ndarray      # (m_param,) group id per param (-1 = frozen)
    num_groups: int

    @classmethod
    def init(cls, prog: program.TensorProgram) -> "ParamState":
        gidx = np.full(prog.m_param, -1, np.int32)
        for g, idx in enumerate(prog.sum_weight_groups):
            gidx[idx] = g
        return cls(prog=prog, params=jnp.asarray(prog.param_values, jnp.float32),
                   group_idx=jnp.asarray(gidx),
                   num_groups=len(prog.sum_weight_groups))


def _group_normalize(params: jnp.ndarray, group_idx: jnp.ndarray,
                     num_groups: int) -> jnp.ndarray:
    """Renormalize each sum's weights to 1 (frozen params pass through)."""
    grp = jnp.where(group_idx < 0, num_groups, group_idx)
    totals = jnp.zeros(num_groups + 1, params.dtype).at[grp].add(params)
    denom = jnp.where(group_idx < 0, 1.0, totals[grp])
    return params / jnp.maximum(denom, 1e-30)


def em_step(state: ParamState, leaf_ind: jnp.ndarray) -> tuple[ParamState, float]:
    """One exact EM step on sum weights; returns (new state, mean LL)."""
    def total_ll(p):
        return executors.eval_leveled(state.prog, leaf_ind, p, True).sum()

    ll, g = jax.value_and_grad(total_ll)(state.params)
    counts = state.params * g                      # n_k = w_k · Σ ∂logP/∂w_k
    counts = jnp.where(state.group_idx >= 0, jnp.maximum(counts, 1e-8),
                       state.params)
    new = _group_normalize(counts, state.group_idx, state.num_groups)
    new_state = dataclasses.replace(state, params=new)
    return new_state, float(ll) / leaf_ind.shape[0]


def fit_em(prog: program.TensorProgram, X: np.ndarray, *, iters: int = 20,
           verbose: bool = False) -> tuple[ParamState, list[float]]:
    state = ParamState.init(prog)
    leaf_ind = jnp.asarray(prog.leaves_from_evidence(X), jnp.float32)
    hist = []
    for it in range(iters):
        state, ll = em_step(state, leaf_ind)
        hist.append(ll)
        if verbose:
            print(f"EM iter {it:3d}  mean LL {ll:.4f}")
    return state, hist


def fit_sgd(prog: program.TensorProgram, X: np.ndarray, *, steps: int = 200,
            lr: float = 5e-2, batch_size: int = 256, seed: int = 0,
            verbose: bool = False) -> tuple[ParamState, list[float]]:
    """Adam on per-sum softmax logits, through the log-domain executor."""
    state = ParamState.init(prog)
    gi, ng = state.group_idx, state.num_groups
    logits0 = jnp.log(jnp.maximum(state.params, 1e-6))

    def to_params(logits):
        # stable per-group softmax via exp + group normalize
        grp = jnp.where(gi < 0, ng, gi)
        gmax = jnp.full(ng + 1, -jnp.inf).at[grp].max(logits)
        z = jnp.exp(logits - gmax[grp])
        z = jnp.where(gi < 0, state.params, z)
        return _group_normalize(z, gi, ng)

    def loss_fn(logits, li):
        return -executors.eval_leveled(prog, li, to_params(logits), True).mean()

    @jax.jit
    def step(logits, mom, vel, t, li):
        loss, g = jax.value_and_grad(loss_fn)(logits, li)
        mom = 0.9 * mom + 0.1 * g
        vel = 0.999 * vel + 0.001 * g * g
        mh = mom / (1 - 0.9 ** t)
        vh = vel / (1 - 0.999 ** t)
        logits = logits - lr * mh / (jnp.sqrt(vh) + 1e-8)
        return logits, mom, vel, loss

    rng = np.random.default_rng(seed)
    leaf_all = prog.leaves_from_evidence(X)
    logits = logits0
    mom = jnp.zeros_like(logits)
    vel = jnp.zeros_like(logits)
    hist = []
    for t in range(1, steps + 1):
        sel = rng.choice(len(X), size=min(batch_size, len(X)), replace=False)
        li = jnp.asarray(leaf_all[sel], jnp.float32)
        logits, mom, vel, loss = step(logits, mom, vel, t, li)
        hist.append(-float(loss))
        if verbose and t % 50 == 0:
            print(f"SGD step {t:4d}  mean LL {-float(loss):.4f}")
    final = dataclasses.replace(state, params=to_params(logits))
    return final, hist
