"""Flat binary-op tensor program — the paper's alg. 2 (`O`/`B`/`C` vectors).

Lowering an :class:`~repro.core.spn.SPN` produces a :class:`TensorProgram`:

- slots ``[0, m_ind)``            : indicator-leaf inputs (the `IN` vector),
- slots ``[m_ind, m)``            : parameter leaves,
- slots ``[m, m+n)``              : binary op outputs, *level-contiguous*.

Multi-ary sums/products are decomposed into balanced binary trees (depth
``ceil(log2 k)``) — balanced rather than chains so levelization exposes
maximal parallelism, which both the GPU baseline and the PE trees exploit.
Weighted sum edges become ``PROD(w, child)`` ops feeding the sum tree,
matching the paper's "parameters are leaves" convention.

This IR is consumed by every backend: the numpy/JAX executors, the VLIW
compiler, the cycle-accurate simulator and the Pallas kernel.

Opcodes form the *semiring axis* of the query engine
(:mod:`repro.queries`): a sum-product program answers likelihood /
marginal queries, and :func:`to_max_product` rewrites every ``OP_SUM``
into ``OP_MAX`` (the tropical / Viterbi semiring) so the same program
skeleton answers MPE/MAP queries on every substrate.
"""
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

from . import levelize
from .spn import LEAF_IND, LEAF_PARAM, PROD, SUM, SPN

OP_SUM = 0
OP_PROD = 1
OP_MAX = 2   # tropical semiring: MPE / Viterbi sweeps (max in both domains)


@dataclasses.dataclass(eq=False)  # identity hash: programs are static jit args
class TensorProgram:
    m_ind: int                 # number of indicator-leaf slots
    m_param: int               # number of parameter-leaf slots
    param_values: np.ndarray   # (m_param,) float64
    opcode: np.ndarray         # (n,) uint8 — the paper's O vector (0=sum,1=prod,2=max)
    b: np.ndarray              # (n,) int32 — first operand slot
    c: np.ndarray              # (n,) int32 — second operand slot
    level_offsets: np.ndarray  # (L+1,) int32 op ranges per level
    root_slot: int
    ind_var: np.ndarray        # (m_ind,) int32 variable of each indicator slot
    ind_value: np.ndarray      # (m_ind,) int32 indicator value
    # param indices (into param_values) of each weighted sum node's weights —
    # the unit of normalization for EM / softmax-SGD learning.
    sum_weight_groups: list[np.ndarray] = dataclasses.field(default_factory=list)
    # multi-root programs (cross-batch interleave): slot of EVERY instance's
    # root, instance order. None for ordinary single-root programs;
    # ``root_slot`` always equals ``root_slots[0]`` when present.
    root_slots: np.ndarray | None = None

    @property
    def op_is_prod(self) -> np.ndarray:
        """Boolean PROD mask (back-compat view of :attr:`opcode`)."""
        return self.opcode == OP_PROD

    @property
    def m(self) -> int:
        return self.m_ind + self.m_param

    @property
    def n_ops(self) -> int:
        return len(self.b)

    @property
    def num_levels(self) -> int:
        return len(self.level_offsets) - 1

    @property
    def num_slots(self) -> int:
        return self.m + self.n_ops

    @property
    def num_vars(self) -> int:
        return int(self.ind_var.max()) + 1 if self.m_ind else 0

    def level_sizes(self) -> np.ndarray:
        return np.diff(self.level_offsets)

    def digest(self) -> str:
        """Stable content hash of the program (sha256 hex, cached).

        Two programs lowered from identical SPNs — e.g. the same circuit
        re-learned from the same data — hash equal, so compiled-artifact
        caches (:mod:`repro.runtime.cache`) survive object identity
        changes. Covers every field that affects evaluation: structure
        (B/C/O vectors, levels, root), leaf layout and parameter values.
        Mutating ``param_values`` in place (EM / SGD learning) must be
        followed by :meth:`invalidate_digest`.
        """
        cached = getattr(self, "_digest", None)
        if cached is not None:
            return cached
        h = hashlib.sha256()
        h.update(np.asarray(
            [self.m_ind, self.m_param, self.root_slot], np.int64).tobytes())
        for arr, dt in ((self.opcode, np.uint8), (self.b, np.int32),
                        (self.c, np.int32), (self.level_offsets, np.int32),
                        (self.ind_var, np.int32), (self.ind_value, np.int32),
                        (self.param_values, np.float64)):
            a = np.ascontiguousarray(np.asarray(arr, dt))
            h.update(np.asarray(a.shape, np.int64).tobytes())
            h.update(a.tobytes())
        for g in self.sum_weight_groups:
            h.update(np.ascontiguousarray(np.asarray(g, np.int32)).tobytes())
        if self.root_slots is not None:   # multi-root (interleaved) programs
            h.update(b"roots")
            h.update(np.ascontiguousarray(
                np.asarray(self.root_slots, np.int64)).tobytes())
        self._digest = h.hexdigest()
        return self._digest

    def invalidate_digest(self) -> None:
        """Drop the cached digest after in-place parameter mutation."""
        self._digest = None

    # ------------------------------------------------------------------ #
    def leaves_from_evidence(self, x: np.ndarray) -> np.ndarray:
        """Indicator inputs for evidence rows ``x`` of shape (batch, num_vars).

        ``x[b, v] == -1`` marginalizes variable ``v`` (both indicators 1).
        """
        x = np.atleast_2d(x)
        ev = x[:, self.ind_var]
        return ((ev == self.ind_value[None, :]) | (ev == -1)).astype(np.float64)

    def full_input(self, leaf_ind: np.ndarray) -> np.ndarray:
        """Concatenate indicator inputs with (broadcast) parameter leaves."""
        leaf_ind = np.atleast_2d(leaf_ind)
        par = np.broadcast_to(self.param_values, (leaf_ind.shape[0], self.m_param))
        return np.concatenate([leaf_ind, par], axis=1)

    def validate(self) -> None:
        n, m = self.n_ops, self.m
        assert self.b.shape == (n,) and self.c.shape == (n,)
        assert (self.b < m + np.arange(n)).all(), "b must reference earlier slots"
        assert (self.c < m + np.arange(n)).all(), "c must reference earlier slots"
        assert (self.b >= 0).all() and (self.c >= 0).all()
        assert self.level_offsets[0] == 0 and self.level_offsets[-1] == n
        # level-contiguity: operands of level ℓ come from levels < ℓ
        for lo, hi in zip(self.level_offsets[:-1], self.level_offsets[1:]):
            assert (self.b[lo:hi] < m + lo).all() and (self.c[lo:hi] < m + lo).all()
        if self.root_slots is not None:
            assert int(self.root_slots[0]) == self.root_slot
            assert all(m <= int(s) < self.num_slots for s in self.root_slots)


def interleave(prog: TensorProgram, k: int) -> TensorProgram:
    """K independent evaluations of the same SPN as ONE program.

    §Perf-C (software pipelining): the processor's pipelined PE trees
    leave RAW bubbles when a single evaluation's dependency chains are
    narrow; the paper's throughput workload (100k executions averaged)
    lets consecutive evaluations overlap. Interleaving K instances — the
    indicator leaves are replicated per instance, the *parameter* leaves
    shared — multiplies the per-level independent work by K so the
    scheduler fills the bubbles. Throughput is ``useful_ops / cycles``
    across all K instances.

    The result is a *multi-root* program: ``root_slots[j]`` is instance
    ``j``'s root (``root_slot`` stays instance 0's root for consumers
    that only know single-root programs). The VLIW compiler stores every
    root and the fast/checked sims return a ``(k, batch)`` root block,
    which the vliw-mc substrate de-interleaves back into request order —
    that is what makes interleave a *serving* knob, not just a
    throughput-accounting trick.
    """
    m_ind, m_par, n = prog.m_ind, prog.m_param, prog.n_ops
    m_new = k * m_ind + m_par

    def remap(sl: np.ndarray, inst: int) -> np.ndarray:
        out = np.where(sl < m_ind, sl + inst * m_ind, 0)
        out = np.where((sl >= m_ind) & (sl < prog.m),
                       sl + (k - 1) * m_ind, out)
        return np.where(sl >= prog.m,
                        m_new + (sl - prog.m) * k + inst, out)

    b_parts, c_parts, o_parts = [], [], []
    offsets = [0]
    for lo, hi in zip(prog.level_offsets[:-1], prog.level_offsets[1:]):
        lo, hi = int(lo), int(hi)
        for i in range(lo, hi):
            for inst in range(k):       # instance-minor: op i → slots i*k+inst
                b_parts.append(remap(prog.b[i: i + 1], inst))
                c_parts.append(remap(prog.c[i: i + 1], inst))
                o_parts.append(prog.opcode[i: i + 1])
        offsets.append(hi * k)

    out = TensorProgram(
        m_ind=k * m_ind, m_param=m_par,
        param_values=prog.param_values.copy(),
        opcode=np.concatenate(o_parts),
        b=np.concatenate(b_parts).astype(np.int32),
        c=np.concatenate(c_parts).astype(np.int32),
        level_offsets=np.asarray(offsets, np.int32),
        root_slot=int(m_new + (prog.root_slot - prog.m) * k),
        ind_var=np.tile(prog.ind_var, k),
        ind_value=np.tile(prog.ind_value, k),
        sum_weight_groups=list(prog.sum_weight_groups),
        root_slots=np.asarray(
            [m_new + (prog.root_slot - prog.m) * k + inst
             for inst in range(k)], np.int64),
    )
    out.validate()
    return out


def to_max_product(prog: TensorProgram) -> TensorProgram:
    """Rewrite a sum-product program into its max-product (Viterbi) twin.

    Every ``OP_SUM`` becomes ``OP_MAX``; ``OP_PROD`` (including the
    weight-times-child ops that weighted sum edges lower into) is
    unchanged, so the max tree maximizes ``w_k * child_k`` exactly as the
    MPE semiring prescribes. The program skeleton (slots, levels, B/C
    vectors, root) is shared with the sum-product twin, which is what lets
    every substrate — numpy oracle, leveled JAX, Pallas kernel, VLIW
    processor — run MPE sweeps with the machinery it already has.

    Note the returned program is a *new object*: substrate-level caches
    (kernel builds, VLIW compiles) key on program identity, so hold on to
    the result (as :class:`repro.queries.QueryEngine` does) instead of
    re-deriving it per call.
    """
    return dataclasses.replace(
        prog,
        opcode=np.where(prog.opcode == OP_SUM, OP_MAX,
                        prog.opcode).astype(np.uint8),
        param_values=prog.param_values.copy(),
        sum_weight_groups=list(prog.sum_weight_groups),
    )


def lower(spn: SPN) -> TensorProgram:
    """Lower an SPN DAG to a level-sorted binary TensorProgram."""
    # ---- slot assignment for leaves -------------------------------------
    ind_nodes = np.flatnonzero(spn.node_type == LEAF_IND)
    par_nodes = np.flatnonzero(spn.node_type == LEAF_PARAM)
    m_ind, m_par0 = len(ind_nodes), len(par_nodes)
    slot_of_node: dict[int, int] = {}
    for s, nd in enumerate(ind_nodes):
        slot_of_node[int(nd)] = s
    param_values: list[float] = [float(spn.param_value[nd]) for nd in par_nodes]
    for s, nd in enumerate(par_nodes):
        slot_of_node[int(nd)] = m_ind + s

    # Weight parameters get appended after explicit param leaves.
    def new_param(v: float) -> int:
        param_values.append(float(v))
        return m_ind + len(param_values) - 1

    # Op emission with temporary slot ids (m will be patched after we know
    # the final param count, so emit with param-relative bookkeeping).
    ops_is_prod: list[int] = []
    ops_b: list[int] = []
    ops_c: list[int] = []
    weight_groups: list[np.ndarray] = []
    PARAM_BASE = 1 << 40   # tag so leaf slots survive the later m shift
    OP_BASE = 1 << 41

    def emit(is_prod: int, bslot: int, cslot: int) -> int:
        ops_is_prod.append(is_prod)
        ops_b.append(bslot)
        ops_c.append(cslot)
        return OP_BASE + len(ops_is_prod) - 1

    def balanced_reduce(slots: list[int], is_prod: int) -> int:
        while len(slots) > 1:
            nxt = []
            for i in range(0, len(slots) - 1, 2):
                nxt.append(emit(is_prod, slots[i], slots[i + 1]))
            if len(slots) % 2:
                nxt.append(slots[-1])
            slots = nxt
        return slots[0]

    for i in range(spn.num_nodes):
        t = spn.node_type[i]
        if t in (LEAF_IND, LEAF_PARAM):
            continue
        ch = [slot_of_node[c] for c in spn.children[i]]
        if t == SUM:
            w = spn.weights[i]
            if w is not None:
                pidx = [new_param(wi) - m_ind for wi in w]
                weight_groups.append(np.asarray(pidx, dtype=np.int32))
                ch = [emit(OP_PROD, PARAM_BASE + pi, cs)
                      for pi, cs in zip(pidx, ch)]
            slot_of_node[i] = ch[0] if len(ch) == 1 else balanced_reduce(ch, OP_SUM)
        else:  # PROD
            slot_of_node[i] = ch[0] if len(ch) == 1 else balanced_reduce(ch, OP_PROD)

    m_param = len(param_values)
    m = m_ind + m_param

    def resolve(s: int) -> int:
        if s >= OP_BASE:
            return m + (s - OP_BASE)
        if s >= PARAM_BASE:
            return m_ind + (s - PARAM_BASE)
        if s >= m_ind and s < m_ind + m_par0:
            return s  # explicit param leaf — already in final position
        return s      # indicator leaf

    n = len(ops_is_prod)
    if n == 0:
        # Degenerate: root is a leaf. Emit a forwarding op (x*1) for uniformity.
        one = new_param(1.0)
        m_param = len(param_values)
        m = m_ind + m_param
        root_raw = slot_of_node[spn.root]
        rr = resolve(root_raw) if root_raw < PARAM_BASE else m_ind + (root_raw - PARAM_BASE)
        ops_is_prod, ops_b, ops_c = [OP_PROD], [rr], [one]
        n = 1
        slot_of_node[spn.root] = OP_BASE

    b = np.array([resolve(s) for s in ops_b], dtype=np.int32)
    c = np.array([resolve(s) for s in ops_c], dtype=np.int32)
    op = np.array(ops_is_prod, dtype=np.uint8)

    perm, new_b, new_c, offsets = levelize.level_sort(b, c, m)
    new_op = op[perm]
    # root slot under the new numbering
    new_slot_of_old = np.empty(n, dtype=np.int64)
    new_slot_of_old[perm] = np.arange(n)
    root_raw = slot_of_node[spn.root]
    if root_raw >= OP_BASE:
        root_slot = int(m + new_slot_of_old[root_raw - OP_BASE])
    elif root_raw >= PARAM_BASE:
        root_slot = m_ind + (root_raw - PARAM_BASE)
    else:
        root_slot = root_raw

    prog = TensorProgram(
        m_ind=m_ind,
        m_param=m_param,
        param_values=np.asarray(param_values, dtype=np.float64),
        opcode=new_op,
        b=new_b,
        c=new_c,
        level_offsets=offsets,
        root_slot=root_slot,
        ind_var=spn.leaf_var[ind_nodes].astype(np.int32),
        ind_value=spn.leaf_value[ind_nodes].astype(np.int32),
        sum_weight_groups=weight_groups,
    )
    prog.validate()
    return prog
