"""Lockstep cycle-accurate simulation of N cores + the interconnect.

All cores share one global clock. Each global cycle every unfinished
core attempts one VLIW instruction (:meth:`CoreSim.step`); a core whose
crossbar reads hit a shared-register-window cell still in flight stalls
that cycle (full/empty-bit flow control) and retries. SENDs push window
rows onto the :class:`~repro.core.multicore.comm.Interconnect` with
cycle-accounted arrival times — including per-link NoC contention and
injection-port arbitration on physical topologies (ring/mesh/torus) —
and arrived rows land through the window fill port even while a core is
frozen. The result's ``comm`` section carries the link occupancy
accounting (busiest-link occupancy, link/inject stall cycles).

Cores that finish early idle at the implicit end-of-program barrier; the
result separates *flow-control stalls* (waiting for a row in transit)
from *barrier idle* (done, waiting for the slowest core), the two
numbers a partition tuner needs.

Total cycle count is **value-independent** — stalls depend only on the
static schedules and transfer latencies — so one 1-row calibration run
at compile time yields the exact serving cycle cost (recorded in the
``vliw-mc`` artifact metadata).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..processor.config import ProcessorConfig
from ..processor.sim import CoreSim, SimError
from .comm import Interconnect
from .compile import MultiCoreProgram

_MAX_GLOBAL_CYCLES = 8_000_000


@dataclasses.dataclass
class MCSimResult:
    root_values: np.ndarray      # (batch,) — (k, batch) when interleaved
    cycles: int                  # global cycles to the last core's finish
    useful_ops: int
    ops_per_cycle: float
    core_cycles: list            # per-core instruction counts
    core_finish: list            # per-core global finish cycle
    stall_cycles: list           # per-core flow-control stalls
    barrier_idle: list           # per-core cycles idling at the barrier
    comm: dict                   # rows/values shipped, max window residency
    checks: dict


def simulate_multicore(mcp: MultiCoreProgram, leaf_ind: np.ndarray,
                       cfg: ProcessorConfig | None = None,
                       recorder=None) -> MCSimResult:
    """Checked lockstep simulation from global indicator-leaf inputs.

    ``recorder`` (a :class:`repro.obs.timeline.TimelineRecorder`)
    optionally captures the per-core, per-cycle state timeline — one of
    ``issue`` / ``stall`` / ``barrier`` per core per global cycle, plus
    SEND/RECV markers and NoC link-occupancy intervals — for the
    ``serve --trace`` cycle-timeline export. ``None`` (the default)
    keeps the simulation loop unchanged.
    """
    cfg = cfg or mcp.cfg
    leaf_ind = np.atleast_2d(leaf_ind)
    batch = leaf_ind.shape[0]
    net = Interconnect(mcp.plan, recorder=recorder)
    cores = []
    for cp in mcp.cores:
        local = (leaf_ind[:, cp.leaf_map] if len(cp.leaf_map)
                 else np.zeros((batch, 0), leaf_ind.dtype))
        cores.append(CoreSim(cp.vprog, local, cfg, core_id=cp.core,
                             interconnect=net, recorder=recorder))

    g = 0
    while any(not c.finished() for c in cores):
        if g >= _MAX_GLOBAL_CYCLES:
            raise SimError(f"multi-core run exceeded {_MAX_GLOBAL_CYCLES} "
                           "global cycles")
        progressed = False
        for c in cores:
            if c.finished():
                if recorder is not None:
                    recorder.core_state(c.core_id, g, "barrier")
                continue
            ok = c.step(g)
            progressed |= ok
            if recorder is not None:
                recorder.core_state(c.core_id, g, "issue" if ok else "stall")
        if not progressed and not net.in_transit(g):
            frozen = [(c.core_id, c.t) for c in cores if not c.finished()]
            raise SimError(f"interconnect deadlock at global cycle {g}: "
                           f"stalled cores (id, pc) = {frozen}")
        g += 1

    root = cores[mcp.root_core].root_values()
    useful = sum(c.useful for c in cores)
    finish = [int(c.finish_at) + 1 for c in cores]
    checks: dict = {"read_conflicts_checked": 0,
                    "write_conflicts_checked": 0}
    for c in cores:
        for k in checks:
            checks[k] += c.checks[k]
    return MCSimResult(
        root_values=root, cycles=g, useful_ops=useful,
        ops_per_cycle=useful / max(g, 1),
        core_cycles=[len(c.vprog.instrs) for c in cores],
        core_finish=finish,
        stall_cycles=[c.stall_cycles for c in cores],
        barrier_idle=[g - f for f in finish],
        comm=dict({"rows_sent": net.sends, "values_sent": net.values_sent,
                   "max_window_rows": net.max_resident,
                   "row_arrivals": {rid: int(arr)
                                    for rid, (arr, _p) in net.rows.items()}},
                  **net.link_stats(total_cycles=g)),
        checks=checks)
