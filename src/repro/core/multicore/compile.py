"""Per-core program extraction and multi-core VLIW compilation.

Each core's share of the partitioned DAG becomes an ordinary
:class:`~repro.core.program.TensorProgram` whose leaf slots are:

- ``[0, n_ind)``            — the *global indicator leaves* this core
  actually reads (ascending global slot order, so the compiled
  ``input_layout`` indexes straight into ``leaf_map`` columns),
- ``[n_ind, n_ind+n_recv)`` — *recv slots*: values imported from other
  cores over the interconnect (ordered by channel row/position),
- params after               — the parameter leaves this core reads.

Because each binary op keeps exactly its original operands (locally
renumbered), the merged dataflow across all cores is the identical
f32 DAG the single-core program executes — the root value is
bit-identical by construction, which the conformance tests assert.

``cores=1`` degenerates to the identity: the local program equals the
global one slot for slot (same opcode/operand/param arrays; only the
``sum_weight_groups`` learning metadata is dropped), so the compiled
stream — and its cycle count — matches the single-core ``vliw-sim``
substrate.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .. import levelize
from ..compiler import isa
from ..compiler.pipeline import compile_program
from ..processor.config import PTREE, ProcessorConfig
from ..program import TensorProgram
from . import comm as comm_mod
from .comm import XBAR, CommPlan, InterconnectConfig, build_comm_plan
from .partition import Partition, partition_ops


@dataclasses.dataclass
class CorePlan:
    """One core's program, leaf wiring and communication spec."""
    core: int                      # effective core index
    prog: TensorProgram
    leaf_map: np.ndarray           # (n_ind,) global indicator slots
    gid_of_op: np.ndarray          # (n_local_ops,) global op ids
    comm: isa.CommSpec
    vprog: isa.VLIWProgram | None = None


@dataclasses.dataclass
class MultiCoreProgram:
    """Everything the lockstep simulator / merged decoder needs."""
    prog: TensorProgram            # the global program
    cfg: ProcessorConfig
    icfg: InterconnectConfig
    n_cores: int                   # requested core count
    cores: list                    # [CorePlan, ...] — effective cores only
    plan: CommPlan
    partition: Partition
    root_core: int                 # index into ``cores``
    meta: dict = dataclasses.field(default_factory=dict)

    @property
    def n_effective(self) -> int:
        return len(self.cores)


def build_core_programs(prog: TensorProgram, part: Partition,
                        icfg: InterconnectConfig = XBAR,
                        banks: int = 32) -> tuple[list, CommPlan]:
    """Extract one TensorProgram (+ CommSpec) per non-empty core."""
    m_ind, m = prog.m_ind, prog.m
    used = sorted(int(c) for c in np.unique(part.core_of_op))
    core_index = {c: i for i, c in enumerate(used)}
    # one height computation feeds both the row chunking order and the
    # per-core scheduler priorities — they must agree
    gh = comm_mod.global_heights(prog)
    plan = build_comm_plan(prog, part, core_index, icfg, banks=banks,
                           heights=gh)
    row_level = {r.row_id: r.level for r in plan.rows}
    row_size = plan.members

    plans: list[CorePlan] = []
    root_gid = prog.root_slot - m
    for pc in used:
        k = core_index[pc]
        gids = np.flatnonzero(part.core_of_op == pc)
        gid_set = set(int(g) for g in gids)

        # leaf slots this core reads ---------------------------------------
        ind_used: set[int] = set()
        par_used: set[int] = set()
        recv_used: set[int] = set()          # remote gids
        for g in gids:
            for s in (int(prog.b[g]), int(prog.c[g])):
                if s < m_ind:
                    ind_used.add(s)
                elif s < m:
                    par_used.add(s)
                elif (s - m) not in gid_set:
                    recv_used.add(s - m)
        leaf_map = np.asarray(sorted(ind_used), np.int64)
        # recv slots ordered by (row, position) — deterministic and
        # row-contiguous, which keeps the window layout readable
        recv_list = sorted(recv_used,
                           key=lambda g: plan.value_pos[(g, k)])
        par_list = sorted(par_used)

        n_ind, n_recv = len(leaf_map), len(recv_list)
        m_ind_loc = n_ind + n_recv
        m_loc = m_ind_loc + len(par_list)
        slot_of = {int(s): i for i, s in enumerate(leaf_map)}
        slot_of.update({m + g: n_ind + i for i, g in enumerate(recv_list)})
        slot_of.update({int(s): m_ind_loc + i
                        for i, s in enumerate(par_list)})
        op_slot = {int(g): m_loc + i for i, g in enumerate(gids)}

        def remap(s: int) -> int:
            if s < m:                       # leaf (indicator or param)
                return slot_of[s]
            g2 = s - m
            # local op output, or a recv slot for a remote value
            return op_slot[g2] if g2 in gid_set else slot_of[m + g2]

        b = np.asarray([remap(int(prog.b[g])) for g in gids], np.int32)
        c = np.asarray([remap(int(prog.c[g])) for g in gids], np.int32)

        perm, new_b, new_c, offsets = levelize.level_sort(b, c, m_loc)
        gid_perm = gids[perm]
        opcode = prog.opcode[gid_perm]

        ind_var = np.full(m_ind_loc, -1, np.int32)
        ind_value = np.full(m_ind_loc, -2, np.int32)
        ind_var[:n_ind] = prog.ind_var[leaf_map]
        ind_value[:n_ind] = prog.ind_value[leaf_map]
        param_values = (prog.param_values[[s - m_ind for s in par_list]]
                        if par_list else np.zeros(0, np.float64))

        local_op_of_gid = {int(g): i for i, g in enumerate(gid_perm)}
        root_slots_loc = None
        if root_gid in gid_set:
            root_slot = m_loc + local_op_of_gid[root_gid]
            if prog.root_slots is not None:
                # multi-root (interleaved): the partitioner pins every
                # instance root onto the root core — carry them all over
                # in instance order so the epilogue stores each one
                root_gids = [int(s) - m for s in prog.root_slots]
                assert all(g in gid_set for g in root_gids), \
                    "interleaved instance roots split across cores"
                root_slots_loc = np.asarray(
                    [m_loc + local_op_of_gid[g] for g in root_gids],
                    np.int64)
        else:
            root_slot = m_loc + len(gids) - 1     # highest-level local op

        sub = TensorProgram(
            m_ind=m_ind_loc, m_param=len(par_list),
            param_values=np.asarray(param_values, np.float64),
            opcode=opcode.astype(np.uint8), b=new_b, c=new_c,
            level_offsets=offsets, root_slot=int(root_slot),
            ind_var=ind_var, ind_value=ind_value,
            sum_weight_groups=[], root_slots=root_slots_loc)
        sub.validate()

        recv_slots = {n_ind + i: plan.value_pos[(g, k)]
                      for i, g in enumerate(recv_list)}
        send_ops: dict[int, list] = {}
        for g, i in local_op_of_gid.items():
            entries = [plan.value_pos[(g, d)] for d in range(len(used))
                       if (g, d) in plan.value_pos]
            if entries:
                send_ops[i] = entries
        comm = isa.CommSpec(recv_slots=recv_slots, send_ops=send_ops,
                            row_level=row_level, row_size=row_size,
                            op_height={i: int(gh[g])
                                       for g, i in local_op_of_gid.items()})
        plans.append(CorePlan(core=k, prog=sub, leaf_map=leaf_map,
                              gid_of_op=gid_perm.astype(np.int64),
                              comm=comm))
    return plans, plan


def compile_multicore(prog: TensorProgram, cfg: ProcessorConfig = PTREE,
                      n_cores: int = 2, icfg: InterconnectConfig = XBAR,
                      *, seed: int = 0, strategy: str = "subtree",
                      eta_iters: int = 2, passes: int = 0,
                      placement: str = "aware",
                      grain: int | None = None,
                      max_arity: int | None = None,
                      allowed_cores: tuple | None = None,
                      **compile_kwargs) -> MultiCoreProgram:
    """Partition, build and VLIW-compile ``prog`` for ``n_cores`` cores.

    After the optimistic first compile, ``eta_iters`` rounds of
    *timing-probe feedback* run: a 1-row lockstep simulation (cycle
    counts are value-independent) measures when every channel row
    actually arrives — per-link NoC contention included — and each core
    is recompiled scheduling its remote reads at those ETAs: local work
    fills what used to be flow-control stalls, and schedules adapt to
    measured link contention. The best-cycle iteration wins (the probe
    is exact, so this is a monotone ratchet on the real serving cost).

    ``placement="aware"`` (default) lets the partitioner permute core
    labels on physical topologies so chatty core pairs land adjacent
    (see :func:`~repro.core.multicore.partition.place_cores`);
    ``"naive"`` keeps the flat partition for comparison. ``grain`` and
    ``max_arity`` forward to :func:`partition_ops` — autotuner knobs for
    cone-crown size and fused-unit granularity.

    ``allowed_cores`` compiles for a *degraded* machine: the partition
    is restricted to the surviving physical core subset (see
    :func:`partition_ops`), and the resulting comm plan is validated
    against the interconnect's dead links
    (:meth:`~repro.core.multicore.comm.CommPlan.check_links` — raising
    :class:`~repro.core.multicore.comm.LinkDownError` when no feasible
    route exists, which the resilience layer catches to descend to
    fewer cores or another substrate).
    """
    from ...obs import trace
    from .sim import simulate_multicore   # local import: cycle avoidance

    with trace.span("compile.partition",
                    lambda: {"cores": n_cores, "strategy": strategy,
                             "topology": icfg.topology,
                             "placement": placement, "n_ops": prog.n_ops}):
        part = partition_ops(prog, n_cores, seed=seed, strategy=strategy,
                             passes=passes, icfg=icfg, placement=placement,
                             grain=grain, max_arity=max_arity,
                             allowed_cores=allowed_cores)
    with trace.span("compile.core_programs",
                    lambda: {"cut_values": part.cut_values,
                             "hop_cut": part.hop_cut}):
        plans, plan = build_core_programs(prog, part, icfg, banks=cfg.banks)
    plan.check_links()      # degraded-mode feasibility (LinkDownError)
    root_gid = prog.root_slot - prog.m
    root_core = next(i for i, cp in enumerate(plans)
                     if root_gid in set(int(g) for g in cp.gid_of_op))

    def recompile(cp: CorePlan) -> None:
        # only the root-owning core stores a root row; every other
        # core's outputs are its SENDs (skipping the pseudo-root store
        # shaves the fixed epilogue off short worker streams)
        cp.vprog = compile_program(cp.prog, cfg, comm=cp.comm,
                                   store_root=(cp.core ==
                                               plans[root_core].core),
                                   **compile_kwargs)

    with trace.span("compile.schedule",
                    lambda: {"cores": len(plans)}):
        for cp in plans:
            recompile(cp)
    mcp = MultiCoreProgram(prog=prog, cfg=cfg, icfg=icfg, n_cores=n_cores,
                           cores=plans, plan=plan, partition=part,
                           root_core=root_core)

    probe_leaves = np.ones((1, prog.m_ind), np.float32)
    best_vprogs, best_res = None, None
    for it in range(max(0, eta_iters) + 1):
        with trace.span("compile.eta_round", {"round": it}) as sp:
            res = simulate_multicore(mcp, probe_leaves)
            sp.set("cycles", res.cycles)
            if best_res is None or res.cycles < best_res.cycles:
                best_vprogs = [cp.vprog for cp in plans]
                best_res = res
            if it == eta_iters or not plan.rows:
                break
            etas = res.comm["row_arrivals"]
            for cp in plans:
                cp.comm.row_eta = dict(etas)
                recompile(cp)
    for cp, v in zip(plans, best_vprogs):
        cp.vprog = v

    mcp.meta = {
        "n_cores": n_cores, "effective_cores": len(plans),
        "cut_values": part.cut_values,
        "hop_cut": part.hop_cut,
        "strategy": part.strategy,
        "grain": grain,
        "max_arity": max_arity,
        "topology": icfg.topology,
        "interconnect": icfg.fingerprint(),
        "placement": placement,
        "core_placement": part.core_placement,
        "core_labels": [int(plan.geometry(cp.core)) for cp in plans],
        "links_used": [[int(a), int(b)] for a, b in plan.links_used()],
        "comm": dict(plan.stats(), **best_res.comm),
        "cycles": best_res.cycles,
        "core_cycles": [cp.vprog.num_cycles for cp in plans],
        "core_ops": [int(len(cp.gid_of_op)) for cp in plans],
        "stall_cycles": best_res.stall_cycles,
        "barrier_idle": best_res.barrier_idle,
        "ops_per_cycle": best_res.ops_per_cycle,
    }
    return mcp
