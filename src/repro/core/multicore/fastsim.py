"""Merged dense decode of a multi-core program.

Each core's VLIW stream is replayed symbolically
(:func:`repro.core.processor.fastsim.symbolic_replay`): SEND rows record
which SSA value each (channel row, position) exports, RECV rows
introduce import placeholders. Because every channel value has exactly
one producer, the per-core graphs stitch together in one resolution
pass — no lockstep interleaving is needed at decode time — and the
merged graph is then level-sorted and segmented by the same
:func:`~repro.core.processor.fastsim.densify` the single-core fast-sim
uses.

The merged dataflow is, op for op, the global program's binary DAG (the
partition only renames slots), executed with the same f32 ufuncs — so
:func:`repro.core.processor.fastsim.run` on the merged program is
**bit-identical** both to the lockstep checked simulator and to the
single-core fast-sim oracle. Leaf indicator columns feed multiple
per-core duplicate cells via ``DenseProgram.input_slots``.
"""
from __future__ import annotations

import numpy as np

from ..compiler import isa
from ..processor.config import ProcessorConfig
from ..processor.fastsim import densify, symbolic_replay
from ..processor.sim import SimError
from .compile import MultiCoreProgram


def decode_multicore(mcp: MultiCoreProgram,
                     cfg: ProcessorConfig | None = None,
                     cycles: int | None = None) -> isa.DenseProgram:
    """Merge all cores' streams into one :class:`DenseProgram`.

    ``cycles`` should be the lockstep simulator's calibrated global
    cycle count (stalls included); it defaults to the slowest core's
    instruction count (a lower bound).
    """
    cfg = cfg or mcp.cfg
    members = mcp.plan.members
    reps = [symbolic_replay(cp.vprog, cfg, members_of=members)
            for cp in mcp.cores]

    init_off = np.cumsum([0] + [r.n_init for r in reps])
    op_off = np.cumsum([0] + [len(r.opcode) for r in reps])
    n_init = int(init_off[-1])

    def shift(core: int, v: int) -> int:
        if v < reps[core].n_init:
            return int(init_off[core]) + v
        return n_init + int(op_off[core]) + (v - reps[core].n_init)

    exports: dict[tuple[int, int], int] = {}
    for k, r in enumerate(reps):
        for key, v in r.exports.items():
            exports[key] = shift(k, v)

    o_parts, a_parts, b_parts = [], [], []
    cell_parts, slot_parts = [], []
    for k, r in enumerate(reps):
        def resolve(arr: np.ndarray) -> np.ndarray:
            out = np.empty(len(arr), np.int64)
            for i, v in enumerate(arr):
                v = int(v)
                if v >= 0:
                    out[i] = shift(k, v)
                else:
                    key = r.imports[-v - 1]
                    if key not in exports:
                        raise SimError(f"channel value {key} recv'd on core "
                                       f"{k} but never sent")
                    out[i] = exports[key]
            return out

        o_parts.append(r.opcode)
        a_parts.append(resolve(r.a))
        b_parts.append(resolve(r.b))
        cell_parts.append(init_off[k] + r.input_cells)
        slot_parts.append(mcp.cores[k].leaf_map)

    root = shift(mcp.root_core, reps[mcp.root_core].root)
    root_rep = reps[mcp.root_core]
    roots = ([shift(mcp.root_core, r) for r in root_rep.roots]
             if root_rep.roots is not None else None)
    if cycles is None:
        cycles = max(len(cp.vprog.instrs) for cp in mcp.cores)

    o = np.concatenate(o_parts).astype(np.uint8)
    a = np.concatenate(a_parts).astype(np.int64)
    b = np.concatenate(b_parts).astype(np.int64)

    # cross-core operands may point *forward* in the concatenation order;
    # densify's level computation assumes producers precede consumers, so
    # topologically re-sort (Kahn — also proves the merged DAG is acyclic)
    n = len(o)
    indeg = np.zeros(n, np.int64)
    adj: list[list[int]] = [[] for _ in range(n)]
    for i in range(n):
        for s in (int(a[i]), int(b[i])):
            if s >= n_init:
                adj[s - n_init].append(i)
                indeg[i] += 1
    queue = [i for i in range(n) if indeg[i] == 0]
    order: list[int] = []
    while queue:
        u = queue.pop()
        order.append(u)
        for v in adj[u]:
            indeg[v] -= 1
            if indeg[v] == 0:
                queue.append(v)
    if len(order) != n:
        raise SimError("cycle in merged multi-core dataflow")
    perm = np.asarray(order, np.int64)
    new_idx = np.empty(n, np.int64)
    new_idx[perm] = np.arange(n)
    remap = lambda x: np.where(x >= n_init, new_idx[np.maximum(x - n_init, 0)]
                               + n_init, x)
    o, a, b = o[perm], remap(a[perm]), remap(b[perm])
    if root >= n_init:
        root = int(n_init + new_idx[root - n_init])
    if roots is not None:
        roots = [int(n_init + new_idx[r - n_init]) if r >= n_init else r
                 for r in roots]

    return densify(
        o, a, b, n_init,
        np.concatenate([r.init_values for r in reps]),
        np.concatenate(cell_parts).astype(np.int32),
        root, int(cycles), sum(r.n_useful_ops for r in reps),
        input_slots=np.concatenate(slot_parts).astype(np.int32)
        if slot_parts else None,
        roots=roots)
