"""Level-aware balanced min-cut partitioning of an SPN program.

The unit of placement is the segment scheduler's *fused node*
(:func:`repro.core.segments.fusion_info`): a whole k-ary reduction tree
whose interior values never escape. Cutting inside a fused node would
turn register-local PE-tree traffic into interconnect traffic, so only
fused-node *outputs* ever cross cores — the cut values of the partition
are exactly the fused roots with a consumer on another core.

The objective is the lockstep multi-core schedule's makespan. Two
placement strategies share the refinement machinery:

- ``"subtree"`` (default) — bottom-up cluster growth in topological
  order: each fused node joins the core owning its heaviest operand
  cluster unless that core is full, so whole SPN subtrees stay
  core-local and only the combining cone near the root crosses cores.
  SPN DAGs are tree-dominated, which makes this the min-cut shape: the
  cut size approaches the core count instead of the level width, and
  with it the number of latency-paying cross-core hops on the critical
  path.
- ``"cone"`` — the root cone (the narrow top levels whose combined
  weight fits one core's fair share) is pinned whole to the last core;
  the leaf forest below it is LPT-distributed over all cores (the cone
  core starts with the cone as its load). The serial combining path
  then lives on ONE core and overlaps the other cores' subtree
  computation as their results stream in, instead of hopping core to
  core and paying transfer latency per hop.
- ``"level"`` — per-fused-level LPT balance with operand-affinity
  tie-breaks; every level is spread across all cores. Maximal level
  parallelism, but every level boundary becomes interconnect traffic —
  kept for machines whose interconnect is effectively free.

``subtree`` and ``level`` enforce the load bound
``max_core_load ≤ ceil(total / K) + max_node_weight`` (level strategy:
additionally per level); ``cone`` pins the crown whole regardless of
its weight — on chain-dominated DAGs the crown can dwarf the fair
share, which is why ``subtree`` is the default. All strategies then run
``passes`` rounds of cut-reducing single-node moves (rng order,
deterministic under ``seed``) within the bound.

Communication volume counts (value, destination-core) pairs — the
multicast unrolling the interconnect actually ships.

**Topology awareness** (``icfg`` + ``placement="aware"``): on a
physical NoC (ring/mesh/torus) not every cut edge costs the same — a
value shipped across the mesh diagonal pays more hops and occupies more
links than one between neighbors. After the flat min-cut, two extra
steps run: (1) *core placement* — the core labels are permuted on the
topology so chatty core pairs land adjacent, minimizing hop-weighted
traffic plus the busiest-link load (:func:`place_cores`); (2) a second
round of single-node moves whose gain weighs each cut edge by
``hops(src, dst)``. Under ``icfg=None`` or the ideal ``xbar`` both
steps are skipped and the result is bit-identical to the flat
partitioner (the golden cycle fixtures pin this).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .. import segments
from ..program import TensorProgram


@dataclasses.dataclass
class Partition:
    """Assignment of a program's fused nodes (and binary ops) to cores."""
    n_cores: int
    core_of_node: np.ndarray      # (n_fused,) int32
    core_of_op: np.ndarray        # (n_binary_ops,) int32
    node_of_root: dict            # fused root op id -> fused node index
    roots: list                   # fused node index -> fused root op id
    node_level: np.ndarray        # (n_fused,) fused level (1-based)
    node_weight: np.ndarray       # (n_fused,) binary ops inside the node
    op_level: np.ndarray          # (n_binary_ops,) binary level (1-based)
    loads: np.ndarray             # (K,) binary ops per core
    cut_values: int               # (value, destination-core) pairs
    seed: int
    strategy: str = "subtree"
    topology: str = "xbar"        # topology the placement was tuned for
    hop_cut: int = 0              # Σ hops(src,dst) over cut pairs
    core_placement: list | None = None   # applied label permutation
    grain: int | None = None      # cone streaming-unit weight (None = auto)
    max_arity: int | None = None  # fused-node arity cap (None = unlimited)

    @property
    def used_cores(self) -> np.ndarray:
        return np.unique(self.core_of_node)


def _fused_graph(prog: TensorProgram, max_arity: int | None = None):
    """Fused nodes, their levels/weights and the fused dependence edges."""
    m = prog.m
    info = segments.fusion_info(prog, max_arity)
    roots = sorted(info.leaves)             # ascending = topological
    node_of_root = {r: j for j, r in enumerate(roots)}
    weight = np.bincount(
        [node_of_root[int(info.root_of[i])] for i in range(prog.n_ops)],
        minlength=len(roots)).astype(np.int64)

    in_nodes: list[list[int]] = []
    level = np.zeros(len(roots), np.int64)
    for j, r in enumerate(roots):
        srcs = sorted({node_of_root[int(info.root_of[s - m])]
                       for s in info.leaves[r] if s >= m})
        in_nodes.append(srcs)
        level[j] = 1 + max((int(level[u]) for u in srcs), default=0)

    out_nodes: list[list[int]] = [[] for _ in roots]
    for j, srcs in enumerate(in_nodes):
        for u in srcs:
            out_nodes[u].append(j)
    return info, roots, node_of_root, weight, level, in_nodes, out_nodes


def _cut_volume(core_of_node: np.ndarray, out_nodes) -> int:
    """(value, destination-core) pairs crossing the partition."""
    vol = 0
    for u, consumers in enumerate(out_nodes):
        dsts = {int(core_of_node[v]) for v in consumers}
        dsts.discard(int(core_of_node[u]))
        vol += len(dsts)
    return vol


def _hop_cut_volume(core_of_node: np.ndarray, out_nodes,
                    hops: np.ndarray) -> int:
    """Hop-weighted (value, destination-core) cut volume."""
    n_cores = hops.shape[0]
    return int((traffic_matrix(core_of_node, out_nodes, n_cores)
                * hops).sum())


def traffic_matrix(core_of_node: np.ndarray, out_nodes,
                   n_cores: int) -> np.ndarray:
    """(K, K) values shipped core→core (multicast unrolled)."""
    T = np.zeros((n_cores, n_cores), np.int64)
    for u, consumers in enumerate(out_nodes):
        cu = int(core_of_node[u])
        for d in {int(core_of_node[v]) for v in consumers} - {cu}:
            T[cu, d] += 1
    return T


def place_cores(traffic: np.ndarray, icfg, n_cores: int,
                positions: list | None = None) -> np.ndarray:
    """Core-label permutation placing chatty core pairs adjacent.

    Minimizes ``Σ traffic[a,b] · hops(π(a), π(b))`` plus the busiest
    physical link's load under the topology's routing (the congestion
    term breaks hop-cost ties toward spreading traffic over disjoint
    routes). Greedy constructive placement — chattiest cores first,
    each at the position minimizing its incremental hop cost — followed
    by deterministic pairwise-swap descent on the full objective.
    Returns ``perm`` with ``perm[old_label] = new_label``.

    ``positions`` (default all of ``range(n_cores)``) restricts the
    physical grid slots labels may land on — the degraded-mode path
    places the partition's parts onto the machine's *surviving* cores
    while hop counts and routes stay on the full physical grid. Routes
    crossing a dead link (``icfg.dead_links``) are charged a huge
    penalty per crossing, steering placement around fabric faults when
    any fault-free placement exists.
    """
    n_parts = traffic.shape[0]
    if positions is None:
        positions = list(range(n_cores))
    assert len(positions) == n_parts, \
        f"{n_parts} parts need {n_parts} positions, got {len(positions)}"
    hops = icfg.hop_matrix(n_cores)
    routes = {(a, b): icfg.route(a, b, n_cores)
              for a in positions for b in positions if a != b}
    dead = set(icfg.dead_links)
    DEAD_PENALTY = 1 << 30

    def cost(perm: np.ndarray) -> int:
        hop_cost = int((traffic * hops[perm[:, None], perm[None, :]]).sum())
        load: dict = {}
        for a in range(n_parts):
            for b in range(n_parts):
                t = int(traffic[a, b])
                if t and a != b:
                    for link in routes[(int(perm[a]), int(perm[b]))]:
                        load[link] = load.get(link, 0) + t
                        if link in dead:
                            hop_cost += DEAD_PENALTY
        return hop_cost + (max(load.values()) if load else 0)

    sym = traffic + traffic.T
    perm = np.full(n_parts, -1, np.int64)
    free = list(positions)
    placed: list[int] = []
    for _ in range(n_parts):
        if not placed:
            c = max(range(n_parts), key=lambda c: (int(sym[c].sum()), -c))
            pos = free[0]
        else:
            c = max((c for c in range(n_parts) if perm[c] < 0),
                    key=lambda c: (int(sym[c, placed].sum()), -c))
            pos = min(free, key=lambda p: (
                sum(int(sym[c, q]) * int(hops[p, perm[q]]) for q in placed),
                p))
        perm[c] = pos
        free.remove(pos)
        placed.append(c)

    def descend(perm: np.ndarray) -> tuple[np.ndarray, int]:
        best = cost(perm)
        improved = True
        while improved:
            improved = False
            for i in range(n_parts):
                for j in range(i + 1, n_parts):
                    perm[i], perm[j] = perm[j], perm[i]
                    cand = cost(perm)
                    if cand < best:
                        best, improved = cand, True
                    else:
                        perm[i], perm[j] = perm[j], perm[i]
        return perm, best

    # descend from the greedy start AND from the identity; the identity
    # (= the flat labeling) guarantees the result never costs more than
    # doing nothing
    perm, best = descend(perm)
    ident, ibest = descend(np.asarray(positions, dtype=np.int64))
    return ident if ibest < best else perm


def partition_ops(prog: TensorProgram, n_cores: int, *, seed: int = 0,
                  passes: int = 2, strategy: str = "subtree",
                  icfg=None, placement: str = "aware",
                  grain: int | None = None,
                  max_arity: int | None = None,
                  allowed_cores: tuple | None = None) -> Partition:
    """Partition ``prog`` onto ``n_cores`` cores (see module doc).

    ``icfg`` (an :class:`~repro.core.multicore.comm.InterconnectConfig`)
    plus ``placement="aware"`` enables topology-aware core placement and
    hop-weighted move refinement on physical NoCs; ``placement="naive"``
    (or ``icfg=None`` / the ideal ``xbar``) keeps the flat partition.

    ``allowed_cores`` restricts the partition to a *surviving* subset of
    the ``n_cores``-core machine (degraded mode after a core fault): ops
    are partitioned into ``len(allowed_cores)`` parts and placed only
    onto those physical grid positions — hop counts and routes stay on
    the full physical grid, so the dead cores' router nodes still exist
    exactly as on a partially-disabled SoC. ``None`` (and the full set)
    keep the healthy path bit-identical.

    Autotuning knobs (defaults reproduce the historical behaviour
    exactly — the golden cycle fixtures pin this):

    - ``grain`` — the ``cone`` strategy's streaming-unit weight bound;
      ``None`` keeps the auto formula ``max(1, total_w // (3 * n_cores))``.
    - ``max_arity`` — cap on fused-node operand count (placement
      granularity); ``None`` keeps maximal fusion. See
      :func:`repro.core.segments.fusion_info`.
    """
    if n_cores < 1:
        raise ValueError(f"n_cores must be >= 1, got {n_cores}")
    if strategy not in ("subtree", "cone", "level"):
        raise ValueError(f"unknown strategy {strategy!r}")
    if placement not in ("aware", "naive"):
        raise ValueError(f"unknown placement {placement!r}")
    if allowed_cores is not None:
        allowed = sorted({int(c) for c in allowed_cores})
        if not allowed:
            raise ValueError("allowed_cores must name at least one core")
        if allowed[0] < 0 or allowed[-1] >= n_cores:
            raise ValueError(f"allowed_cores {allowed} outside the "
                             f"{n_cores}-core machine")
        if allowed != list(range(n_cores)):
            return _partition_restricted(
                prog, n_cores, allowed, seed=seed, passes=passes,
                strategy=strategy, icfg=icfg, placement=placement,
                grain=grain, max_arity=max_arity)
    info, roots, node_of_root, weight, level, in_nodes, out_nodes = \
        _fused_graph(prog, max_arity)
    n_nodes = len(roots)
    core_of_node = np.zeros(n_nodes, np.int32)
    placement_perm: list | None = None
    num_levels = int(level.max()) if n_nodes else 0
    total_w = int(weight.sum())
    wmax = int(weight.max()) if n_nodes else 0
    bound = -(-total_w // n_cores) + wmax     # ceil(total/K) + max weight

    if n_cores > 1 and n_nodes:
        if strategy == "subtree":
            # ---- post-order linear clustering (min-cut on trees) -------
            # SPN fused DAGs are tree-dominated (out-degree ≤ 1 almost
            # everywhere): a post-order walk lists every subtree
            # contiguously, so cutting the walk into K weight-balanced
            # chunks keeps whole subtrees core-local and only the chunk
            # boundaries (≈ the combining path) cross cores. DAG edges
            # outside the spanning forest just become extra cut edges.
            visited = np.zeros(n_nodes, bool)
            order: list[int] = []
            sinks = [j for j in range(n_nodes) if not out_nodes[j]]
            for sink in sinks:
                stack: list[tuple[int, bool]] = [(sink, False)]
                while stack:
                    j, expanded = stack.pop()
                    if visited[j]:
                        continue
                    if expanded:
                        visited[j] = True
                        order.append(j)
                        continue
                    stack.append((j, True))
                    for u in reversed(in_nodes[j]):
                        if not visited[u]:
                            stack.append((u, False))
            assert len(order) == n_nodes
            cum, core = 0, 0
            for j in order:
                core_of_node[j] = core
                cum += int(weight[j])
                if core < n_cores - 1 and \
                        cum * n_cores >= (core + 1) * total_w:
                    core += 1
        elif strategy == "cone":
            # ---- grain decomposition: streamed units + a crown core ----
            # Units are maximal subtrees of weight ≤ grain; everything
            # above them (the *crown* — the combining cone whose every
            # node spans multiple units) goes to the last core. Unit
            # roots stream onto the interconnect as each unit finishes,
            # so the crown ascends concurrently with unit production
            # instead of hopping core-to-core like nested-prefix chunks.
            spar = np.full(n_nodes, -1, np.int64)   # spanning parent
            for j in range(n_nodes):
                if out_nodes[j]:
                    spar[j] = out_nodes[j][0]
            subw = weight.astype(np.int64).copy()
            for j in range(n_nodes):                # children before parents
                if spar[j] >= 0:
                    subw[spar[j]] += subw[j]
            eff_grain = (max(1, total_w // (3 * n_cores))
                         if grain is None else max(1, int(grain)))
            crown = subw > eff_grain
            cone_core = n_cores - 1
            core_of_node[crown] = cone_core
            unit = np.full(n_nodes, -1, np.int64)
            for j in range(n_nodes - 1, -1, -1):    # parents first
                if crown[j]:
                    continue
                p = int(spar[j])
                unit[j] = j if (p < 0 or crown[p]) else unit[p]
            unit_w: dict[int, int] = {}
            for j in range(n_nodes):
                if not crown[j]:
                    unit_w[int(unit[j])] = unit_w.get(int(unit[j]), 0) \
                        + int(weight[j])
            load = np.zeros(n_cores, np.int64)
            load[cone_core] = int(weight[crown].sum())
            for u in sorted(unit_w, key=lambda x: (-unit_w[x], x)):
                best = int(np.argmin(load))
                load[best] += unit_w[u]
                core_of_node[(unit == u) & ~crown] = best
        else:
            # ---- per-level LPT with operand-affinity tie-breaks --------
            for lv in range(1, num_levels + 1):
                idx = np.flatnonzero(level == lv)
                idx = idx[np.argsort(-weight[idx], kind="stable")]
                lv_total = int(weight[idx].sum())
                lv_bound = -(-lv_total // n_cores)
                load = np.zeros(n_cores, np.int64)
                for j in idx:
                    w = int(weight[j])
                    safe = [c for c in range(n_cores)
                            if load[c] + w <= lv_bound]
                    if not safe:
                        safe = [int(np.argmin(load))]
                    aff = {c: 0 for c in safe}
                    for u in in_nodes[j]:
                        c = int(core_of_node[u])
                        if c in aff:
                            aff[c] += int(weight[u])
                    best = max(safe, key=lambda c: (aff[c], -load[c], -c))
                    core_of_node[j] = best
                    load[best] += w

        # ---- refinement: cut-reducing single-node moves ----------------
        core_load = np.zeros(n_cores, np.int64)
        for j in range(n_nodes):
            core_load[int(core_of_node[j])] += int(weight[j])

        def refine(H: np.ndarray, rounds: int) -> None:
            """Single-node moves reducing the H-weighted cut within the
            load bound (H = all-ones ⇒ the flat (value, dst-core) cut,
            identical to the pre-NoC refinement; H = hop matrix ⇒ cut
            edges cost their route length)."""

            def move_gain(j: int, dst: int) -> int:
                src = int(core_of_node[j])
                gain = 0
                for u in in_nodes[j]:                 # edges into j
                    cu = int(core_of_node[u])
                    before = {int(core_of_node[v]) for v in out_nodes[u]}
                    after = {int(core_of_node[v]) for v in out_nodes[u]
                             if v != j} | {dst}
                    before.discard(cu)
                    after.discard(cu)
                    gain += int(sum(H[cu][d] for d in before)
                                - sum(H[cu][d] for d in after))
                dsts = {int(core_of_node[v]) for v in out_nodes[j]}
                gain += int(sum(H[src][d] for d in dsts - {src})
                            - sum(H[dst][d] for d in dsts - {dst}))
                return gain

            rng = np.random.default_rng(seed)
            for _ in range(rounds):
                improved = False
                for j in rng.permutation(n_nodes):
                    j = int(j)
                    w, src = int(weight[j]), int(core_of_node[j])
                    best_dst, best_gain = -1, 0
                    for dst in range(n_cores):
                        if dst == src:
                            continue
                        if core_load[dst] + w > bound:
                            continue
                        g = move_gain(j, dst)
                        if g > best_gain:
                            best_gain, best_dst = g, dst
                    if best_dst >= 0:
                        core_of_node[j] = best_dst
                        core_load[src] -= w
                        core_load[best_dst] += w
                        improved = True
                if not improved:
                    break

        refine(np.ones((n_cores, n_cores), np.int64), passes)

        # ---- topology-aware placement + hop-weighted refinement --------
        # Skipped for the ideal crossbar (every pair is one hop, so both
        # steps would be no-ops): xbar partitions stay bit-identical to
        # the flat partitioner.
        if (icfg is not None and placement == "aware"
                and icfg.topology != "xbar"):
            perm = place_cores(
                traffic_matrix(core_of_node, out_nodes, n_cores),
                icfg, n_cores)
            core_of_node = perm[core_of_node].astype(np.int32)
            relabeled = np.zeros_like(core_load)
            relabeled[perm] = core_load
            core_load = relabeled
            placement_perm = [int(p) for p in perm]
            if passes > 0:
                # explicit opt-in: node moves trading flat cut for hop
                # cut (the label permutation alone never changes the
                # partition shape, only where each part physically sits)
                refine(icfg.hop_matrix(n_cores), passes)

        # ---- multi-root (interleaved) programs: co-locate the roots ----
        # Every instance's root must end on ONE core — the root core is
        # the only core that stores result rows, and the merged decoder /
        # lockstep sim read all k roots from it. The k root cones are
        # also exactly the narrow serial tails interleaving exists to
        # overlap, so sharing a core is the profitable placement anyway.
        # Majority vote keeps most nodes where the partitioner put them
        # (ties break toward the highest core, the cone crown convention).
        if prog.root_slots is not None and len(prog.root_slots) > 1:
            root_nodes = {node_of_root[int(info.root_of[int(s) - prog.m])]
                          for s in prog.root_slots}
            votes = np.zeros(n_cores, np.int64)
            for j in root_nodes:
                votes[int(core_of_node[j])] += 1
            target = int(np.flatnonzero(votes == votes.max())[-1])
            for j in root_nodes:
                core_of_node[j] = target

    core_of_op = np.asarray(
        [core_of_node[node_of_root[int(info.root_of[i])]]
         for i in range(prog.n_ops)], np.int32)
    op_level = np.searchsorted(prog.level_offsets[1:], np.arange(prog.n_ops),
                               side="right") + 1
    loads = np.bincount(core_of_op, minlength=n_cores).astype(np.int64)
    cut = _cut_volume(core_of_node, out_nodes)
    if icfg is not None and icfg.topology != "xbar":
        hop_cut = _hop_cut_volume(core_of_node, out_nodes,
                                  icfg.hop_matrix(n_cores))
    else:
        hop_cut = cut           # every xbar pair is exactly one hop
    part = Partition(
        n_cores=n_cores, core_of_node=core_of_node.astype(np.int32),
        core_of_op=core_of_op, node_of_root=node_of_root, roots=list(roots),
        node_level=level, node_weight=weight,
        op_level=op_level.astype(np.int64),
        loads=loads, cut_values=cut,
        seed=seed, strategy=strategy,
        topology=icfg.topology if icfg is not None else "xbar",
        hop_cut=hop_cut, core_placement=placement_perm,
        grain=grain, max_arity=max_arity)
    validate_partition(prog, part)
    return part


def _partition_restricted(prog: TensorProgram, n_cores: int, allowed: list,
                          *, seed: int, passes: int, strategy: str,
                          icfg, placement: str, grain: int | None,
                          max_arity: int | None) -> Partition:
    """Degraded-mode partition onto a surviving subset of the machine.

    Partitions into ``len(allowed)`` parts with the flat partitioner,
    then maps the part labels onto the surviving *physical* grid
    positions (:func:`place_cores` with ``positions=allowed`` when the
    NoC is physical and placement is aware, else the identity onto
    ``allowed``). Hop counts, routes and dead-link penalties all live on
    the full physical grid — the dead cores' routers still exist. The
    hop-weighted move-refinement pass of the healthy path is skipped:
    label-space restriction makes its load bookkeeping ambiguous, and
    degraded mode optimizes for *serving at all*, not the last cycle.
    """
    base = partition_ops(prog, len(allowed), seed=seed, passes=passes,
                         strategy=strategy, icfg=None, placement="naive",
                         grain=grain, max_arity=max_arity)
    _info, _roots, _node_of_root, _w, _lv, _in_nodes, out_nodes = \
        _fused_graph(prog, max_arity)
    if (icfg is not None and placement == "aware"
            and icfg.topology != "xbar" and len(allowed) > 1):
        perm = place_cores(
            traffic_matrix(base.core_of_node, out_nodes, len(allowed)),
            icfg, n_cores, positions=allowed)
    else:
        perm = np.asarray(allowed, np.int64)
    core_of_node = perm[base.core_of_node].astype(np.int32)
    core_of_op = perm[base.core_of_op].astype(np.int32)
    loads = np.bincount(core_of_op, minlength=n_cores).astype(np.int64)
    if icfg is not None and icfg.topology != "xbar":
        hop_cut = _hop_cut_volume(core_of_node, out_nodes,
                                  icfg.hop_matrix(n_cores))
        topo = icfg.topology
    else:
        hop_cut, topo = base.cut_values, "xbar"
    part = Partition(
        n_cores=n_cores, core_of_node=core_of_node, core_of_op=core_of_op,
        node_of_root=base.node_of_root, roots=base.roots,
        node_level=base.node_level, node_weight=base.node_weight,
        op_level=base.op_level, loads=loads,
        cut_values=base.cut_values,       # label permutation keeps the cut
        seed=seed, strategy=strategy, topology=topo, hop_cut=hop_cut,
        core_placement=[int(p) for p in perm],
        grain=grain, max_arity=max_arity)
    validate_partition(prog, part)
    return part


def validate_partition(prog: TensorProgram, part: Partition) -> None:
    """Scope-completeness, fused-node integrity and acyclicity.

    Acyclicity at (core, level) granularity: every cross-core edge goes
    from a strictly lower binary level to a higher one, which is what
    makes the lockstep schedule's level grading deadlock-free.
    """
    m = prog.m
    assert part.core_of_op.shape == (prog.n_ops,)
    assert ((part.core_of_op >= 0) & (part.core_of_op < part.n_cores)).all()
    info = segments.fusion_info(prog, part.max_arity)
    # fused-node integrity: every binary op lives with its fused root
    for i in range(prog.n_ops):
        r = int(info.root_of[i])
        assert part.core_of_op[i] == part.core_of_op[r], \
            "fused reduction tree split across cores"
    # cross-core edges strictly increase binary level
    for i in range(prog.n_ops):
        for s in (int(prog.b[i]), int(prog.c[i])):
            if s >= m and part.core_of_op[s - m] != part.core_of_op[i]:
                assert part.op_level[s - m] < part.op_level[i]
    assert int(part.loads.sum()) == prog.n_ops
    # multi-root (interleaved) programs: every instance root on ONE core
    if prog.root_slots is not None and len(prog.root_slots) > 1:
        owners = {int(part.core_of_op[int(s) - m]) for s in prog.root_slots}
        assert len(owners) == 1, "interleaved instance roots split across cores"
