"""Inter-core communication model: shared-register-window channel rows.

Cut values (fused-node outputs consumed on another core) travel as
*channel rows* — vectors of up to ``banks`` values between one (src,
dst) core pair, all produced at one binary level. The producer's window
hardware latches each value at writeback commit (AIA-style register
sharing — no bank gather needed), the compiler's explicit ``SEND`` row
flushes the completed window row onto the link, and the consumer's
``RECV`` row maps it into its register file (member position *i* lands
in bank *i*, full/empty bits stall a PE read that arrives early).

Level-homogeneous rows are a correctness feature, not just a packing
choice: together with the compiler's send-before-dependent-read rule
they give the lockstep schedule a strictly decreasing wait-level
ordering, which is what makes it deadlock-free (see
:mod:`repro.core.compiler.pipeline`).

Transfer latency is cycle-accounted per row:
``hop_latency(src, dst) + ceil(members / link_width)`` — a flat crossbar
by default (``hops=1``); ring distances model cheaper NoCs.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..program import TensorProgram
from .partition import Partition


@dataclasses.dataclass(frozen=True)
class InterconnectConfig:
    """Modeled interconnect between cores."""
    name: str = "xbar"
    topology: str = "xbar"      # "xbar" (flat) | "ring"
    hop_latency: int = 1        # cycles per hop, SEND issue -> visibility
    link_width: int = 32        # values serialized per cycle per link
    row_capacity: int = 32      # max values per channel row (≤ banks)

    def hops(self, src: int, dst: int, n_cores: int) -> int:
        if self.topology == "ring" and n_cores > 1:
            d = abs(src - dst)
            return min(d, n_cores - d)
        return 1

    def transfer_cycles(self, members: int, src: int = 0, dst: int = 1,
                        n_cores: int = 2) -> int:
        serial = -(-members // self.link_width)
        return self.hops(src, dst, n_cores) * self.hop_latency + serial

    def fingerprint(self) -> str:
        return (f"{self.topology}/hop={self.hop_latency}"
                f"/w={self.link_width}/cap={self.row_capacity}")


XBAR = InterconnectConfig()


@dataclasses.dataclass
class ChannelRow:
    """One shared-register-window row: src -> dst, level-homogeneous."""
    row_id: int
    src: int                    # effective core indices
    dst: int
    level: int                  # binary level of every member's producer
    gids: list                  # member global op ids (position = bank)


@dataclasses.dataclass
class CommPlan:
    """All channel rows of one partition + their latency accounting."""
    rows: list                              # [ChannelRow, ...]
    icfg: InterconnectConfig
    n_cores: int
    # (gid, dst core) -> (row_id, position): consumer-side lookup
    value_pos: dict = dataclasses.field(default_factory=dict)

    @property
    def members(self) -> dict:
        return {r.row_id: len(r.gids) for r in self.rows}

    @property
    def volume(self) -> int:
        """Values crossed per batch (multicast unrolled)."""
        return sum(len(r.gids) for r in self.rows)

    def latency(self, row: ChannelRow) -> int:
        return self.icfg.transfer_cycles(len(row.gids), row.src, row.dst,
                                         self.n_cores)

    def stats(self) -> dict:
        return {"rows": len(self.rows), "values": self.volume,
                "interconnect": self.icfg.fingerprint()}


def build_comm_plan(prog: TensorProgram, part: Partition,
                    core_index: dict, icfg: InterconnectConfig = XBAR,
                    banks: int = 32,
                    heights: np.ndarray | None = None) -> CommPlan:
    """Group the partition's cut values into channel rows.

    ``core_index`` maps partition core ids to effective (compacted) core
    indices — empty cores own nothing and are dropped by the compiler.
    ``heights`` are the global critical-path heights (computed by the
    caller when it already has them — the per-core builder shares them
    with the scheduler priorities, so the chunking order and the issue
    order can never silently diverge).
    """
    m = prog.m
    cap = min(icfg.row_capacity, banks)
    # (src, dst, level) -> [gid, ...] in ascending gid order
    groups: dict[tuple[int, int, int], list[int]] = {}
    seen: set[tuple[int, int]] = set()
    for i in range(prog.n_ops):
        ci = int(part.core_of_op[i])
        for s in (int(prog.b[i]), int(prog.c[i])):
            if s < m:
                continue
            g = s - m
            cg = int(part.core_of_op[g])
            if cg == ci or (g, ci) in seen:
                continue
            seen.add((g, ci))
            key = (core_index[cg], core_index[ci], int(part.op_level[g]))
            groups.setdefault(key, []).append(g)

    # chunk each group in descending global-height order: the values the
    # consumer's critical path needs first are produced first (the list
    # scheduler prioritizes by height), so the first row of a group
    # completes — and ships — earliest
    gh = heights if heights is not None else global_heights(prog)

    rows: list[ChannelRow] = []
    value_pos: dict[tuple[int, int], tuple[int, int]] = {}
    for (src, dst, level) in sorted(groups):
        gids = sorted(groups[(src, dst, level)],
                      key=lambda g: (-int(gh[g]), g))
        for lo in range(0, len(gids), cap):
            chunk = gids[lo: lo + cap]
            row = ChannelRow(row_id=len(rows), src=src, dst=dst,
                             level=level, gids=chunk)
            rows.append(row)
            for pos, g in enumerate(chunk):
                value_pos[(g, dst)] = (row.row_id, pos)
    return CommPlan(rows=rows, icfg=icfg, n_cores=len(core_index) or 1,
                    value_pos=value_pos)


def global_heights(prog: TensorProgram) -> np.ndarray:
    """(n_ops,) critical-path height of every binary op (1 = the root)."""
    m = prog.m
    gh = np.ones(max(prog.n_ops, 1), np.int64)
    for j in range(prog.n_ops - 1, -1, -1):
        for s in (int(prog.b[j]), int(prog.c[j])):
            if s >= m:
                gh[s - m] = max(gh[s - m], gh[j] + 1)
    return gh


class Interconnect:
    """Runtime window state shared by the lockstep simulator's cores.

    Arrived rows stay readable (window memory, AIA register-sharing
    semantics), so consumers may evict and re-RECV a row freely.
    """

    def __init__(self, plan: CommPlan):
        self.plan = plan
        self._members = plan.members
        self._latency = {r.row_id: plan.latency(r) for r in plan.rows}
        self.rows: dict[int, tuple[int, np.ndarray]] = {}
        self.sends = 0
        self.values_sent = 0
        self.max_resident = 0

    def members(self, row_id: int) -> int:
        return self._members[row_id]

    def push(self, row_id: int, payload: np.ndarray, now: int) -> None:
        self.rows[row_id] = (now + self._latency[row_id], payload)
        self.sends += 1
        self.values_sent += payload.shape[0]
        self.max_resident = max(self.max_resident, len(self.rows))

    def arrived(self, row_id: int, now: int):
        entry = self.rows.get(row_id)
        if entry is None or entry[0] > now:
            return None
        return entry[1]

    def in_transit(self, now: int) -> bool:
        return any(arr > now for arr, _ in self.rows.values())
