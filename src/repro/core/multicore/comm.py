"""Inter-core communication model: shared-register-window channel rows.

Cut values (fused-node outputs consumed on another core) travel as
*channel rows* — vectors of up to ``banks`` values between one (src,
dst) core pair, all produced at one binary level. The producer's window
hardware latches each value at writeback commit (AIA-style register
sharing — no bank gather needed), the compiler's explicit ``SEND`` row
flushes the completed window row onto the link, and the consumer's
``RECV`` row maps it into its register file (member position *i* lands
in bank *i*, full/empty bits stall a PE read that arrives early).

Level-homogeneous rows are a correctness feature, not just a packing
choice: together with the compiler's send-before-dependent-read rule
they give the lockstep schedule a strictly decreasing wait-level
ordering, which is what makes it deadlock-free (see
:mod:`repro.core.compiler.pipeline`).

Transfer latency is cycle-accounted per transfer:
``hops(src, dst) * hop_latency + ceil(members / link_width)`` in the
uncontended case. Four topologies are modeled:

``xbar``
    The *ideal* flat crossbar: every (src, dst) pair owns a dedicated
    wire, so hops ≡ 1 and concurrent transfers never interact. This is
    the optimistic pre-NoC model and is kept bit-exact (the golden
    cycle fixtures pin it).
``ring``
    Cores on a bidirectional ring; hop count is the shorter arc. Links
    are physical and shared: transfers whose arcs overlap serialize.
``mesh`` / ``torus``
    Cores on a near-square 2-D grid (largest divisor ``h ≤ √n`` when
    one exists, else the ragged ``ceil``-grid — unoccupied positions
    still carry routers, as on a partially-populated SoC). Routing is
    dimension-ordered (XY): the full x-leg in the source row, then the
    y-leg in the destination column. ``torus`` adds per-axis wraparound
    links and picks the shorter direction per axis.

For the physical topologies the runtime :class:`Interconnect` charges
*per-link occupancy*: each directed physical link is busy for
``ceil(members / link_width)`` cycles per transfer crossing it, the
head flit pays ``hop_latency`` per hop, transfers whose routes share a
link serialize on it, and each core's injection port admits one row's
flits at a time (injection arbitration). ``xbar`` bypasses all of this
by construction.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from ..program import TensorProgram
from .partition import Partition

TOPOLOGIES = ("xbar", "ring", "mesh", "torus")


class LinkDownError(RuntimeError):
    """A transfer (or a compiled comm plan) requires a dead NoC link.

    Raised at compile time by the route validation in
    :func:`~repro.core.multicore.compile.compile_multicore` and at
    simulation time by :meth:`Interconnect.push` — the fabric-level
    signal the degraded-mode repartitioner
    (:mod:`repro.runtime.resilience`) reacts to by recompiling onto a
    smaller surviving core set.
    """

    def __init__(self, link: tuple, msg: str | None = None):
        self.link = tuple(link)
        super().__init__(msg or f"NoC link {self.link[0]}->{self.link[1]} "
                         "is down")


@dataclasses.dataclass(frozen=True)
class InterconnectConfig:
    """Modeled interconnect between cores.

    ``dead_links``/``slow_links`` model *fabric faults*: directed
    physical links (grid-node id pairs — equal to core ids on exact
    grids and for the xbar's dedicated wires) that are out of service or
    serialize flits ``factor`` times slower. A dead link makes every
    transfer routed across it raise :class:`LinkDownError`; a slow link
    multiplies its per-transfer busy time in the contention model. Both
    are carried in :meth:`fingerprint` (suffixes appear only when faults
    are present, so healthy fingerprints — and the artifact-cache keys
    built from them — are unchanged).
    """
    name: str = "xbar"
    topology: str = "xbar"      # "xbar" | "ring" | "mesh" | "torus"
    hop_latency: int = 1        # cycles per hop, SEND issue -> visibility
    link_width: int = 32        # values serialized per cycle per link
    row_capacity: int = 32      # max values per channel row (≤ banks)
    dead_links: tuple = ()      # ((a, b), ...) directed dead links
    slow_links: tuple = ()      # ((a, b, factor), ...) degraded links

    # ---------------- geometry ---------------------------------------- #
    def grid_shape(self, n_cores: int) -> tuple[int, int]:
        """(w, h) of the mesh/torus grid for ``n_cores`` cores.

        Prefers the most square exact factorization (``h`` = largest
        divisor ≤ √n); prime-ish counts fall back to the ragged
        ``w = ceil(√n)`` grid whose unoccupied tail positions are
        router-only nodes.
        """
        n = max(n_cores, 1)
        h = max((d for d in range(1, int(math.isqrt(n)) + 1)
                 if n % d == 0), default=1)
        if h == 1 and n > 3:          # prime: avoid a degenerate 1-D chain
            w = math.ceil(math.sqrt(n))
            return w, math.ceil(n / w)
        return n // h, h

    def coords(self, core: int, n_cores: int) -> tuple[int, int]:
        w, _h = self.grid_shape(n_cores)
        return core % w, core // w

    # ---------------- hop metric -------------------------------------- #
    def hops(self, src: int, dst: int, n_cores: int) -> int:
        if src == dst:
            return 0
        if self.topology == "ring" and n_cores > 1:
            d = abs(src - dst)
            return min(d, n_cores - d)
        if self.topology in ("mesh", "torus"):
            w, h = self.grid_shape(n_cores)
            (x0, y0), (x1, y1) = (self.coords(src, n_cores),
                                  self.coords(dst, n_cores))
            dx, dy = abs(x0 - x1), abs(y0 - y1)
            if self.topology == "torus":
                dx, dy = min(dx, w - dx), min(dy, h - dy)
            return dx + dy
        if self.topology == "xbar":
            return 1
        raise ValueError(f"unknown topology {self.topology!r}; "
                         f"pick from {TOPOLOGIES}")

    # ---------------- routing ----------------------------------------- #
    def route(self, src: int, dst: int,
              n_cores: int) -> tuple[tuple[int, int], ...]:
        """Directed physical links the transfer crosses, in order.

        ``xbar`` returns the dedicated (src, dst) wire. ``ring`` walks
        the shorter arc (ties break toward ascending indices).
        ``mesh``/``torus`` use XY dimension-ordered routing over grid
        node ids ``y * w + x`` (which equal core ids on exact grids;
        ragged grids route through router-only tail nodes the same
        way). ``len(route) == hops`` for every physical topology.
        """
        if src == dst:
            return ()
        if self.topology == "xbar":
            return ((src, dst),)
        if self.topology == "ring":
            n = n_cores
            fwd = (dst - src) % n
            step = 1 if fwd <= n - fwd else -1
            path, cur = [], src
            while cur != dst:
                nxt = (cur + step) % n
                path.append((cur, nxt))
                cur = nxt
            return tuple(path)
        if self.topology in ("mesh", "torus"):
            w, h = self.grid_shape(n_cores)
            (x0, y0), (x1, y1) = (self.coords(src, n_cores),
                                  self.coords(dst, n_cores))
            path: list[tuple[int, int]] = []

            def shorter(delta: int, size: int) -> int:
                if self.topology == "torus" and abs(delta) > size - abs(delta):
                    return delta - size if delta > 0 else delta + size
                return delta

            wrap = self.topology == "torus"
            dx, cur = shorter(x1 - x0, w), x0
            for _ in range(abs(dx)):                # x-leg in the src row
                nxt = (cur + (1 if dx > 0 else -1)) % w if wrap \
                    else cur + (1 if dx > 0 else -1)
                path.append((y0 * w + cur, y0 * w + nxt))
                cur = nxt
            dy, cur = shorter(y1 - y0, h), y0
            for _ in range(abs(dy)):                # y-leg in the dst column
                nxt = (cur + (1 if dy > 0 else -1)) % h if wrap \
                    else cur + (1 if dy > 0 else -1)
                path.append((cur * w + x1, nxt * w + x1))
                cur = nxt
            return tuple(path)
        raise ValueError(f"unknown topology {self.topology!r}; "
                         f"pick from {TOPOLOGIES}")

    # ---------------- latency ----------------------------------------- #
    def serial_cycles(self, members: int) -> int:
        return -(-members // self.link_width)

    def transfer_cycles(self, members: int, src: int = 0, dst: int = 1,
                        n_cores: int = 2) -> int:
        """Uncontended transfer latency (contention is charged by the
        runtime :class:`Interconnect`, which sees concurrent traffic)."""
        return (self.hops(src, dst, n_cores) * self.hop_latency
                + self.serial_cycles(members))

    def hop_matrix(self, n_cores: int) -> np.ndarray:
        """(n_cores, n_cores) all-pairs hop counts."""
        return np.asarray([[self.hops(a, b, n_cores)
                            for b in range(n_cores)]
                           for a in range(n_cores)], np.int64)

    # ---------------- fabric faults ------------------------------------ #
    def link_factor(self, link: tuple[int, int]) -> int:
        """Serialization slowdown factor of a (possibly degraded) link."""
        for a, b, f in self.slow_links:
            if (a, b) == tuple(link):
                return max(int(f), 1)
        return 1

    def link_is_dead(self, link: tuple[int, int]) -> bool:
        return tuple(link) in self.dead_links

    def degraded(self, dead_links=(), slow_links=()) -> "InterconnectConfig":
        """This config with additional fault state merged in.

        ``dead_links``: iterable of (a, b) directed pairs. ``slow_links``:
        iterable of (a, b, factor). Existing faults are kept; a link both
        dead and slow is dead. Entries are normalized (sorted, deduped)
        so equal fault sets produce equal configs — and therefore equal
        fingerprints / artifact-cache keys.
        """
        dead = {tuple(l) for l in self.dead_links}
        dead.update(tuple(l) for l in dead_links)
        slow = {(a, b): max(int(f), 1) for a, b, f in self.slow_links}
        for a, b, f in slow_links:
            slow[(a, b)] = max(int(f), 1)
        for link in dead:
            slow.pop(link, None)
        return dataclasses.replace(
            self,
            dead_links=tuple(sorted(dead)),
            slow_links=tuple(sorted((a, b, f)
                                    for (a, b), f in slow.items())))

    def fingerprint(self) -> str:
        fp = (f"{self.topology}/hop={self.hop_latency}"
              f"/w={self.link_width}/cap={self.row_capacity}")
        # fault suffixes only when present: healthy fingerprints (and the
        # artifact-cache keys derived from them) stay byte-identical
        if self.dead_links:
            fp += "/dead=" + ".".join(f"{a}-{b}" for a, b in self.dead_links)
        if self.slow_links:
            fp += "/slow=" + ".".join(f"{a}-{b}x{f}"
                                      for a, b, f in self.slow_links)
        return fp


XBAR = InterconnectConfig()
RING = InterconnectConfig(name="ring", topology="ring")
MESH = InterconnectConfig(name="mesh", topology="mesh")
TORUS = InterconnectConfig(name="torus", topology="torus")


def named_interconnect(topology: str, **overrides) -> InterconnectConfig:
    """Build an :class:`InterconnectConfig` for a topology name."""
    if topology not in TOPOLOGIES:
        raise ValueError(f"unknown topology {topology!r}; "
                         f"pick from {TOPOLOGIES}")
    return InterconnectConfig(name=topology, topology=topology, **overrides)


@dataclasses.dataclass
class ChannelRow:
    """One shared-register-window row: src -> dst, level-homogeneous."""
    row_id: int
    src: int                    # effective core indices
    dst: int
    level: int                  # binary level of every member's producer
    gids: list                  # member global op ids (position = bank)


@dataclasses.dataclass
class CommPlan:
    """All channel rows of one partition + their latency accounting.

    Channel-row ``src``/``dst`` are *effective* (compacted) core
    indices — the window/recv layout space. Routing geometry, however,
    lives on the **physical** core grid the partitioner placed onto:
    ``label_of`` maps effective indices back to the partition's core
    labels and ``geom_cores`` is the machine's full core count, so hop
    counts and link routes agree with what topology-aware placement
    optimized even when some physical cores ended up empty.
    """
    rows: list                              # [ChannelRow, ...]
    icfg: InterconnectConfig
    n_cores: int                            # effective cores
    # (gid, dst core) -> (row_id, position): consumer-side lookup
    value_pos: dict = dataclasses.field(default_factory=dict)
    geom_cores: int = 0                     # physical cores (0 = n_cores)
    label_of: dict = dataclasses.field(default_factory=dict)

    @property
    def members(self) -> dict:
        return {r.row_id: len(r.gids) for r in self.rows}

    @property
    def volume(self) -> int:
        """Values crossed per batch (multicast unrolled)."""
        return sum(len(r.gids) for r in self.rows)

    def geometry(self, core: int) -> int:
        """Physical core label of effective core index ``core``."""
        return self.label_of.get(core, core)

    @property
    def n_geom(self) -> int:
        return self.geom_cores or self.n_cores

    def latency(self, row: ChannelRow) -> int:
        return self.icfg.transfer_cycles(
            len(row.gids), self.geometry(row.src), self.geometry(row.dst),
            self.n_geom)

    def route(self, row: ChannelRow) -> tuple:
        return self.icfg.route(self.geometry(row.src),
                               self.geometry(row.dst), self.n_geom)

    def check_links(self) -> None:
        """Raise :class:`LinkDownError` if any channel row's route
        crosses a dead link — the compile-time feasibility check the
        degraded-mode repartitioner descends on (fewer cores ⇒ fewer
        routes; one core ⇒ no routes, always feasible)."""
        if not self.icfg.dead_links:
            return
        for row in self.rows:
            for link in self.route(row):
                if self.icfg.link_is_dead(link):
                    raise LinkDownError(
                        link, f"channel row {row.row_id} "
                        f"({self.geometry(row.src)}->"
                        f"{self.geometry(row.dst)}) is routed over dead "
                        f"link {link[0]}->{link[1]}")

    def links_used(self) -> list:
        """Sorted directed physical links any channel row crosses."""
        return sorted({link for row in self.rows
                       for link in self.route(row)})

    def stats(self) -> dict:
        return {"rows": len(self.rows), "values": self.volume,
                "interconnect": self.icfg.fingerprint()}


def build_comm_plan(prog: TensorProgram, part: Partition,
                    core_index: dict, icfg: InterconnectConfig = XBAR,
                    banks: int = 32,
                    heights: np.ndarray | None = None) -> CommPlan:
    """Group the partition's cut values into channel rows.

    ``core_index`` maps partition core ids to effective (compacted) core
    indices — empty cores own nothing and are dropped by the compiler.
    ``heights`` are the global critical-path heights (computed by the
    caller when it already has them — the per-core builder shares them
    with the scheduler priorities, so the chunking order and the issue
    order can never silently diverge).
    """
    m = prog.m
    cap = min(icfg.row_capacity, banks)
    # (src, dst, level) -> [gid, ...] in ascending gid order
    groups: dict[tuple[int, int, int], list[int]] = {}
    seen: set[tuple[int, int]] = set()
    for i in range(prog.n_ops):
        ci = int(part.core_of_op[i])
        for s in (int(prog.b[i]), int(prog.c[i])):
            if s < m:
                continue
            g = s - m
            cg = int(part.core_of_op[g])
            if cg == ci or (g, ci) in seen:
                continue
            seen.add((g, ci))
            key = (core_index[cg], core_index[ci], int(part.op_level[g]))
            groups.setdefault(key, []).append(g)

    # chunk each group in descending global-height order: the values the
    # consumer's critical path needs first are produced first (the list
    # scheduler prioritizes by height), so the first row of a group
    # completes — and ships — earliest
    gh = heights if heights is not None else global_heights(prog)

    rows: list[ChannelRow] = []
    value_pos: dict[tuple[int, int], tuple[int, int]] = {}
    for (src, dst, level) in sorted(groups):
        gids = sorted(groups[(src, dst, level)],
                      key=lambda g: (-int(gh[g]), g))
        for lo in range(0, len(gids), cap):
            chunk = gids[lo: lo + cap]
            row = ChannelRow(row_id=len(rows), src=src, dst=dst,
                             level=level, gids=chunk)
            rows.append(row)
            for pos, g in enumerate(chunk):
                value_pos[(g, dst)] = (row.row_id, pos)
    return CommPlan(rows=rows, icfg=icfg, n_cores=len(core_index) or 1,
                    value_pos=value_pos,
                    geom_cores=int(part.n_cores),
                    label_of={v: int(k) for k, v in core_index.items()})


def global_heights(prog: TensorProgram) -> np.ndarray:
    """(n_ops,) critical-path height of every binary op (1 = the root)."""
    m = prog.m
    gh = np.ones(max(prog.n_ops, 1), np.int64)
    for j in range(prog.n_ops - 1, -1, -1):
        for s in (int(prog.b[j]), int(prog.c[j])):
            if s >= m:
                gh[s - m] = max(gh[s - m], gh[j] + 1)
    return gh


class Interconnect:
    """Runtime window state shared by the lockstep simulator's cores.

    Arrived rows stay readable (window memory, AIA register-sharing
    semantics), so consumers may evict and re-RECV a row freely.

    Physical topologies (``ring``/``mesh``/``torus``) charge per-link
    occupancy: a transfer's head flit pays ``hop_latency`` per hop and
    each link on the route is busy ``serial`` cycles, so concurrent
    transfers whose routes share a link serialize on it; a core's
    injection port admits one row's flits at a time. ``xbar`` keeps the
    ideal dedicated-wire model (arrival = push + uncontended latency),
    bit-exact with the pre-NoC interconnect.
    """

    def __init__(self, plan: CommPlan, recorder=None):
        self.plan = plan
        icfg = plan.icfg
        self._members = plan.members
        self._latency = {r.row_id: plan.latency(r) for r in plan.rows}
        self._serial = {r.row_id: icfg.serial_cycles(len(r.gids))
                        for r in plan.rows}
        # optional cycle-timeline recorder (repro.obs.timeline): captures
        # per-link busy intervals and row transit windows for profiling
        self.recorder = recorder
        self._dst = {r.row_id: plan.geometry(r.dst) for r in plan.rows}
        # routes + injection ports live on the physical core grid the
        # partitioner placed onto (see CommPlan.geometry)
        self._src = {r.row_id: plan.geometry(r.src) for r in plan.rows}
        self._route = ({} if icfg.topology == "xbar" else
                       {r.row_id: plan.route(r) for r in plan.rows})
        # fabric faults: dead xbar wires fail at push; slow xbar wires
        # stretch the dedicated wire's serialization (no cross-transfer
        # contention — the wire is still private); physical topologies
        # handle both per route link inside push()
        self._dead_rows: set[int] = set()
        if icfg.topology == "xbar" and (icfg.dead_links or icfg.slow_links):
            for r in plan.rows:
                wire = (plan.geometry(r.src), plan.geometry(r.dst))
                if icfg.link_is_dead(wire):
                    self._dead_rows.add(r.row_id)
                factor = icfg.link_factor(wire)
                if factor > 1:
                    self._latency[r.row_id] += \
                        (factor - 1) * self._serial[r.row_id]
        self.rows: dict[int, tuple[int, np.ndarray]] = {}
        self.sends = 0
        self.values_sent = 0
        self.max_resident = 0
        # per-link contention state (empty under the ideal crossbar)
        self.link_free: dict[tuple[int, int], int] = {}
        self.link_busy: dict[tuple[int, int], int] = {}
        self.inject_free: dict[int, int] = {}
        self.link_stall_cycles = 0      # waits for a busy route link
        self.inject_stall_cycles = 0    # waits for the injection port

    def members(self, row_id: int) -> int:
        return self._members[row_id]

    def push(self, row_id: int, payload: np.ndarray, now: int) -> None:
        route = self._route.get(row_id)
        inject_wait = 0
        if route is None:
            # ideal crossbar: dedicated wires, no shared resources
            if row_id in self._dead_rows:
                raise LinkDownError((self._src[row_id], self._dst[row_id]))
            arrival = now + self._latency[row_id]
        else:
            icfg, serial = self.plan.icfg, self._serial[row_id]
            src = self._src[row_id]
            start = max(now, self.inject_free.get(src, 0))
            inject_wait = start - now
            self.inject_stall_cycles += inject_wait
            self.inject_free[src] = start + serial
            head, tail = start, serial
            for link in route:
                if icfg.link_is_dead(link):
                    raise LinkDownError(link)
                busy = serial * icfg.link_factor(link)
                t = max(head, self.link_free.get(link, 0))
                self.link_free[link] = t + busy
                self.link_busy[link] = self.link_busy.get(link, 0) + busy
                if self.recorder is not None:
                    self.recorder.link_busy(link, t, t + busy, row_id)
                head = t + icfg.hop_latency
                tail = max(tail, busy)   # the slowest link paces the tail
            arrival = head + tail
            self.link_stall_cycles += \
                arrival - (start + len(route) * icfg.hop_latency + serial)
        if self.recorder is not None:
            self.recorder.row_transit(row_id, self._src[row_id],
                                      self._dst[row_id], now, arrival,
                                      self._members[row_id],
                                      inject=inject_wait)
        self.rows[row_id] = (arrival, payload)
        self.sends += 1
        self.values_sent += payload.shape[0]
        self.max_resident = max(self.max_resident, len(self.rows))

    def link_stats(self, total_cycles: int | None = None) -> dict:
        """Per-link occupancy accounting (all zeros under ``xbar``)."""
        busiest = max(self.link_busy.values(), default=0)
        out = {
            "links_used": len(self.link_busy),
            "busiest_link_busy_cycles": busiest,
            "link_stall_cycles": self.link_stall_cycles,
            "inject_stall_cycles": self.inject_stall_cycles,
            "link_busy_cycles": {f"{a}->{b}": c for (a, b), c
                                 in sorted(self.link_busy.items())},
        }
        if total_cycles:
            out["busiest_link_occupancy"] = round(
                busiest / max(total_cycles, 1), 4)
        return out

    def arrived(self, row_id: int, now: int):
        entry = self.rows.get(row_id)
        if entry is None or entry[0] > now:
            return None
        return entry[1]

    def in_transit(self, now: int) -> bool:
        return any(arr > now for arr, _ in self.rows.values())
