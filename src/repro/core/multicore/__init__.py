"""Multi-core SPN processor subsystem.

The paper's processor is a single datapath; its successors (AIA's
multi-core RISC-V SoC with inter-core register sharing, REASON's
scalable probabilistic-reasoning fabric) replicate the core and
partition the SPN DAG across the replicas. This package supplies the
whole stack for that step:

- :mod:`partition` — level-aware balanced min-cut of the fused-node DAG
  onto N cores,
- :mod:`comm`      — the modeled interconnect: level-homogeneous channel
  rows over AIA-style shared-register windows, with cycle-accounted
  transfer latency,
- :mod:`compile`   — per-core :class:`TensorProgram` extraction + VLIW
  compilation with explicit SEND/RECV rows,
- :mod:`sim`       — lockstep cycle-accurate simulation of all cores
  (flow-control stalls, barrier accounting),
- :mod:`fastsim`   — merged dense decode of every core's stream into ONE
  vectorized numpy program, bit-identical to the checked sim.

The ``vliw-mc`` substrate (:mod:`repro.runtime.substrates`) packages it
for serving: throughput becomes a function of ``cores=N`` instead of a
single-datapath constant.
"""
from .comm import (MESH, RING, TOPOLOGIES, TORUS, XBAR, ChannelRow,
                   CommPlan, Interconnect, InterconnectConfig,
                   LinkDownError, build_comm_plan, named_interconnect)
from .compile import CorePlan, MultiCoreProgram, build_core_programs, \
    compile_multicore
from .fastsim import decode_multicore
from .partition import (Partition, partition_ops, place_cores,
                        traffic_matrix, validate_partition)
from .sim import MCSimResult, simulate_multicore

__all__ = [
    "ChannelRow", "CommPlan", "Interconnect", "InterconnectConfig",
    "LinkDownError", "build_comm_plan", "named_interconnect",
    "TOPOLOGIES", "XBAR", "RING", "MESH", "TORUS",
    "CorePlan", "MultiCoreProgram",
    "build_core_programs", "compile_multicore", "decode_multicore",
    "Partition", "partition_ops", "place_cores", "traffic_matrix",
    "validate_partition",
    "MCSimResult", "simulate_multicore",
]
