"""SPN executors over the :class:`~repro.core.program.TensorProgram` IR.

Three execution strategies, mirroring the paper:

- :func:`eval_ops_numpy` — alg. 1 "list of operations" (the float64 oracle),
- :func:`eval_scan`      — alg. 2 "for loop over a vector" via ``lax.scan``
  (faithful to the sequential formulation; slow, used for validation),
- :func:`eval_leveled`   — the *group decomposition* execution (paper
  fig. 2a adapted to TPU), scheduled by the **segment scheduler**
  (:mod:`repro.core.segments`): per level, one gather and one
  unpredicated halving reduction per opcode-homogeneous n-ary segment —
  no per-element opcode ``where``-selects, k-ary reductions fused into
  single segments. Bit-identical (at f32) to the binary leveled pass it
  replaces. This is the production JAX path; the Pallas kernel in
  :mod:`repro.kernels.spn_eval` implements the same schedule with an
  explicitly VMEM-resident value buffer.

All executors support linear and log domain ((+,×) → (logaddexp,+)) and
all three opcodes — SUM, PROD and MAX (the tropical semiring used by
max-product/MPE programs; ``max`` is the same in both domains since log
is monotone).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import segments
from .program import OP_MAX, OP_PROD, TensorProgram


def _combine(op: jnp.ndarray, vb: jnp.ndarray, vc: jnp.ndarray,
             log_domain: bool) -> jnp.ndarray:
    """Elementwise semiring op select: 0=sum, 1=prod, 2=max (jnp)."""
    prod = vb + vc if log_domain else vb * vc
    add = jnp.logaddexp(vb, vc) if log_domain else vb + vc
    return jnp.where(op == OP_PROD, prod,
                     jnp.where(op == OP_MAX, jnp.maximum(vb, vc), add))


# --------------------------------------------------------------------------- #
# alg. 1 — list of operations (numpy oracle, float64)
# --------------------------------------------------------------------------- #
def eval_ops_numpy(prog: TensorProgram, leaf_ind: np.ndarray,
                   log_domain: bool = False,
                   return_buffer: bool = False) -> np.ndarray:
    """Reference evaluation; ``leaf_ind``: (batch, m_ind). Returns (batch,).

    With ``return_buffer`` the whole ``(num_slots, batch)`` value buffer is
    returned instead of the root row — the MPE backtrace
    (:mod:`repro.queries.mpe`) walks it to recover argmax choices.
    """
    leaf_ind = np.atleast_2d(np.asarray(leaf_ind, dtype=np.float64))
    batch = leaf_ind.shape[0]
    A = np.zeros((prog.num_slots, batch), dtype=np.float64)
    A[: prog.m_ind] = leaf_ind.T
    A[prog.m_ind: prog.m] = prog.param_values[:, None]
    if log_domain:
        with np.errstate(divide="ignore"):
            A[: prog.m] = np.log(A[: prog.m])
    for i in range(prog.n_ops):
        vb, vc = A[prog.b[i]], A[prog.c[i]]
        o = prog.opcode[i]
        if o == OP_PROD:
            A[prog.m + i] = vb + vc if log_domain else vb * vc
        elif o == OP_MAX:
            A[prog.m + i] = np.maximum(vb, vc)
        else:
            A[prog.m + i] = np.logaddexp(vb, vc) if log_domain else vb + vc
    return A if return_buffer else A[prog.root_slot]


# --------------------------------------------------------------------------- #
# alg. 2 — sequential for-loop via lax.scan
# --------------------------------------------------------------------------- #
def _full_input(prog: TensorProgram, leaf_ind: jnp.ndarray,
                params: jnp.ndarray | None, log_domain: bool) -> jnp.ndarray:
    """(batch, m) input vector; ``params`` overrides stored values (for AD)."""
    leaf_ind = jnp.atleast_2d(leaf_ind)
    p = jnp.asarray(prog.param_values, leaf_ind.dtype) if params is None else params
    p = jnp.broadcast_to(p, (leaf_ind.shape[0], prog.m_param))
    full = jnp.concatenate([leaf_ind, p], axis=1)
    return jnp.log(full) if log_domain else full


@functools.partial(jax.jit, static_argnums=(0, 3))
def eval_scan(prog: TensorProgram, leaf_ind: jnp.ndarray,
              params: jnp.ndarray | None = None,
              log_domain: bool = False) -> jnp.ndarray:
    """alg. 2, one op per scan step (batched). Returns (batch,)."""
    full = _full_input(prog, leaf_ind, params, log_domain)     # (batch, m)
    batch = full.shape[0]
    A0 = jnp.zeros((prog.num_slots, batch), full.dtype).at[: prog.m].set(full.T)
    xs = (jnp.asarray(prog.opcode), jnp.asarray(prog.b), jnp.asarray(prog.c),
          jnp.arange(prog.n_ops, dtype=jnp.int32))

    def step(A, x):
        o, bi, ci, i = x
        val = _combine(o, A[bi], A[ci], log_domain)
        return jax.lax.dynamic_update_index_in_dim(A, val, prog.m + i, 0), None

    A, _ = jax.lax.scan(step, A0, xs)
    return A[prog.root_slot]


# --------------------------------------------------------------------------- #
# leveled (group-decomposed) execution — the production JAX path
# --------------------------------------------------------------------------- #
def segment_reduce(vals: jnp.ndarray, op: int, log_domain: bool,
                   n_nodes: int) -> jnp.ndarray:
    """Halving reduction of one homogeneous segment.

    ``vals``: (arity * n_nodes, batch) operand rows, position-major in
    bit-reversed order (:mod:`repro.core.segments` layout), so every
    halving step is a contiguous split executed as ONE unpredicated
    vector ufunc — the vectorized analogue of the paper's PE trees
    running a single operation per step. The pairing rule itself lives
    in :func:`repro.core.segments.halving_reduce`, shared with the
    numpy reference and the Pallas kernel.
    """
    return segments.halving_reduce(
        vals, segments.combine_fn(op, log_domain, jnp), n_nodes)


def _segmented_impl(seg: segments.SegmentedProgram, full_T: jnp.ndarray,
                    log_domain: bool) -> jnp.ndarray:
    """Segment-scheduled leveled pass. ``full_T``: (m, batch) leaf rows."""
    batch = full_T.shape[1]
    pad_rows = jnp.asarray(seg.init_rows(log_domain)[seg.m:], full_T.dtype)
    A = jnp.zeros((seg.num_slots, batch), full_T.dtype)
    A = jax.lax.dynamic_update_slice(A, full_T, (0, 0))
    A = jax.lax.dynamic_update_slice(
        A, jnp.broadcast_to(pad_rows[:, None],
                            (seg.node_base - seg.m, batch)), (seg.m, 0))
    for level in range(seg.num_levels):
        s0, s1 = int(seg.level_offsets[level]), int(seg.level_offsets[level + 1])
        lo, _ = seg.level_out_range(level)
        outs = []
        for s in range(s0, s1):
            g0 = int(seg.seg_off[s])
            ns = int(seg.seg_nodes[s])
            g1 = g0 + int(seg.seg_arity[s]) * ns
            vals = jnp.take(A, jnp.asarray(seg.gather[g0:g1]), axis=0)
            outs.append(segment_reduce(vals, int(seg.seg_op[s]),
                                       log_domain, ns))
        block = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=0)
        A = jax.lax.dynamic_update_slice(A, block, (lo, 0))
    return A[seg.root_slot]


def _leveled_impl(prog: TensorProgram, full_T: jnp.ndarray,
                  log_domain: bool) -> jnp.ndarray:
    """Core leveled pass over the program's segment schedule.

    Kept as the single entry point every leveled consumer (likelihood,
    learning, MPE grad-decode) routes through; the schedule itself is
    the cached :func:`repro.core.segments.segment_program`.
    """
    return _segmented_impl(segments.segment_program(prog), full_T, log_domain)


@functools.partial(jax.jit, static_argnums=(0, 3))
def eval_leveled(prog: TensorProgram, leaf_ind: jnp.ndarray,
                 params: jnp.ndarray | None = None,
                 log_domain: bool = False) -> jnp.ndarray:
    """Group-decomposed evaluation. ``leaf_ind``: (batch, m_ind) → (batch,)."""
    full = _full_input(prog, leaf_ind, params, log_domain)
    return _leveled_impl(prog, full.T, log_domain)


def make_leveled_eval(prog: TensorProgram, log_domain: bool = True):
    """Bind ``prog`` into a standalone jit'd leveled evaluator.

    This is the "compile" step of the leveled-jax substrate
    (:mod:`repro.runtime.substrates`): the returned closure owns its own
    jit cache entry and is the cacheable artifact payload; the leveled
    pass itself is the shared :func:`_leveled_impl`.
    """
    @jax.jit
    def run(leaf_ind: jnp.ndarray) -> jnp.ndarray:
        leaf_ind = jnp.atleast_2d(leaf_ind).astype(jnp.float32)
        full = _full_input(prog, leaf_ind, None, log_domain)
        return _leveled_impl(prog, full.T, log_domain)

    return run


def log_likelihood(prog: TensorProgram, leaf_ind: jnp.ndarray,
                   params: jnp.ndarray | None = None) -> jnp.ndarray:
    """Batched root log-probability (log-domain leveled executor)."""
    return eval_leveled(prog, leaf_ind, params, True)


# --------------------------------------------------------------------------- #
# evidence helpers (jit-friendly)
# --------------------------------------------------------------------------- #
def leaves_from_evidence_jnp(prog: TensorProgram, x: jnp.ndarray) -> jnp.ndarray:
    """JAX version of :meth:`TensorProgram.leaves_from_evidence`."""
    ev = x[:, jnp.asarray(prog.ind_var)]
    tgt = jnp.asarray(prog.ind_value)[None, :]
    return ((ev == tgt) | (ev == -1)).astype(jnp.float32)
