from .config import CPU_MODEL, GPU_MODEL, PTREE, PVECT, ProcessorConfig  # noqa: F401
