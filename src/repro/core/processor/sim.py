"""Cycle-accurate simulator of the SPN processor (paper §V: "a
cycle-accurate model is developed in the MyHDL framework").

Executes a compiled :class:`~repro.core.compiler.isa.VLIWProgram` against
the machine model of :mod:`config`, enforcing every structural constraint
the hardware imposes:

- ≤ 1 read address per register bank per cycle (crossbar rule; broadcast
  of one address to many ports is allowed),
- ≤ 1 write per bank per cycle, including pipelined writebacks landing
  ``level`` cycles after issue and vector loads occupying every bank,
- PEs compute strictly from their two children in the tree (level 0 =
  crossbar ports), with sum/product/max/forward opcodes,
- data memory moves whole 32-wide vector rows.

The model is packaged as a *steppable* :class:`CoreSim` so that the
multi-core simulator (:mod:`repro.core.multicore.sim`) can clock N cores
in lockstep: each ``step(now)`` call executes one VLIW instruction at
global cycle ``now``, or stalls (returns ``False``) when a PE reads a
shared-register-window cell whose RECV data has not arrived yet
(full/empty-bit flow control). Arrival times come from the modeled
interconnect — on physical NoC topologies they include per-link
contention and injection-port arbitration, so flow-control stalls here
are where link congestion becomes visible as core cycles. Single-core
simulation (:func:`simulate_leaves`) is the trivial driver loop and
never stalls.

Values carry a batch dimension, so one simulation validates a whole batch
of SPN evaluations bit-for-bit against the numpy oracle while costing the
same number of machine cycles as a single one (the throughput metric is
cycles per evaluation, as in the paper's 100k-execution average).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..compiler import isa
from ..program import TensorProgram
from .config import ProcessorConfig


class SimError(AssertionError):
    pass


@dataclasses.dataclass
class SimResult:
    root_values: np.ndarray      # (batch,)
    cycles: int
    useful_ops: int
    ops_per_cycle: float
    checks: dict


def input_memory_from_leaves(vprog: isa.VLIWProgram, leaf_ind: np.ndarray,
                             cfg: ProcessorConfig) -> dict[int, np.ndarray]:
    """Data-memory image: constant rows + indicator-leaf overlay."""
    leaf_ind = np.atleast_2d(leaf_ind).astype(np.float32)  # (batch, m_ind)
    batch = leaf_ind.shape[0]
    mem: dict[int, np.ndarray] = {}
    for row, consts in vprog.const_rows.items():
        mem[row] = np.broadcast_to(
            np.asarray(consts, np.float32)[:, None], (cfg.banks, batch)).copy()
    for slot, (row, bank) in enumerate(vprog.input_layout):
        mem[row][bank] = leaf_ind[:, slot]
    return mem


def build_input_memory(vprog: isa.VLIWProgram, prog: TensorProgram,
                       X: np.ndarray, cfg: ProcessorConfig) -> dict[int, np.ndarray]:
    """Data-memory image for evidence rows ``X`` (indicator expansion)."""
    return input_memory_from_leaves(
        vprog, prog.leaves_from_evidence(X), cfg)


class CoreSim:
    """Checked simulation of one core, one VLIW instruction per ``step``.

    ``interconnect`` (see :class:`repro.core.multicore.comm.Interconnect`)
    is only consulted for ``send``/``recv`` comm ops; single-core
    programs never carry those, so ``None`` is fine there.
    """

    def __init__(self, vprog: isa.VLIWProgram, leaf_ind: np.ndarray,
                 cfg: ProcessorConfig, *, core_id: int = 0,
                 interconnect=None, recorder=None):
        leaf_ind = np.atleast_2d(leaf_ind)
        self.vprog, self.cfg, self.core_id = vprog, cfg, core_id
        self.net = interconnect
        self.batch = leaf_ind.shape[0]
        self.mem = input_memory_from_leaves(vprog, leaf_ind, cfg)
        self.nan = np.full(self.batch, np.nan, np.float32)
        self.regs = np.full((cfg.banks, cfg.regs_per_bank, self.batch),
                            np.nan, np.float32)
        self.valid = np.zeros((cfg.banks, cfg.regs_per_bank), bool)
        # pending commits: local cycle -> list of (bank, reg, value)
        self.pending: dict[int, list] = {}
        # write-port reservations by COMMIT cycle — global across issue
        # cycles, since pipelined writebacks from different issues can
        # land together
        self.write_res: dict[int, set[int]] = {}
        # in-flight RECV rows: reg row -> (channel row id, member count);
        # cells land through the window's dedicated fill port when the
        # row arrives, reads of them stall the core until then
        self.inflight: dict[int, tuple[int, int]] = {}
        self.t = 0                   # local cycle == instructions executed
        self.useful = 0
        self.stall_cycles = 0
        self.finish_at: int | None = None   # global cycle of last instr
        # optional cycle-timeline recorder (repro.obs.timeline); the
        # lockstep driver passes one for `serve --trace` profiling and
        # the attribution engine's probe — None keeps the hot
        # simulation path branch-cheap
        self.recorder = recorder
        self.checks = {"read_conflicts_checked": 0,
                       "write_conflicts_checked": 0}

    # ------------------------------------------------------------------ #
    def finished(self) -> bool:
        return self.t >= len(self.vprog.instrs)

    def _reserve_write(self, commit: int, bank: int) -> None:
        busy = self.write_res.setdefault(commit, set())
        if bank == -1:
            if busy:
                raise SimError(
                    f"cycle {self.t}: vload write collides @ {commit}")
            busy.add(-1)
        else:
            if bank in busy or -1 in busy:
                raise SimError(
                    f"cycle {self.t}: write-port conflict bank {bank} "
                    f"@ {commit}")
            busy.add(bank)
        self.checks["write_conflicts_checked"] += 1

    def _deliver(self, now: int) -> None:
        """Land arrived in-flight window rows (dedicated fill port)."""
        if not self.inflight:
            return
        for reg, (row_id, members) in list(self.inflight.items()):
            payload = self.net.arrived(row_id, now)
            if payload is None:
                continue
            self.regs[:members, reg] = payload
            self.valid[:members, reg] = True
            del self.inflight[reg]

    def _stalled_read(self, src: isa.ReadSrc) -> bool:
        return src.reg in self.inflight and not self.valid[src.bank, src.reg]

    # ------------------------------------------------------------------ #
    def step(self, now: int | None = None) -> bool:
        """Execute the next instruction at global cycle ``now``.

        Returns ``False`` (and leaves all state untouched) when the
        instruction reads a window cell still in flight — the core
        stalls this cycle and retries the same instruction next cycle.
        """
        if now is None:
            now = self.t
        t, instr = self.t, self.vprog.instrs[self.t]
        self._deliver(now)

        # 1) commits for this cycle land at cycle start — even on a
        # stalled cycle: the pipeline drains while issue is frozen (and a
        # whole-row commit legitimately retires a stale in-flight window
        # fill, which the stall check below must observe)
        for (bank, reg, val) in self.pending.pop(t, []):
            if bank == -1:  # whole-row vector load
                self.regs[:, reg] = val
                self.valid[:, reg] = True
                # reusing the row retires any stale in-flight window fill
                self.inflight.pop(reg, None)
            elif bank == -2:  # window row: only the member cells land
                members = val.shape[0]
                self.regs[:members, reg] = val
                self.valid[:members, reg] = True
            else:
                self.regs[bank, reg] = val
                self.valid[bank, reg] = True
        self.write_res.pop(t - 1, None)

        # 2) flow control: stall before any issue-side state changes if a
        # crossbar read targets an in-flight window cell
        if self.inflight:
            for ti in instr.trees:
                if ti is None:
                    continue
                for src in ti.reads.values():
                    if self._stalled_read(src):
                        self.stall_cycles += 1
                        return False

        # 3) crossbar reads (global ≤1 address per bank)
        bank_addr: dict[int, int] = {}
        port_vals: dict[tuple[int, int], np.ndarray] = {}
        for ti in instr.trees:
            if ti is None:
                continue
            for port, src in ti.reads.items():
                prev = bank_addr.get(src.bank)
                if prev is not None and prev != src.reg:
                    raise SimError(
                        f"cycle {t}: bank {src.bank} read conflict "
                        f"(regs {prev} and {src.reg})")
                bank_addr[src.bank] = src.reg
                self.checks["read_conflicts_checked"] += 1
                if not self.valid[src.bank, src.reg]:
                    raise SimError(
                        f"cycle {t}: read of invalid cell "
                        f"({src.bank},{src.reg})")
                port_vals[(ti.tree, port)] = self.regs[src.bank, src.reg]

        # 4) evaluate trees
        for ti in instr.trees:
            if ti is None:
                continue
            level_vals: dict[tuple[int, int], np.ndarray] = {}
            for port in range(self.cfg.leaf_ports_per_tree):
                v = port_vals.get((ti.tree, port))
                level_vals[(0, port)] = v if v is not None else self.nan
            for level in range(1, self.cfg.tree_levels + 1):
                for pos in range(self.cfg.level_pes(level)):
                    code = ti.pe_ops.get((level, pos), isa.PE_NOP)
                    if code == isa.PE_NOP:
                        level_vals[(level, pos)] = self.nan
                        continue
                    a = level_vals[(level - 1, 2 * pos)]
                    b = level_vals[(level - 1, 2 * pos + 1)]
                    if code == isa.PE_ADD:
                        v = a + b
                    elif code == isa.PE_MUL:
                        v = a * b
                    elif code == isa.PE_MAX:
                        v = np.maximum(a, b)
                    elif code == isa.PE_FWD_A:
                        v = a
                    else:
                        v = b
                    level_vals[(level, pos)] = v
            self.useful += ti.num_useful_ops
            # 5) writebacks
            for wb in ti.writes:
                commit = t + wb.level * self.cfg.pe_latency
                val = level_vals[(wb.level, wb.pos)]
                if np.isnan(val).all():
                    raise SimError(f"cycle {t}: writeback of NOP output")
                self._reserve_write(commit, wb.bank)
                self.pending.setdefault(commit, []).append(
                    (wb.bank, wb.reg, val.copy()))

        # 6) memory op (data-memory port)
        if instr.mem is not None:
            mi = instr.mem
            if mi.kind == "load":
                if mi.addr not in self.mem:
                    raise SimError(
                        f"cycle {t}: load of unwritten row {mi.addr}")
                self._reserve_write(t + 1, -1)
                self.pending.setdefault(t + 1, []).append(
                    (-1, mi.reg, self.mem[mi.addr].copy()))
            elif mi.kind == "store":
                row = np.where(self.valid[:, mi.reg][:, None],
                               self.regs[:, mi.reg], 0.0).astype(np.float32)
                self.mem[mi.addr] = row
            else:
                raise SimError(f"cycle {t}: {mi.kind!r} on the memory port")

        # 7) comm op (network-interface port)
        if instr.comm is not None:
            ci = instr.comm
            if ci.kind == "send":
                spec = self.vprog.send_specs.get(ci.addr)
                if not spec:
                    raise SimError(f"cycle {t}: send of unknown row {ci.addr}")
                payload = np.empty((len(spec), self.batch), np.float32)
                for (pos, bank, reg) in spec:
                    if not self.valid[bank, reg]:
                        raise SimError(
                            f"cycle {t}: send row {ci.addr} snapshots "
                            f"invalid cell ({bank},{reg})")
                    payload[pos] = self.regs[bank, reg]
                self.net.push(ci.addr, payload, now)
                if self.recorder is not None:
                    self.recorder.comm_event(self.core_id, now, "send",
                                             ci.addr, len(spec))
            elif ci.kind == "recv":
                members = self.net.members(ci.addr)
                payload = self.net.arrived(ci.addr, now)
                if self.recorder is not None:
                    self.recorder.comm_event(self.core_id, now, "recv",
                                             ci.addr, members)
                self.valid[:, ci.reg] = False
                self.inflight.pop(ci.reg, None)
                if payload is not None:
                    # already arrived: behaves like a vector load (t+1)
                    self.pending.setdefault(t + 1, []).append(
                        (-2, ci.reg, payload.copy()))
                else:
                    self.inflight[ci.reg] = (ci.addr, members)
            else:
                raise SimError(f"cycle {t}: {ci.kind!r} on the comm port")

        self.t += 1
        if self.finished():
            self.finish_at = now
            if self.pending:
                raise SimError(
                    f"program ended with pending commits: "
                    f"{sorted(self.pending)}")
        return True

    def root_values(self) -> np.ndarray:
        """Root memory cell(s): (batch,) — or (k, batch) for multi-root
        (interleaved) programs, one row per instance root."""
        if self.vprog.root_locs is not None:
            rows = []
            for row, bank in self.vprog.root_locs:
                if row not in self.mem:
                    raise SimError(f"root row {row} never stored")
                rows.append(self.mem[row][bank])
            return np.stack(rows)
        root_row, root_bank = self.vprog.root_loc
        if root_row not in self.mem:
            raise SimError("root row never stored")
        return self.mem[root_row][root_bank]


def simulate(vprog: isa.VLIWProgram, prog: TensorProgram, X: np.ndarray,
             cfg: ProcessorConfig) -> SimResult:
    """Checked simulation of evidence rows ``X`` (batch, num_vars)."""
    return simulate_leaves(vprog,
                           prog.leaves_from_evidence(np.atleast_2d(X)), cfg)


def simulate_leaves(vprog: isa.VLIWProgram, leaf_ind: np.ndarray,
                    cfg: ProcessorConfig) -> SimResult:
    """Checked simulation from indicator-leaf inputs (batch, m_ind)."""
    core = CoreSim(vprog, leaf_ind, cfg)
    while not core.finished():
        core.step()
    cycles = len(vprog.instrs)
    return SimResult(root_values=core.root_values(), cycles=cycles,
                     useful_ops=core.useful,
                     ops_per_cycle=core.useful / max(cycles, 1),
                     checks=core.checks)
