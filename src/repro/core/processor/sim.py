"""Cycle-accurate simulator of the SPN processor (paper §V: "a
cycle-accurate model is developed in the MyHDL framework").

Executes a compiled :class:`~repro.core.compiler.isa.VLIWProgram` against
the machine model of :mod:`config`, enforcing every structural constraint
the hardware imposes:

- ≤ 1 read address per register bank per cycle (crossbar rule; broadcast
  of one address to many ports is allowed),
- ≤ 1 write per bank per cycle, including pipelined writebacks landing
  ``level`` cycles after issue and vector loads occupying every bank,
- PEs compute strictly from their two children in the tree (level 0 =
  crossbar ports), with sum/product/max/forward opcodes,
- data memory moves whole 32-wide vector rows.

Values carry a batch dimension, so one simulation validates a whole batch
of SPN evaluations bit-for-bit against the numpy oracle while costing the
same number of machine cycles as a single one (the throughput metric is
cycles per evaluation, as in the paper's 100k-execution average).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..compiler import isa
from ..program import TensorProgram
from .config import ProcessorConfig


class SimError(AssertionError):
    pass


@dataclasses.dataclass
class SimResult:
    root_values: np.ndarray      # (batch,)
    cycles: int
    useful_ops: int
    ops_per_cycle: float
    checks: dict


def input_memory_from_leaves(vprog: isa.VLIWProgram, leaf_ind: np.ndarray,
                             cfg: ProcessorConfig) -> dict[int, np.ndarray]:
    """Data-memory image: constant rows + indicator-leaf overlay."""
    leaf_ind = np.atleast_2d(leaf_ind).astype(np.float32)  # (batch, m_ind)
    batch = leaf_ind.shape[0]
    mem: dict[int, np.ndarray] = {}
    for row, consts in vprog.const_rows.items():
        mem[row] = np.broadcast_to(
            np.asarray(consts, np.float32)[:, None], (cfg.banks, batch)).copy()
    for slot, (row, bank) in enumerate(vprog.input_layout):
        mem[row][bank] = leaf_ind[:, slot]
    return mem


def build_input_memory(vprog: isa.VLIWProgram, prog: TensorProgram,
                       X: np.ndarray, cfg: ProcessorConfig) -> dict[int, np.ndarray]:
    """Data-memory image for evidence rows ``X`` (indicator expansion)."""
    return input_memory_from_leaves(
        vprog, prog.leaves_from_evidence(X), cfg)


def simulate(vprog: isa.VLIWProgram, prog: TensorProgram, X: np.ndarray,
             cfg: ProcessorConfig) -> SimResult:
    """Checked simulation of evidence rows ``X`` (batch, num_vars)."""
    return simulate_leaves(vprog,
                           prog.leaves_from_evidence(np.atleast_2d(X)), cfg)


def simulate_leaves(vprog: isa.VLIWProgram, leaf_ind: np.ndarray,
                    cfg: ProcessorConfig) -> SimResult:
    """Checked simulation from indicator-leaf inputs (batch, m_ind)."""
    leaf_ind = np.atleast_2d(leaf_ind)
    batch = leaf_ind.shape[0]
    mem = input_memory_from_leaves(vprog, leaf_ind, cfg)
    nan = np.full(batch, np.nan, np.float32)

    regs = np.full((cfg.banks, cfg.regs_per_bank, batch), np.nan, np.float32)
    valid = np.zeros((cfg.banks, cfg.regs_per_bank), bool)
    # pending commits: cycle -> list of (bank, reg, value or ("row", row_vals))
    pending: dict[int, list] = {}

    useful = 0
    checks = {"read_conflicts_checked": 0, "write_conflicts_checked": 0}
    # write-port reservations by COMMIT cycle — global across issue cycles,
    # since pipelined writebacks from different issues can land together
    write_res: dict[int, set[int]] = {}

    def make_reserver(t: int):
        def reserve_write(commit: int, bank: int) -> None:
            busy = write_res.setdefault(commit, set())
            if bank == -1:
                if busy:
                    raise SimError(f"cycle {t}: vload write collides @ {commit}")
                busy.add(-1)
            else:
                if bank in busy or -1 in busy:
                    raise SimError(
                        f"cycle {t}: write-port conflict bank {bank} @ {commit}")
                busy.add(bank)
            checks["write_conflicts_checked"] += 1
        return reserve_write

    for t, instr in enumerate(vprog.instrs):
        # 1) commits for this cycle land at cycle start
        for (bank, reg, val) in pending.pop(t, []):
            if bank == -1:  # whole-row vector load
                regs[:, reg] = val
                valid[:, reg] = True
            else:
                regs[bank, reg] = val
                valid[bank, reg] = True
        write_res.pop(t - 1, None)
        reserve_write = make_reserver(t)

        # 2) crossbar reads (global ≤1 address per bank)
        bank_addr: dict[int, int] = {}
        port_vals: dict[tuple[int, int], np.ndarray] = {}
        for ti in instr.trees:
            if ti is None:
                continue
            for port, src in ti.reads.items():
                prev = bank_addr.get(src.bank)
                if prev is not None and prev != src.reg:
                    raise SimError(
                        f"cycle {t}: bank {src.bank} read conflict "
                        f"(regs {prev} and {src.reg})")
                bank_addr[src.bank] = src.reg
                checks["read_conflicts_checked"] += 1
                if not valid[src.bank, src.reg]:
                    raise SimError(
                        f"cycle {t}: read of invalid cell "
                        f"({src.bank},{src.reg})")
                port_vals[(ti.tree, port)] = regs[src.bank, src.reg]

        # 3) evaluate trees
        for ti in instr.trees:
            if ti is None:
                continue
            level_vals: dict[tuple[int, int], np.ndarray] = {}
            for port in range(cfg.leaf_ports_per_tree):
                v = port_vals.get((ti.tree, port))
                level_vals[(0, port)] = v if v is not None else nan
            for level in range(1, cfg.tree_levels + 1):
                for pos in range(cfg.level_pes(level)):
                    code = ti.pe_ops.get((level, pos), isa.PE_NOP)
                    if code == isa.PE_NOP:
                        level_vals[(level, pos)] = nan
                        continue
                    a = level_vals[(level - 1, 2 * pos)]
                    b = level_vals[(level - 1, 2 * pos + 1)]
                    if code == isa.PE_ADD:
                        v = a + b
                    elif code == isa.PE_MUL:
                        v = a * b
                    elif code == isa.PE_MAX:
                        v = np.maximum(a, b)
                    elif code == isa.PE_FWD_A:
                        v = a
                    else:
                        v = b
                    level_vals[(level, pos)] = v
            useful += ti.num_useful_ops
            # 4) writebacks
            for wb in ti.writes:
                commit = t + wb.level * cfg.pe_latency
                val = level_vals[(wb.level, wb.pos)]
                if np.isnan(val).all():
                    raise SimError(f"cycle {t}: writeback of NOP output")
                reserve_write(commit, wb.bank)
                pending.setdefault(commit, []).append((wb.bank, wb.reg, val.copy()))

        # 5) memory op
        if instr.mem is not None:
            mi = instr.mem
            if mi.kind == "load":
                if mi.addr not in mem:
                    raise SimError(f"cycle {t}: load of unwritten row {mi.addr}")
                reserve_write(t + 1, -1)
                pending.setdefault(t + 1, []).append((-1, mi.reg, mem[mi.addr].copy()))
            else:
                row = np.where(valid[:, mi.reg][:, None],
                               regs[:, mi.reg], 0.0).astype(np.float32)
                mem[mi.addr] = row

    if pending:
        raise SimError(f"program ended with pending commits: {sorted(pending)}")

    root_row, root_bank = vprog.root_loc
    if root_row not in mem:
        raise SimError("root row never stored")
    root = mem[root_row][root_bank]
    cycles = len(vprog.instrs)
    return SimResult(root_values=root, cycles=cycles, useful_ops=useful,
                     ops_per_cycle=useful / max(cycles, 1), checks=checks)
