"""Embedded-GPU (Jetson TX2-class) SIMT performance model (paper §III).

Models the exact execution scheme of the paper's CUDA implementation
(alg. 3): the SPN is decomposed into groups of independent nodes; each
group executes striped over T threads followed by ``__syncthreads()``;
the value vector lives in 32-bank shared memory.

Cost terms, all derived from the program structure:

- **instruction issue**: each op is 2 shared loads + 1 shared store + the
  arithmetic instruction; warps issue on ``schedulers`` (TX2 SM: 4 warp
  schedulers for 128 cores),
- **bank conflicts**: serialization factor = max distinct addresses per
  bank per warp access, computed from the actual B/C vectors (after the
  paper's graph-coloring bank assignment when enabled),
- **divergence**: warps containing both sums and products issue both
  paths (factor 2 on the arithmetic instruction),
- **latency exposure**: shared-memory latency is hidden by other resident
  warps; the un-hidden residue surfaces per level as a pipeline drain,
- **synchronization**: ``sync_cost`` per group barrier (needed once >1
  warp participates).

The model is calibrated (``issue_cost``, ``sync_cost``) so that the
*endpoints* match the paper's measurements (T=1 ≈ 0.23, T=256 ≈ 0.95
ops/cycle on the benchmark SPNs); the sublinear *shape* of fig. 2(c)
emerges from the structural terms, not from fitting.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..program import TensorProgram
from .config import GPUModelConfig

MEM_LATENCY = 28.0      # shared-memory round trip on an embedded SM
SCHEDULERS = 4          # TX2 SM warp schedulers


@dataclasses.dataclass
class GPUPerf:
    threads: int
    cycles: float
    ops_per_cycle: float
    breakdown: dict


def color_banks(prog: TensorProgram, banks: int) -> np.ndarray:
    """Graph-coloring bank assignment for the shared value array (§III.2).

    Greedy repair pass: wherever an op's two operands collide in a bank,
    move the later-defined slot to the least-loaded non-conflicting bank.
    """
    nslots = prog.num_slots
    bank_of = (np.arange(nslots) % banks).astype(np.int64)
    load = np.bincount(bank_of, minlength=banks).astype(np.int64)
    for i in range(prog.n_ops):
        bi, ci = int(prog.b[i]), int(prog.c[i])
        if bi != ci and bank_of[bi] == bank_of[ci]:
            mv, keep = max(bi, ci), min(bi, ci)
            load[bank_of[mv]] -= 1
            for cand in np.argsort(load):
                if cand != bank_of[keep]:
                    bank_of[mv] = int(cand)
                    break
            load[bank_of[mv]] += 1
    return bank_of


def analyze(prog: TensorProgram, threads: int,
            cfg: GPUModelConfig = GPUModelConfig()) -> GPUPerf:
    n = prog.n_ops
    warp = cfg.warp_size
    bank_of = (color_banks(prog, cfg.shared_banks) if cfg.use_bank_coloring
               else None)

    def serialization(addrs: np.ndarray) -> float:
        if len(addrs) <= 1:
            return 1.0
        bk = bank_of[addrs] if bank_of is not None else addrs % cfg.shared_banks
        factor = 1
        for u in np.unique(bk):
            factor = max(factor, len(np.unique(addrs[bk == u])))
        return float(factor)

    warps_resident = max(1, min(threads, cfg.cuda_cores) // warp)
    schedulers = min(SCHEDULERS, warps_resident)

    issue = 0.0       # arithmetic issue cycles (aggregated per scheduler)
    lsu = 0.0         # shared-memory pipe cycles (global serializer)
    conflict = 0.0    # extra shared-mem transactions from bank conflicts
    sync = 0.0
    drain = 0.0
    offsets = prog.level_offsets
    for lo, hi in zip(offsets[:-1], offsets[1:]):
        lo, hi = int(lo), int(hi)
        if hi == lo:
            continue
        for w0 in range(lo, hi, threads):
            w1 = min(w0 + threads, hi)
            for ws in range(w0, w1, warp):
                we = min(ws + warp, w1)
                ser = (serialization(prog.b[ws:we])
                       + serialization(prog.c[ws:we])
                       + serialization(np.arange(prog.m + ws, prog.m + we)))
                ops = prog.opcode[ws:we]
                div = 2.0 if int(ops.min()) != int(ops.max()) else 1.0
                # arithmetic (x divergence) issues on the warp schedulers;
                # the 3 shared-memory accesses per op (2 ld + 1 st, plus
                # bank-conflict replays) serialize on the SM's shared-memory
                # pipe — ONE warp access per cycle regardless of schedulers.
                issue += div * cfg.issue_cost
                lsu += ser * cfg.issue_cost
        # un-hidden latency at the level boundary (dependent levels)
        drain += MEM_LATENCY / warps_resident
        if warps_resident > 1:
            sync += cfg.sync_cost
    total = issue / schedulers + lsu + sync + drain
    total = max(total, 1.0)
    return GPUPerf(threads=threads, cycles=total, ops_per_cycle=n / total,
                   breakdown={"issue": issue / schedulers, "lsu": lsu,
                              "sync": sync, "drain": drain})
