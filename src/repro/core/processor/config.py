"""Machine configurations (paper Table I).

The processor datapath is ``num_trees`` binary PE trees of depth
``tree_levels``:

- a depth-L tree has ``2**L`` crossbar-fed leaf ports and
  ``2**L - 1`` PEs (level 1 = ``2**(L-1)`` PEs ... level L = root),
- *Ptree*  = 2 trees × 4 levels → 2·15 = **30 PEs**,
- *Pvect*  = the same machine with the trees removed: 16 independent
  1-level PEs (2 leaf ports each) → **16 PEs**.

Both configurations share the storage system exactly as in the paper:
32 register banks × 64 registers (2K 32b registers) and a 64 KB data
memory moving one 32-wide vector row per access. Each tree owns a
*private* slice of banks for writes; reads go through a full crossbar
(any port can read any bank, ≤ 1 distinct address per bank per cycle).
A level-ℓ PE at position ``p`` may write only to the banks covering its
leaf-port block — 2 banks at level 1, 4 at level 2, ... (paper fig. 3).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ProcessorConfig:
    name: str
    num_trees: int
    tree_levels: int          # L
    banks: int = 32           # total register banks (across all trees)
    regs_per_bank: int = 64
    data_mem_rows: int = 512  # 64KB / (32 banks × 4B)
    pe_latency: int = 1       # pipeline cycles per tree level

    @property
    def leaf_ports_per_tree(self) -> int:
        return 2 ** self.tree_levels

    @property
    def banks_per_tree(self) -> int:
        return self.banks // self.num_trees

    @property
    def pes_per_tree(self) -> int:
        return 2 ** self.tree_levels - 1

    @property
    def num_pes(self) -> int:
        return self.num_trees * self.pes_per_tree

    @property
    def total_regs(self) -> int:
        return self.banks * self.regs_per_bank

    def level_pes(self, level: int) -> int:
        """PEs at ``level`` (1 = bottom) per tree."""
        return 2 ** (self.tree_levels - level)

    def write_banks(self, level: int, pos: int) -> range:
        """Banks (tree-local ids) a level-``level`` PE at ``pos`` may write."""
        span = (2 ** level) * self.banks_per_tree // self.leaf_ports_per_tree
        span = max(span, 1)
        lo = min(pos * span, self.banks_per_tree - 1)
        return range(lo, min(lo + span, self.banks_per_tree))

    def port_bank(self, port: int) -> int:
        """Tree-local bank aligned with leaf ``port`` (used as write default)."""
        return port * self.banks_per_tree // self.leaf_ports_per_tree


PTREE = ProcessorConfig("Ptree", num_trees=2, tree_levels=4)
PVECT = ProcessorConfig("Pvect", num_trees=16, tree_levels=1)

assert PTREE.num_pes == 30 and PVECT.num_pes == 16  # paper Table I


# ---------------------------------------------------------------------------
# General-purpose platform models (paper §III / Table I rows 1-2)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class CPUModelConfig:
    """Superscalar CPU (i5-7200-class): 2 FP units, OoO window, L1."""
    name: str = "CPU"
    issue_width: int = 2          # arith units in the superscalar core
    fp_latency: int = 4           # FP add/mul latency (Skylake: 4)
    window: int = 64              # effective OoO scheduling window
    regs: int = 168               # physical FP registers
    l1_latency: int = 4
    load_ports: int = 1           # effective AGU throughput for this kernel
    frontend_ops_per_cycle: float = 2.0
    # real-machine scheduling efficiency vs the ideal resource bound —
    # calibrated ONCE against the paper's measured 0.55 ops/cycle endpoint
    # (§III); the cross-dataset SHAPE stays structural.
    sched_efficiency: float = 0.53


@dataclasses.dataclass(frozen=True)
class GPUModelConfig:
    """Embedded GPU (Jetson TX2-class SM): SIMT, shared memory banks."""
    name: str = "GPU"
    cuda_cores: int = 128
    warp_size: int = 32
    shared_banks: int = 32
    sync_cost: int = 28           # __syncthreads() cost per group barrier
    issue_cost: float = 1.0       # cycles per instr per warp scheduler
    gather_accesses: int = 3      # 2 operand reads + 1 write per op
    use_bank_coloring: bool = True


CPU_MODEL = CPUModelConfig()
GPU_MODEL = GPUModelConfig()
