"""Superscalar CPU performance model (paper §III "CPU", Table I row 1).

The paper measures 0.55 effective ops/cycle on an i5-7200U running the SPN
as a compiled list of operations (alg. 1). This model reproduces that
number from microarchitectural first principles rather than hard-coding it:

- every SPN op is one FP µop (add/mul, latency ``fp_latency``, 2 ports),
- values live in registers only within a *register reach* window (compiled
  code has 16 architectural registers; the renamer extends this, but
  values produced too far from their use are spilled by the compiler), so
  far operands cost a load µop (2 load ports) and far-consumed results a
  store µop (1 port),
- the frontend sustains ``frontend_ops_per_cycle`` µops/cycle,
- dependency chains bound the schedule from below via the critical path.

cycles = max(throughput bound over each resource, dependency bound).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..program import TensorProgram
from .config import CPUModelConfig

# how many µops back a value can still be in a register (compiler register
# reach; calibrated once against the paper's 0.55 ops/cycle endpoint)
REGISTER_REACH = 18


@dataclasses.dataclass
class CPUPerf:
    cycles: float
    ops_per_cycle: float
    uops: dict
    bound: str


def analyze(prog: TensorProgram, cfg: CPUModelConfig = CPUModelConfig()) -> CPUPerf:
    n, m = prog.n_ops, prog.m
    b, c = prog.b, prog.c

    # last-use distance: operand in registers iff produced < REACH µops ago.
    # Leaves always come from memory (they arrive as the input vector).
    pos = np.arange(n)
    def load_needed(operand):
        is_leaf = operand < m
        dist = pos - (operand - m)
        return is_leaf | (dist > REGISTER_REACH)
    loads = load_needed(b).astype(np.int64) + load_needed(c).astype(np.int64)

    # store needed if any consumer is further than REACH away (or no
    # consumer inside the window — conservatively: last consumer distance)
    last_use = np.full(n, 1 << 30, np.int64)
    for i in range(n - 1, -1, -1):
        for s in (b[i], c[i]):
            if s >= m:
                last_use[s - m] = min(last_use[s - m], i)
    dist_use = last_use - pos
    stores = (dist_use > REGISTER_REACH).astype(np.int64)

    n_load = int(loads.sum())
    n_store = int(stores.sum())
    n_uops = n + n_load + n_store

    # resource (throughput) bounds
    bounds = {
        "fp": n / cfg.issue_width,
        "load": n_load / 2.0,
        "store": n_store / 1.0,
        "frontend": n_uops / (cfg.frontend_ops_per_cycle * 2),
    }
    # dependency bound: critical path in FP-latency units (+ load latency
    # on leaf edges, charged once)
    depth = np.zeros(n, np.int64)
    for i in range(n):
        db = depth[b[i] - m] if b[i] >= m else 0
        dc = depth[c[i] - m] if c[i] >= m else 0
        depth[i] = max(db, dc) + 1
    bounds["deps"] = int(depth.max()) * cfg.fp_latency + cfg.l1_latency

    bound = max(bounds, key=lambda k: bounds[k])
    cycles = float(bounds[bound]) / cfg.sched_efficiency
    return CPUPerf(cycles=cycles, ops_per_cycle=n / cycles,
                   uops={"fp": n, "load": n_load, "store": n_store},
                   bound=bound)
