"""Vectorized fast simulator of the SPN processor.

The checked simulator (:mod:`repro.core.processor.sim`) interprets the
VLIW stream cycle by cycle in Python, enforcing every structural rule of
the machine — invaluable as a conformance oracle, far too slow to serve
traffic. This module makes the processor model a *throughput substrate*:

1. :func:`decode` replays the instruction stream **once, symbolically** —
   crossbar reads, pipelined writebacks, vector loads/stores — tracking
   which SSA value each register/memory cell holds, and emits the dense
   :class:`~repro.core.compiler.isa.DenseProgram` encoding (flat numpy
   opcode/operand arrays grouped into dependence levels);
2. :func:`run` executes that encoding with a few vectorized numpy
   gather→op→scatter passes over a ``(values, batch)`` f32 buffer.

Because the decode preserves the exact f32 dataflow the checked
simulator executes (same ops, same operands, forwards resolved to
aliases), root values are **bit-identical** to the cycle-accurate model
— asserted in ``tests/test_runtime.py`` — while the per-request cost
drops from O(cycles × machine state) Python work to O(levels) numpy
calls. Cycle/throughput accounting still comes from the real stream.

The replay (:func:`symbolic_replay`) and the densification
(:func:`densify`) are exposed separately so the multi-core decoder
(:mod:`repro.core.multicore.fastsim`) can replay each core's stream —
``SEND`` rows record exported SSA ids, ``RECV`` rows introduce import
placeholders — and merge the per-core graphs into ONE dense program.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .. import levelize
from ..compiler import isa
from ..program import TensorProgram
from .config import ProcessorConfig
from .sim import SimError, SimResult


@dataclasses.dataclass
class Replay:
    """Symbolic replay of one core's VLIW stream.

    SSA ids: ``[0, n_init)`` are memory-image cells, ``[n_init, ...)``
    are PE outputs in emission order. *Negative* operand ids ``-(k+1)``
    reference ``imports[k] = (channel_row_id, position)`` — values that
    arrive over the interconnect (multi-core only).
    """
    init_values: np.ndarray                  # (n_init,) f32
    input_cells: np.ndarray                  # (m_ind_local,) int32
    opcode: np.ndarray                       # (n_ops,) uint8 D_* codes
    a: np.ndarray                            # (n_ops,) int32 (or negative)
    b: np.ndarray                            # (n_ops,) int32 (or negative)
    root: int                                # SSA id of the root cell
    imports: list                            # [(row_id, pos), ...]
    exports: dict                            # (row_id, pos) -> SSA id
    cycles: int
    n_useful_ops: int
    # multi-root (interleaved) programs: SSA id per instance root, in
    # instance order; None for single-root. roots[0] == root when present.
    roots: list[int] | None = None

    @property
    def n_init(self) -> int:
        return len(self.init_values)


def symbolic_replay(vprog: isa.VLIWProgram, cfg: ProcessorConfig,
                    members_of=None) -> Replay:
    """Replay one instruction stream symbolically into SSA form.

    ``members_of`` maps channel-row id -> member count (required when the
    stream carries RECV rows).
    """
    banks = cfg.banks

    # initial SSA values: the constant data-memory image, cell by cell
    init_vals: list[np.float32] = []
    mem_sym: dict[tuple[int, int], int] = {}
    for row, consts in vprog.const_rows.items():
        cv = np.asarray(consts, np.float32)
        for bank in range(banks):
            mem_sym[(row, bank)] = len(init_vals)
            init_vals.append(cv[bank])
    zero_id = len(init_vals)          # stores zero-fill invalid cells
    init_vals.append(np.float32(0.0))
    input_cells = np.asarray(
        [mem_sym[(row, bank)] for (row, bank) in vprog.input_layout],
        np.int32)
    n_init = len(init_vals)

    ops_o: list[int] = []
    ops_a: list[int] = []
    ops_b: list[int] = []
    imports: list[tuple[int, int]] = []
    exports: dict[tuple[int, int], int] = {}

    def new_op(code: int, a: int, b: int) -> int:
        ops_o.append(code)
        ops_a.append(a)
        ops_b.append(b)
        return n_init + len(ops_o) - 1

    reg_sym: dict[tuple[int, int], int] = {}
    pending: dict[int, list] = {}

    for t, instr in enumerate(vprog.instrs):
        # commits land at cycle start (same ordering as the checked sim)
        for entry in pending.pop(t, ()):
            if entry[0] == "row":                  # vector load: every bank
                _, reg, vals = entry
                for bank in range(banks):
                    reg_sym[(bank, reg)] = vals[bank]
            else:
                _, bank, reg, v = entry
                reg_sym[(bank, reg)] = v

        # crossbar reads
        port_vals: dict[tuple[int, int], int] = {}
        for ti in instr.trees:
            if ti is None:
                continue
            for port, src in ti.reads.items():
                v = reg_sym.get((src.bank, src.reg))
                if v is None:
                    raise SimError(f"cycle {t}: read of invalid cell "
                                   f"({src.bank},{src.reg})")
                port_vals[(ti.tree, port)] = v

        # tree datapaths, bottom-up — forwards alias, arithmetic emits SSA
        for ti in instr.trees:
            if ti is None:
                continue
            level_vals: dict[tuple[int, int], int | None] = {}
            for port in range(cfg.leaf_ports_per_tree):
                level_vals[(0, port)] = port_vals.get((ti.tree, port))
            for (level, pos), code in sorted(ti.pe_ops.items()):
                a = level_vals.get((level - 1, 2 * pos))
                b = level_vals.get((level - 1, 2 * pos + 1))
                if code == isa.PE_FWD_A:
                    v = a
                elif code == isa.PE_FWD_B:
                    v = b
                else:
                    if a is None or b is None:
                        raise SimError(f"cycle {t}: PE ({level},{pos}) "
                                       "computes from undriven input")
                    v = new_op(isa._D_OF_PE[code], a, b)
                level_vals[(level, pos)] = v
            for wb in ti.writes:
                v = level_vals.get((wb.level, wb.pos))
                if v is None:
                    raise SimError(f"cycle {t}: writeback of NOP output")
                commit = t + wb.level * cfg.pe_latency
                pending.setdefault(commit, []).append(
                    ("cell", wb.bank, wb.reg, v))

        # memory op
        if instr.mem is not None:
            mi = instr.mem
            if mi.kind == "load":
                if (mi.addr, 0) not in mem_sym:
                    raise SimError(f"cycle {t}: load of unwritten "
                                   f"row {mi.addr}")
                vals = [mem_sym[(mi.addr, bank)] for bank in range(banks)]
                pending.setdefault(t + 1, []).append(("row", mi.reg, vals))
            else:
                for bank in range(banks):
                    mem_sym[(mi.addr, bank)] = reg_sym.get((bank, mi.reg),
                                                           zero_id)

        # comm op (multi-core): exports snapshot, imports placeholder
        if instr.comm is not None:
            ci = instr.comm
            if ci.kind == "send":
                for (pos, bank, reg) in vprog.send_specs[ci.addr]:
                    v = reg_sym.get((bank, reg))
                    if v is None:
                        raise SimError(f"cycle {t}: send row {ci.addr} "
                                       f"snapshots invalid cell "
                                       f"({bank},{reg})")
                    exports[(ci.addr, pos)] = v
            else:   # recv: member position p lands in bank p
                if members_of is None:
                    raise SimError("recv row in a stream decoded without "
                                   "channel metadata")
                for pos in range(members_of[ci.addr]):
                    imports.append((ci.addr, pos))
                    reg_sym[(pos, ci.reg)] = -len(imports)

    if pending:
        raise SimError(f"program ended with pending commits: "
                       f"{sorted(pending)}")
    root_row, root_bank = vprog.root_loc
    roots: list[int] | None = None
    if root_row < 0:          # storeless worker core: outputs are SENDs
        root = -1
    else:
        root = mem_sym.get((root_row, root_bank))
        if root is None:
            raise SimError("root row never stored")
        if vprog.root_locs is not None:   # multi-root (interleaved) program
            roots = []
            for row, bank in vprog.root_locs:
                v = mem_sym.get((row, bank))
                if v is None:
                    raise SimError(f"root row {row} never stored")
                roots.append(int(v))

    return Replay(init_values=np.asarray(init_vals, np.float32),
                  input_cells=input_cells,
                  opcode=np.asarray(ops_o, np.uint8),
                  a=np.asarray(ops_a, np.int32),
                  b=np.asarray(ops_b, np.int32),
                  root=int(root), imports=imports, exports=exports,
                  cycles=len(vprog.instrs),
                  n_useful_ops=vprog.n_useful_ops,
                  roots=roots)


def densify(o: np.ndarray, a: np.ndarray, b: np.ndarray, n_init: int,
            init_values: np.ndarray, input_cells: np.ndarray,
            root: int, cycles: int, n_useful_ops: int,
            input_slots: np.ndarray | None = None,
            roots: list[int] | np.ndarray | None = None
            ) -> isa.DenseProgram:
    """Level-sort an SSA op graph and cut it into ufunc segments.

    ``a``/``b`` must be fully resolved (no negative import ids).
    """
    # sort ops by (dependence level, opcode): levels make every range
    # independent (vectorizable), the within-level opcode sort makes each
    # level a handful of contiguous single-ufunc runs — reordering inside
    # a level is free because same-level ops never feed each other
    n = len(o)
    lvl = levelize.op_levels(a, b, n_init)
    order = np.lexsort((o, lvl))
    new_slot_of_old = np.empty(n, np.int64)
    new_slot_of_old[order] = np.arange(n)
    remap = lambda x: np.where(x >= n_init,
                               new_slot_of_old[np.maximum(x - n_init, 0)]
                               + n_init, x).astype(np.int32)
    new_a, new_b, new_o = remap(a[order]), remap(b[order]), o[order]
    lvl_s = lvl[order]
    num_levels = int(lvl_s.max()) if n else 0
    offsets = np.searchsorted(lvl_s, np.arange(2, num_levels + 2))
    offsets = np.concatenate([[0], offsets]).astype(np.int32)
    # (level, opcode) change points -> contiguous execution segments; the
    # two operand vectors are pre-fused into one gather index per segment
    segments: list[tuple[int, int, int, np.ndarray]] = []
    key = lvl_s.astype(np.int64) * 8 + new_o
    cuts = np.flatnonzero(np.diff(key)) + 1
    bounds = np.concatenate([[0], cuts, [n]])
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        lo, hi = int(lo), int(hi)
        if hi - lo == 1:   # single-op run: basic-index row views, no gather
            ab = (int(new_a[lo]), int(new_b[lo]))
        else:
            ab = np.concatenate([new_a[lo:hi], new_b[lo:hi]])
        segments.append((lo, hi, int(new_o[lo]), ab))
    if root >= n_init:
        root = int(n_init + new_slot_of_old[root - n_init])
    if roots is not None:
        roots = np.asarray(
            [int(n_init + new_slot_of_old[r - n_init]) if r >= n_init
             else int(r) for r in roots], np.int64)
    return isa.DenseProgram(
        n_init=n_init,
        init_values=np.asarray(init_values, np.float32),
        input_cells=np.asarray(input_cells, np.int32),
        opcode=new_o, a=new_a, b=new_b,
        level_offsets=offsets, segments=segments,
        root=int(root),
        cycles=cycles,
        n_useful_ops=n_useful_ops,
        input_slots=input_slots,
        roots=roots)


def decode(vprog: isa.VLIWProgram, cfg: ProcessorConfig) -> isa.DenseProgram:
    """Pre-decode a compiled (single-core) VLIW program."""
    r = symbolic_replay(vprog, cfg)
    assert not r.imports and not r.exports, \
        "multi-core streams decode via repro.core.multicore.fastsim"
    return densify(r.opcode, r.a, r.b, r.n_init, r.init_values,
                   r.input_cells, r.root, r.cycles, r.n_useful_ops,
                   roots=r.roots)


def run(dense: isa.DenseProgram, leaf_ind: np.ndarray,
        workspace: dict | None = None) -> np.ndarray:
    """Execute the dense encoding for a batch of leaf inputs.

    ``leaf_ind``: (batch, m_ind) indicator values → (batch,) f32 root
    values, bit-identical to the checked simulator's. Multi-root
    (interleaved) programs return ``(k, batch)`` instead — one row per
    instance root, in instance order. Pass a ``workspace`` dict (owned by
    the caller, e.g. the vliw-sim artifact) to reuse the value buffer
    across calls of the same batch size — op outputs live in rows
    ``>= n_init`` and every input cell is overwritten per call, so reuse
    never leaks state between requests.
    """
    leaf_ind = np.atleast_2d(np.asarray(leaf_ind, np.float32))
    batch = leaf_ind.shape[0]
    n_init = dense.n_init
    V = None if workspace is None else workspace.get(batch)
    if V is None:
        V = np.empty((n_init + dense.n_ops, batch), np.float32)
        V[:n_init] = dense.init_values[:, None]
        if workspace is not None:
            workspace[batch] = V
    if dense.input_slots is None:
        V[dense.input_cells] = leaf_ind.T
    else:   # multi-core: leaf columns fan out to per-core duplicate cells
        V[dense.input_cells] = leaf_ind.T[dense.input_slots]
    for lo, hi, code, ab in dense.segments:
        if type(ab) is tuple:           # single op: zero-copy row views
            va, vb = V[ab[0]], V[ab[1]]
            out = V[n_init + lo]
        else:
            G = V[ab]                   # one fused gather for both operands
            w = hi - lo
            va, vb = G[:w], G[w:]
            out = V[n_init + lo: n_init + hi]
        if code == isa.D_MUL:
            np.multiply(va, vb, out=out)
        elif code == isa.D_MAX:
            np.maximum(va, vb, out=out)
        else:
            np.add(va, vb, out=out)
    if dense.roots is not None:
        return V[dense.roots].copy()      # (k, batch), instance order
    return V[dense.root].copy()


def simulate_fast(vprog: isa.VLIWProgram, prog: TensorProgram,
                  X: np.ndarray, cfg: ProcessorConfig,
                  dense: isa.DenseProgram | None = None) -> SimResult:
    """Drop-in counterpart of :func:`repro.core.processor.sim.simulate`.

    Pass a pre-decoded ``dense`` program to amortize the decode across
    calls (the vliw-sim substrate artifact does exactly that).
    """
    if dense is None:
        dense = decode(vprog, cfg)
    leaf_ind = prog.leaves_from_evidence(np.atleast_2d(X)).astype(np.float32)
    root = run(dense, leaf_ind)
    return SimResult(root_values=root, cycles=dense.cycles,
                     useful_ops=dense.n_useful_ops,
                     ops_per_cycle=dense.n_useful_ops / max(dense.cycles, 1),
                     checks={})
