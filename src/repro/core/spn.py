"""Sum-Product Network (arithmetic circuit) graph representation.

An SPN is a rooted DAG whose internal nodes are (weighted) sums and
products, and whose leaves are either *indicator* inputs (evidence on a
discrete variable) or *parameter* constants (the paper: "leaf nodes are
probabilistic parameters or data inputs").

This module holds the high-level graph; :mod:`repro.core.program` lowers it
to the flat binary-op tensor program of the paper's alg. 2 (vectors O/B/C
over a value buffer), which every executor / compiler / kernel consumes.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

# Node type codes (kept stable: serialized in .ac files and test fixtures).
LEAF_IND = 0   # indicator leaf: [var == value]
LEAF_PARAM = 1 # parameter leaf: a (learnable) constant
SUM = 2
PROD = 3

_TYPE_NAMES = {LEAF_IND: "ind", LEAF_PARAM: "param", SUM: "sum", PROD: "prod"}


class SPNBuilder:
    """Incremental builder; node ids are returned in creation order.

    Children must be created before their parents, so creation order is a
    valid topological order — invariant relied on throughout.
    """

    def __init__(self) -> None:
        self.node_type: list[int] = []
        self.children: list[tuple[int, ...]] = []
        self.weights: list[tuple[float, ...] | None] = []
        self.leaf_var: list[int] = []
        self.leaf_value: list[int] = []
        self.param_value: list[float] = []

    def _add(self, ntype: int, children=(), weights=None, var=-1, value=-1,
             param=0.0) -> int:
        nid = len(self.node_type)
        for c in children:
            if not 0 <= c < nid:
                raise ValueError(f"child {c} of node {nid} not yet defined")
        self.node_type.append(ntype)
        self.children.append(tuple(children))
        self.weights.append(tuple(weights) if weights is not None else None)
        self.leaf_var.append(var)
        self.leaf_value.append(value)
        self.param_value.append(param)
        return nid

    def indicator(self, var: int, value: int) -> int:
        return self._add(LEAF_IND, var=var, value=value)

    def param(self, value: float) -> int:
        return self._add(LEAF_PARAM, param=float(value))

    def sum(self, children: Sequence[int], weights: Sequence[float] | None = None) -> int:
        if len(children) < 1:
            raise ValueError("sum needs >=1 child")
        if weights is not None and len(weights) != len(children):
            raise ValueError("weights/children length mismatch")
        return self._add(SUM, children=children, weights=weights)

    def product(self, children: Sequence[int]) -> int:
        if len(children) < 1:
            raise ValueError("product needs >=1 child")
        return self._add(PROD, children=children)

    def build(self, root: int | None = None) -> "SPN":
        root = len(self.node_type) - 1 if root is None else root
        return SPN(
            node_type=np.asarray(self.node_type, dtype=np.int8),
            children=list(self.children),
            weights=list(self.weights),
            leaf_var=np.asarray(self.leaf_var, dtype=np.int32),
            leaf_value=np.asarray(self.leaf_value, dtype=np.int32),
            param_value=np.asarray(self.param_value, dtype=np.float64),
            root=root,
        )


@dataclasses.dataclass
class SPN:
    """Frozen SPN DAG in topological (children-first) node order."""

    node_type: np.ndarray            # (N,) int8
    children: list[tuple[int, ...]]  # per node
    weights: list[tuple[float, ...] | None]
    leaf_var: np.ndarray             # (N,) int32, -1 for non-indicator
    leaf_value: np.ndarray           # (N,) int32
    param_value: np.ndarray          # (N,) float64
    root: int

    # ------------------------------------------------------------------ #
    @property
    def num_nodes(self) -> int:
        return len(self.node_type)

    @property
    def num_vars(self) -> int:
        lv = self.leaf_var[self.node_type == LEAF_IND]
        return int(lv.max()) + 1 if lv.size else 0

    def counts(self) -> dict[str, int]:
        t = self.node_type
        return {name: int((t == code).sum()) for code, name in _TYPE_NAMES.items()}

    # ------------------------------------------------------------------ #
    def scopes(self) -> list[int]:
        """Per-node variable scope as bitmask ints."""
        sc: list[int] = [0] * self.num_nodes
        for i in range(self.num_nodes):
            t = self.node_type[i]
            if t == LEAF_IND:
                sc[i] = 1 << int(self.leaf_var[i])
            elif t == LEAF_PARAM:
                sc[i] = 0
            else:
                m = 0
                for c in self.children[i]:
                    m |= sc[c]
                sc[i] = m
        return sc

    def check_valid(self) -> list[str]:
        """Return list of validity violations (empty == smooth+decomposable)."""
        sc = self.scopes()
        problems: list[str] = []
        for i in range(self.num_nodes):
            t = self.node_type[i]
            ch = self.children[i]
            if t == SUM:
                # smoothness: all children share the sum's scope (parameter
                # leaves have empty scope and are exempt: they appear as
                # explicit weight leaves after lowering).
                scopes = {sc[c] for c in ch if self.node_type[c] != LEAF_PARAM}
                if len(scopes) > 1:
                    problems.append(f"sum {i} not smooth: child scopes differ")
            elif t == PROD:
                seen = 0
                for c in ch:
                    if seen & sc[c]:
                        problems.append(f"product {i} not decomposable")
                        break
                    seen |= sc[c]
        return problems

    # ------------------------------------------------------------------ #
    def evaluate(self, leaf_ind_values: np.ndarray) -> float:
        """Reference (float64, topological) evaluation — the oracle.

        ``leaf_ind_values``: value for every node that is an indicator leaf,
        indexed by *node id* (non-indicator entries ignored).
        """
        vals = np.zeros(self.num_nodes, dtype=np.float64)
        for i in range(self.num_nodes):
            t = self.node_type[i]
            if t == LEAF_IND:
                vals[i] = leaf_ind_values[i]
            elif t == LEAF_PARAM:
                vals[i] = self.param_value[i]
            elif t == SUM:
                w = self.weights[i]
                if w is None:
                    vals[i] = sum(vals[c] for c in self.children[i])
                else:
                    vals[i] = sum(wi * vals[c] for wi, c in zip(w, self.children[i]))
            else:  # PROD
                p = 1.0
                for c in self.children[i]:
                    p *= vals[c]
                vals[i] = p
        return float(vals[self.root])

    def evaluate_evidence(self, x: Sequence[int] | np.ndarray,
                          marginalized: Iterable[int] = ()) -> float:
        """Evaluate with evidence vector ``x`` (per variable, -1 == marginalize)."""
        marg = set(marginalized)
        vals = np.zeros(self.num_nodes, dtype=np.float64)
        for i in range(self.num_nodes):
            if self.node_type[i] == LEAF_IND:
                v = int(self.leaf_var[i])
                if v in marg or (v < len(x) and int(x[v]) == -1):
                    vals[i] = 1.0
                else:
                    vals[i] = 1.0 if int(x[v]) == int(self.leaf_value[i]) else 0.0
        return self.evaluate(vals)


def normalize_weights(spn: SPN) -> SPN:
    """Return a copy with every sum's weights normalized to 1."""
    new_w: list[tuple[float, ...] | None] = []
    for i in range(spn.num_nodes):
        w = spn.weights[i]
        if spn.node_type[i] == SUM:
            if w is None:
                w = tuple(1.0 for _ in spn.children[i])
            s = sum(w)
            w = tuple(wi / s for wi in w) if s > 0 else w
        new_w.append(w)
    return dataclasses.replace(spn, weights=new_w)
