"""Per-SPN compiler autotuning (§Perf: per-workload mapping).

The compiler exposes a handful of tunables — partition strategy / seed /
refinement passes / cone grain, fused-unit ``max_arity``, ETA-feedback
rounds, effective core count, and cross-batch ``program.interleave(k)``
— whose best values depend on each SPN's shape. :func:`tune_program`
sweeps them with a budgeted random + greedy-refinement search scored by
the bit-exact multicore fast-probe cycle count (value-independent, so
one 1-row lockstep probe per candidate is the *exact* serving cost).

The search is fully deterministic: same program digest + budget + seed
⇒ identical :class:`TuneConfig` and fingerprint, so tuned artifacts are
reproducible and cache-stable across processes.
"""
from .search import (DEFAULT_BUDGET, INFEASIBLE, TUNE_CACHE, TuneConfig,
                     TuneResult, default_config, tune_program)

__all__ = ["TuneConfig", "TuneResult", "tune_program", "default_config",
           "DEFAULT_BUDGET", "INFEASIBLE", "TUNE_CACHE"]
