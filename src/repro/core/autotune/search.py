"""Budgeted search over the multicore compiler's tunables.

Scoring: every candidate compiles with :func:`compile_multicore`, whose
``meta["cycles"]`` already comes from the exact 1-row lockstep probe
(cycle counts are value-independent), so the tuner's objective —
**cycles per evaluation** = probe cycles / interleave factor — is the
true steady-state serving cost, not an estimate. Scores are exact
rationals (:class:`fractions.Fraction`), so comparisons and tie-breaks
are platform-independent.

Determinism contract (property-tested): ``tune_program`` is a pure
function of (program digest, processor, interconnect, max_cores,
placement, max_interleave, budget, seed). No wall-clock measurement
enters the objective.

The search is *attribution-guided*: after probing the default config it
runs the cycle-attribution engine (:mod:`repro.obs.attr`) on the
default compilation and spends its next trials on candidates targeting
the named bottleneck — comm-bound programs try placement passes /
higher interleave / fewer cores, imbalance-bound ones try alternative
partition strategies and seeds, compute-bound ones try arity rebalance
and interleave. The prior is itself deterministic (it derives from the
same value-independent lockstep probe), so the contract above still
holds; :meth:`TuneResult.summary` records the prior and whether a
guided candidate won.
"""
from __future__ import annotations

import dataclasses
from fractions import Fraction

import numpy as np

from ...obs import metrics, trace
from ..multicore.comm import XBAR, InterconnectConfig
from ..processor.config import PTREE, ProcessorConfig
from ..program import TensorProgram, interleave

#: trials used by ``autotune="cached"`` when no cached entry exists yet
DEFAULT_BUDGET = 32

_STRATEGIES = ("subtree", "cone", "level")

#: score for a config whose compile fails (scheduler live-lock on a
#: pathological partition, machine too small for the interleaved
#: program, ...). Infeasible points rank behind every feasible one and
#: still consume budget — the search space legitimately contains them.
INFEASIBLE = 1 << 62
_SEEDS = tuple(range(8))
_PASSES = (0, 1, 2, 3)
_ETAS = (0, 1, 2, 3)
_ARITIES = (None, 2, 4, 8)


@dataclasses.dataclass(frozen=True)
class TuneConfig:
    """One point in the compiler's knob space (canonical form)."""
    cores: int = 2
    strategy: str = "subtree"
    seed: int = 0
    passes: int = 0
    grain: int | None = None
    max_arity: int | None = None
    eta_iters: int = 2
    interleave: int = 1

    def fingerprint(self) -> str:
        return (f"c{self.cores}/{self.strategy}/s{self.seed}"
                f"/p{self.passes}/g{self.grain}/a{self.max_arity}"
                f"/e{self.eta_iters}/i{self.interleave}")

    def canonical(self, max_cores: int) -> "TuneConfig":
        """Collapse knobs that cannot affect the compiled program.

        ``grain`` only exists for the cone strategy; at ``cores=1`` the
        partition is the identity, so every partition knob (and the
        ETA-feedback loop, which needs comm rows) is inert — only the
        interleave factor matters. Canonicalizing *before* dedup means
        the budget never pays twice for one distinct compilation.
        """
        cores = max(1, min(int(self.cores), max_cores))
        strategy, seed = self.strategy, int(self.seed)
        passes, grain = int(self.passes), self.grain
        max_arity, eta = self.max_arity, int(self.eta_iters)
        if strategy != "cone":
            grain = None
        if cores == 1:
            strategy, seed, passes = "subtree", 0, 0
            grain, max_arity, eta = None, None, 0
        return TuneConfig(cores=cores, strategy=strategy, seed=seed,
                          passes=passes, grain=grain, max_arity=max_arity,
                          eta_iters=eta, interleave=max(1, int(self.interleave)))


@dataclasses.dataclass
class TuneResult:
    """Outcome of one :func:`tune_program` run."""
    config: TuneConfig
    cycles: int                    # probe cycles of the winning program
    cycles_per_eval: float         # cycles / interleave
    default_config: TuneConfig
    default_cycles: int            # probe cycles at the default config
    default_cycles_per_eval: float
    trials: list                   # [(fingerprint, cycles, cyc/eval), ...]
    evaluated: int
    budget: int
    seed: int
    prior: dict | None = None      # attribution prior of the default config
    guided: list = dataclasses.field(default_factory=list)
    guided_win: bool = False       # best config came from the prior

    @property
    def improved(self) -> bool:
        return (Fraction(self.cycles, self.config.interleave)
                < Fraction(self.default_cycles,
                           self.default_config.interleave))

    def summary(self) -> dict:
        return {"config": self.config.fingerprint(),
                "cycles": self.cycles,
                "cycles_per_eval": self.cycles_per_eval,
                "default_cycles": self.default_cycles,
                "default_cycles_per_eval": self.default_cycles_per_eval,
                "evaluated": self.evaluated,
                "budget": self.budget,
                "seed": self.seed,
                "prior": self.prior,
                "guided": list(self.guided),
                "guided_win": self.guided_win}


def default_config(max_cores: int) -> TuneConfig:
    """The untuned compiler defaults at the requested core count."""
    return TuneConfig(cores=max_cores).canonical(max_cores)


#: process-global memo: one tune per (SPN digest, search context).
#: ``budget``/``seed`` are part of the key so the determinism contract
#: holds across processes that tune with different budgets.
TUNE_CACHE: dict[tuple, TuneResult] = {}


def lookup_cached(digest: str) -> TuneResult | None:
    """Any cached result for this SPN digest (``autotune="cached"``).

    Deterministic pick: the smallest full cache key wins when several
    search contexts tuned the same program.
    """
    hits = sorted(k for k in TUNE_CACHE if k[0] == digest)
    return TUNE_CACHE[hits[0]] if hits else None


def _grain_ladder(prog: TensorProgram) -> tuple:
    """Cone-grain sweep values, scaled to the program's op count."""
    n = max(1, prog.n_ops)
    return (None,) + tuple(sorted({max(1, n // d)
                                   for d in (6, 12, 24, 48, 96)}))


def _guided_candidates(group: str, max_cores: int, ks: tuple,
                       grains: tuple) -> list[TuneConfig]:
    """Candidates targeted at the attributed bottleneck of the default.

    ``group`` is the coarse verdict from :mod:`repro.obs.attr`
    (``compute`` / ``comm`` / ``imbalance``). Order within each arm is
    by expected leverage so a small budget still covers the top picks.
    """
    out: list[TuneConfig] = []
    top_k = ks[-1]
    if group == "comm":
        # comm-bound: hide transfer latency (placement passes), raise
        # arithmetic intensity per row (interleave), or cut the wires
        # entirely (fewer cores)
        for p in (1, 2):
            out.append(TuneConfig(cores=max_cores, passes=p))
        if top_k > 1:
            out.append(TuneConfig(cores=max_cores, interleave=top_k,
                                  passes=1))
        if max_cores > 1:
            out.append(TuneConfig(cores=max_cores - 1))
    elif group == "imbalance":
        # barrier-bound: the partition is lopsided — different cut
        # strategies and partition seeds move work between cores
        for strat in _STRATEGIES[1:]:
            out.append(TuneConfig(cores=max_cores, strategy=strat))
        out.append(TuneConfig(cores=max_cores, seed=1))
        if len(grains) > 1:
            out.append(TuneConfig(cores=max_cores, strategy="cone",
                                  grain=grains[1]))
    else:
        # compute-bound: the machine is busy — rebalance the tree
        # (max_arity) or amortize fixed schedule overhead (interleave)
        for a in (2, 4):
            out.append(TuneConfig(cores=max_cores, max_arity=a))
        if top_k > 1:
            out.append(TuneConfig(cores=max_cores, interleave=top_k))
    return out


def tune_program(prog: TensorProgram, cfg: ProcessorConfig = PTREE,
                 max_cores: int = 2, icfg: InterconnectConfig = XBAR,
                 *, budget: int = DEFAULT_BUDGET, seed: int = 0,
                 placement: str = "aware", max_interleave: int = 4,
                 use_cache: bool = True,
                 compile_kwargs: dict | None = None) -> TuneResult:
    """Search the knob space for ``prog``; return the best config found.

    ``budget`` bounds the number of *distinct canonical configurations*
    compiled and probed (the default config always costs trial #1, so
    ``budget=1`` measures the baseline and returns it). The search runs
    three deterministic phases — seeded sweep, random sampling, greedy
    single-knob refinement — and ties break toward smaller interleave,
    then fewer cores, then the lexicographically smallest fingerprint.

    Candidates whose compile raises (the knob space legitimately
    contains infeasible points — e.g. a poor-locality partition that
    live-locks the scheduler, or an interleaved program too large for
    one core) score :data:`INFEASIBLE`, consume budget, and are
    recorded in ``trials`` with ``cycles=None``; the search continues.
    """
    from ..multicore.compile import compile_multicore  # cycle avoidance

    budget = max(1, int(budget))
    key = (prog.digest(), cfg.name, icfg.fingerprint(), max_cores,
           placement, max_interleave, budget, int(seed))
    if use_cache and key in TUNE_CACHE:
        return TUNE_CACHE[key]

    ks = tuple(k for k in (1, 2, 4, 8) if k <= max(1, max_interleave))
    grains = _grain_ladder(prog)
    iprogs: dict[int, TensorProgram] = {1: prog}

    def iprog(k: int) -> TensorProgram:
        if k not in iprogs:
            iprogs[k] = interleave(prog, k)
        return iprogs[k]

    scores: dict[TuneConfig, int] = {}
    trials: list[tuple[str, int, float]] = []
    captured: dict[TuneConfig, object] = {}

    def evaluate(tc: TuneConfig, keep: bool = False) -> int | None:
        """Compile + probe one canonical config; None once over budget."""
        if tc in scores:
            return scores[tc]
        if len(scores) >= budget:
            return None
        with trace.span("autotune.trial",
                        lambda: {"config": tc.fingerprint()}) as sp:
            try:
                mcp = compile_multicore(
                    iprog(tc.interleave), cfg, n_cores=tc.cores, icfg=icfg,
                    seed=tc.seed, strategy=tc.strategy,
                    eta_iters=tc.eta_iters, passes=tc.passes,
                    placement=placement, grain=tc.grain,
                    max_arity=tc.max_arity, **(compile_kwargs or {}))
                cycles = int(mcp.meta["cycles"])
                if keep:
                    captured[tc] = mcp
            except RuntimeError as exc:
                cycles = INFEASIBLE
                sp.set("infeasible", str(exc)[:160])
                metrics.counter("autotune.infeasible").inc()
            sp.set("cycles", cycles)
        scores[tc] = cycles
        trials.append((tc.fingerprint(),
                       None if cycles == INFEASIBLE else cycles,
                       None if cycles == INFEASIBLE
                       else cycles / tc.interleave))
        metrics.counter("autotune.trials").inc()
        return cycles

    def rank(tc: TuneConfig) -> tuple:
        return (Fraction(scores[tc], tc.interleave), tc.interleave,
                tc.cores, tc.fingerprint())

    with trace.span("compile.autotune",
                    lambda: {"budget": budget, "seed": seed,
                             "max_cores": max_cores,
                             "digest": prog.digest()[:12]}) as span:
        default = default_config(max_cores)
        evaluate(default, keep=True)

        # phase 0 — attribution-guided candidates: run the cycle
        # attribution engine on the default compilation and spend the
        # next trials on its bottleneck's highest-leverage knobs. The
        # prior derives from the same value-independent lockstep probe,
        # so the search stays a pure function of the tune key.
        prior: dict | None = None
        guided_fps: list[str] = []
        mcp0 = captured.pop(default, None)
        if mcp0 is not None:
            from ...obs.attr import attribute_multicore
            a = attribute_multicore(mcp0, interleave=default.interleave)
            prior = {"bottleneck": a.bottleneck,
                     "group": a.bottleneck_group,
                     "fractions": dict(a.fractions),
                     "roofline_bound": a.roofline["bound"]}
            span.set("prior", f"{a.bottleneck}/{a.bottleneck_group}")
            for tc in _guided_candidates(a.bottleneck_group, max_cores,
                                         ks, grains):
                tc = tc.canonical(max_cores)
                guided_fps.append(tc.fingerprint())
                evaluate(tc)

        # phase 1 — seeded sweep, highest-leverage knobs first so even a
        # tiny budget covers them: interleave at full cores (the paper's
        # big cycles/eval lever), then the core-count fallback ladder
        # (the "fewer cores win on small SPNs" regression), then the
        # alternative partition strategies, then cross terms
        seeded: list[TuneConfig] = []
        for k in ks[1:]:
            seeded.append(TuneConfig(cores=max_cores, interleave=k))
        for c in range(max_cores - 1, 0, -1):
            seeded.append(TuneConfig(cores=c))
        for strat in _STRATEGIES[1:]:
            seeded.append(TuneConfig(cores=max_cores, strategy=strat))
        for c in range(max_cores - 1, 0, -1):
            for k in ks[1:]:
                seeded.append(TuneConfig(cores=c, interleave=k))
        for tc in seeded:
            evaluate(tc.canonical(max_cores))

        # phase 2 — random sampling across the full product space
        rng = np.random.default_rng(seed)
        while len(scores) < budget:
            n_before = len(scores)
            tc = TuneConfig(
                cores=int(rng.integers(1, max_cores + 1)),
                strategy=_STRATEGIES[int(rng.integers(len(_STRATEGIES)))],
                seed=int(rng.integers(len(_SEEDS))),
                passes=int(_PASSES[int(rng.integers(len(_PASSES)))]),
                grain=grains[int(rng.integers(len(grains)))],
                max_arity=_ARITIES[int(rng.integers(len(_ARITIES)))],
                eta_iters=int(_ETAS[int(rng.integers(len(_ETAS)))]),
                interleave=int(ks[int(rng.integers(len(ks)))]),
            ).canonical(max_cores)
            evaluate(tc)
            if len(scores) == n_before and len(scores) >= budget:
                break   # duplicate draw at the budget edge

        # phase 3 — greedy single-knob refinement (steepest descent)
        def neighbors(tc: TuneConfig) -> list[TuneConfig]:
            out = []
            for c in (tc.cores - 1, tc.cores + 1):
                if 1 <= c <= max_cores:
                    out.append(dataclasses.replace(tc, cores=c))
            for s in _STRATEGIES:
                if s != tc.strategy:
                    out.append(dataclasses.replace(tc, strategy=s))
            for s in ((tc.seed + 1) % len(_SEEDS),
                      (tc.seed + 3) % len(_SEEDS)):
                out.append(dataclasses.replace(tc, seed=int(s)))
            for p in (tc.passes - 1, tc.passes + 1):
                if _PASSES[0] <= p <= _PASSES[-1]:
                    out.append(dataclasses.replace(tc, passes=p))
            gi = grains.index(tc.grain) if tc.grain in grains else 0
            for g in (gi - 1, gi + 1):
                if 0 <= g < len(grains):
                    out.append(dataclasses.replace(tc, grain=grains[g]))
            ai = _ARITIES.index(tc.max_arity)
            for a in (ai - 1, ai + 1):
                if 0 <= a < len(_ARITIES):
                    out.append(dataclasses.replace(tc,
                                                   max_arity=_ARITIES[a]))
            for e in (tc.eta_iters - 1, tc.eta_iters + 1):
                if _ETAS[0] <= e <= _ETAS[-1]:
                    out.append(dataclasses.replace(tc, eta_iters=e))
            ki = ks.index(tc.interleave)
            for k in (ki - 1, ki + 1):
                if 0 <= k < len(ks):
                    out.append(dataclasses.replace(tc, interleave=ks[k]))
            return [n.canonical(max_cores) for n in out]

        best = min(scores, key=rank)
        improving = True
        while improving and len(scores) < budget:
            improving = False
            for n in neighbors(best):
                if evaluate(n) is None:
                    break
            new_best = min(scores, key=rank)
            if rank(new_best) < rank(best):
                best, improving = new_best, True

        best = min(scores, key=rank)
        if scores[best] == INFEASIBLE:
            raise RuntimeError(
                "autotune: every candidate failed to compile "
                f"(budget={budget}, digest={prog.digest()[:12]})")
        span.set("trials", len(scores))
        span.set("best_cycles", scores[best])
        span.set("best_config", best.fingerprint())
        metrics.gauge("autotune.best_cycles").set(scores[best])
        metrics.gauge("autotune.best_cycles_per_eval").set(
            scores[best] / best.interleave)

    result = TuneResult(
        config=best, cycles=scores[best],
        cycles_per_eval=scores[best] / best.interleave,
        default_config=default, default_cycles=scores[default],
        default_cycles_per_eval=scores[default] / default.interleave,
        trials=trials, evaluated=len(scores), budget=budget,
        seed=int(seed), prior=prior, guided=guided_fps)
    result.guided_win = (result.improved
                         and best.fingerprint() in guided_fps)
    if result.guided_win:
        metrics.counter("autotune.guided_wins").inc()
    if use_cache:
        TUNE_CACHE[key] = result
    return result
