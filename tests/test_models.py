"""Architecture zoo: per-arch smoke tests + model-math correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import api, ssm
from repro.models.attention import (decode_attention, flash_attention,
                                    full_attention)
from repro.configs.base import ArchConfig

KEY = jax.random.PRNGKey(0)


def _train_batch(cfg, B=2, S=16):
    batch = {"tokens": jnp.ones((B, S), jnp.int32) * 3,
             "labels": jnp.ones((B, S), jnp.int32) * 5}
    if cfg.family == "encdec":
        batch["frames"] = jnp.ones((B, cfg.enc_ctx, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.ones((B, cfg.n_img_tokens, cfg.d_model),
                                         jnp.bfloat16)
    return batch


# ---------------------------------------------------------------------------
# per-arch smoke: one loss + one decode step, finite everywhere
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_train(arch):
    cfg = get_smoke_config(arch)
    params = api.init_params(cfg, KEY)
    loss, metrics = api.loss_fn(cfg, params, _train_batch(cfg), remat=False)
    assert jnp.isfinite(loss), arch
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_decode(arch):
    cfg = get_smoke_config(arch)
    params = api.init_params(cfg, KEY)
    B = 2
    cache = api.init_cache(cfg, B, 32)
    logits, cache2 = api.decode_step(cfg, params, cache,
                                     jnp.ones((B, 1), jnp.int32))
    assert logits.shape == (B, 1, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), arch
    # cache length advanced
    if "length" in cache2:
        assert int(cache2["length"]) == 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_grads(arch):
    """Gradients flow to every parameter (no dead weights)."""
    cfg = get_smoke_config(arch)
    params = api.init_params(cfg, KEY)
    g = jax.grad(lambda p: api.loss_fn(cfg, p, _train_batch(cfg),
                                       remat=False)[0])(params)
    norms = [float(jnp.abs(x.astype(jnp.float32)).sum())
             for x in jax.tree.leaves(g)]
    assert all(np.isfinite(norms))
    assert sum(1 for n in norms if n > 0) / len(norms) > 0.9


# ---------------------------------------------------------------------------
# attention math
# ---------------------------------------------------------------------------
def test_flash_equals_full():
    k1, k2, k3 = jax.random.split(KEY, 3)
    B, S, H, KV, hd = 2, 256, 4, 2, 32
    q = jax.random.normal(k1, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(k2, (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(k3, (B, S, KV, hd), jnp.float32)
    of = full_attention(q, k, v, causal=True)
    ob = flash_attention(q, k, v, causal=True, q_block=64, kv_block=128)
    np.testing.assert_allclose(np.asarray(of), np.asarray(ob),
                               rtol=2e-5, atol=2e-5)


def test_decode_matches_full_last_position():
    k1, k2, k3 = jax.random.split(KEY, 3)
    B, S, H, KV, hd = 2, 33, 4, 2, 16
    q = jax.random.normal(k1, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(k2, (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(k3, (B, S, KV, hd), jnp.float32)
    full = full_attention(q, k, v, causal=True)
    dec = decode_attention(q[:, -1:], k, v, S)
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(full[:, -1]),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# decode == teacher forcing (the serving path computes the same function)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["qwen2-0.5b", "glm4-9b", "mamba2-780m"])
def test_decode_consistency_with_forward(arch):
    cfg = get_smoke_config(arch)
    params = api.init_params(cfg, KEY)
    B, S = 2, 12
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    # full forward logits
    from repro.models import transformer as T
    mod = api.family_module(cfg)
    hidden, _ = mod.forward(cfg, params, toks, remat=False)
    full_logits = T.logits_from_hidden(cfg, params, hidden)
    # token-by-token decode
    cache = api.init_cache(cfg, B, S + 1)
    step_logits = []
    for t in range(S):
        lg, cache = api.decode_step(cfg, params, cache, toks[:, t:t + 1])
        step_logits.append(lg)
    dec_logits = jnp.concatenate(step_logits, axis=1)
    # ssm chunked-vs-sequential accumulates slightly more bf16 noise
    atol = 0.15 if cfg.family == "ssm" else 0.03
    np.testing.assert_allclose(np.asarray(dec_logits, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=2e-2, atol=atol)


# ---------------------------------------------------------------------------
# SSD correctness vs naive recurrence
# ---------------------------------------------------------------------------
def test_ssd_chunked_vs_naive():
    cfg = ArchConfig(name="t", family="ssm", n_layers=1, d_model=32,
                     vocab=11, ssm_state=8, ssm_expand=2, ssm_headdim=8,
                     ssm_chunk=4, conv_width=4)
    B, L, H, P, N = 2, 16, ssm.n_ssm_heads(cfg), cfg.ssm_headdim, cfg.ssm_state
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (B, L, H, P), jnp.float32)
    Bm = jax.random.normal(ks[1], (B, L, N), jnp.float32) * 0.5
    Cm = jax.random.normal(ks[2], (B, L, N), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (B, L, H)))
    A_log = jnp.log(jnp.linspace(1., 4., H))
    y, final = ssm.ssd_chunked(cfg, x, Bm, Cm, dt, A_log)
    # naive sequential recurrence
    A = -np.exp(np.asarray(A_log))
    s = np.zeros((B, H, P, N))
    ys = np.zeros((B, L, H, P))
    for t in range(L):
        dA = np.exp(np.asarray(dt[:, t]) * A)
        dBx = np.einsum("bh,bn,bhp->bhpn", np.asarray(dt[:, t]),
                        np.asarray(Bm[:, t]), np.asarray(x[:, t]))
        s = s * dA[..., None, None] + dBx
        ys[:, t] = np.einsum("bhpn,bn->bhp", s, np.asarray(Cm[:, t]))
    np.testing.assert_allclose(np.asarray(y), ys, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(final), s, rtol=2e-4, atol=2e-4)


def test_mamba_step_matches_chunked():
    cfg = ArchConfig(name="t", family="ssm", n_layers=1, d_model=32,
                     vocab=11, ssm_state=8, ssm_expand=2, ssm_headdim=8,
                     ssm_chunk=4, conv_width=4)
    p = ssm.init_mamba_block(cfg, KEY)
    B, L = 2, 8
    x = jax.random.normal(jax.random.PRNGKey(5), (B, L, cfg.d_model),
                          jnp.float32)
    full, _ = ssm.apply_mamba_block(cfg, p, x)
    H, P, N = ssm.n_ssm_heads(cfg), cfg.ssm_headdim, cfg.ssm_state
    conv_dim = ssm.d_inner(cfg) + 2 * N
    s_ssm = jnp.zeros((B, H, P, N), jnp.float32)
    s_conv = jnp.zeros((B, cfg.conv_width - 1, conv_dim), jnp.float32)
    outs = []
    for t in range(L):
        o, s_ssm, s_conv = ssm.mamba_block_step(cfg, p, x[:, t:t + 1],
                                                s_ssm, s_conv)
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# MoE properties
# ---------------------------------------------------------------------------
def test_moe_dense_vs_ragged_close():
    """With generous capacity, dense dispatch ≈ ragged (no drops)."""
    from repro.models import moe
    cfg = get_smoke_config("granite-moe-1b-a400m")
    p = moe.init_moe(KEY, cfg.d_model, cfg.d_ff, cfg.n_experts)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, cfg.d_model),
                          jnp.bfloat16)
    dense, _ = moe.moe_ffn(p, x, top_k=2, impl="dense",
                           capacity_factor=8.0, group_size=64)
    ragged, _ = moe.moe_ffn(p, x, top_k=2, impl="ragged")
    np.testing.assert_allclose(np.asarray(dense, np.float32),
                               np.asarray(ragged, np.float32),
                               rtol=0.1, atol=0.05)


def test_moe_aux_loss_bounds():
    from repro.models import moe
    cfg = get_smoke_config("qwen3-moe-235b-a22b")
    p = moe.init_moe(KEY, cfg.d_model, cfg.d_ff, cfg.n_experts)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 64, cfg.d_model),
                          jnp.bfloat16)
    _, aux = moe.moe_ffn(p, x, top_k=cfg.top_k, impl="dense", group_size=64)
    # Switch aux loss is >= top_k/E... >= k/E*E = k? It's E*sum(f_e*P_e) >= k
    assert float(aux) >= 0.9 * cfg.top_k / cfg.n_experts * 1.0


# ---------------------------------------------------------------------------
# SPN reasoning head (the paper's hybrid integration, fig. 1)
# ---------------------------------------------------------------------------
def test_spn_head_trains(nltcs_prog):
    from repro.models import spn_head
    d_model = 32
    p = spn_head.init_spn_head(KEY, d_model, nltcs_prog)
    feats = jax.random.normal(jax.random.PRNGKey(1), (16, d_model))
    ll = spn_head.apply_spn_head(nltcs_prog, p, feats)
    assert ll.shape == (16,)
    assert bool(jnp.isfinite(ll).all()) and float(ll.max()) <= 0.0
    g = jax.grad(lambda pp: spn_head.nll_loss(nltcs_prog, pp, feats))(p)
    assert float(jnp.abs(g["proj"]["w"]).sum()) > 0
    assert float(jnp.abs(g["spn_logits"]).sum()) > 0


def test_spn_head_kernel_path_matches(nltcs_prog):
    from repro.models import spn_head
    p = spn_head.init_spn_head(KEY, 16, nltcs_prog)
    feats = jax.random.normal(jax.random.PRNGKey(1), (8, 16))
    a = spn_head.apply_spn_head(nltcs_prog, p, feats, use_kernel=False)
    b = spn_head.apply_spn_head(nltcs_prog, p, feats, use_kernel=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-5)
