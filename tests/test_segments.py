"""Segment scheduler: descriptor invariants, n-ary fusion correctness on
ragged fan-ins, and cross-substrate parity of segmented vs seed (binary
alg.-1) execution in both domains."""
import numpy as np
import pytest

from repro.core import executors, program, segments
from repro.core.learn import random_spn
from repro.core.spn import SPNBuilder

SUBLANE = segments.SUBLANE


def _leaves(prog, n, seed=0, mask_frac=0.0):
    rng = np.random.default_rng(seed)
    X = rng.integers(0, 2, size=(n, max(prog.num_vars, 1)))
    if mask_frac:
        X = np.where(rng.random(X.shape) < mask_frac, -1, X)
    return prog.leaves_from_evidence(X)


def _ragged_spn(fanins=(3, 5, 6, 7, 10)):
    """Sum/product nodes with deliberately non-power-of-two fan-ins."""
    b = SPNBuilder()
    rng = np.random.default_rng(0)
    tops = []
    for v, k in enumerate(fanins):
        kids = []
        for j in range(k):
            kids.append(b.product([b.indicator(2 * v, j % 2),
                                   b.indicator(2 * v + 1, (j + 1) % 2)]))
        w = rng.dirichlet(np.ones(k))
        tops.append(b.sum(kids, w))
    return b.build(b.product(tops))


# ---------------------------------------------------------------------------
# descriptor invariants
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("progname", ["small_prog", "nltcs_prog"])
def test_segment_invariants(progname, request):
    prog = request.getfixturevalue(progname)
    seg = segments.segment_program(prog)
    segments.validate(seg)   # contiguity, pow2 arities, operand ordering
    # 8-aligned level offsets and widths
    assert seg.node_base % SUBLANE == 0 and seg.num_slots % SUBLANE == 0
    for level in range(seg.num_levels):
        lo, hi = seg.level_out_range(level)
        assert lo % SUBLANE == 0 and (hi - lo) % SUBLANE == 0
    # homogeneous opcodes: one opcode per segment, by construction — and
    # padded operand positions point at that opcode's neutral slot only
    pad = seg.pad_slots
    for s in range(seg.num_segments):
        g0 = int(seg.seg_off[s])
        g1 = g0 + int(seg.seg_arity[s]) * int(seg.seg_nodes[s])
        idx = seg.gather[g0:g1]
        others = np.setdiff1d(pad, [pad[int(seg.seg_op[s])]])
        assert not np.isin(idx, others).any()
    # every real binary op is covered by exactly one fused node
    info = segments.fusion_info(prog)
    covered = sorted(int(i) for r in info.leaves
                     for i in np.flatnonzero(info.root_of == r))
    assert covered == list(range(prog.n_ops))


def test_segments_fuse_nary(nltcs_prog):
    """k-ary reductions collapse: fewer nodes than binary ops, arity > 2."""
    seg = segments.segment_program(nltcs_prog)
    assert seg.n_nodes < nltcs_prog.n_ops
    assert int(seg.seg_arity.max()) > 2
    assert seg.num_levels <= nltcs_prog.num_levels


# ---------------------------------------------------------------------------
# n-ary fusion correctness on ragged fan-ins
# ---------------------------------------------------------------------------
def test_ragged_fanin_fusion_bit_identical():
    prog = program.lower(_ragged_spn())
    seg = segments.segment_program(prog)
    arities = sorted({int(a) for a in seg.seg_arity})
    assert max(arities) >= 8          # the 7/10-ary sums really fused
    leaf = _leaves(prog, 40, seed=2)
    for log in (False, True):
        ref = executors.eval_ops_numpy(prog, leaf, log)
        got = segments.eval_segmented_numpy(seg, leaf, log)
        np.testing.assert_array_equal(got, ref)


@pytest.mark.parametrize("log_domain", [False, True])
def test_segmented_numpy_bit_identical_random(log_domain):
    for seed in range(8):
        spn = random_spn(6, depth=2, num_sums=3, repetitions=2, seed=seed)
        prog = program.lower(spn)
        seg = segments.segment_program(prog)
        leaf = _leaves(prog, 9, seed=seed, mask_frac=0.3)
        np.testing.assert_array_equal(
            segments.eval_segmented_numpy(seg, leaf, log_domain),
            executors.eval_ops_numpy(prog, leaf, log_domain))


def test_max_product_twin_fuses_and_matches(nltcs_prog):
    mp = program.to_max_product(nltcs_prog)
    seg = segments.segment_program(mp)
    assert (seg.seg_op == program.OP_MAX).any()
    leaf = _leaves(nltcs_prog, 16, seed=5, mask_frac=0.4)
    np.testing.assert_array_equal(
        segments.eval_segmented_numpy(seg, leaf, True),
        executors.eval_ops_numpy(mp, leaf, True))


# ---------------------------------------------------------------------------
# cross-substrate parity: segmented vs seed execution, both domains
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("log_domain", [False, True])
def test_cross_substrate_parity_nltcs(nltcs_prog, log_domain):
    from repro.kernels.spn_eval import spn_eval, spn_eval_ref
    leaf = _leaves(nltcs_prog, 64, seed=7, mask_frac=0.3)
    ref64 = executors.eval_ops_numpy(nltcs_prog, leaf, log_domain)  # seed oracle
    lvl = np.asarray(executors.eval_leveled(
        nltcs_prog, leaf.astype(np.float32), None, log_domain))
    ker = np.asarray(spn_eval(nltcs_prog, leaf.astype(np.float32),
                              log_domain=log_domain))
    jref = np.asarray(spn_eval_ref(nltcs_prog, leaf.astype(np.float32),
                                   log_domain=log_domain))
    np.testing.assert_allclose(lvl, ref64, rtol=5e-4, atol=5e-5)
    np.testing.assert_allclose(ker, ref64, rtol=5e-4, atol=5e-5)
    np.testing.assert_array_equal(ker, jref)   # same schedule, same bits


def test_segment_stats_recorded_in_artifacts(small_spn):
    from repro.runtime import Server
    srv = Server(small_spn, substrates=("leveled-jax", "pallas"))
    for name in ("leveled-jax", "pallas"):
        meta = srv.artifact("marginal", name).meta
        assert meta["segments"]["n_nodes"] <= srv.prog.n_ops
        assert meta["segments"]["segments"] >= 1
    assert isinstance(srv.artifact("marginal", "pallas").meta["interpret"],
                      bool)
