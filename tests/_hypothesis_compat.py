"""Degrade property tests to fixed examples when hypothesis is absent.

The tier-1 suite must collect on a minimal environment (jax + numpy +
pytest only). Importing ``given``/``settings``/``st`` from here instead of
``hypothesis`` keeps the real property-based behavior whenever hypothesis
is installed, and otherwise substitutes a lightweight shim that runs each
property against a deterministic set of representative draws (endpoints +
midpoints of every strategy, zipped cyclically so runtime stays linear in
the widest strategy, not the cartesian product).
"""
from __future__ import annotations

try:                                          # pragma: no cover - env-dependent
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import inspect

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """A fixed, deterministic set of representative values."""

        def __init__(self, values):
            self.values = list(values)

    class st:  # noqa: N801 - mirrors the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            mid = (min_value + max_value) // 2
            vals = {min_value, mid, max_value, min_value + 1}
            return _Strategy(sorted(v for v in vals
                                    if min_value <= v <= max_value))

        @staticmethod
        def floats(min_value, max_value, **_kw):
            return _Strategy([min_value, (min_value + max_value) / 2,
                              max_value])

        @staticmethod
        def booleans():
            return _Strategy([False, True])

        @staticmethod
        def sampled_from(elements):
            return _Strategy(elements)

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            """Representative lists: each endpoint value alone (at the
            minimum feasible size), the full value cycle padded to
            ``max_size``, and the empty list when allowed."""
            ev = list(elements.values)
            out = []
            if min_size == 0:
                out.append([])
            lo = max(min_size, 1)
            for v in ev:
                out.append([v] * lo)
            cycle = [ev[i % len(ev)] for i in range(max_size)]
            if len(cycle) >= min_size:
                out.append(cycle)
            return _Strategy([x for x in out if min_size <= len(x)
                              <= max_size])

    def settings(**_kw):
        def deco(fn):
            return fn
        return deco

    def given(**strategies):
        names = list(strategies)

        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                vals = [strategies[n].values for n in names]
                for i in range(max(len(v) for v in vals)):
                    drawn = {n: v[i % len(v)] for n, v in zip(names, vals)}
                    fn(*args, **drawn, **kwargs)

            # hide the drawn parameters from pytest's fixture resolution
            # (__signature__ wins over __wrapped__ in inspect.signature)
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(parameters=[
                p for p in sig.parameters.values() if p.name not in names])
            return wrapper

        return deco
