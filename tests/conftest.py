import numpy as np
import pytest

from repro.core import program
from repro.core.learn import learn_spn, random_spn
from repro.data import spn_datasets


@pytest.fixture(scope="session")
def small_spn():
    return random_spn(8, depth=2, num_sums=2, repetitions=2, seed=1)


@pytest.fixture(scope="session")
def small_prog(small_spn):
    return program.lower(small_spn)


@pytest.fixture(scope="session")
def nltcs_spn():
    X = spn_datasets.load("nltcs", "train", 300)
    return learn_spn(X, min_instances=80)


@pytest.fixture(scope="session")
def nltcs_prog(nltcs_spn):
    return program.lower(nltcs_spn)


@pytest.fixture(scope="session")
def nltcs_data():
    return spn_datasets.load("nltcs", "test", 64)
