"""Pallas spn_eval kernel vs oracles: shape/dtype/batch sweeps + hypothesis."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import executors, program
from repro.core.learn import random_spn
from repro.kernels.spn_eval import pad_program, spn_eval, spn_eval_ref


def _leaves(prog, n, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.integers(0, 2, size=(n, max(prog.num_vars, 1)))
    return prog.leaves_from_evidence(X).astype(np.float32)


# ---------------------------------------------------------------------------
# padding layout invariants (the segment schedule is the padded program)
# ---------------------------------------------------------------------------
def test_pad_program_layout(nltcs_prog):
    pp = pad_program(nltcs_prog)
    assert pp.node_base % 8 == 0 and pp.num_slots % 8 == 0
    for level in range(pp.num_levels):
        lo, hi = pp.level_out_range(level)
        assert lo % 8 == 0 and hi % 8 == 0          # tile-aligned levels
        s0, s1 = pp.level_offsets[level], pp.level_offsets[level + 1]
        for s in range(s0, s1):
            g0 = int(pp.seg_off[s])
            g1 = g0 + int(pp.seg_arity[s]) * int(pp.seg_nodes[s])
            assert (pp.gather[g0:g1] < lo).all()    # operands from the past
    assert pp.node_base <= pp.root_slot < pp.num_slots


# ---------------------------------------------------------------------------
# kernel vs ref vs float64 oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("batch", [1, 7, 128, 300])
@pytest.mark.parametrize("log_domain", [False, True])
def test_kernel_matches_oracle(nltcs_prog, batch, log_domain):
    leaf = _leaves(nltcs_prog, batch)
    ref64 = executors.eval_ops_numpy(nltcs_prog, leaf, log_domain)
    got = np.asarray(spn_eval(nltcs_prog, leaf, log_domain=log_domain))
    np.testing.assert_allclose(got, ref64, rtol=5e-4, atol=5e-5)


def test_kernel_matches_ref_exactly(nltcs_prog):
    """Kernel and pure-jnp ref share dtype/layout → bitwise equal (linear)."""
    leaf = _leaves(nltcs_prog, 64)
    r = np.asarray(spn_eval_ref(nltcs_prog, leaf))
    k = np.asarray(spn_eval(nltcs_prog, leaf))
    np.testing.assert_array_equal(k, r)


def test_kernel_batch_tile_sweep(small_prog):
    leaf = _leaves(small_prog, 200)
    ref = executors.eval_ops_numpy(small_prog, leaf)
    for bt in (128, 256):
        got = np.asarray(spn_eval(small_prog, leaf, batch_tile=bt))
        np.testing.assert_allclose(got, ref, rtol=5e-4)


def test_kernel_learned_params(nltcs_prog):
    """Kernel honours overridden parameters (the differentiable path)."""
    rng = np.random.default_rng(1)
    params = jnp.asarray(
        np.clip(nltcs_prog.param_values
                * rng.uniform(0.5, 1.5, nltcs_prog.m_param), 1e-4, 1.0),
        jnp.float32)
    leaf = _leaves(nltcs_prog, 32)
    ref = np.asarray(executors.eval_leveled(
        nltcs_prog, leaf, params, False))
    got = np.asarray(spn_eval(nltcs_prog, leaf, params))
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), nvars=st.integers(2, 10),
       depth=st.integers(1, 3), batch=st.integers(1, 40),
       log_domain=st.booleans())
def test_kernel_random_spns(seed, nvars, depth, batch, log_domain):
    spn = random_spn(nvars, depth=depth, num_sums=2, repetitions=1, seed=seed)
    prog = program.lower(spn)
    leaf = _leaves(prog, batch, seed)
    ref64 = executors.eval_ops_numpy(prog, leaf, log_domain)
    got = np.asarray(spn_eval(prog, leaf, log_domain=log_domain))
    np.testing.assert_allclose(got, ref64, rtol=1e-3, atol=1e-4)


def test_kernel_vmem_guard():
    """Oversized value buffers are rejected with a clear error."""
    from repro.core import segments
    from repro.kernels.spn_eval import kernel as K
    big = segments.SegmentedProgram(
        base=None, m=8, node_base=16, num_slots=40_000,
        gather=np.zeros(0, np.int32),
        seg_off=np.zeros(0, np.int32), seg_op=np.zeros(0, np.uint8),
        seg_arity=np.zeros(0, np.int32), seg_nodes=np.zeros(0, np.int32),
        seg_out=np.zeros(0, np.int32),
        level_offsets=np.zeros(1, np.int32), root_slot=16,
        n_nodes=0, n_pad_nodes=0)
    with pytest.raises(ValueError, match="VMEM"):
        K.build_spn_kernel(big, batch_tile=128)
