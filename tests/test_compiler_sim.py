"""VLIW compiler + cycle-accurate simulator: correctness & paper properties."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import executors, program
from repro.core.compiler.pipeline import compile_program
from repro.core.learn import learn_spn, random_spn
from repro.core.processor import sim
from repro.core.processor.config import PTREE, PVECT, ProcessorConfig
from repro.data import spn_datasets


def _compile_and_check(spn, X, cfg):
    prog = program.lower(spn)
    vprog = compile_program(prog, cfg)
    res = sim.simulate(vprog, prog, X, cfg)
    ref = executors.eval_ops_numpy(prog, prog.leaves_from_evidence(X))
    np.testing.assert_allclose(res.root_values, ref, rtol=1e-4, atol=1e-6)
    return vprog, res


@pytest.mark.parametrize("cfg", [PTREE, PVECT], ids=lambda c: c.name)
def test_compile_simulate_nltcs(nltcs_spn, nltcs_data, cfg):
    vprog, res = _compile_and_check(nltcs_spn, nltcs_data[:16], cfg)
    assert res.ops_per_cycle > 1.0          # beats the CPU/GPU ceiling
    # the simulator enforces the structural rules; make sure it exercised them
    assert res.checks["read_conflicts_checked"] > 0
    assert res.checks["write_conflicts_checked"] > 0


def test_ptree_beats_pvect(nltcs_spn, nltcs_data):
    """Paper §V: the tree arrangement outperforms the flat one."""
    _, r_tree = _compile_and_check(nltcs_spn, nltcs_data[:4], PTREE)
    _, r_vect = _compile_and_check(nltcs_spn, nltcs_data[:4], PVECT)
    assert r_tree.ops_per_cycle > r_vect.ops_per_cycle


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), nvars=st.integers(3, 10),
       depth=st.integers(1, 3))
def test_compile_simulate_random(seed, nvars, depth):
    spn = random_spn(nvars, depth=depth, num_sums=2, repetitions=1, seed=seed)
    rng = np.random.default_rng(seed)
    X = rng.integers(0, 2, size=(3, nvars))
    _compile_and_check(spn, X, PTREE)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 1000))
def test_compile_simulate_learned(seed):
    X = spn_datasets.load("msnbc", "train", 200)
    spn = learn_spn(X, min_instances=60, seed=seed)
    _compile_and_check(spn, X[:3], PTREE)


def test_small_machine_spills(nltcs_spn, nltcs_data):
    """A tiny register file forces spills; results must still be exact."""
    tiny = ProcessorConfig("tiny", num_trees=2, tree_levels=2, banks=8,
                           regs_per_bank=8, data_mem_rows=512)
    vprog, res = _compile_and_check(nltcs_spn, nltcs_data[:4], tiny)
    assert vprog.stats["stores"] > 1        # it actually spilled


def test_infeasible_machine_fails_loudly(nltcs_prog):
    """A machine too small must raise, not hang."""
    micro = ProcessorConfig("micro", num_trees=1, tree_levels=1, banks=2,
                            regs_per_bank=2, data_mem_rows=512)
    with pytest.raises(RuntimeError):
        compile_program(nltcs_prog, micro, max_cycles=50_000)


def test_useful_ops_accounting(nltcs_prog):
    vprog = compile_program(nltcs_prog, PTREE)
    assert vprog.n_useful_ops == nltcs_prog.n_ops
    per_instr = sum(t.num_useful_ops for i in vprog.instrs
                    for t in i.trees if t is not None)
    assert per_instr == nltcs_prog.n_ops    # every op issued exactly once


def test_paper_table1_configs():
    assert PTREE.num_pes == 30 and PVECT.num_pes == 16
    assert PTREE.banks == PVECT.banks == 32
    assert PTREE.total_regs == PVECT.total_regs == 2048   # "2K 32b registers"
