"""HLO analyzer: shape parsing, trip-weighted walking, dot FLOPs."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import (HLOModule, Roofline, analyze_hlo,
                                       shape_bytes, shape_elems)


def test_shape_bytes():
    assert shape_bytes("f32[4,8]") == 128
    assert shape_bytes("bf16[10]{0}") == 20
    assert shape_bytes("pred[2,2]") == 4
    assert shape_bytes("(s32[], f32[4], /*index=5*/bf16[2,2])") == 4 + 16 + 8
    assert shape_elems("f32[3,3]") == 9


def test_analyze_simple_matmul():
    """FLOPs of a plain jit'd matmul ≈ 2·M·N·K."""
    M = N = K = 128
    f = jax.jit(lambda a, b: a @ b)
    a = jax.ShapeDtypeStruct((M, K), jnp.float32)
    b = jax.ShapeDtypeStruct((K, N), jnp.float32)
    hlo = f.lower(a, b).compile().as_text()
    acc = analyze_hlo(hlo)
    expect = 2 * M * N * K
    assert 0.9 * expect <= acc["dot_flops"] <= 1.2 * expect


def test_analyze_scan_trip_weighting():
    """A scanned matmul must count once per iteration."""
    T, D = 8, 64

    def f(x, ws):
        def body(h, w):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, ws)
        return h

    x = jax.ShapeDtypeStruct((D, D), jnp.float32)
    ws = jax.ShapeDtypeStruct((T, D, D), jnp.float32)
    hlo = jax.jit(f).lower(x, ws).compile().as_text()
    acc = analyze_hlo(hlo)
    expect = T * 2 * D * D * D
    assert 0.9 * expect <= acc["dot_flops"] <= 1.3 * expect, acc["dot_flops"]


def test_roofline_bottleneck_logic():
    r = Roofline(flops=197e12, hbm_bytes=0, collective_bytes=0, chips=1)
    assert abs(r.t_compute - 1.0) < 1e-9 and r.bottleneck == "compute"
    r = Roofline(flops=0, hbm_bytes=819e9, collective_bytes=0, chips=1)
    assert abs(r.t_memory - 1.0) < 1e-9 and r.bottleneck == "memory"
    r = Roofline(flops=0, hbm_bytes=0, collective_bytes=200e9, chips=1)
    assert abs(r.t_collective - 1.0) < 1e-9 and r.bottleneck == "collective"


def test_module_parse_tuple_types():
    text = """
HloModule test

%body.1 (p: (s32[], f32[4,4])) -> (s32[], f32[4,4]) {
  %p = (s32[], f32[4,4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[4,4]{1,0} get-tuple-element(%p), index=1
  %y = f32[4,4]{1,0} add(%x, %x)
  ROOT %t = (s32[], f32[4,4]) tuple(%i, %y)
}

%cond.1 (p: (s32[], f32[4,4])) -> pred[] {
  %p = (s32[], f32[4,4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (a: f32[4,4]) -> f32[4,4] {
  %a = f32[4,4]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[4,4]) tuple(%z, %a)
  %w = (s32[], f32[4,4]) while(%t0), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %out = f32[4,4]{1,0} get-tuple-element(%w), index=1
}
"""
    m = HLOModule(text)
    assert m.entry == "main"
    acc = m.analyze()
    # add of 16 elems × 5 trips
    assert acc["flops"] >= 5 * 16
