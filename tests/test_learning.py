"""Parameter learning: EM and SGD improve likelihood; structures valid."""
import numpy as np

from repro.core import executors, learn, program
from repro.data import spn_datasets


def test_em_increases_ll(nltcs_prog):
    X = spn_datasets.load("nltcs", "train", 400)
    state, hist = learn.fit_em(nltcs_prog, X, iters=8)
    assert hist[-1] > hist[0]
    # monotone up to small float noise
    assert all(b - a > -1e-3 for a, b in zip(hist, hist[1:]))


def test_em_weights_normalized(nltcs_prog):
    X = spn_datasets.load("nltcs", "train", 200)
    state, _ = learn.fit_em(nltcs_prog, X, iters=3)
    p = np.asarray(state.params)
    gi = np.asarray(state.group_idx)
    for g in range(state.num_groups):
        s = p[gi == g].sum()
        assert abs(s - 1.0) < 1e-4


def test_sgd_improves_ll(nltcs_prog):
    X = spn_datasets.load("nltcs", "train", 300)
    state, hist = learn.fit_sgd(nltcs_prog, X, steps=60, lr=3e-2,
                                batch_size=128, seed=0)
    assert np.mean(hist[-10:]) > np.mean(hist[:10])


def test_learned_params_valid_distribution(nltcs_prog):
    """After EM, the SPN still normalizes (partition function == 1)."""
    X = spn_datasets.load("nltcs", "train", 200)
    state, _ = learn.fit_em(nltcs_prog, X, iters=4)
    marg = -np.ones((1, nltcs_prog.num_vars), np.int64)
    leaf = nltcs_prog.leaves_from_evidence(marg).astype(np.float32)
    z = float(np.asarray(executors.eval_leveled(
        nltcs_prog, leaf, state.params, False))[0])
    assert abs(z - 1.0) < 1e-3
