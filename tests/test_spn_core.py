"""SPN graph / program lowering / executor equivalence tests."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import executors, io, program
from repro.core.learn import learn_spn, random_spn
from repro.core.spn import SPNBuilder
from repro.data import spn_datasets


def _random_evidence(prog, n, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.integers(0, 2, size=(n, max(prog.num_vars, 1)))
    return prog.leaves_from_evidence(X)


# ---------------------------------------------------------------------------
# builder / validity
# ---------------------------------------------------------------------------
def test_builder_rejects_forward_refs():
    b = SPNBuilder()
    with pytest.raises(ValueError):
        b.sum([5])


def test_random_spn_valid(small_spn):
    assert small_spn.check_valid() == []


def test_learned_spn_valid(nltcs_spn):
    assert nltcs_spn.check_valid() == []


def test_spn_is_distribution(small_spn):
    """Normalized SPN sums to 1 over all evidence (8 vars → 256 states)."""
    from repro.core.spn import normalize_weights
    spn = normalize_weights(small_spn)
    total = 0.0
    for x in range(2 ** 8):
        bits = [(x >> i) & 1 for i in range(8)]
        total += spn.evaluate_evidence(bits)
    assert abs(total - 1.0) < 1e-6


# ---------------------------------------------------------------------------
# program lowering invariants
# ---------------------------------------------------------------------------
def test_lowering_invariants(nltcs_prog):
    nltcs_prog.validate()          # asserts level-contiguity etc.
    assert nltcs_prog.n_ops > 0
    assert nltcs_prog.num_levels >= 1


def test_lowered_matches_graph_eval(small_spn, small_prog):
    rng = np.random.default_rng(3)
    for _ in range(16):
        x = rng.integers(0, 2, size=8)
        direct = small_spn.evaluate_evidence(x)
        leaf = small_prog.leaves_from_evidence(x[None])
        lowered = executors.eval_ops_numpy(small_prog, leaf)[0]
        assert abs(direct - lowered) < 1e-9 * max(1.0, abs(direct))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), nvars=st.integers(2, 12),
       depth=st.integers(1, 3))
def test_lowering_matches_oracle_random(seed, nvars, depth):
    spn = random_spn(nvars, depth=depth, num_sums=2, repetitions=1, seed=seed)
    prog = program.lower(spn)
    prog.validate()
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 2, size=nvars)
    direct = spn.evaluate_evidence(x)
    lowered = executors.eval_ops_numpy(
        prog, prog.leaves_from_evidence(x[None]))[0]
    assert abs(direct - lowered) < 1e-9 * max(1.0, abs(direct))


# ---------------------------------------------------------------------------
# executor equivalence (alg.1 == alg.2 == leveled, linear & log domain)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("log_domain", [False, True])
def test_executors_agree(nltcs_prog, nltcs_data, log_domain):
    leaf = nltcs_prog.leaves_from_evidence(nltcs_data)
    ref = executors.eval_ops_numpy(nltcs_prog, leaf, log_domain)
    scan = np.asarray(executors.eval_scan(nltcs_prog, leaf.astype(np.float32),
                                          None, log_domain))
    lvl = np.asarray(executors.eval_leveled(nltcs_prog,
                                            leaf.astype(np.float32),
                                            None, log_domain))
    np.testing.assert_allclose(scan, ref, rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(lvl, ref, rtol=2e-4, atol=2e-5)


def test_log_equals_linear(nltcs_prog, nltcs_data):
    leaf = nltcs_prog.leaves_from_evidence(nltcs_data)
    lin = executors.eval_ops_numpy(nltcs_prog, leaf, False)
    log = executors.eval_ops_numpy(nltcs_prog, leaf, True)
    np.testing.assert_allclose(np.exp(log), lin, rtol=1e-9)


def test_marginalization(nltcs_prog):
    """Marginalizing every variable gives the partition function (~1)."""
    from repro.core.spn import normalize_weights
    x = -np.ones((1, nltcs_prog.num_vars), dtype=np.int64)
    leaf = nltcs_prog.leaves_from_evidence(x)
    z = executors.eval_ops_numpy(nltcs_prog, leaf)[0]
    assert abs(z - 1.0) < 1e-6      # learn_spn emits normalized weights


# ---------------------------------------------------------------------------
# io roundtrip
# ---------------------------------------------------------------------------
def test_ac_roundtrip(small_spn):
    text = io.dumps(small_spn)
    back = io.loads(text)
    rng = np.random.default_rng(0)
    for _ in range(8):
        x = rng.integers(0, 2, size=8)
        assert abs(small_spn.evaluate_evidence(x)
                   - back.evaluate_evidence(x)) < 1e-12


# ---------------------------------------------------------------------------
# datasets
# ---------------------------------------------------------------------------
def test_dataset_determinism():
    a = spn_datasets.load("nltcs", "train", 50)
    b = spn_datasets.load("nltcs", "train", 50)
    np.testing.assert_array_equal(a, b)
    c = spn_datasets.load("nltcs", "valid", 50)
    assert not np.array_equal(a, c)


def test_dataset_shapes():
    for name in ["nltcs", "msnbc", "kdd"]:
        X = spn_datasets.load(name, "test", 10)
        assert X.shape == (10, spn_datasets.DATASETS[name])
        assert set(np.unique(X)) <= {0, 1}
