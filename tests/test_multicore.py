"""Multi-core subsystem conformance: partitioner invariants, per-core
program extraction, lockstep checked-sim vs merged fast-sim
bit-identity, cross-core parity against the single-core oracle on the
benchmark suite (both domains), cycle accounting, and the substrate
configuration fingerprint in the artifact cache."""
import numpy as np
import pytest

from repro.core import multicore as mc
from repro.core import program
from repro.core.compiler.pipeline import compile_program
from repro.core.processor import fastsim
from repro.core.processor.config import PTREE
from repro.data.spn_datasets import BENCH_SUITE
from repro.runtime import ArtifactCache, Server, get_substrate
from repro.data import spn_datasets
from repro.core import learn

_SUITE_CACHE: dict = {}


def suite_prog(name: str):
    """Learned suite SPN (cached per session, small learn for speed)."""
    if name not in _SUITE_CACHE:
        X = spn_datasets.load(name, "train", 300)
        spn = learn.learn_spn(X, min_instances=64, seed=0)
        _SUITE_CACHE[name] = (spn, program.lower(spn))
    return _SUITE_CACHE[name]


def _leaves(prog, n=8, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.integers(0, 2, (n, prog.num_vars))
    return prog.leaves_from_evidence(X).astype(np.float32)


# ---------------------------------------------------------------------------
# partitioner invariants
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("strategy", ["subtree", "cone", "level"])
@pytest.mark.parametrize("cores", [2, 4])
def test_partition_invariants(nltcs_prog, strategy, cores):
    part = mc.partition_ops(nltcs_prog, cores, strategy=strategy)
    # scope-completeness: every binary op on exactly one core
    assert part.core_of_op.shape == (nltcs_prog.n_ops,)
    assert int(part.loads.sum()) == nltcs_prog.n_ops
    # validate_partition (runs in partition_ops) re-checked explicitly:
    # acyclicity — cross-core edges strictly increase binary level
    m = nltcs_prog.m
    for i in range(nltcs_prog.n_ops):
        for s in (int(nltcs_prog.b[i]), int(nltcs_prog.c[i])):
            if s >= m and part.core_of_op[s - m] != part.core_of_op[i]:
                assert part.op_level[s - m] < part.op_level[i]


def test_partition_load_balance_bound(nltcs_prog):
    part = mc.partition_ops(nltcs_prog, 4, strategy="subtree")
    total = int(part.node_weight.sum())
    wmax = int(part.node_weight.max())
    assert part.loads.max() <= -(-total // 4) + wmax


def test_partition_deterministic_under_seed(nltcs_prog):
    a = mc.partition_ops(nltcs_prog, 4, seed=7, passes=2)
    b = mc.partition_ops(nltcs_prog, 4, seed=7, passes=2)
    np.testing.assert_array_equal(a.core_of_op, b.core_of_op)
    assert a.cut_values == b.cut_values


def test_comm_plan_rows_are_level_homogeneous(nltcs_prog):
    """Level-homogeneous channel rows are the deadlock-freedom grading."""
    part = mc.partition_ops(nltcs_prog, 4)
    core_index = {int(c): i for i, c in enumerate(
        sorted(np.unique(part.core_of_op)))}
    plan = mc.build_comm_plan(nltcs_prog, part, core_index,
                              banks=PTREE.banks)
    for row in plan.rows:
        assert 1 <= len(row.gids) <= plan.icfg.row_capacity
        for pos, g in enumerate(row.gids):
            assert part.op_level[g] == row.level
            assert plan.value_pos[(g, row.dst)] == (row.row_id, pos)
        assert row.src != row.dst


# ---------------------------------------------------------------------------
# cores=1 degenerates to the single-core program
# ---------------------------------------------------------------------------
def test_single_core_partition_is_identity(nltcs_prog):
    plans, plan = mc.build_core_programs(
        nltcs_prog, mc.partition_ops(nltcs_prog, 1), banks=PTREE.banks)
    assert len(plans) == 1 and not plan.rows
    sub = plans[0].prog
    # structurally the original program, slot for slot (weight groups are
    # learning metadata the per-core build intentionally drops)
    assert (sub.m_ind, sub.m_param) == (nltcs_prog.m_ind,
                                        nltcs_prog.m_param)
    np.testing.assert_array_equal(sub.opcode, nltcs_prog.opcode)
    np.testing.assert_array_equal(sub.b, nltcs_prog.b)
    np.testing.assert_array_equal(sub.c, nltcs_prog.c)
    np.testing.assert_array_equal(sub.param_values,
                                  nltcs_prog.param_values)
    assert sub.root_slot == nltcs_prog.root_slot


def test_cores1_cycles_match_single_core(nltcs_prog):
    """Acceptance: cores=1 within 5% of vliw-sim cycle counts."""
    single = compile_program(nltcs_prog, PTREE).num_cycles
    mcp = mc.compile_multicore(nltcs_prog, PTREE, 1)
    assert abs(mcp.meta["cycles"] - single) / single <= 0.05


# ---------------------------------------------------------------------------
# lockstep checked sim vs merged fast-sim vs single-core oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cores", [2, 4])
def test_multicore_bit_identical_nltcs(nltcs_prog, cores):
    vprog = compile_program(nltcs_prog, PTREE)
    ref = fastsim.run(fastsim.decode(vprog, PTREE), _leaves(nltcs_prog, 16))
    mcp = mc.compile_multicore(nltcs_prog, PTREE, cores)
    leaves = _leaves(nltcs_prog, 16)
    res = mc.simulate_multicore(mcp, leaves)
    fast = fastsim.run(mc.decode_multicore(mcp, cycles=res.cycles), leaves)
    np.testing.assert_array_equal(res.root_values, fast)
    np.testing.assert_array_equal(fast, ref)
    # stall accounting: every global cycle of a core is either one
    # executed instruction or one flow-control stall
    for finish, stream, stalls in zip(res.core_finish, res.core_cycles,
                                      res.stall_cycles):
        assert finish == stream + stalls
    assert res.cycles == max(res.core_finish)


@pytest.mark.parametrize("topology", mc.TOPOLOGIES)
@pytest.mark.parametrize("dataset", BENCH_SUITE)
def test_cross_core_parity_suite(dataset, topology):
    """Acceptance: vliw-mc roots bit-identical to single-core vliw-sim
    on the BENCH_SUITE datasets, across the full NoC topology matrix
    (cores {2,4,8} are additionally covered in test_noc)."""
    _spn, prog = suite_prog(dataset)
    vprog = compile_program(prog, PTREE)
    leaves = _leaves(prog, 8, seed=3)
    ref = fastsim.run(fastsim.decode(vprog, PTREE), leaves)
    mcp = mc.compile_multicore(prog, PTREE, 2,
                               mc.named_interconnect(topology),
                               eta_iters=0)
    res = mc.simulate_multicore(mcp, leaves)
    fast = fastsim.run(mc.decode_multicore(mcp, cycles=res.cycles), leaves)
    np.testing.assert_array_equal(fast, res.root_values)
    np.testing.assert_array_equal(fast, ref)


@pytest.mark.parametrize("log_domain", [True, False])
def test_multicore_substrate_both_domains(nltcs_prog, log_domain):
    """Substrate-level parity in both domains, fast vs checked."""
    mc_sub = get_substrate("vliw-mc", cores=2)
    sc_sub = get_substrate("vliw-sim")
    art_mc = mc_sub.compile(nltcs_prog, query="marginal",
                            log_domain=log_domain)
    art_sc = sc_sub.compile(nltcs_prog, query="marginal",
                            log_domain=log_domain)
    leaves = _leaves(nltcs_prog, 8, seed=5)
    fast = mc_sub.execute(art_mc, leaves)
    np.testing.assert_array_equal(fast, mc_sub.execute_checked(art_mc,
                                                               leaves))
    np.testing.assert_array_equal(fast, sc_sub.execute(art_sc, leaves))


def test_multicore_mpe_semiring(nltcs_prog):
    """The max-product twin partitions and executes identically."""
    sub = get_substrate("vliw-mc", cores=2)
    art = sub.compile(nltcs_prog, query="mpe", log_domain=True)
    ref = get_substrate("numpy").compile(nltcs_prog, query="mpe",
                                         log_domain=True)
    leaves = _leaves(nltcs_prog, 6, seed=2)
    got = sub.execute(art, leaves)
    want = get_substrate("numpy").execute(ref, leaves)
    np.testing.assert_allclose(got, want, atol=1e-4)


# ---------------------------------------------------------------------------
# cycle accounting / scaling
# ---------------------------------------------------------------------------
def test_multicore_speedup_at_four_cores():
    """Cycle-count scaling floor on the bench-sized nltcs SPN (the
    benchmark records the full 1/2/4-core curve in BENCH_serve.json)."""
    X = spn_datasets.load("nltcs", "train", 600)
    prog = program.lower(learn.learn_spn(X, min_instances=60, seed=0))
    single = compile_program(prog, PTREE).num_cycles
    mcp = mc.compile_multicore(prog, PTREE, 4)
    assert mcp.meta["cycles"] * 2 < single     # ≥ 2x, deterministic
    assert mcp.meta["cut_values"] > 0
    assert mcp.meta["comm"]["values"] >= mcp.meta["cut_values"]


def test_calibrated_cycles_are_value_independent(nltcs_prog):
    mcp = mc.compile_multicore(nltcs_prog, PTREE, 2)
    a = mc.simulate_multicore(mcp, _leaves(nltcs_prog, 1, seed=0))
    b = mc.simulate_multicore(mcp, _leaves(nltcs_prog, 32, seed=9))
    assert a.cycles == b.cycles == mcp.meta["cycles"]


# ---------------------------------------------------------------------------
# cache fingerprint + server integration
# ---------------------------------------------------------------------------
def test_cache_distinguishes_substrate_config(small_prog):
    """Acceptance: same program, different substrate configuration must
    MISS — the key carries config_fingerprint(), not just the name."""
    cache = ArtifactCache(capacity=8)
    two = get_substrate("vliw-mc", cores=2)
    four = get_substrate("vliw-mc", cores=4)
    a2 = cache.get_or_compile(two, small_prog, query="marginal")
    a4 = cache.get_or_compile(four, small_prog, query="marginal")
    assert a2 is not a4
    assert cache.stats()["misses"] == 2
    assert a2.meta["multicore"]["n_cores"] == 2
    assert a4.meta["multicore"]["n_cores"] == 4
    # and the same config hits
    assert cache.get_or_compile(two, small_prog, query="marginal") is a2
    # pallas interpret modes are distinct configurations too
    on = get_substrate("pallas", interpret=True)
    off = get_substrate("pallas", interpret=False)
    assert (ArtifactCache.key(small_prog, "marginal", on, 128, True)
            != ArtifactCache.key(small_prog, "marginal", off, 128, True))


def test_server_reports_noc_stats_mesh(small_spn):
    """Acceptance: per-link contention is visible in
    Server.stats()["multicore"] when serving over a physical NoC."""
    srv = Server(small_spn, substrates=("numpy", "vliw-mc"), cores=4,
                 topology="mesh")
    x = np.abs(np.random.default_rng(1).integers(
        0, 2, (5, srv.prog.num_vars)))
    np.testing.assert_allclose(srv.query(x, "joint", "vliw-mc"),
                               srv.query(x, "joint", "numpy"), atol=1e-4)
    entry = next(iter(srv.stats()["multicore"].values()))
    assert entry["topology"] == "mesh"
    assert entry["hop_cut"] >= entry["cut_values"] >= 0
    assert 0.0 <= entry["busiest_link_occupancy"] <= 1.0
    assert entry["link_stall_cycles"] >= 0
    assert entry["inject_stall_cycles"] >= 0


def test_server_reports_multicore_stats(small_spn):
    srv = Server(small_spn, substrates=("numpy", "vliw-mc"), cores=2)
    x = np.abs(np.random.default_rng(0).integers(
        0, 2, (5, srv.prog.num_vars)))
    np.testing.assert_allclose(srv.query(x, "joint", "vliw-mc"),
                               srv.query(x, "joint", "numpy"), atol=1e-4)
    stats = srv.stats()["multicore"]
    assert len(stats) == 1
    entry = next(iter(stats.values()))
    assert entry["cycles"] > 0 and len(entry["core_utilization"]) >= 1
    assert entry["comm_values_per_batch"] >= 0
    assert "stall_cycles" in entry and "barrier_idle_cycles" in entry
    # NoC accounting is always present (zeros under the ideal crossbar)
    assert entry["topology"] == "xbar"
    assert entry["busiest_link_occupancy"] == 0.0
    assert entry["link_stall_cycles"] == 0
