"""program.interleave — the §Perf-C software-pipelining transform."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import executors, program
from repro.core.compiler.pipeline import compile_program
from repro.core.learn import random_spn
from repro.core.processor import sim
from repro.core.processor.config import PTREE


@pytest.mark.parametrize("k", [2, 3])
def test_interleave_structure(nltcs_prog, k):
    p2 = program.interleave(nltcs_prog, k)
    p2.validate()
    assert p2.n_ops == k * nltcs_prog.n_ops
    assert p2.m_ind == k * nltcs_prog.m_ind
    assert p2.m_param == nltcs_prog.m_param          # params shared
    assert p2.num_levels == nltcs_prog.num_levels    # same depth


def test_interleave_instances_independent(nltcs_prog):
    """Each instance computes its own evidence row's likelihood."""
    k = 2
    p2 = program.interleave(nltcs_prog, k)
    rng = np.random.default_rng(0)
    X = rng.integers(0, 2, size=(2, nltcs_prog.num_vars))
    # instance j of the interleaved program gets evidence row j by
    # feeding the two rows' indicators concatenated
    l0 = nltcs_prog.leaves_from_evidence(X[0:1])
    l1 = nltcs_prog.leaves_from_evidence(X[1:2])
    leaf = np.concatenate([l0, l1], axis=1)          # (1, 2*m_ind)
    vals = executors.eval_ops_numpy(p2, leaf)
    # p2.root_slot is instance 0's root; instance 1's root is +1 slot
    ref0 = executors.eval_ops_numpy(nltcs_prog, l0)[0]
    assert abs(vals[0] - ref0) < 1e-9 * max(abs(ref0), 1)


def test_interleave_improves_throughput(nltcs_prog):
    """The point of the transform: ops/cycle strictly improves at k=2."""
    v1 = compile_program(nltcs_prog, PTREE)
    v2 = compile_program(program.interleave(nltcs_prog, 2), PTREE)
    assert v2.ops_per_cycle > v1.ops_per_cycle * 1.1


def test_interleave_simulates_exactly(nltcs_prog, nltcs_data):
    p2 = program.interleave(nltcs_prog, 2)
    vp = compile_program(p2, PTREE)
    res = sim.simulate(vp, p2, nltcs_data[:4], PTREE)
    ref = executors.eval_ops_numpy(
        nltcs_prog, nltcs_prog.leaves_from_evidence(nltcs_data[:4]))
    # multi-root program: one row of root values per instance; feeding
    # p2.leaves_from_evidence duplicates each evidence row across both
    # instances, so every instance row must equal the reference
    assert res.root_values.shape == (2, 4)
    for inst in range(2):
        np.testing.assert_allclose(res.root_values[inst], ref, rtol=1e-4)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000), k=st.integers(2, 3))
def test_interleave_random_valid(seed, k):
    spn = random_spn(6, depth=2, num_sums=2, repetitions=1, seed=seed)
    prog = program.lower(spn)
    p2 = program.interleave(prog, k)
    p2.validate()
    rng = np.random.default_rng(seed)
    X = rng.integers(0, 2, size=(1, prog.num_vars))
    leaf1 = prog.leaves_from_evidence(X)
    leafk = np.tile(leaf1, (1, k))
    ref = executors.eval_ops_numpy(prog, leaf1)[0]
    got = executors.eval_ops_numpy(p2, leafk)[0]
    assert abs(got - ref) < 1e-9 * max(abs(ref), 1)
