"""NoC topology model conformance & property suite.

Covers the mesh/torus/ring/xbar interconnect models end to end:

- property-based invariants of the hop metric and XY routing (symmetry,
  bounds, route/metric agreement, torus wraparound),
- the per-link contention model (ideal-crossbar exactness, shared-link
  serialization, injection-port arbitration, transfer monotonicity),
- topology-aware core placement (hop-weighted traffic never worse than
  the flat labeling, partition shape preserved),
- deadlock-freedom stress: seeded random SPN programs x partition
  strategies x {xbar, ring, mesh, torus} x cores {2, 4, 8} run to
  completion in the lockstep simulator with bit-parity against the
  single-core fast-sim,
- the golden cycle-count regression fixture ``golden_cycles.json``:
  checked-sim cycle counts for nltcs/kdd/plants at cores {1, 2, 4} x
  topology, asserted EXACTLY. A deliberate scheduler or contention-model
  change must regenerate the file:

      PYTHONPATH=src python tests/test_noc.py --regen
"""
import json
import pathlib

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import learn, multicore as mc, program
from repro.core.compiler.pipeline import compile_program
from repro.core.multicore.comm import (TOPOLOGIES, XBAR, ChannelRow,
                                       CommPlan, Interconnect,
                                       named_interconnect)
from repro.core.processor import fastsim
from repro.core.processor.config import PTREE
from repro.data import spn_datasets
from repro.runtime import get_substrate

PHYSICAL = ("ring", "mesh", "torus")

GOLDEN_PATH = pathlib.Path(__file__).parent / "golden_cycles.json"
GOLDEN_DATASETS = ("nltcs", "kdd", "plants")
GOLDEN_CORES = (1, 2, 4)
GOLDEN_LEARN = {"rows": 300, "min_instances": 64, "seed": 0}

_PROG_CACHE: dict = {}


def golden_prog(name: str):
    if name not in _PROG_CACHE:
        X = spn_datasets.load(name, "train", GOLDEN_LEARN["rows"])
        spn = learn.learn_spn(X, min_instances=GOLDEN_LEARN["min_instances"],
                              seed=GOLDEN_LEARN["seed"])
        _PROG_CACHE[name] = program.lower(spn)
    return _PROG_CACHE[name]


def _leaves(prog, n=8, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.integers(0, 2, (n, prog.num_vars))
    return prog.leaves_from_evidence(X).astype(np.float32)


# ---------------------------------------------------------------------------
# hop metric: symmetry, bounds, topology relations
# ---------------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(n_cores=st.integers(1, 9), topology=st.sampled_from(TOPOLOGIES))
def test_hop_metric_symmetry_and_identity(n_cores, topology):
    icfg = named_interconnect(topology)
    for a in range(n_cores):
        for b in range(n_cores):
            h = icfg.hops(a, b, n_cores)
            assert h == icfg.hops(b, a, n_cores)
            if a == b:
                assert h == 0
            else:
                assert 1 <= h <= max(n_cores - 1, 1)


@settings(max_examples=20, deadline=None)
@given(n_cores=st.integers(2, 9))
def test_hop_bounds_per_topology(n_cores):
    ring = named_interconnect("ring")
    mesh = named_interconnect("mesh")
    torus = named_interconnect("torus")
    w, h = mesh.grid_shape(n_cores)
    for a in range(n_cores):
        for b in range(n_cores):
            if a == b:
                continue
            # ring: exactly the shorter arc, never longer than the chain
            assert ring.hops(a, b, n_cores) == min(abs(a - b),
                                                   n_cores - abs(a - b))
            assert ring.hops(a, b, n_cores) <= n_cores // 2
            # mesh: bounded by the grid diameter
            assert mesh.hops(a, b, n_cores) <= (w - 1) + (h - 1)
            # torus wrap links can only shorten mesh routes
            assert torus.hops(a, b, n_cores) <= mesh.hops(a, b, n_cores)
            assert XBAR.hops(a, b, n_cores) == 1


def test_total_hops_mesh_le_ring_le_chain():
    """mesh <= ring <= worst-case chain, summed over all pairs, on the
    power-of-two core counts the substrate actually serves."""
    mesh = named_interconnect("mesh")
    ring = named_interconnect("ring")
    for n in (2, 4, 8, 16):
        pairs = [(a, b) for a in range(n) for b in range(n) if a != b]
        mesh_sum = sum(mesh.hops(a, b, n) for a, b in pairs)
        ring_sum = sum(ring.hops(a, b, n) for a, b in pairs)
        chain_sum = sum(abs(a - b) for a, b in pairs)
        assert mesh_sum <= ring_sum <= chain_sum


def test_torus_wraparound():
    mesh, torus = named_interconnect("mesh"), named_interconnect("torus")
    # 8 cores -> 4x2 grid: the x wrap link turns 3 mesh hops into 1
    assert mesh.grid_shape(8) == (4, 2)
    assert mesh.hops(0, 3, 8) == 3 and torus.hops(0, 3, 8) == 1
    assert torus.route(0, 3, 8) == ((0, 3),)
    # 16 cores -> 4x4: column wrap
    assert mesh.grid_shape(16) == (4, 4)
    assert mesh.hops(0, 12, 16) == 3 and torus.hops(0, 12, 16) == 1
    # wrap never helps on a 2-wide axis
    assert torus.hops(0, 4, 8) == mesh.hops(0, 4, 8) == 1


@settings(max_examples=30, deadline=None)
@given(topology=st.sampled_from(PHYSICAL), n_cores=st.integers(2, 9))
def test_route_agrees_with_hop_metric(topology, n_cores):
    """len(route) == hops; routes are contiguous link chains src->dst."""
    icfg = named_interconnect(topology)
    for a in range(n_cores):
        for b in range(n_cores):
            r = icfg.route(a, b, n_cores)
            assert len(r) == icfg.hops(a, b, n_cores)
            if a == b:
                assert r == ()
                continue
            assert r[0][0] == a and r[-1][1] == b
            for (x, y) in zip(r, r[1:]):
                assert x[1] == y[0]
            assert all(u != v for (u, v) in r)


# ---------------------------------------------------------------------------
# transfer latency + contention model
# ---------------------------------------------------------------------------
@settings(max_examples=40, deadline=None)
@given(topology=st.sampled_from(TOPOLOGIES), members=st.integers(1, 63),
       link_width=st.integers(1, 64), hop_latency=st.integers(1, 4))
def test_transfer_cycles_monotone(topology, members, link_width,
                                  hop_latency):
    """transfer_cycles is monotone in members and in hop distance."""
    icfg = named_interconnect(topology, link_width=link_width,
                              hop_latency=hop_latency)
    n = 8
    assert (icfg.transfer_cycles(members, 0, 1, n)
            <= icfg.transfer_cycles(members + 1, 0, 1, n))
    pairs = sorted(((a, b) for a in range(n) for b in range(n) if a != b),
                   key=lambda p: icfg.hops(p[0], p[1], n))
    cycles = [icfg.transfer_cycles(members, a, b, n) for a, b in pairs]
    assert cycles == sorted(cycles)


def _plan(icfg, n_cores, rows_spec):
    """Synthetic CommPlan: rows_spec = [(src, dst, members), ...]."""
    rows = [ChannelRow(row_id=i, src=s, dst=d, level=1,
                       gids=list(range(g)))
            for i, (s, d, g) in enumerate(rows_spec)]
    return CommPlan(rows=rows, icfg=icfg, n_cores=n_cores)


def test_xbar_is_ideal_no_contention():
    """Concurrent xbar transfers never interact — the pre-NoC model."""
    plan = _plan(XBAR, 4, [(0, 1, 32), (0, 1, 32), (2, 1, 32), (0, 3, 7)])
    net = Interconnect(plan)
    for r in plan.rows:
        net.push(r.row_id, np.zeros((len(r.gids), 1), np.float32), 0)
    for r in plan.rows:
        assert net.rows[r.row_id][0] == XBAR.transfer_cycles(
            len(r.gids), r.src, r.dst, 4)
    assert net.link_stall_cycles == 0
    assert net.inject_stall_cycles == 0
    assert not net.link_busy
    stats = net.link_stats(total_cycles=10)
    assert stats["busiest_link_occupancy"] == 0.0


def test_mesh_shared_link_serializes():
    """Two transfers whose XY routes share a physical link serialize."""
    mesh = named_interconnect("mesh")
    # 2x2 grid: 0->3 goes (0,1) then (1,3); 1->3 uses (1,3) directly
    assert mesh.route(0, 3, 4) == ((0, 1), (1, 3))
    assert mesh.route(1, 3, 4) == ((1, 3),)
    plan = _plan(mesh, 4, [(0, 3, 32), (1, 3, 32)])
    net = Interconnect(plan)
    net.push(0, np.zeros((32, 1), np.float32), 0)
    net.push(1, np.zeros((32, 1), np.float32), 0)
    assert net.rows[0][0] == mesh.transfer_cycles(32, 0, 3, 4) == 3
    # row 1 uncontended would arrive at 2; link (1,3) is busy until 2
    assert net.rows[1][0] == 4
    assert net.link_stall_cycles == 2
    assert net.link_busy[(1, 3)] == 2
    assert net.link_stats(total_cycles=4)["busiest_link_occupancy"] == 0.5


def test_disjoint_mesh_routes_do_not_interact():
    mesh = named_interconnect("mesh")
    plan = _plan(mesh, 4, [(0, 1, 32), (2, 3, 32)])
    net = Interconnect(plan)
    net.push(0, np.zeros((32, 1), np.float32), 0)
    net.push(1, np.zeros((32, 1), np.float32), 0)
    for r in plan.rows:
        assert net.rows[r.row_id][0] == mesh.transfer_cycles(
            32, r.src, r.dst, 4)
    assert net.link_stall_cycles == 0


def test_injection_port_arbitration():
    """A core streams one row's flits at a time onto the NoC."""
    mesh = named_interconnect("mesh", link_width=8)   # 32 members -> 4 cy
    plan = _plan(mesh, 4, [(0, 1, 32), (0, 2, 32)])
    net = Interconnect(plan)
    net.push(0, np.zeros((32, 1), np.float32), 0)
    net.push(1, np.zeros((32, 1), np.float32), 0)
    assert net.rows[0][0] == mesh.transfer_cycles(32, 0, 1, 4) == 5
    # second transfer waits 4 cycles for the injection port, then takes
    # its own uncontended 1 hop + 4 serialization cycles
    assert net.inject_stall_cycles == 4
    assert net.rows[1][0] == 4 + 5
    assert net.link_stall_cycles == 0     # disjoint links: port-only wait


# ---------------------------------------------------------------------------
# topology-aware placement
# ---------------------------------------------------------------------------
def test_place_cores_moves_chatty_pairs_adjacent():
    """Diagonal-chatting cores on a 2x2 mesh get relabeled adjacent."""
    mesh = named_interconnect("mesh")
    traffic = np.zeros((4, 4), np.int64)
    traffic[0, 3] = 10                    # 2 hops apart on the flat grid
    traffic[3, 0] = 10
    traffic[1, 2] = 8                     # the other diagonal
    perm = mc.place_cores(traffic, mesh, 4)
    hop_cost = lambda p: sum(
        int(traffic[a, b]) * mesh.hops(int(p[a]), int(p[b]), 4)
        for a in range(4) for b in range(4))
    ident = np.arange(4)
    assert hop_cost(perm) < hop_cost(ident)
    assert mesh.hops(int(perm[0]), int(perm[3]), 4) == 1


@settings(max_examples=15, deadline=None)
@given(n_cores=st.integers(2, 8), seed=st.integers(0, 5),
       topology=st.sampled_from(PHYSICAL))
def test_place_cores_never_worse_than_identity(n_cores, seed, topology):
    icfg = named_interconnect(topology)
    rng = np.random.default_rng(seed)
    traffic = rng.integers(0, 20, (n_cores, n_cores)).astype(np.int64)
    np.fill_diagonal(traffic, 0)
    perm = mc.place_cores(traffic, icfg, n_cores)
    assert sorted(int(p) for p in perm) == list(range(n_cores))
    hop = lambda p: sum(
        int(traffic[a, b]) * icfg.hops(int(p[a]), int(p[b]), n_cores)
        for a in range(n_cores) for b in range(n_cores))
    # the full objective adds a congestion term, but the identity start
    # of the swap descent guarantees hop cost parity at worst
    assert hop(perm) <= hop(np.arange(n_cores)) + _congestion_slack(
        traffic, icfg, n_cores)


def _congestion_slack(traffic, icfg, n_cores) -> int:
    """Max congestion-term difference the placement may trade hops for."""
    load: dict = {}
    for a in range(n_cores):
        for b in range(n_cores):
            t = int(traffic[a, b])
            if t and a != b:
                for link in icfg.route(a, b, n_cores):
                    load[link] = load.get(link, 0) + t
    return max(load.values()) if load else 0


def test_aware_placement_preserves_partition_shape(nltcs_prog):
    """Default aware placement only relabels cores: the flat cut, the
    load distribution and the hop-weighted cut never get worse."""
    for topology in ("mesh", "torus"):
        icfg = named_interconnect(topology)
        aware = mc.partition_ops(nltcs_prog, 4, passes=0, icfg=icfg)
        naive = mc.partition_ops(nltcs_prog, 4, passes=0, icfg=icfg,
                                 placement="naive")
        assert aware.cut_values == naive.cut_values
        np.testing.assert_array_equal(np.sort(aware.loads),
                                      np.sort(naive.loads))
        assert aware.hop_cut <= naive.hop_cut
        assert aware.topology == topology
        assert naive.core_placement is None


def test_xbar_partition_bit_identical_to_flat(nltcs_prog):
    """The ideal crossbar must reproduce the pre-NoC partitioner
    exactly — no silent drift of existing cycle counts."""
    flat = mc.partition_ops(nltcs_prog, 4, passes=0)
    xbar = mc.partition_ops(nltcs_prog, 4, passes=0, icfg=XBAR)
    mesh_naive = mc.partition_ops(nltcs_prog, 4, passes=0,
                                  icfg=named_interconnect("mesh"),
                                  placement="naive")
    np.testing.assert_array_equal(flat.core_of_op, xbar.core_of_op)
    np.testing.assert_array_equal(flat.core_of_op, mesh_naive.core_of_op)
    assert xbar.hop_cut == xbar.cut_values
    assert xbar.core_placement is None


# ---------------------------------------------------------------------------
# deadlock-freedom stress + bit-parity across the full topology matrix
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cores", [2, 4, 8])
@pytest.mark.parametrize("topology", TOPOLOGIES)
def test_stress_random_programs_run_to_completion(topology, cores):
    """Seeded random SPNs x partition strategies x narrow links: the
    lockstep sim must terminate (deadlock-free) and stay bit-identical
    to the single-core fast-sim under link contention."""
    for seed, strategy in ((0, "subtree"), (1, "cone"), (2, "level")):
        spn = learn.random_spn(12, depth=3, num_sums=2, repetitions=3,
                               seed=seed)
        prog = program.lower(spn)
        # narrow links + multi-cycle hops make contention actually bite
        icfg = named_interconnect(topology, link_width=4, hop_latency=2) \
            if topology != "xbar" else XBAR
        mcp = mc.compile_multicore(prog, PTREE, cores, icfg, seed=seed,
                                   strategy=strategy, eta_iters=1)
        leaves = _leaves(prog, 4, seed=seed)
        res = mc.simulate_multicore(mcp, leaves)   # completes = no deadlock
        ref = fastsim.run(
            fastsim.decode(compile_program(prog, PTREE), PTREE), leaves)
        np.testing.assert_array_equal(res.root_values, ref)
        fast = fastsim.run(mc.decode_multicore(mcp, cycles=res.cycles),
                           leaves)
        np.testing.assert_array_equal(fast, res.root_values)


@pytest.mark.parametrize("cores", [2, 4, 8])
@pytest.mark.parametrize("topology", PHYSICAL)
def test_nltcs_parity_topology_matrix(nltcs_prog, topology, cores):
    """nltcs bit-parity vs single-core vliw-sim on every physical
    topology at cores {2, 4, 8} (xbar is covered by test_multicore)."""
    vprog = compile_program(nltcs_prog, PTREE)
    leaves = _leaves(nltcs_prog, 8, seed=3)
    ref = fastsim.run(fastsim.decode(vprog, PTREE), leaves)
    mcp = mc.compile_multicore(nltcs_prog, PTREE, cores,
                               named_interconnect(topology))
    res = mc.simulate_multicore(mcp, leaves)
    fast = fastsim.run(mc.decode_multicore(mcp, cycles=res.cycles), leaves)
    np.testing.assert_array_equal(res.root_values, fast)
    np.testing.assert_array_equal(fast, ref)
    # the lockstep result carries the per-link accounting
    assert "link_stall_cycles" in res.comm
    assert "busiest_link_occupancy" in res.comm


@pytest.mark.parametrize("log_domain", [True, False])
@pytest.mark.parametrize("topology", PHYSICAL)
def test_substrate_parity_both_domains(nltcs_prog, topology, log_domain):
    """Substrate-level parity in both domains on physical topologies."""
    mc_sub = get_substrate("vliw-mc", cores=4,
                           interconnect=named_interconnect(topology))
    sc_sub = get_substrate("vliw-sim")
    art_mc = mc_sub.compile(nltcs_prog, query="marginal",
                            log_domain=log_domain)
    art_sc = sc_sub.compile(nltcs_prog, query="marginal",
                            log_domain=log_domain)
    leaves = _leaves(nltcs_prog, 8, seed=5)
    fast = mc_sub.execute(art_mc, leaves)
    np.testing.assert_array_equal(
        fast, mc_sub.execute_checked(art_mc, leaves))
    np.testing.assert_array_equal(fast, sc_sub.execute(art_sc, leaves))
    assert art_mc.meta["multicore"]["topology"] == topology


def test_routing_geometry_uses_physical_core_labels():
    """With empty or scattered physical cores, routing must happen on
    the full grid the placement optimized — not on compacted effective
    indices (which would be a different, smaller grid)."""
    spn = learn.random_spn(6, depth=2, num_sums=2, repetitions=1, seed=0)
    prog = program.lower(spn)
    icfg = named_interconnect("mesh")
    mcp = mc.compile_multicore(prog, PTREE, 8, icfg)
    plan = mcp.plan
    assert plan.n_geom == 8                 # the machine keeps 8 cores
    labels = [plan.geometry(c) for c in range(plan.n_cores)]
    assert all(0 <= l < 8 for l in labels)
    assert len(set(labels)) == len(labels)
    for row in plan.rows:
        src, dst = plan.geometry(row.src), plan.geometry(row.dst)
        # latency charged == hop metric on the PHYSICAL 8-core grid
        assert plan.latency(row) == icfg.transfer_cycles(
            len(row.gids), src, dst, 8)
        r = plan.route(row)
        assert len(r) == icfg.hops(src, dst, 8)
        assert r[0][0] == src and r[-1][1] == dst
    # and the lockstep sim still runs to completion, bit-identical
    leaves = _leaves(prog, 4, seed=1)
    res = mc.simulate_multicore(mcp, leaves)
    ref = fastsim.run(
        fastsim.decode(compile_program(prog, PTREE), PTREE), leaves)
    np.testing.assert_array_equal(res.root_values, ref)


def test_contended_cycles_value_independent(nltcs_prog):
    """Link contention depends only on the static schedule, so the
    calibrated cycle count stays value-independent on a mesh."""
    mcp = mc.compile_multicore(nltcs_prog, PTREE, 4,
                               named_interconnect("mesh", link_width=4))
    a = mc.simulate_multicore(mcp, _leaves(nltcs_prog, 1, seed=0))
    b = mc.simulate_multicore(mcp, _leaves(nltcs_prog, 32, seed=9))
    assert a.cycles == b.cycles == mcp.meta["cycles"]
    assert a.comm["link_stall_cycles"] == b.comm["link_stall_cycles"]


# ---------------------------------------------------------------------------
# golden cycle-count regression fixture
# ---------------------------------------------------------------------------
def _golden_cases():
    for ds in GOLDEN_DATASETS:
        for cores in GOLDEN_CORES:
            for topo in TOPOLOGIES:
                if cores == 1 and topo != "xbar":
                    continue    # one core has no interconnect at all
                yield ds, cores, topo


def _golden_cycles(dataset: str, cores: int, topology: str) -> int:
    mcp = mc.compile_multicore(golden_prog(dataset), PTREE, cores,
                               named_interconnect(topology))
    return int(mcp.meta["cycles"])


@pytest.mark.parametrize("dataset,cores,topology", list(_golden_cases()))
def test_golden_cycle_counts(dataset, cores, topology):
    """Checked-sim cycle counts pinned EXACTLY: any scheduler, placement
    or contention-model change that shifts cycles fails here and must
    update tests/golden_cycles.json deliberately
    (PYTHONPATH=src python tests/test_noc.py --regen)."""
    golden = json.loads(GOLDEN_PATH.read_text())
    assert golden["learn"] == GOLDEN_LEARN, "fixture/learn config drift"
    want = golden["cycles"][dataset][str(cores)][topology]
    got = _golden_cycles(dataset, cores, topology)
    assert got == want, (
        f"{dataset}@{cores}c/{topology}: {got} cycles != golden {want}; "
        "if this change is deliberate, regenerate via "
        "`PYTHONPATH=src python tests/test_noc.py --regen`")


def regenerate_golden() -> None:
    data: dict = {"learn": GOLDEN_LEARN, "eta_iters": 2, "cycles": {}}
    for ds, cores, topo in _golden_cases():
        cyc = _golden_cycles(ds, cores, topo)
        data["cycles"].setdefault(ds, {}).setdefault(str(cores), {})[topo] \
            = cyc
        print(f"{ds}@{cores}c/{topo}: {cyc}")
    GOLDEN_PATH.write_text(json.dumps(data, indent=2) + "\n")
    print(f"wrote {GOLDEN_PATH}")


if __name__ == "__main__":
    import sys
    if "--regen" in sys.argv:
        regenerate_golden()
    else:
        print(__doc__)
