"""Per-SPN compiler autotuning (repro.core.autotune + runtime wiring)."""
import numpy as np
import pytest

from repro.core import learn, program
from repro.core.autotune import (TUNE_CACHE, TuneConfig, default_config,
                                 tune_program)
from repro.core.processor.config import PTREE
from repro.runtime import Server
from repro.runtime.cache import ArtifactCache
from repro.runtime.substrates import make_substrate


# ---------------- TuneConfig canonicalization ------------------------------ #
def test_canonical_drops_inert_knobs_at_one_core():
    tc = TuneConfig(cores=1, strategy="cone", seed=3, passes=2, grain=7,
                    max_arity=4, eta_iters=3, interleave=2).canonical(4)
    # at cores=1 every partition knob (and ETA feedback) is inert —
    # only the interleave factor survives
    assert tc == TuneConfig(cores=1, interleave=2, eta_iters=0)


def test_canonical_grain_only_for_cone():
    tc = TuneConfig(strategy="subtree", grain=9).canonical(4)
    assert tc.grain is None
    tc = TuneConfig(strategy="cone", grain=9).canonical(4)
    assert tc.grain == 9


def test_canonical_clamps_cores():
    assert TuneConfig(cores=8).canonical(2).cores == 2
    assert TuneConfig(cores=0).canonical(2).cores == 1


# ---------------- the search ----------------------------------------------- #
def test_tune_deterministic(nltcs_prog):
    """Same digest + budget + seed => identical TuneConfig/fingerprint."""
    kw = dict(max_cores=4, budget=10, seed=7, use_cache=False)
    a = tune_program(nltcs_prog, PTREE, **kw)
    b = tune_program(nltcs_prog, PTREE, **kw)
    assert a.config == b.config
    assert a.config.fingerprint() == b.config.fingerprint()
    assert a.cycles == b.cycles
    assert a.trials == b.trials          # full trial sequence, in order


def test_tune_seed_changes_random_phase_only_deterministically(nltcs_prog):
    a = tune_program(nltcs_prog, PTREE, max_cores=2, budget=12, seed=0,
                     use_cache=False)
    b = tune_program(nltcs_prog, PTREE, max_cores=2, budget=12, seed=1,
                     use_cache=False)
    # different seeds may land on different winners, but each run is
    # internally reproducible and never loses to the default
    for r in (a, b):
        assert r.cycles_per_eval <= r.default_cycles_per_eval


def test_tune_respects_budget(nltcs_prog):
    res = tune_program(nltcs_prog, PTREE, max_cores=4, budget=5,
                       use_cache=False)
    assert 1 <= res.evaluated <= 5
    assert len(res.trials) == res.evaluated


def test_tune_budget_one_is_the_default(nltcs_prog):
    res = tune_program(nltcs_prog, PTREE, max_cores=4, budget=1,
                       use_cache=False)
    assert res.evaluated == 1
    assert res.config == default_config(4)
    assert res.cycles == res.default_cycles


def test_tune_never_loses_to_default(nltcs_prog):
    res = tune_program(nltcs_prog, PTREE, max_cores=4, budget=8,
                       use_cache=False)
    assert res.cycles_per_eval <= res.default_cycles_per_eval
    # nltcs at 4 cores: interleave is a large modeled win — the tuner
    # must find *some* strict improvement within 8 trials
    assert res.improved


def test_tune_survives_infeasible_trials(nltcs_prog, monkeypatch):
    """A candidate whose compile live-locks must not kill the search —
    it scores INFEASIBLE, consumes budget, and the winner is feasible
    (observed in the wild: strategy="level" on baudio@4c)."""
    from repro.core.multicore import compile as mc_compile
    real = mc_compile.compile_multicore

    def flaky(prog, cfg, n_cores=2, *args, **kw):
        if kw.get("strategy") == "level":
            raise RuntimeError("live-lock at cycle 4144: ...")
        return real(prog, cfg, n_cores, *args, **kw)

    monkeypatch.setattr(mc_compile, "compile_multicore", flaky)
    # budget 16 guarantees the seeded sweep reaches the level-strategy
    # candidate even after the attribution-guided phase spends its slots
    res = tune_program(nltcs_prog, PTREE, max_cores=4, budget=16,
                       use_cache=False)
    assert res.config.strategy != "level"
    assert res.cycles_per_eval <= res.default_cycles_per_eval
    failed = [t for t in res.trials if t[1] is None]
    assert len(failed) >= 1
    assert all("/level/" in fp for fp, _, _ in failed)
    assert res.evaluated == 16 and len(res.trials) == 16


def test_tune_cache_memoizes(nltcs_prog):
    kw = dict(max_cores=2, budget=4, seed=0)
    n0 = len(TUNE_CACHE)
    a = tune_program(nltcs_prog, PTREE, **kw)
    assert len(TUNE_CACHE) == n0 + 1
    assert tune_program(nltcs_prog, PTREE, **kw) is a
    assert len(TUNE_CACHE) == n0 + 1


# ---------------- substrate integration ------------------------------------ #
def test_autotune_mode_validation():
    with pytest.raises(ValueError, match="autotune"):
        make_substrate("vliw-mc", autotune="sometimes")


def test_tuned_fingerprint_suffix_only_when_tuning():
    off = make_substrate("vliw-mc", cores=2)
    on = make_substrate("vliw-mc", cores=2, autotune="budget=4")
    assert "/tune=" not in off.config_fingerprint()
    assert on.config_fingerprint() == \
        off.config_fingerprint() + "/tune=budget=4:0"


def test_tuned_artifact_parity_and_meta(nltcs_prog, nltcs_data):
    """Forced tuned config: values bit-match the untuned artifact and
    the checked sim of the tuned interleaved multicore machine."""
    leaves = nltcs_prog.leaves_from_evidence(nltcs_data[:13])
    plain = make_substrate("vliw-mc", cores=2)
    ref = plain.execute(plain.compile(nltcs_prog), leaves)

    sub = make_substrate("vliw-mc", cores=2)
    sub.tune_config = TuneConfig(cores=2, interleave=2)
    art = sub.compile(nltcs_prog)
    assert art.meta["interleave"] == 2
    assert art.meta["cycles_per_eval"] == art.meta["cycles"] / 2
    assert art.meta["autotune"]["mode"] == "manual"
    assert art.meta["core_decision"]["reason"] == "autotune"
    fast = sub.execute(art, leaves)
    checked = sub.execute_checked(art, leaves)   # odd batch: pads 1 row
    assert np.array_equal(fast, checked)
    assert np.array_equal(fast, ref)


def test_tuned_artifact_cached_separately(nltcs_prog):
    cache = ArtifactCache(8)
    off = make_substrate("vliw-mc", cores=2)
    on = make_substrate("vliw-mc", cores=2, autotune="budget=4")
    a = cache.get_or_compile(off, nltcs_prog)
    b = cache.get_or_compile(on, nltcs_prog)
    assert a is not b
    assert cache.get_or_compile(on, nltcs_prog) is b
    assert cache.stats()["hits"] == 1


def test_server_autotune_stats_and_flow(nltcs_prog, nltcs_data):
    srv = Server(prog=nltcs_prog, substrates=("numpy", "vliw-mc"),
                 cores=4, autotune="budget=6")
    vals = srv.query(nltcs_data[:8], "marginal", "vliw-mc")
    ref = srv.query(nltcs_data[:8], "marginal", "numpy")
    np.testing.assert_allclose(vals, ref, atol=1e-4)
    tune = srv.stats()["autotune"]["sum/vliw-mc"]
    assert tune["cycles_per_eval"] <= tune["default_cycles_per_eval"]
    assert tune["mode"] == "budget=6"
    assert tune["core_decision"]["reason"] == "autotune"


# ---------------- cores=1 fallback heuristic (untuned path) ---------------- #
def test_single_core_fallback_on_tiny_spn():
    """SEND/RECV + barrier overhead makes 2 cores a net loss on a tiny
    SPN; the untuned vliw-mc build must fall back to one core and say so."""
    prog = program.lower(learn.random_spn(4, depth=1, num_sums=2,
                                          repetitions=1, seed=0))
    sub = make_substrate("vliw-mc", cores=2)
    art = sub.compile(prog)
    d = art.meta["core_decision"]
    assert d["reason"] == "single-core-fallback"
    assert d["chosen"] == 1 and d["requested"] == 2
    assert d["single_core_cycles"] < d["multicore_cycles"]
    assert art.meta["cycles"] == d["single_core_cycles"]
    assert art.meta["multicore"]["n_cores"] == 1


def test_multicore_kept_when_it_wins(nltcs_prog):
    sub = make_substrate("vliw-mc", cores=2)
    art = sub.compile(nltcs_prog)
    d = art.meta["core_decision"]
    assert d["reason"] == "multicore" and d["chosen"] == 2
    assert d["multicore_cycles"] <= d["single_core_cycles"]
    assert art.meta["multicore"]["n_cores"] == 2
