"""Interleaved programs under the multicore compiler (§Perf-C x §Multi).

Bit-parity: the k-way interleaved program partitioned across N cores
must reproduce, bit for bit, the single-core fast-sim oracle's values on
the base program — through both the merged fast-sim decode and the
lockstep checked simulator. Plus the modeled-cycles regression contract:
interleaving never *increases* cycles/eval (it exists to amortize
pipeline latency across independent evaluations).
"""
import functools

import numpy as np
import pytest

from repro.core import learn, program
from repro.core.compiler.pipeline import compile_program
from repro.core.multicore import (compile_multicore, decode_multicore,
                                  named_interconnect, simulate_multicore)
from repro.core.processor import fastsim
from repro.core.processor.config import PTREE
from repro.data import spn_datasets


@pytest.mark.parametrize("topology", ["xbar", "mesh"])
@pytest.mark.parametrize("cores", [1, 2, 4])
@pytest.mark.parametrize("k", [2, 4])
def test_interleave_multicore_bit_parity(nltcs_prog, nltcs_data,
                                         cores, k, topology):
    rows = nltcs_data[:8]
    base_leaves = nltcs_prog.leaves_from_evidence(rows).astype(np.float32)
    ref = fastsim.run(
        fastsim.decode(compile_program(nltcs_prog, PTREE), PTREE),
        base_leaves, {})                                   # (8,) oracle

    ip = program.interleave(nltcs_prog, k)
    mcp = compile_multicore(ip, PTREE, cores,
                            named_interconnect(topology))
    dense = decode_multicore(mcp, cycles=mcp.meta["cycles"])
    packed = base_leaves.reshape(len(rows) // k, k * nltcs_prog.m_ind)

    fast = fastsim.run(dense, packed, {})                  # (k, 8//k)
    assert fast.shape == (k, len(rows) // k)
    # de-interleave back to evidence-row order and compare bitwise
    assert np.array_equal(fast.T.reshape(-1), ref)

    checked = simulate_multicore(mcp, packed).root_values
    assert np.array_equal(checked, fast)


def test_interleave_multicore_mcp_meta(nltcs_prog):
    """The interleaved multicore compile reports per-batch cycles; the
    per-eval cost (cycles/k) must beat the uninterleaved compile."""
    base = compile_multicore(nltcs_prog, PTREE, 4).meta["cycles"]
    mcp = compile_multicore(program.interleave(nltcs_prog, 4), PTREE, 4)
    assert mcp.meta["cycles"] / 4 < base


# ---------------- cycles/eval regression over the bench suite -------------- #
SUITE_SMALL = ["nltcs", "msnbc"]
SUITE_BIG = ["kdd", "plants", "baudio", "jester", "bnetflix"]


@functools.lru_cache(maxsize=None)
def _suite_prog(name: str):
    # mirrors benchmarks.common.bench_spn (same data budget and seed)
    X = spn_datasets.load(name, "train", 600)
    return program.lower(learn.learn_spn(X, min_instances=60, seed=0))


@pytest.mark.parametrize(
    "dataset",
    SUITE_SMALL + [pytest.param(d, marks=pytest.mark.slow)
                   for d in SUITE_BIG])
def test_interleave_never_increases_cycles_per_eval(dataset):
    prog = _suite_prog(dataset)
    base = compile_multicore(prog, PTREE, 4).meta["cycles"]
    for k in ((2, 4) if dataset in SUITE_SMALL else (2,)):
        mcp = compile_multicore(program.interleave(prog, k), PTREE, 4)
        assert mcp.meta["cycles"] / k <= base, \
            f"{dataset}: interleave k={k} worsened cycles/eval"
