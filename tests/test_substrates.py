"""Substrate tests: sharding plan, optimizer, compression, checkpoint,
fault-tolerance runtime, data pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.checkpoint import AsyncCheckpointer, gc_old, latest_step, restore, save
from repro.configs import get_config, get_smoke_config
from repro.data.lm_pipeline import PipelineConfig, TokenPipeline
from repro.models import api
from repro.optim import AdamWConfig, adamw
from repro.optim import compress as C
from repro.parallel.plan import Planner
from repro.runtime import (FailureInjector, Heartbeat, RestartPolicy,
                           TrainingAborted, Watchdog, run_with_restarts)

KEY = jax.random.PRNGKey(0)


def _abstract_mesh(multi_pod=False):
    # AbstractMesh takes a tuple of (axis_name, size) pairs
    if multi_pod:
        return AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))
    return AbstractMesh((("data", 16), ("model", 16)))


# ---------------------------------------------------------------------------
# sharding plan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["qwen2-0.5b", "qwen3-moe-235b-a22b",
                                  "zamba2-7b", "whisper-medium"])
@pytest.mark.parametrize("multi_pod", [False, True])
def test_param_specs_divisibility(arch, multi_pod):
    """Every sharded dim must be divisible by its mesh axes product."""
    cfg = get_config(arch)
    mesh = _abstract_mesh(multi_pod)
    planner = Planner(cfg, mesh)
    tree = api.param_specs(cfg)
    sh = planner.params_sharding(tree)
    for leaf, s in zip(jax.tree.leaves(tree), jax.tree.leaves(sh)):
        for dim, axis in zip(leaf.shape, s.spec):
            if axis is None:
                continue
            size = (np.prod([mesh.shape[a] for a in axis])
                    if isinstance(axis, tuple) else mesh.shape[axis])
            assert dim % size == 0, (leaf.shape, s.spec)


def test_plan_shards_big_weights():
    """Large matmul weights must actually be 2D-sharded (FSDP+TP)."""
    cfg = get_config("command-r-plus-104b")
    planner = Planner(cfg, _abstract_mesh())
    tree = api.param_specs(cfg)
    paths_sh = planner.params_sharding(tree)
    flat, _ = jax.tree_util.tree_flatten_with_path(paths_sh)
    flat_t = jax.tree.leaves(tree)
    n_big = n_2d = 0
    for (kp, s), leaf in zip(flat, flat_t):
        if np.prod(leaf.shape) > 10_000_000:
            n_big += 1
            sharded_dims = sum(1 for a in s.spec if a is not None)
            assert sharded_dims >= 1
            if sharded_dims == 2:
                n_2d += 1
    assert n_big > 0 and n_2d / n_big > 0.8


def test_cache_specs_long_context():
    """long_500k (batch=1): KV cache must shard sequence, not batch."""
    cfg = get_config("zamba2-7b")
    planner = Planner(cfg, _abstract_mesh())
    cache = api.cache_specs(cfg, 1, 524_288)
    sh = planner.cache_sharding(cache)
    kv_spec = sh["kv"]["k"].spec
    assert kv_spec[2] == "data"       # sequence sharded
    assert kv_spec[3] == "model"      # kv heads sharded


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------
def test_adamw_minimizes_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=200,
                      weight_decay=0.0, clip_norm=100.0)
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = adamw.init_state(params)
    for _ in range(150):
        g = {"w": 2 * (params["w"] - target)}
        params, state, m = adamw.apply_gradients(cfg, params, g, state)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.05)


def test_adamw_clips():
    cfg = AdamWConfig(lr=1e-3, clip_norm=1.0, warmup_steps=0)
    params = {"w": jnp.zeros(4)}
    state = adamw.init_state(params)
    _, _, m = adamw.apply_gradients(cfg, params, {"w": jnp.full(4, 100.0)},
                                    state)
    assert float(m["grad_norm"]) > 100.0     # reported raw norm


def test_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(adamw.schedule(cfg, jnp.asarray(s))) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0            # warmup rises
    assert abs(lrs[99] - 0.1) < 0.05         # decays to min ratio


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------
def test_compression_error_feedback_unbiased():
    """Sum of dequantized values over steps tracks the true sum (EF)."""
    rng = np.random.default_rng(0)
    true_sum = np.zeros(64)
    sent_sum = np.zeros(64)
    r = jnp.zeros(64)
    for _ in range(50):
        g = jnp.asarray(rng.normal(size=64) * rng.uniform(0.1, 10))
        d, r = C.compress_roundtrip(g, r)
        true_sum += np.asarray(g)
        sent_sum += np.asarray(d)
    # residual bounded → cumulative error bounded by one quantization step
    err = np.abs(true_sum - sent_sum).max()
    assert err < 1.0


def test_compression_quantize_range():
    x = jnp.asarray([-3.0, 0.0, 5.0])
    q, s = C.quantize(x)
    assert q.dtype == jnp.int8
    assert int(jnp.abs(q).max()) <= 127
    np.testing.assert_allclose(np.asarray(C.dequantize(q, s)),
                               np.asarray(x), atol=float(s) + 1e-6)


def test_compress_tree_roundtrip_structure():
    g = {"a": jnp.ones((4, 4)), "b": {"c": jnp.zeros(3)}}
    r = C.init_residuals(g)
    d, r2 = C.compress_tree(g, r)
    assert jax.tree.structure(d) == jax.tree.structure(g)
    assert jax.tree.structure(r2) == jax.tree.structure(g)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------
def _tree():
    return {"params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                       "b": jnp.ones(3, jnp.bfloat16)},
            "step": jnp.asarray(7)}


def test_checkpoint_roundtrip(tmp_path):
    root = str(tmp_path / "ck")
    save(root, 7, _tree(), extras={"step": 7})
    assert latest_step(root) == 7
    target = jax.eval_shape(_tree)
    back, extras = restore(root, target)
    assert extras["step"] == 7
    np.testing.assert_array_equal(np.asarray(back["params"]["w"]),
                                  np.asarray(_tree()["params"]["w"]))
    assert back["params"]["b"].dtype == jnp.bfloat16


def test_checkpoint_atomic_no_partial(tmp_path):
    root = str(tmp_path / "ck")
    save(root, 1, _tree())
    # a stale .tmp dir must be invisible to latest_step
    os.makedirs(os.path.join(root, "step_00000009.tmp"))
    assert latest_step(root) == 1


def test_checkpoint_gc(tmp_path):
    root = str(tmp_path / "ck")
    for s in range(5):
        save(root, s, _tree())
    removed = gc_old(root, keep=2)
    assert len(removed) == 3
    assert latest_step(root) == 4


def test_async_checkpointer(tmp_path):
    root = str(tmp_path / "ck")
    ck = AsyncCheckpointer(root, keep=2)
    for s in (1, 2, 3):
        ck.save(s, _tree(), extras={"step": s})
    ck.close()
    assert latest_step(root) == 3


def test_restore_shape_mismatch_raises(tmp_path):
    root = str(tmp_path / "ck")
    save(root, 1, _tree())
    bad = {"params": {"w": jax.ShapeDtypeStruct((3, 3), jnp.float32),
                      "b": jax.ShapeDtypeStruct((3,), jnp.bfloat16)},
           "step": jax.ShapeDtypeStruct((), jnp.int32)}
    with pytest.raises(ValueError, match="saved"):
        restore(root, bad)


# ---------------------------------------------------------------------------
# runtime: watchdog + restart harness
# ---------------------------------------------------------------------------
def test_watchdog_dead_worker(tmp_path):
    hb_dir = str(tmp_path / "hb")
    hb = Heartbeat(hb_dir, 0)
    hb.beat(5)
    wd = Watchdog(hb_dir, timeout_s=60)
    assert wd.dead_workers() == []
    import time
    assert wd.dead_workers(now=time.time() + 120) == [0]


def test_watchdog_straggler(tmp_path):
    wd = Watchdog(str(tmp_path), straggler_factor=2.0)
    for _ in range(8):
        wd.record_step_time(0, 1.0)
        wd.record_step_time(1, 1.1)
        wd.record_step_time(2, 5.0)      # limping node
    assert wd.stragglers() == [2]


def test_restart_harness_recovers():
    calls = {"n": 0}
    saved = {"state": None}

    def make_state():
        return {"i": 0}

    def resume_state():
        return saved["state"]

    def run(state):
        calls["n"] += 1
        for i in range(state["i"], 10):
            state = {"i": i + 1}
            saved["state"] = state        # "checkpoint"
            if i == 4 and calls["n"] == 1:
                raise RuntimeError("injected")
        return state

    out = run_with_restarts(make_state, resume_state, run)
    assert out["i"] == 10 and calls["n"] == 2


def test_restart_harness_gives_up():
    def run(_):
        raise RuntimeError("always")
    with pytest.raises(TrainingAborted):
        run_with_restarts(lambda: {}, lambda: None, run,
                          RestartPolicy(max_failures=2))


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_pipeline_deterministic():
    pc = PipelineConfig(vocab=97, seq_len=16, global_batch=4, seed=3)
    a = TokenPipeline(pc).batch_for_step(11)
    b = TokenPipeline(pc).batch_for_step(11)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = TokenPipeline(pc).batch_for_step(12)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_pipeline_labels_shifted():
    pc = PipelineConfig(vocab=97, seq_len=16, global_batch=2, seed=0)
    b = TokenPipeline(pc).batch_for_step(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_pipeline_local_slice():
    pc = PipelineConfig(vocab=97, seq_len=8, global_batch=8, seed=0)
    pipe = TokenPipeline(pc)
    full = pipe.batch_for_step(0)
    parts = [pipe.local_slice(full, i, 4) for i in range(4)]
    stitched = np.concatenate([p["tokens"] for p in parts])
    np.testing.assert_array_equal(stitched, full["tokens"])
