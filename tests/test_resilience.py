"""Fault-tolerant serving fabric: deterministic fault plans, the
injector tick clock, circuit-breaker state machine, hardened request
path (retry / degrade / fallback / backpressure), batcher rejection +
split-retry, and the chaos-drill sweep — every answer under injected
faults is either bit-consistent with the numpy oracle or a *typed*
fabric error; never a hang, never silent corruption."""
import types

import numpy as np
import pytest

from repro.obs import metrics
from repro.runtime import (Backpressure, CircuitBreaker, CoreFault,
                           FabricError, FailureInjector, FaultEvent,
                           FaultInjector, FaultPlan, MicroBatcher,
                           ParityError, ResilienceExhausted,
                           ResiliencePolicy, RestartPolicy, Server,
                           TrainingAborted, TransientFault,
                           run_with_restarts, verify_parity)
from repro.runtime.fault import Heartbeat, Watchdog


def _mask(num_vars, n=6, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.integers(0, 2, (n, num_vars))
    X[rng.random(X.shape) < 0.3] = -1
    return X


def _art(substrate="vliw-sim", meta=None):
    """Minimal artifact stand-in: the injector only reads these attrs."""
    return types.SimpleNamespace(substrate=substrate, semiring="sum",
                                 meta=meta or {})


# ---------------------------------------------------------------------------
# fault plans
# ---------------------------------------------------------------------------
def test_fault_plan_parse_roundtrip():
    plan = FaultPlan.parse("core=1@t3, link=0-2, slow=1-3x4@t2, flip@t5")
    assert plan.specs() == ["link=0-2@t0", "slow=1-3x4@t2",
                            "core=1@t3", "flip@t5"]      # sorted by tick
    assert FaultPlan.parse(plan.specs()).events == plan.events


def test_fault_plan_parse_rejects_garbage():
    for bad in ("core=x", "link=1", "slow=1-2", "core=1@z9", "nuke"):
        with pytest.raises(ValueError, match="bad fault spec"):
            FaultPlan.parse(bad)


def test_fault_plan_random_deterministic_and_survivable():
    a = FaultPlan.random(7, n_cores=4)
    assert a.specs() == FaultPlan.random(7, n_cores=4).specs()
    # a core-kill-only plan can never schedule the whole machine dead
    plan = FaultPlan.random(0, n_cores=2, n_events=10, kinds=("core",))
    assert len({e.core for e in plan.events}) <= 1


# ---------------------------------------------------------------------------
# injector: tick clock, footprints, immunity
# ---------------------------------------------------------------------------
def test_injector_kills_core_and_spares_host_substrates():
    inj = FaultInjector(FaultPlan.parse("core=0@t0"), n_cores=2)
    inj.before_execute(_art("numpy"))           # oracle is immune
    assert inj.state.dead_cores == {0}
    with pytest.raises(CoreFault) as ei:
        inj.before_execute(_art("vliw-sim"))    # single-core ⇒ core 0
    assert ei.value.core == 0
    # a multicore artifact placed off the dead core is unaffected
    inj.before_execute(_art("vliw-mc", meta={
        "multicore": {"core_labels": [1], "links_used": []}}))


def test_injector_never_kills_last_core():
    inj = FaultInjector(FaultPlan.parse("core=0@t0,core=1@t1"), n_cores=2)
    inj.before_execute(_art("numpy"))
    inj.before_execute(_art("numpy"))
    assert inj.state.dead_cores == {0}          # second kill refused
    assert inj.state.healthy == [1]


def test_injector_flip_is_one_shot_and_detected():
    inj = FaultInjector(FaultPlan.parse("flip@t0"), n_cores=1)
    art = _art("vliw-sim")
    inj.before_execute(art)
    with pytest.raises(TransientFault):
        inj.after_execute(art, np.zeros(1))     # detected, discarded
    inj.after_execute(art, np.zeros(1))         # the retry heals
    # host substrates never consume (or suffer) a flip
    inj2 = FaultInjector(FaultPlan.parse("flip@t0"), n_cores=1)
    inj2.before_execute(_art("numpy"))
    inj2.after_execute(_art("numpy"), np.zeros(1))


# ---------------------------------------------------------------------------
# circuit breaker (deterministic fake clock)
# ---------------------------------------------------------------------------
def test_circuit_breaker_state_machine():
    now = [0.0]
    br = CircuitBreaker(threshold=2, cooldown_s=10.0, clock=lambda: now[0])
    assert br.allow() and br.state == "closed"
    br.record_failure()
    assert br.allow()                           # below threshold
    br.record_failure()
    assert br.state == "open" and br.trips == 1
    assert not br.allow()                       # cooling down
    now[0] = 10.0
    assert br.allow() and br.state == "half-open"
    assert not br.allow()                       # one probe only
    br.record_failure()                         # probe failed → re-open
    assert br.state == "open" and br.trips == 2
    now[0] = 20.0
    assert br.allow()
    br.record_success()                         # probe healed → closed
    assert br.state == "closed" and br.failures == 0 and br.allow()


# ---------------------------------------------------------------------------
# batcher: rejection + split-retry
# ---------------------------------------------------------------------------
def test_pending_rejected_with_original_exception():
    boom = RuntimeError("flaky backend")

    def execute(rows):
        raise boom

    mb = MicroBatcher(execute)
    p1 = mb.submit(np.ones((2, 4)))
    p2 = mb.submit(np.ones((1, 4)))
    with pytest.raises(RuntimeError, match="flaky backend"):
        mb.flush()
    # every member resolved with the ORIGINAL exception — no hangs
    assert p1.ready() and p2.ready()
    assert p1.exception() is boom and p2.exception() is boom
    with pytest.raises(RuntimeError, match="flaky backend"):
        p1.result()


def test_split_retry_saves_nonfaulty_members():
    def execute(rows):
        if rows.shape[0] > 2:                   # coalesced batch fails
            raise RuntimeError("batch too hot")
        if np.isneginf(rows).any():             # one poisoned request
            raise RuntimeError("poison row")
        return rows.sum(axis=1)

    mb = MicroBatcher(execute, split_retry=True)
    good = mb.submit(np.ones((2, 4)))
    bad = mb.submit(np.full((1, 4), -np.inf))
    mb.flush()                                  # does not raise
    np.testing.assert_array_equal(good.result(), np.full(2, 4.0))
    assert isinstance(bad.exception(), RuntimeError)
    assert mb.stats["batches"] == 1


def test_split_retry_telemetry_counts_member_flushes():
    """A successful split-retry member is a real flush: it counts in
    ``batch.flushes`` and observes its fill, and BOTH the failed
    coalesced attempt's padding and each retry's own padding land in
    ``padded_rows`` — those waste rows were executed. (The old path
    dropped all three, undercounting exactly when faults were live.)"""
    calls = []

    def execute(rows):
        calls.append(rows.shape[0])
        if len(calls) == 1:                     # coalesced attempt fails
            raise RuntimeError("transient batch fault")
        if np.isneginf(rows).any():             # one poisoned member
            raise RuntimeError("poison row")
        return rows.sum(axis=1)

    mb = MicroBatcher(execute, tile=4, split_retry=True)
    flushes0 = metrics.counter("batch.flushes").value
    padded0 = metrics.counter("batch.padded_rows").value
    fill0 = metrics.histogram("batch.fill").count
    good = mb.submit(np.ones((2, 4)))
    bad = mb.submit(np.full((1, 4), -np.inf))
    mb.flush()
    np.testing.assert_array_equal(good.result(), np.full(2, 4.0))
    assert isinstance(bad.exception(), RuntimeError)
    # coalesced 3->4 (fails), retry good 2->4 (ok), retry bad 1->4
    assert calls == [4, 4, 4]
    # padding: 1 coalesced + 2 good retry; the failed bad retry's own
    # padding is not waste *executed for a result* and stays out
    assert mb.stats == {"requests": 2, "rows": 3, "batches": 1,
                        "padded_rows": 3}
    assert metrics.counter("batch.flushes").value - flushes0 == 1
    assert metrics.counter("batch.padded_rows").value - padded0 == 3
    assert metrics.histogram("batch.fill").count - fill0 == 1


# ---------------------------------------------------------------------------
# watchdog hardening
# ---------------------------------------------------------------------------
def test_watchdog_skips_corrupt_heartbeats(tmp_path):
    hb_dir = str(tmp_path)
    Heartbeat(hb_dir, 3).beat(1)
    (tmp_path / "worker_.hb").write_text("{}")          # unparseable id
    (tmp_path / "worker_0xbad.hb").write_text("{}")     # non-numeric id
    (tmp_path / "worker_00007.hb").write_text("{not json")
    (tmp_path / "worker_00008.hb").write_text('["t"]')  # not a dict
    before = metrics.counter("fault.heartbeat_corrupt").value
    wd = Watchdog(hb_dir, timeout_s=60)
    assert [wid for wid, _ in wd._workers()] == [3]
    # one scan counted each of the 4 corrupt files exactly once
    assert metrics.counter("fault.heartbeat_corrupt").value == before + 4
    assert wd.dead_workers() == []              # never crashes


# ---------------------------------------------------------------------------
# restart harness × failure injection (end to end)
# ---------------------------------------------------------------------------
def test_restart_harness_with_injector_end_to_end():
    inj = FailureInjector({2, 4})
    saved = {"state": None}
    before = metrics.counter("fault.restarts").value

    def run(state):
        for step in range(state["step"], 6):
            inj.maybe_fail(step)
            saved["state"] = {"step": step + 1}     # "checkpoint"
        return saved["state"]

    out = run_with_restarts(lambda: {"step": 0}, lambda: saved["state"],
                            run, RestartPolicy(max_failures=3))
    assert out["step"] == 6
    assert inj.tripped == {2, 4}
    assert metrics.counter("fault.restarts").value == before + 2


def test_restart_budget_exhaustion_chains_cause_and_backs_off(monkeypatch):
    import repro.runtime.fault as fault_mod
    sleeps = []
    monkeypatch.setattr(fault_mod.time, "sleep", sleeps.append)

    def run(_):
        raise RuntimeError("root cause")

    with pytest.raises(TrainingAborted) as ei:
        run_with_restarts(lambda: {}, lambda: None, run,
                          RestartPolicy(max_failures=2, backoff_s=0.5))
    assert isinstance(ei.value.__cause__, RuntimeError)
    assert "root cause" in str(ei.value.__cause__)
    assert sleeps == [0.5, 0.5]                 # once per allowed restart


# ---------------------------------------------------------------------------
# verify_parity: typed errors, never a hang
# ---------------------------------------------------------------------------
def test_verify_parity_raises_typed_error_on_broken_backend(small_spn):
    srv = Server(small_spn, substrates=("numpy", "vliw-sim"))

    def broken(art, leaves):
        raise RuntimeError("datapath offline")

    srv.substrate("vliw-sim").execute = broken
    with pytest.raises(ParityError, match="failed to execute") as ei:
        verify_parity(srv, _mask(srv.prog.num_vars), query="marginal")
    assert isinstance(ei.value.__cause__, RuntimeError)


# ---------------------------------------------------------------------------
# hardened request path
# ---------------------------------------------------------------------------
def test_transient_flip_retries_and_heals(small_spn):
    srv = Server(small_spn, substrates=("vliw-sim", "numpy"),
                 faults="flip@t0")
    X = _mask(srv.prog.num_vars)
    ref = srv.query_once(X, "marginal", "numpy")
    np.testing.assert_allclose(srv.query(X, "marginal", "vliw-sim"), ref,
                               atol=1e-5)
    res = srv.stats()["resilience"]
    assert res["enabled"] and res["applied"]
    assert not res["redirects"]                 # healed, no fallback


def test_core_fault_falls_back_and_redirects(small_spn):
    # vliw-sim is single-core and cannot repartition → the chain serves
    # the request from the numpy oracle and pins the redirect
    srv = Server(small_spn, substrates=("vliw-sim", "numpy"), cores=2,
                 faults="core=0@t0")
    X = _mask(srv.prog.num_vars)
    ref = srv.query_once(X, "marginal", "numpy")
    np.testing.assert_allclose(srv.query(X, "marginal", "vliw-sim"), ref,
                               atol=1e-6)
    res = srv.stats()["resilience"]
    assert res["redirects"] == {"vliw-sim": "numpy"}
    assert [h["kind"] for h in res["history"]] == ["fabric_fault",
                                                   "fallback"]
    # subsequent requests serve straight from the redirect
    np.testing.assert_allclose(srv.query(X, "marginal", "vliw-sim"), ref,
                               atol=1e-6)
    assert len(srv.stats()["resilience"]["history"]) == 2


def test_core_fault_degrades_multicore_server(small_spn):
    srv = Server(small_spn, substrates=("vliw-mc",), cores=4,
                 topology="mesh", faults="core=1@t0")
    X = _mask(srv.prog.num_vars)
    out = srv.query(X, "marginal", "vliw-mc")
    oracle = Server(small_spn, substrates=("numpy",))
    np.testing.assert_allclose(
        out, oracle.query(X, "marginal", "numpy"), atol=1e-5)
    res = srv.stats()["resilience"]
    assert res["fabric"]["dead_cores"] == [1]
    assert not res["redirects"]                 # repartitioned, no fallback
    art = srv.artifact("marginal", "vliw-mc")
    assert art.meta["degraded"]["to_cores"] == 3
    assert 1 not in art.meta["multicore"]["core_labels"]
    assert "/alive=0.2.3" in srv.substrate("vliw-mc").config_fingerprint()


def test_exhausted_chain_is_a_typed_error(small_spn):
    # no fallback, no way to degrade ⇒ honest ResilienceExhausted that
    # chains the real CoreFault — never a hang, never a bare crash
    srv = Server(small_spn, substrates=("vliw-sim",), cores=2,
                 faults="core=0@t0",
                 resilience=ResiliencePolicy(fallback=False))
    with pytest.raises(ResilienceExhausted) as ei:
        srv.query(_mask(srv.prog.num_vars), "marginal", "vliw-sim")
    assert isinstance(ei.value.__cause__, CoreFault)


def test_client_errors_bypass_the_breaker(small_spn):
    srv = Server(small_spn, substrates=("numpy",), faults="flip@t99999")
    X = _mask(srv.prog.num_vars)
    with pytest.raises(ValueError, match="full evidence"):
        srv.query(X, "joint", "numpy")          # partial evidence
    br = srv.resilience.breaker("numpy", "sum")
    assert br.failures == 0 and br.state == "closed"


def test_backpressure_rejects_oversized_requests(small_spn):
    srv = Server(small_spn, substrates=("numpy",), max_rows=8,
                 faults="flip@t99999")
    with pytest.raises(Backpressure, match="admission limit"):
        srv.submit(np.zeros((9, srv.prog.num_vars), np.int64), "marginal",
                   "numpy")
    # an un-hardened server keeps the legacy contract (no admission gate)
    legacy = Server(small_spn, substrates=("numpy",), max_rows=8)
    assert legacy.submit(
        np.zeros((9, legacy.prog.num_vars), np.int64), "marginal",
        "numpy").result().shape == (9,)


# ---------------------------------------------------------------------------
# chaos drill: fault plans × substrates × topologies × core counts
# ---------------------------------------------------------------------------
CHAOS_PLANS = ("core=1@t1", "link=0-1@t0,flip@t2", "random:3", "random:11")


def _chaos_plan(spec: str, n_cores: int) -> FaultPlan:
    if spec.startswith("random:"):
        return FaultPlan.random(int(spec.split(":")[1]), n_cores=n_cores,
                                n_events=3, ticks=4)
    return FaultPlan.parse(spec)


@pytest.mark.parametrize("plan_spec", CHAOS_PLANS)
@pytest.mark.parametrize("substrate,topology,cores", [
    ("vliw-mc", "xbar", 2), ("vliw-mc", "xbar", 4),
    ("vliw-mc", "mesh", 2), ("vliw-mc", "mesh", 4),
    ("vliw-sim", "xbar", 2), ("vliw-sim", "mesh", 4),
])
def test_chaos_drill(small_spn, plan_spec, substrate, topology, cores):
    """Under every drilled fault plan the hardened server either answers
    bit-consistently with the numpy oracle or raises a typed
    FabricError — and every pending resolves (the test completing at
    all is the no-hang assertion)."""
    plan = _chaos_plan(plan_spec, cores)
    srv = Server(small_spn, substrates=(substrate, "vliw-sim", "numpy"),
                 cores=cores, topology=topology, faults=plan)
    X = _mask(srv.prog.num_vars, n=5)
    ref = srv.query_once(X, "marginal", "numpy")    # oracle is immune
    for _ in range(4):                              # outlive every tick
        try:
            out = srv.query(X, "marginal", substrate)
        except FabricError:
            continue                                # honest typed error
        np.testing.assert_allclose(out, ref, atol=1e-5)
    res = srv.stats()["resilience"]
    assert res["enabled"] and res["tick"] > 0
    assert res["plan"] == plan.specs()
    # persistent fabric damage must be visible in the snapshot
    if any(e.kind in ("core", "link") for e in plan.events):
        assert res["applied"]


def test_degraded_nltcs_serves_from_three_cores(nltcs_spn, nltcs_data):
    """The acceptance drill: kill 1 of 4 cores on nltcs — the server
    repartitions onto the 3 survivors and keeps answering with oracle
    parity, recorded in stats()['resilience']."""
    srv = Server(nltcs_spn, substrates=("vliw-mc",), cores=4,
                 topology="mesh", faults="core=1@t0")
    X = nltcs_data[:32].copy()
    X[np.random.default_rng(0).random(X.shape) < 0.3] = -1
    srv.query(X, "marginal", "vliw-mc")             # fault → degrade
    devs = verify_parity(srv, X, query="marginal", substrates=("vliw-mc",))
    assert devs["vliw-mc/checked"] == 0.0           # fast sim bit-exact
    res = srv.stats()["resilience"]
    assert res["fabric"]["healthy_cores"] == [0, 2, 3]
    assert res["degraded_artifacts"]
    assert any(h["kind"] == "degrade" and h["alive"] == [0, 2, 3]
               for h in res["history"])
